//! Minimal offline stand-in for the `anyhow` crate: the build image has no
//! crates.io access (see DESIGN.md "Substitutions"), so this vendored shim
//! provides the small API surface the crate actually uses — [`Error`],
//! [`Result`], the [`Context`] extension trait and the [`bail!`]/[`anyhow!`]
//! macros — with anyhow's context-chain semantics:
//!
//! - `Display` shows the outermost context message,
//! - alternate `Display` (`{:#}`) shows the whole chain `outer: ...: root`.

use std::fmt;

/// An error carrying a chain of context messages (outermost first).
pub struct Error {
    /// msgs[0] is the outermost context, msgs.last() the root cause.
    msgs: Vec<String>,
}

impl Error {
    /// Create an error from a single message.
    pub fn msg<M: fmt::Display>(m: M) -> Self {
        Error { msgs: vec![m.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, c: C) -> Self {
        self.msgs.insert(0, c.to_string());
        self
    }

    /// The root-cause message (innermost).
    pub fn root_cause(&self) -> &str {
        self.msgs.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.msgs.join(": "))
        } else {
            write!(f, "{}", self.msgs.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Mirror anyhow's Debug: full chain, most recent context first.
        write!(f, "{}", self.msgs.join(": "))
    }
}

// Note: Error deliberately does NOT implement std::error::Error, so the
// blanket From below cannot overlap with the reflexive From<Error>.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// `anyhow`-style result alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to results.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => { $crate::Error::msg(format!($($arg)*)) };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => { return Err($crate::anyhow!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        let v: i32 = s.parse().context("parsing an int")?;
        if v < 0 {
            bail!("negative value {v}");
        }
        Ok(v)
    }

    #[test]
    fn question_mark_and_context_chain() {
        let e = parse("abc").unwrap_err();
        assert_eq!(e.to_string(), "parsing an int");
        let alt = format!("{e:#}");
        assert!(alt.starts_with("parsing an int: "), "{alt}");
        assert!(alt.contains("invalid digit"), "{alt}");
    }

    #[test]
    fn bail_formats() {
        let e = parse("-3").unwrap_err();
        assert_eq!(e.to_string(), "negative value -3");
        assert_eq!(e.root_cause(), "negative value -3");
    }

    #[test]
    fn with_context_lazy() {
        let r: std::result::Result<(), std::io::Error> =
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "gone"));
        let e = r.with_context(|| format!("opening {}", "x.txt")).unwrap_err();
        assert_eq!(e.to_string(), "opening x.txt");
        assert!(format!("{e:#}").contains("gone"));
    }
}
