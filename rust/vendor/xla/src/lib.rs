//! Offline stub of the `xla` PJRT bindings.
//!
//! The build image carries no PJRT shared library, so this vendored crate
//! provides the exact API surface `runtime::xla_backend` compiles against
//! while failing fast at runtime: [`PjRtClient::cpu`] returns an error, so
//! `XlaBackend::new` fails before any other stubbed method can be reached
//! and callers fall back to the native backend (see DESIGN.md
//! "Substitutions"). Swapping this crate for real PJRT bindings requires no
//! source change in the main crate.

use std::fmt;

/// Stub error type: every runtime entry point returns it.
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: PJRT runtime unavailable (offline xla stub — link real PJRT bindings to enable)"
    ))
}

/// A host tensor. The stub carries no data: it can never be produced by an
/// executable because client creation fails first.
pub struct Literal;

impl Literal {
    pub fn vec1(_data: &[f64]) -> Literal {
        Literal
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(unavailable("Literal::reshape"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module proto.
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A compiled, device-loaded executable.
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// PJRT client handle. [`PjRtClient::cpu`] is the single runtime gate: it
/// always errors in the stub, so no other stubbed call is reachable through
/// `runtime::XlaBackend`.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_creation_fails_with_clear_message() {
        let e = PjRtClient::cpu().err().expect("stub must fail");
        assert!(e.to_string().contains("offline xla stub"), "{e}");
    }

    #[test]
    fn literal_roundtrip_is_gated() {
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
        assert!(Literal.to_vec::<f64>().is_err());
    }
}
