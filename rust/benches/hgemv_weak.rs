//! E1 (Fig. 9): weak scalability of distributed HGEMV.
//!
//! Per-rank problem size is held fixed while P grows; reports virtual
//! time, *measured* wall-clock of the threaded executor, Gflop/s/rank and
//! relative efficiency (G_P/G_P0)/(P/P0) for the 2D and 3D kernel test
//! sets and nv ∈ {1, 16, 64} — the paper's Fig. 9 rows. Protocol: trimmed
//! mean over repeated runs (§6.1). Set H2OPUS_BENCH_TINY=1 for the CI
//! smoke configuration (small sizes, fewer repetitions).

use h2opus::backend::native::NativeBackend;
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::hgemv::{dist_hgemv, DistOptions, ExecMode};
use h2opus::geometry::PointSet;
use h2opus::util::timer::trimmed_mean;
use h2opus::util::Prng;

fn tiny() -> bool {
    std::env::var("H2OPUS_BENCH_TINY").is_ok()
}

fn bench_set(dim: usize, local_n: usize, ps: &[usize], nvs: &[usize]) {
    println!("\n== {dim}D exponential kernel, weak scaling, pN = {local_n}/rank ==");
    println!(
        "{:>4} {:>9} {:>4} {:>13} {:>13} {:>14} {:>11} {:>12}",
        "P", "N", "nv", "virt (ms)", "meas (ms)", "Gflop/s/rank", "eff (%)", "comm (KiB)"
    );
    let runs = if tiny() { 3 } else { 5 };
    let mut base_rate: Vec<Option<f64>> = vec![None; nvs.len()];
    for &p in ps {
        let n_target = local_n * p;
        let (points, corr, cfg) = if dim == 2 {
            let side = (n_target as f64).sqrt().ceil() as usize;
            (PointSet::grid_2d(side, 1.0), 0.1, H2Config { leaf_size: 32, eta: 0.9, cheb_grid: 4 })
        } else {
            let side = (n_target as f64).cbrt().ceil() as usize;
            (PointSet::grid_3d(side, 1.0), 0.2, H2Config { leaf_size: 32, eta: 0.95, cheb_grid: 2 })
        };
        let kernel = ExponentialKernel { dim, corr_len: corr };
        let a = build_h2(points, &kernel, &cfg);
        if a.depth() < p.trailing_zeros() as usize {
            continue;
        }
        let n = a.n();
        let mut rng = Prng::new(42);
        for (nvi, &nv) in nvs.iter().enumerate() {
            let x = rng.normal_vec(n * nv);
            let mut y = vec![0.0; n * nv];
            let opts = DistOptions::default();
            let mut times = Vec::new();
            let mut flops = 0u64;
            let mut comm = 0usize;
            for _ in 0..runs {
                let rep = dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y, &opts);
                times.push(rep.time);
                flops = rep.metrics.flops;
                comm = rep.recv_bytes;
            }
            let t = trimmed_mean(&times);
            // Measured wall-clock of the real OS-thread executor on the
            // same (matrix, P, nv) — the reality the virtual time models.
            let topts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
            let mut measured = Vec::new();
            for _ in 0..runs {
                let rep = dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y, &topts);
                measured.push(rep.measured.unwrap());
            }
            let tm = trimmed_mean(&measured);
            let rate = flops as f64 / t / 1e9 / p as f64;
            let eff = match base_rate[nvi] {
                None => {
                    base_rate[nvi] = Some(rate);
                    100.0
                }
                Some(r0) => 100.0 * rate / r0,
            };
            println!(
                "{:>4} {:>9} {:>4} {:>13.3} {:>13.3} {:>14.3} {:>11.1} {:>12.1}",
                p,
                n,
                nv,
                t * 1e3,
                tm * 1e3,
                rate,
                eff,
                comm as f64 / 1024.0
            );
        }
    }
}

fn main() {
    println!("E1 / Fig. 9 — HGEMV weak scalability (virtual + measured, see DESIGN.md)");
    if tiny() {
        bench_set(2, 512, &[1, 2, 4], &[1, 8]);
        bench_set(3, 512, &[1, 2], &[1]);
    } else {
        bench_set(2, 4096, &[1, 2, 4, 8, 16], &[1, 16, 64]);
        bench_set(3, 4096, &[1, 2, 4, 8], &[1, 16, 64]);
    }
}
