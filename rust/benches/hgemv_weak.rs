//! E1 (Fig. 9): weak scalability of distributed HGEMV.
//!
//! Per-rank problem size is held fixed while P grows; reports virtual
//! time, *measured* wall-clock of the real executor, Gflop/s/rank and
//! relative efficiency (G_P/G_P0)/(P/P0) for the 2D and 3D kernel test
//! sets and nv ∈ {1, 16, 64} — the paper's Fig. 9 rows. Protocol: trimmed
//! mean over repeated runs (§6.1).
//!
//! Axes: set H2OPUS_BENCH_TINY=1 for the CI smoke configuration; pass
//! `--transport inproc|socket` (after `--` under `cargo bench`) to choose
//! the measured executor — `inproc` runs pooled rank threads, `socket`
//! spawns real `h2opus worker` subprocesses with O(N/P) memory each.
//!
//! Every measured row (with its executed flops, batch launches and GEMM
//! word traffic) is appended to `target/hgemv_weak_rows.json`, which
//! `python/tests/model_check.py --fit` uses to calibrate the CostModel
//! constants for this machine.

use h2opus::backend::native::NativeBackend;
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::hgemv::{dist_hgemv, DistOptions, ExecMode};
use h2opus::dist::transport::{JobKind, MatrixJob};
use h2opus::geometry::PointSet;
use h2opus::metrics::Metrics;
use h2opus::obs::trajectory::{append_and_report, BenchRow};
use h2opus::util::timer::trimmed_mean;
use h2opus::util::Prng;

fn tiny() -> bool {
    std::env::var("H2OPUS_BENCH_TINY").is_ok()
}

fn transport() -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--transport")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "inproc".into())
}

/// Measured wall-clock (trimmed mean) + executed counters on the chosen
/// transport, plus the resident-session per-iteration latency (one
/// pipelined `submit`/`wait` round trip — what a CG iteration pays; see
/// `solve_with_session`). In-process there is no session, so the
/// per-iteration latency is the measured product itself.
fn measure(
    transport: &str,
    a: &h2opus::tree::H2Matrix,
    job: &MatrixJob,
    p: usize,
    nv: usize,
    x: &[f64],
    y: &mut [f64],
    runs: usize,
) -> (f64, Metrics, f64) {
    match transport {
        #[cfg(unix)]
        "socket" => {
            use h2opus::dist::transport::socket::{socket_hgemv, SocketOptions, SocketSession};
            let opts = SocketOptions {
                worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
                ..SocketOptions::default()
            };
            let mut times = Vec::new();
            let mut metrics = Metrics::new();
            for _ in 0..runs {
                let rep = socket_hgemv(job, p, nv, x, y, &opts).expect("socket transport run");
                times.push(rep.measured);
                metrics = rep.metrics;
            }
            // Session-side iteration latency: barrier-free submit/wait
            // against resident workers (plan caches warm after round 0).
            let mut session =
                SocketSession::start(job, p, nv, opts).expect("session start");
            let pid = session.submit(x, nv).expect("warmup submit");
            session.wait(pid, y).expect("warmup wait");
            let mut iters = Vec::new();
            for _ in 0..runs {
                let t0 = std::time::Instant::now();
                let pid = session.submit(x, nv).expect("session submit");
                session.wait(pid, y).expect("session wait");
                iters.push(t0.elapsed().as_secs_f64());
            }
            (trimmed_mean(&times), metrics, trimmed_mean(&iters))
        }
        _ => {
            let _ = job;
            assert!(
                transport != "socket",
                "--transport socket requires Unix domain sockets on this platform"
            );
            let topts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
            let mut times = Vec::new();
            let mut metrics = Metrics::new();
            for _ in 0..runs {
                let rep = dist_hgemv(a, &NativeBackend, p, nv, x, y, &topts);
                times.push(rep.measured.unwrap());
                metrics = rep.metrics;
            }
            let t = trimmed_mean(&times);
            (t, metrics, t)
        }
    }
}

fn bench_set(dim: usize, local_n: usize, ps: &[usize], nvs: &[usize], rows: &mut Vec<String>) {
    let transport = transport();
    println!("\n== {dim}D exponential kernel, weak scaling, pN = {local_n}/rank, transport = {transport} ==");
    println!(
        "{:>4} {:>9} {:>4} {:>13} {:>13} {:>13} {:>14} {:>11} {:>12}",
        "P", "N", "nv", "virt (ms)", "meas (ms)", "iter (ms)", "Gflop/s/rank", "eff (%)",
        "comm (KiB)"
    );
    let runs = if tiny() { 3 } else { 5 };
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // Recorded per row so the CostModel fit knows how wide the batched
    // backend ran when these wall-clocks were measured.
    let bt = h2opus::backend::backend_threads();
    let mut base_rate: Vec<Option<f64>> = vec![None; nvs.len()];
    for &p in ps {
        let n_target = local_n * p;
        let (side, cfg, corr) = if dim == 2 {
            let side = (n_target as f64).sqrt().ceil() as usize;
            (side, H2Config { leaf_size: 32, eta: 0.9, cheb_grid: 4 }, 0.1)
        } else {
            let side = (n_target as f64).cbrt().ceil() as usize;
            (side, H2Config { leaf_size: 32, eta: 0.95, cheb_grid: 2 }, 0.2)
        };
        let job = MatrixJob {
            dim,
            n_side: side,
            leaf_size: cfg.leaf_size,
            eta: cfg.eta,
            cheb_grid: cfg.cheb_grid,
            corr_len: corr,
            kind: JobKind::Exponential,
        };
        let points =
            if dim == 2 { PointSet::grid_2d(side, 1.0) } else { PointSet::grid_3d(side, 1.0) };
        let kernel = ExponentialKernel { dim, corr_len: corr };
        let a = build_h2(points, &kernel, &cfg);
        if a.depth() < p.trailing_zeros() as usize {
            continue;
        }
        let n = a.n();
        let mut rng = Prng::new(42);
        for (nvi, &nv) in nvs.iter().enumerate() {
            let x = rng.normal_vec(n * nv);
            let mut y = vec![0.0; n * nv];
            let opts = DistOptions::default();
            let mut times = Vec::new();
            let mut flops = 0u64;
            let mut comm = 0usize;
            for _ in 0..runs {
                let rep = dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y, &opts);
                times.push(rep.time);
                flops = rep.metrics.flops;
                comm = rep.recv_bytes;
            }
            let t = trimmed_mean(&times);
            // Measured wall-clock of the real executor on the same
            // (matrix, P, nv) — the reality the virtual time models.
            let (tm, mm, si) = measure(&transport, &a, &job, p, nv, &x, &mut y, runs);
            let rate = flops as f64 / t / 1e9 / p as f64;
            let eff = match base_rate[nvi] {
                None => {
                    base_rate[nvi] = Some(rate);
                    100.0
                }
                Some(r0) => 100.0 * rate / r0,
            };
            println!(
                "{:>4} {:>9} {:>4} {:>13.3} {:>13.3} {:>13.3} {:>14.3} {:>11.1} {:>12.1}",
                p,
                n,
                nv,
                t * 1e3,
                tm * 1e3,
                si * 1e3,
                rate,
                eff,
                comm as f64 / 1024.0
            );
            rows.push(format!(
                "{{\"p\": {p}, \"n\": {n}, \"nv\": {nv}, \"cores\": {cores}, \"transport\": \"{transport}\", \
                 \"backend_threads\": {bt}, \
                 \"virtual_s\": {t:e}, \"measured_s\": {tm:e}, \"session_iter_s\": {si:e}, \
                 \"flops\": {}, \"launches\": {}, \"words\": {}, \
                 \"matrix_bytes\": {}}}",
                mm.flops, mm.batch_launches, mm.gemm_words, mm.matrix_bytes
            ));
            append_and_report(
                &BenchRow::new(
                    "hgemv_weak",
                    &format!("{dim}D pN={local_n} p={p} nv={nv} t={transport}"),
                )
                .metric("virtual_s", t)
                .metric("measured_s", tm)
                .metric("iter_s", si)
                .metric("gflops_per_rank", rate),
            );
        }
    }
}

fn main() {
    println!("E1 / Fig. 9 — HGEMV weak scalability (virtual + measured, see DESIGN.md)");
    let mut rows = Vec::new();
    if tiny() {
        bench_set(2, 512, &[1, 2, 4], &[1, 8], &mut rows);
        bench_set(3, 512, &[1, 2], &[1], &mut rows);
    } else {
        bench_set(2, 4096, &[1, 2, 4, 8, 16], &[1, 16, 64], &mut rows);
        bench_set(3, 4096, &[1, 2, 4, 8], &[1, 16, 64], &mut rows);
    }
    std::fs::create_dir_all("target").ok();
    let path = "target/hgemv_weak_rows.json";
    std::fs::write(path, format!("[\n{}\n]\n", rows.join(",\n"))).expect("writing rows");
    println!("\ncalibration rows written: {path} (fit with python/tests/model_check.py --fit)");
}
