//! Observability overhead gate: the disabled span path must cost ~one
//! relaxed atomic load per site, so leaving instrumentation compiled into
//! every execution layer is free in production.
//!
//! Reports:
//! - ns/call for a disabled RAII span guard and a disabled explicit
//!   [`h2opus::obs::record`] (the two instrumentation shapes);
//! - ns/call for the *enabled* guard, for scale;
//! - end-to-end threaded HGEMV wall-clock with recording disabled vs
//!   enabled (same binary — the instrumentation is always compiled in).
//!
//! `H2OPUS_OBS_ASSERT=1` (CI) turns the disabled-path numbers into a
//! hard gate (exit 1 past the bound), following the E9/E10 pattern.
//! `H2OPUS_BENCH_TINY=1` shrinks iteration counts for CI smoke.

use std::hint::black_box;
use std::time::Instant;

use h2opus::backend::native::NativeBackend;
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::hgemv::{dist_hgemv, DistOptions, ExecMode};
use h2opus::geometry::PointSet;
use h2opus::obs;
use h2opus::obs::names as obs_names;
use h2opus::util::Prng;

fn tiny() -> bool {
    std::env::var("H2OPUS_BENCH_TINY").is_ok()
}

/// Best-of-reps ns/call for `f` run `iters` times per rep.
fn ns_per_call<F: FnMut()>(iters: u64, reps: usize, mut f: F) -> f64 {
    let mut best = f64::INFINITY;
    for _ in 0..reps {
        let t0 = Instant::now();
        for _ in 0..iters {
            f();
        }
        best = best.min(t0.elapsed().as_nanos() as f64 / iters as f64);
    }
    best
}

fn main() {
    println!("obs overhead — disabled-path cost per instrumentation site");
    let iters: u64 = if tiny() { 2_000_000 } else { 20_000_000 };

    obs::set_enabled(false);
    let _ = obs::drain();
    let guard_off = ns_per_call(iters, 5, || {
        let g = obs::span(black_box(obs_names::UPSWEEP));
        black_box(&g);
    });
    let record_off = ns_per_call(iters, 5, || {
        obs::record(black_box(obs_names::UPSWEEP), 0, 1, 2);
    });

    obs::set_enabled(true);
    let _ = obs::drain();
    // The ring wraps (and counts drops) rather than growing, so a long
    // enabled loop is safe; drain afterwards to leave a clean recorder.
    let guard_on = ns_per_call(iters.min(2_000_000), 3, || {
        let g = obs::span_arg(black_box(obs_names::UPSWEEP), 3);
        black_box(&g);
    });
    let (_, _) = obs::drain();
    obs::set_enabled(false);

    println!("  span guard, disabled:  {guard_off:>8.2} ns/call");
    println!("  record,     disabled:  {record_off:>8.2} ns/call");
    println!("  span guard, enabled:   {guard_on:>8.2} ns/call (for scale)");

    // End-to-end: the threaded executor with its instrumentation compiled
    // in, recording off vs on. Same binary, same matrix, best of 5.
    let points = PointSet::grid_2d(if tiny() { 16 } else { 32 }, 1.0);
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
    let a = build_h2(points, &kernel, &cfg);
    let n = a.n();
    let mut rng = Prng::new(880);
    let x = rng.normal_vec(n);
    let mut y = vec![0.0; n];
    let opts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
    let mut e2e = |on: bool| {
        obs::set_enabled(on);
        let _ = obs::drain();
        let mut best = f64::INFINITY;
        for _ in 0..5 {
            let t0 = Instant::now();
            let _ = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &opts);
            best = best.min(t0.elapsed().as_secs_f64());
            let _ = obs::drain();
        }
        obs::set_enabled(false);
        best
    };
    let off_s = e2e(false);
    let on_s = e2e(true);
    println!(
        "  HGEMV (N = {n}, P = 4): disabled {:.3} ms, enabled {:.3} ms ({:+.1}%)",
        off_s * 1e3,
        on_s * 1e3,
        (on_s / off_s - 1.0) * 100.0
    );

    let row = h2opus::obs::trajectory::BenchRow::new("obs_overhead", &format!("N={n} P=4"))
        .metric("guard_disabled_ns", guard_off)
        .metric("record_disabled_ns", record_off)
        .metric("guard_enabled_ns", guard_on)
        .metric("hgemv_disabled_s", off_s)
        .metric("hgemv_enabled_s", on_s);
    h2opus::obs::trajectory::append_and_report(&row);

    if std::env::var("H2OPUS_OBS_ASSERT").is_ok() {
        // A relaxed atomic load is ~1ns; the bound leaves room for noisy
        // shared CI runners while still catching any accidental work
        // (clock read, allocation, lock) sneaking onto the disabled path.
        const MAX_DISABLED_NS: f64 = 25.0;
        println!(
            "obs assert: disabled guard {guard_off:.2} ns, disabled record {record_off:.2} ns \
             (need <= {MAX_DISABLED_NS} ns)"
        );
        if guard_off > MAX_DISABLED_NS || record_off > MAX_DISABLED_NS {
            println!("obs assert: FAIL — disabled instrumentation is not ~free");
            std::process::exit(1);
        }
    }
}
