//! E6 (Fig. 13): weak scalability of the integral fractional diffusion
//! solver — setup time (K construction+compression, D via K̂·1, C+MG),
//! total solve time, time per iteration, and the iteration counts (paper:
//! 24, 26, 30, 32 over 512²..4096²; roughly dimension-independent).

use h2opus::apps::fractional::{setup, solve, FractionalProblem};
use h2opus::backend::native::NativeBackend;

fn main() {
    println!("E6 / Fig. 13 — fractional diffusion weak scaling (β = 0.75, τ = 1e-6)");
    println!(
        "{:>6} {:>9} {:>3} {:>10} {:>10} {:>10} {:>10} {:>9} {:>12}",
        "grid", "N", "P", "K (s)", "D (s)", "C+MG (s)", "solve (s)", "iters", "ms/iter"
    );
    // weak pairs: fixed ~1024 points per rank
    for &(n_side, ranks) in &[(32usize, 1usize), (64, 4), (96, 8)] {
        let ranks = if (n_side * n_side / 1024).is_power_of_two() { ranks } else { ranks };
        let problem = FractionalProblem::paper_defaults(n_side, ranks);
        let mut sys = setup(problem, &NativeBackend);
        let sol = solve(&mut sys, &NativeBackend, 1e-6);
        println!(
            "{:>4}^2 {:>9} {:>3} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>9} {:>12.2}",
            n_side,
            n_side * n_side,
            ranks,
            sys.setup_k,
            sys.setup_d,
            sys.setup_c,
            sol.solve_time,
            sol.result.iterations,
            sol.time_per_iteration * 1e3
        );
        assert!(sol.result.converged, "solver did not converge at {n_side}");
    }
    println!("\n(Setup phases should grow ~linearly in N; iteration counts ~flat.)");
}
