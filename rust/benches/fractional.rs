//! E6 (Fig. 13): weak scalability of the integral fractional diffusion
//! solver — setup time (K construction+compression, D via K̂·1, C+MG),
//! total solve time, time per iteration, and the iteration counts (paper:
//! 24, 26, 30, 32 over 512²..4096²; roughly dimension-independent).

use h2opus::apps::fractional::{setup, solve, FractionalProblem};
use h2opus::backend::native::NativeBackend;
use h2opus::obs::trajectory::{append_and_report, BenchRow};

fn main() {
    println!("E6 / Fig. 13 — fractional diffusion weak scaling (β = 0.75, τ = 1e-6)");
    println!(
        "{:>6} {:>9} {:>3} {:>10} {:>10} {:>10} {:>10} {:>9} {:>12}",
        "grid", "N", "P", "K (s)", "D (s)", "C+MG (s)", "solve (s)", "iters", "ms/iter"
    );
    let mut row = BenchRow::new("fractional", "weak beta=0.75 tau=1e-6");
    let (mut setup_s, mut solve_s) = (0.0, 0.0);
    // weak pairs: fixed ~1024 points per rank
    for &(n_side, ranks) in &[(32usize, 1usize), (64, 4), (96, 8)] {
        let ranks = if (n_side * n_side / 1024).is_power_of_two() { ranks } else { ranks };
        let problem = FractionalProblem::paper_defaults(n_side, ranks);
        let mut sys = setup(problem, &NativeBackend);
        let sol = solve(&mut sys, &NativeBackend, 1e-6);
        println!(
            "{:>4}^2 {:>9} {:>3} {:>10.3} {:>10.3} {:>10.3} {:>10.3} {:>9} {:>12.2}",
            n_side,
            n_side * n_side,
            ranks,
            sys.setup_k,
            sys.setup_d,
            sys.setup_c,
            sol.solve_time,
            sol.result.iterations,
            sol.time_per_iteration * 1e3
        );
        assert!(sol.result.converged, "solver did not converge at {n_side}");
        setup_s += sys.setup_k + sys.setup_d + sys.setup_c;
        solve_s += sol.solve_time;
        row.set_metric("largest_per_iter_ms", sol.time_per_iteration * 1e3);
        row.set_metric("largest_iters", sol.result.iterations as f64);
    }
    row.set_metric("setup_total_s", setup_s);
    row.set_metric("solve_total_s", solve_s);
    append_and_report(&row);
    println!("\n(Setup phases should grow ~linearly in N; iteration counts ~flat.)");
}
