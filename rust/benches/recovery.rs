//! E11: crash-recovery latency (MTTR) of the supervised socket session.
//!
//! A `SessionSupervisor` streams products while a deterministic chaos
//! plan kills a worker rank mid-pipeline; the supervisor reaps the dead
//! crew, respawns it and replays the in-flight product. Measured:
//!
//! - **mttr_ms** — wall-clock of the recovery (reap + respawn + shard
//!   rebuild + replay), straight from `RecoveryStats::last_recovery_s`;
//! - **reqs_per_s** — end-to-end product throughput *including* the
//!   recovery stall;
//! - **baseline_reqs_per_s** — the same stream with chaos disabled, so
//!   the supervision + CRC-framing overhead on the fault-free path is
//!   visible next to the recovery cost.
//!
//! Each config appends a `recovery` row to `BENCH_TRAJECTORY.jsonl`
//! (`h2opus analyze --assert-no-regression` gates `_ms` metrics as
//! lower-better). `H2OPUS_BENCH_TINY=1` shrinks the matrix for CI smoke.

#[cfg(unix)]
use std::path::PathBuf;
#[cfg(unix)]
use std::time::{Duration, Instant};

#[cfg(unix)]
use h2opus::dist::supervisor::{SessionSupervisor, SupervisorOptions};
#[cfg(unix)]
use h2opus::dist::transport::chaos::CHAOS_PLAN_ENV;
#[cfg(unix)]
use h2opus::dist::transport::socket::SocketOptions;
#[cfg(unix)]
use h2opus::dist::transport::{JobKind, MatrixJob};
#[cfg(unix)]
use h2opus::util::Prng;

#[cfg(unix)]
fn tiny() -> bool {
    std::env::var("H2OPUS_BENCH_TINY").is_ok()
}

#[cfg(unix)]
fn worker_opts(plan: Option<&str>) -> SocketOptions {
    let mut extra_env = Vec::new();
    if let Some(p) = plan {
        extra_env.push((CHAOS_PLAN_ENV.to_string(), p.to_string()));
    }
    SocketOptions {
        worker_exe: PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        timeout: Duration::from_secs(10),
        extra_env,
        // Reap latency is part of MTTR; bound it tightly — the dead crew
        // has nothing graceful left to do.
        shutdown_grace: Duration::from_millis(500),
        ..SocketOptions::default()
    }
}

/// Stream `products` single-vector products through a supervised
/// session; returns (elapsed_s, recoveries, mttr_ms, replayed).
#[cfg(unix)]
fn run_stream(
    job: &MatrixJob,
    p: usize,
    plan: Option<&str>,
    products: usize,
) -> (f64, u64, f64, u64) {
    let mut sup = SessionSupervisor::start(
        job,
        p,
        1,
        worker_opts(plan),
        SupervisorOptions { max_rebuilds: 3 },
    )
    .expect("supervised start");
    let n = sup.n();
    let mut rng = Prng::new(1111);
    // Warm the plan caches off the clock.
    let warm = vec![0.1; n];
    let mut y = vec![0.0; n];
    sup.hgemv(&warm, &mut y).expect("warmup product");

    let t0 = Instant::now();
    for _ in 0..products {
        let x = rng.normal_vec(n);
        sup.hgemv(&x, &mut y).expect("supervised product");
    }
    let elapsed = t0.elapsed().as_secs_f64();
    let st = sup.recovery_stats();
    (elapsed, st.recoveries, st.last_recovery_s * 1e3, st.replayed_products)
}

#[cfg(unix)]
fn main() {
    println!("E11 — supervised-session crash recovery (MTTR)");
    let (side, products) = if tiny() { (16usize, 8usize) } else { (32, 24) };
    let job = MatrixJob {
        dim: 2,
        n_side: side,
        leaf_size: 16,
        eta: 0.9,
        cheb_grid: 3,
        corr_len: 0.1,
        kind: JobKind::Exponential,
    };
    let p = 2usize;
    let n = side * side;
    // Kill rank 1 on its Nth send: lands a few products into the stream,
    // well clear of the (unchaosed) handshake.
    let plan = "kill,src=1,nth=9";
    println!("N = {n}, P = {p}, {products} products, plan \"{plan}\"");

    let (base_s, base_rec, _, _) = run_stream(&job, p, None, products);
    assert_eq!(base_rec, 0, "the fault-free baseline must not recover");
    let (chaos_s, recoveries, mttr_ms, replayed) =
        run_stream(&job, p, Some(plan), products);
    assert!(recoveries >= 1, "the kill plan must force at least one recovery");

    let baseline_rps = products as f64 / base_s;
    let chaos_rps = products as f64 / chaos_s;
    println!("  fault-free baseline: {base_s:.3} s ({baseline_rps:.1} products/s)");
    println!(
        "  under kill plan:     {chaos_s:.3} s ({chaos_rps:.1} products/s), \
         {recoveries} recovery(ies), {replayed} replayed, MTTR {mttr_ms:.1} ms"
    );

    let row = h2opus::obs::trajectory::BenchRow::new(
        "recovery",
        &format!("N={n} P={p} products={products} plan=kill"),
    )
    .metric("mttr_ms", mttr_ms)
    .metric("recoveries", recoveries as f64)
    .metric("replayed", replayed as f64)
    .metric("reqs_per_s", chaos_rps)
    .metric("baseline_reqs_per_s", baseline_rps);
    h2opus::obs::trajectory::append_and_report(&row);
}

#[cfg(not(unix))]
fn main() {
    println!("E11 requires the Unix-domain-socket transport; skipping on this platform");
}
