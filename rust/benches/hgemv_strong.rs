//! E2 (Fig. 10): strong scalability of distributed HGEMV — fixed N,
//! growing P, for 2D and 3D test sets and several nv. Expect good scaling
//! until the local problem becomes too small to hide communication
//! (paper: limit around 32 GPUs at pN = 2^14). Reports the virtual-time
//! speedup next to the *measured* wall-clock speedup of the threaded
//! executor, so the CostModel can be checked against reality. Set
//! H2OPUS_BENCH_TINY=1 for the CI smoke configuration.

use h2opus::backend::native::NativeBackend;
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::hgemv::{dist_hgemv, DistOptions, ExecMode};
use h2opus::geometry::PointSet;
use h2opus::util::timer::trimmed_mean;
use h2opus::util::Prng;

fn tiny() -> bool {
    std::env::var("H2OPUS_BENCH_TINY").is_ok()
}

fn bench_set(dim: usize, n_target: usize, ps: &[usize], nvs: &[usize]) {
    let (points, corr, cfg) = if dim == 2 {
        let side = (n_target as f64).sqrt().ceil() as usize;
        (PointSet::grid_2d(side, 1.0), 0.1, H2Config { leaf_size: 32, eta: 0.9, cheb_grid: 4 })
    } else {
        let side = (n_target as f64).cbrt().ceil() as usize;
        (PointSet::grid_3d(side, 1.0), 0.2, H2Config { leaf_size: 32, eta: 0.95, cheb_grid: 2 })
    };
    let kernel = ExponentialKernel { dim, corr_len: corr };
    let a = build_h2(points, &kernel, &cfg);
    let n = a.n();
    let runs = if tiny() { 3 } else { 5 };
    println!("\n== {dim}D test set, strong scaling, N = {n} ==");
    println!(
        "{:>4} {:>4} {:>13} {:>9} {:>13} {:>9} {:>9}",
        "P", "nv", "virt (ms)", "virt spd", "meas (ms)", "meas spd", "eff (%)"
    );
    let mut rng = Prng::new(43);
    for &nv in nvs {
        let x = rng.normal_vec(n * nv);
        let mut y = vec![0.0; n * nv];
        let mut t1 = None;
        let mut m1 = None;
        for &p in ps {
            if a.depth() < p.trailing_zeros() as usize {
                continue;
            }
            let mut times = Vec::new();
            for _ in 0..runs {
                let rep = dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y, &DistOptions::default());
                times.push(rep.time);
            }
            let t = trimmed_mean(&times);
            let topts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
            let mut measured = Vec::new();
            for _ in 0..runs {
                let rep = dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y, &topts);
                measured.push(rep.measured.unwrap());
            }
            let tm = trimmed_mean(&measured);
            let base = *t1.get_or_insert(t);
            let mbase = *m1.get_or_insert(tm);
            println!(
                "{:>4} {:>4} {:>13.3} {:>9.2} {:>13.3} {:>9.2} {:>9.1}",
                p,
                nv,
                t * 1e3,
                base / t,
                tm * 1e3,
                mbase / tm,
                100.0 * base / t / p as f64
            );
        }
    }
}

fn main() {
    println!("E2 / Fig. 10 — HGEMV strong scalability (virtual + measured wall-clock)");
    if tiny() {
        bench_set(2, 1 << 10, &[1, 2, 4], &[1, 8]);
    } else {
        bench_set(2, 1 << 14, &[1, 2, 4, 8, 16, 32], &[1, 16, 64]);
        bench_set(3, 1 << 14, &[1, 2, 4, 8, 16, 32], &[1, 16, 64]);
    }
}
