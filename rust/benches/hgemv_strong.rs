//! E2 (Fig. 10): strong scalability of distributed HGEMV — fixed N,
//! growing P, for 2D and 3D test sets and several nv. Expect good scaling
//! until the local problem becomes too small to hide communication
//! (paper: limit around 32 GPUs at pN = 2^14). Reports the virtual-time
//! speedup next to the *measured* wall-clock speedup of the real
//! executor, so the CostModel can be checked against reality.
//!
//! Axes: set H2OPUS_BENCH_TINY=1 for the CI smoke configuration; pass
//! `--transport inproc|socket` to choose the measured executor (`socket`
//! spawns real `h2opus worker` subprocesses, each holding only its O(N/P)
//! branch workspace).
//!
//! Measured rows (flops, launches, GEMM words) append to
//! `target/hgemv_strong_rows.json` for `model_check.py --fit`.

use h2opus::backend::native::NativeBackend;
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::hgemv::{dist_hgemv, DistOptions, ExecMode};
use h2opus::dist::transport::{JobKind, MatrixJob};
use h2opus::geometry::PointSet;
use h2opus::metrics::Metrics;
use h2opus::obs::trajectory::{append_and_report, BenchRow};
use h2opus::util::timer::trimmed_mean;
use h2opus::util::Prng;

fn tiny() -> bool {
    std::env::var("H2OPUS_BENCH_TINY").is_ok()
}

fn transport() -> String {
    let args: Vec<String> = std::env::args().collect();
    args.iter()
        .position(|a| a == "--transport")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "inproc".into())
}

fn measure(
    transport: &str,
    a: &h2opus::tree::H2Matrix,
    job: &MatrixJob,
    p: usize,
    nv: usize,
    x: &[f64],
    y: &mut [f64],
    runs: usize,
) -> (f64, Metrics, f64) {
    match transport {
        #[cfg(unix)]
        "socket" => {
            use h2opus::dist::transport::socket::{socket_hgemv, SocketOptions, SocketSession};
            let opts = SocketOptions {
                worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
                ..SocketOptions::default()
            };
            let mut times = Vec::new();
            let mut metrics = Metrics::new();
            for _ in 0..runs {
                let rep = socket_hgemv(job, p, nv, x, y, &opts).expect("socket transport run");
                times.push(rep.measured);
                metrics = rep.metrics;
            }
            // Session-side iteration latency: barrier-free submit/wait
            // against resident workers — the CG-iteration round trip.
            let mut session =
                SocketSession::start(job, p, nv, opts).expect("session start");
            let pid = session.submit(x, nv).expect("warmup submit");
            session.wait(pid, y).expect("warmup wait");
            let mut iters = Vec::new();
            for _ in 0..runs {
                let t0 = std::time::Instant::now();
                let pid = session.submit(x, nv).expect("session submit");
                session.wait(pid, y).expect("session wait");
                iters.push(t0.elapsed().as_secs_f64());
            }
            (trimmed_mean(&times), metrics, trimmed_mean(&iters))
        }
        _ => {
            let _ = job;
            assert!(
                transport != "socket",
                "--transport socket requires Unix domain sockets on this platform"
            );
            let topts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
            let mut times = Vec::new();
            let mut metrics = Metrics::new();
            for _ in 0..runs {
                let rep = dist_hgemv(a, &NativeBackend, p, nv, x, y, &topts);
                times.push(rep.measured.unwrap());
                metrics = rep.metrics;
            }
            let t = trimmed_mean(&times);
            (t, metrics, t)
        }
    }
}

fn bench_set(dim: usize, n_target: usize, ps: &[usize], nvs: &[usize], rows: &mut Vec<String>) {
    let transport = transport();
    let (side, cfg, corr) = if dim == 2 {
        let side = (n_target as f64).sqrt().ceil() as usize;
        (side, H2Config { leaf_size: 32, eta: 0.9, cheb_grid: 4 }, 0.1)
    } else {
        let side = (n_target as f64).cbrt().ceil() as usize;
        (side, H2Config { leaf_size: 32, eta: 0.95, cheb_grid: 2 }, 0.2)
    };
    let job = MatrixJob {
        dim,
        n_side: side,
        leaf_size: cfg.leaf_size,
        eta: cfg.eta,
        cheb_grid: cfg.cheb_grid,
        corr_len: corr,
        kind: JobKind::Exponential,
    };
    let points =
        if dim == 2 { PointSet::grid_2d(side, 1.0) } else { PointSet::grid_3d(side, 1.0) };
    let kernel = ExponentialKernel { dim, corr_len: corr };
    let a = build_h2(points, &kernel, &cfg);
    let n = a.n();
    let runs = if tiny() { 3 } else { 5 };
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // Recorded per row so the CostModel fit knows how wide the batched
    // backend ran when these wall-clocks were measured.
    let bt = h2opus::backend::backend_threads();
    println!("\n== {dim}D test set, strong scaling, N = {n}, transport = {transport} ==");
    println!(
        "{:>4} {:>4} {:>13} {:>9} {:>13} {:>9} {:>13} {:>9}",
        "P", "nv", "virt (ms)", "virt spd", "meas (ms)", "meas spd", "iter (ms)", "eff (%)"
    );
    let mut rng = Prng::new(43);
    for &nv in nvs {
        let x = rng.normal_vec(n * nv);
        let mut y = vec![0.0; n * nv];
        let mut t1 = None;
        let mut m1 = None;
        for &p in ps {
            if a.depth() < p.trailing_zeros() as usize {
                continue;
            }
            let mut times = Vec::new();
            for _ in 0..runs {
                let rep = dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y, &DistOptions::default());
                times.push(rep.time);
            }
            let t = trimmed_mean(&times);
            let (tm, mm, si) = measure(&transport, &a, &job, p, nv, &x, &mut y, runs);
            let base = *t1.get_or_insert(t);
            let mbase = *m1.get_or_insert(tm);
            println!(
                "{:>4} {:>4} {:>13.3} {:>9.2} {:>13.3} {:>9.2} {:>13.3} {:>9.1}",
                p,
                nv,
                t * 1e3,
                base / t,
                tm * 1e3,
                mbase / tm,
                si * 1e3,
                100.0 * base / t / p as f64
            );
            rows.push(format!(
                "{{\"p\": {p}, \"n\": {n}, \"nv\": {nv}, \"cores\": {cores}, \"transport\": \"{transport}\", \
                 \"backend_threads\": {bt}, \
                 \"virtual_s\": {t:e}, \"measured_s\": {tm:e}, \"session_iter_s\": {si:e}, \
                 \"flops\": {}, \"launches\": {}, \"words\": {}, \
                 \"matrix_bytes\": {}}}",
                mm.flops, mm.batch_launches, mm.gemm_words, mm.matrix_bytes
            ));
            append_and_report(
                &BenchRow::new(
                    "hgemv_strong",
                    &format!("{dim}D N={n} p={p} nv={nv} t={transport}"),
                )
                .metric("virtual_s", t)
                .metric("measured_s", tm)
                .metric("iter_s", si)
                .metric("virtual_speedup", base / t),
            );
        }
    }
}

/// E2 companion rows: *measured* distributed in-place compression over
/// the same strong-scaling axis. Effective Gflop/s uses the serial flop
/// count over the distributed wall-clock — legitimate because every
/// per-block operation of the distributed path is bitwise identical to
/// serial `compress_full` (tests/compress_dist.rs) — and `matrix_bytes`
/// is the peak per-rank *compressed* shard, so the out-of-core memory
/// trajectory is benchmarked through compression too. Rows append to
/// their own file (`target/compress_dist_rows.json`), keeping the
/// HGEMV calibration schema untouched.
fn bench_compression(dim: usize, n_target: usize, ps: &[usize], tau: f64, rows: &mut Vec<String>) {
    use h2opus::compression::compress_full;
    use h2opus::dist::compress_sharded;
    let (side, cfg, corr) = if dim == 2 {
        let side = (n_target as f64).sqrt().ceil() as usize;
        (side, H2Config { leaf_size: 32, eta: 0.9, cheb_grid: 4 }, 0.1)
    } else {
        let side = (n_target as f64).cbrt().ceil() as usize;
        (side, H2Config { leaf_size: 32, eta: 0.95, cheb_grid: 2 }, 0.2)
    };
    let points =
        if dim == 2 { PointSet::grid_2d(side, 1.0) } else { PointSet::grid_3d(side, 1.0) };
    let kernel = ExponentialKernel { dim, corr_len: corr };
    let a = build_h2(points, &kernel, &cfg);
    let n = a.n();
    let runs = if tiny() { 3 } else { 5 };
    let bt = h2opus::backend::backend_threads();

    // Serial reference: the flop count and compressed size the
    // distributed path must reproduce.
    let mut metrics = Metrics::new();
    let mut work = a.clone();
    let (_, serial_stats) = compress_full(&mut work, tau, &NativeBackend, &mut metrics);
    let flops = metrics.flops;

    println!("\n== {dim}D distributed compression, strong scaling, N = {n}, tau = {tau:.0e} ==");
    println!(
        "{:>4} {:>13} {:>9} {:>10} {:>14} {:>8}",
        "P", "meas (ms)", "spd", "Gflop/s", "peak shard (B)", "ratio"
    );
    let mut t1 = None;
    for &p in ps {
        if a.depth() < p.trailing_zeros() as usize {
            continue;
        }
        let mut times = Vec::new();
        let mut peak = 0u64;
        let mut ratio = 0.0;
        for _ in 0..runs {
            let t0 = std::time::Instant::now();
            let (shards, _top, st) =
                compress_sharded(&a, p, tau, &NativeBackend).expect("distributed compression");
            times.push(t0.elapsed().as_secs_f64());
            assert_eq!(st.post_words, serial_stats.post_words, "P={p}: diverged from serial");
            peak = shards.iter().map(|s| s.matrix_bytes() as u64).max().unwrap();
            ratio = st.ratio();
        }
        let t = trimmed_mean(&times);
        let base = *t1.get_or_insert(t);
        let gflops = flops as f64 / t / 1e9;
        println!(
            "{:>4} {:>13.2} {:>9.2} {:>10.2} {:>14} {:>8.2}",
            p,
            t * 1e3,
            base / t,
            gflops,
            peak,
            ratio
        );
        rows.push(format!(
            "{{\"p\": {p}, \"n\": {n}, \"backend_threads\": {bt}, \"tau\": {tau:e}, \
             \"measured_s\": {t:e}, \"flops\": {flops}, \"gflops\": {gflops:e}, \
             \"matrix_bytes\": {peak}, \"ratio\": {ratio:e}}}"
        ));
    }
}

fn main() {
    println!("E2 / Fig. 10 — HGEMV strong scalability (virtual + measured wall-clock)");
    let mut rows = Vec::new();
    let mut crows = Vec::new();
    if tiny() {
        bench_set(2, 1 << 10, &[1, 2, 4], &[1, 8], &mut rows);
        bench_compression(2, 1 << 10, &[1, 2, 4], 1e-3, &mut crows);
    } else {
        bench_set(2, 1 << 14, &[1, 2, 4, 8, 16, 32], &[1, 16, 64], &mut rows);
        bench_set(3, 1 << 14, &[1, 2, 4, 8, 16, 32], &[1, 16, 64], &mut rows);
        bench_compression(2, 1 << 14, &[1, 2, 4, 8, 16], 1e-3, &mut crows);
        bench_compression(3, 1 << 13, &[1, 2, 4, 8], 1e-3, &mut crows);
    }
    std::fs::create_dir_all("target").ok();
    let path = "target/hgemv_strong_rows.json";
    std::fs::write(path, format!("[\n{}\n]\n", rows.join(",\n"))).expect("writing rows");
    println!("\ncalibration rows written: {path} (fit with python/tests/model_check.py --fit)");
    let cpath = "target/compress_dist_rows.json";
    std::fs::write(cpath, format!("[\n{}\n]\n", crows.join(",\n"))).expect("writing rows");
    println!("compression rows written: {cpath}");
}
