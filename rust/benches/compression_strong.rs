//! E4 (Fig. 12): strong scalability of algebraic compression — fixed N,
//! growing P. Expect efficiency to fall once the per-rank share of each
//! level is too small (paper: ~50% at pN = 2^17 in 2D, limit by 32 GPUs).

use h2opus::backend::native::NativeBackend;
use h2opus::config::{H2Config, NetworkModel};
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::compress::dist_compress;
use h2opus::dist::ExecMode;
use h2opus::geometry::PointSet;
use h2opus::obs::trajectory::{append_and_report, BenchRow};
use h2opus::util::timer::trimmed_mean;

fn bench_set(dim: usize, n_target: usize, cfg: H2Config) {
    let (points, corr) = if dim == 2 {
        let side = (n_target as f64).sqrt().ceil() as usize;
        (PointSet::grid_2d(side, 1.0), 0.1)
    } else {
        let side = (n_target as f64).cbrt().ceil() as usize;
        (PointSet::grid_3d(side, 1.0), 0.2)
    };
    let kernel = ExponentialKernel { dim, corr_len: corr };
    let a = build_h2(points, &kernel, &cfg);
    println!("\n== {dim}D compression strong scaling, N = {} ==", a.n());
    println!("{:>4} {:>12} {:>11} {:>13}", "P", "total (ms)", "speedup", "eff (%)");
    let mut row = BenchRow::new("compression_strong", &format!("{dim}D N={}", a.n()));
    let mut t1 = None;
    for &p in &[1usize, 2, 4, 8, 16] {
        if a.depth() < p.trailing_zeros() as usize {
            continue;
        }
        let mut times = Vec::new();
        for _ in 0..3 {
            let mut b = a.clone();
            let (_, rep) = dist_compress(&mut b, p, 1e-3, &NativeBackend, NetworkModel::default(), ExecMode::Virtual);
            times.push(rep.orthogonalization_time + rep.compression_time);
        }
        let t = trimmed_mean(&times);
        let base = *t1.get_or_insert(t);
        println!(
            "{:>4} {:>12.2} {:>11.2} {:>13.1}",
            p,
            t * 1e3,
            base / t,
            100.0 * base / t / p as f64
        );
        row.set_metric("p1_s", base);
        row.set_metric("pmax_s", t);
        row.set_metric("pmax", p as f64);
        row.set_metric("speedup", base / t);
    }
    append_and_report(&row);
}

fn main() {
    println!("E4 / Fig. 12 — compression strong scalability (virtual time)");
    bench_set(2, 1 << 14, H2Config { leaf_size: 64, eta: 0.9, cheb_grid: 6 });
    bench_set(3, 1 << 13, H2Config { leaf_size: 64, eta: 0.95, cheb_grid: 3 });
}
