//! E5 (Fig. 8): communication/computation overlap ablation at P = 8.
//!
//! Runs the distributed HGEMV with and without overlapping the x̂
//! exchanges with the diagonal multiplication, writes the two chrome
//! traces (`target/trace_overlap_{on,off}.json` — open in Perfetto to see
//! Fig. 8's timelines), and reports the virtual time difference under the
//! default and a slow network. Also reports the §4.1 communication-volume
//! optimization (compressed vs naive volume), the batched-execution
//! padding waste, and the *measured vs virtual* times of the threaded
//! executor (P = 8 and P = 1), all recorded in
//! `target/overlap_summary.json` for the model-check harness. A *measured*
//! Chrome trace — per-phase `Instant` stamps inside the rank workers plus
//! the recording transport's per-message stamps — is written to
//! `target/trace_measured.json` next to the two virtual-schedule traces.
//! Set H2OPUS_BENCH_TINY=1 for the CI smoke configuration; pass
//! `--transport inproc|socket` to pick the measured executor.

use h2opus::backend::native::NativeBackend;
use h2opus::config::{H2Config, NetworkModel};
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::hgemv::{dist_hgemv, DistOptions, ExecMode};
use h2opus::dist::{Decomposition, ExchangePlan};
use h2opus::geometry::PointSet;
use h2opus::util::timer::trimmed_mean;
use h2opus::util::Prng;

fn tiny() -> bool {
    std::env::var("H2OPUS_BENCH_TINY").is_ok()
}

fn main() {
    println!("E5 / Fig. 8 — overlap of communication and computation (P = 8)");
    let (side, nv, runs) = if tiny() { (32usize, 4usize, 3usize) } else { (128, 16, 5) };
    let points = PointSet::grid_2d(side, 1.0); // N = side^2
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: 32, eta: 0.9, cheb_grid: 4 };
    let a = build_h2(points, &kernel, &cfg);
    let n = a.n();
    let mut rng = Prng::new(8);
    let x = rng.normal_vec(n * nv);
    let mut y = vec![0.0; n * nv];

    let mut overlap_speedup = None;
    for (label, net) in [
        ("default network (α=5µs, 25 GB/s)", NetworkModel::default()),
        ("slow network (α=500µs, 10 GB/s)", NetworkModel { alpha: 5e-4, beta: 1e-10 * 10.0 }),
    ] {
        println!("\n-- {label}, nv = {nv} --");
        let mut results = Vec::new();
        for overlap in [false, true] {
            let opts = DistOptions {
                net,
                overlap,
                trace: true,
                mode: ExecMode::Virtual,
                ..DistOptions::default()
            };
            let mut times = Vec::new();
            let mut trace = None;
            for _ in 0..runs {
                let rep = dist_hgemv(&a, &NativeBackend, 8, nv, &x, &mut y, &opts);
                times.push(rep.time);
                trace = rep.trace_json;
            }
            let t = trimmed_mean(&times);
            println!("  overlap={overlap:5}  virtual time {:.3} ms", t * 1e3);
            let path = format!("target/trace_overlap_{}.json", if overlap { "on" } else { "off" });
            std::fs::create_dir_all("target").ok();
            std::fs::write(&path, trace.unwrap()).unwrap();
            println!("  trace written: {path}");
            results.push(t);
        }
        println!("  speedup from overlap: {:.2}x", results[0] / results[1]);
        overlap_speedup.get_or_insert(results[0] / results[1]);
    }

    // One overlapped run on a slow network for the counters used by the
    // JSON summary below.
    let opts = DistOptions {
        net: NetworkModel { alpha: 5e-4, beta: 4e-11 },
        overlap: true,
        trace: false,
        mode: ExecMode::Virtual,
        ..DistOptions::default()
    };
    let rep = dist_hgemv(&a, &NativeBackend, 8, nv, &x, &mut y, &opts);
    println!("\n(Perfetto traces contain the full Fig. 8-style timelines.)");

    // Measured wall-clock of the real executor, P = 8 vs P = 1, next to
    // the virtual prediction — the CostModel reality check.
    let transport = {
        let args: Vec<String> = std::env::args().collect();
        args.iter()
            .position(|arg| arg == "--transport")
            .and_then(|i| args.get(i + 1))
            .cloned()
            .unwrap_or_else(|| "inproc".into())
    };
    println!(
        "\n-- measured vs virtual (real executor, transport = {transport}, default network) --"
    );
    let job = h2opus::dist::transport::MatrixJob {
        dim: 2,
        n_side: side,
        leaf_size: 32,
        eta: 0.9,
        cheb_grid: 4,
        corr_len: 0.1,
        kind: h2opus::dist::transport::JobKind::Exponential,
    };
    let mut measured_of = |p: usize| {
        let vopts = DistOptions::default();
        let mut virts = Vec::new();
        for _ in 0..runs {
            virts.push(dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y, &vopts).time);
        }
        let mut meas = Vec::new();
        let _ = &job; // used only by the unix socket arm
        match transport.as_str() {
            #[cfg(unix)]
            "socket" => {
                use h2opus::dist::transport::socket::{socket_hgemv, SocketOptions};
                let sopts = SocketOptions {
                    worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
                    ..SocketOptions::default()
                };
                for _ in 0..runs {
                    let rep =
                        socket_hgemv(&job, p, nv, &x, &mut y, &sopts).expect("socket transport");
                    meas.push(rep.measured);
                }
            }
            _ => {
                assert!(
                    transport != "socket",
                    "--transport socket requires Unix domain sockets on this platform"
                );
                let topts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
                for _ in 0..runs {
                    meas.push(
                        dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y, &topts)
                            .measured
                            .unwrap(),
                    );
                }
            }
        }
        (trimmed_mean(&virts), trimmed_mean(&meas))
    };
    let (virt1, meas1) = measured_of(1);
    let (virt8, meas8) = measured_of(8);

    // The measured Chrome trace (Fig. 8 from reality): per-phase stamps
    // inside the rank workers + the recording transport's message events.
    {
        let topts = DistOptions {
            mode: ExecMode::Threaded,
            measured_trace: true,
            ..DistOptions::default()
        };
        let rep = dist_hgemv(&a, &NativeBackend, 8, nv, &x, &mut y, &topts);
        let path = "target/trace_measured.json";
        std::fs::create_dir_all("target").ok();
        std::fs::write(path, rep.measured_trace_json.expect("measured trace requested")).unwrap();
        println!("  measured trace written: {path}");
    }
    println!("  P=1: virtual {:.3} ms, measured {:.3} ms", virt1 * 1e3, meas1 * 1e3);
    println!("  P=8: virtual {:.3} ms, measured {:.3} ms", virt8 * 1e3, meas8 * 1e3);
    println!(
        "  speedup P=1 -> P=8: virtual {:.2}x, measured {:.2}x (machine-limited)",
        virt1 / virt8,
        meas1 / meas8
    );

    // §4.1 volume optimization
    println!("\n-- communication volume (nv = {nv}) --");
    let d = Decomposition::new(8, a.depth()).unwrap();
    let plan = ExchangePlan::build(&a, d);
    let mut opt_total = 0usize;
    let mut naive_total = 0usize;
    for p in 0..8 {
        opt_total += plan.bytes_into(&a, p, nv);
        naive_total += plan.naive_bytes_into(&a, p, nv);
    }
    println!(
        "  compressed-node volume {:.1} KiB vs naive allgather {:.1} KiB ({:.1}x reduction)",
        opt_total as f64 / 1024.0,
        naive_total as f64 / 1024.0,
        naive_total as f64 / opt_total as f64
    );
    println!(
        "  padding waste {} elements over {} batch launches",
        rep.metrics.pad_waste, rep.metrics.batch_launches
    );

    // Machine-readable summary: comm volume, padding waste and the
    // measured-vs-virtual columns, so the comm benches and the Python
    // model-check harness record both (hand-rolled JSON — no serde
    // offline).
    let summary = format!(
        "{{\n  \"n\": {},\n  \"ranks\": 8,\n  \"nv\": {},\n  \"opt_bytes\": {},\n  \"naive_bytes\": {},\n  \"bytes_sent\": {},\n  \"messages\": {},\n  \"pad_waste_elems\": {},\n  \"batch_launches\": {},\n  \"virtual_time_s\": {:.9},\n  \"virtual_p1_s\": {:.9},\n  \"virtual_p8_s\": {:.9},\n  \"measured_p1_s\": {:.9},\n  \"measured_p8_s\": {:.9}\n}}\n",
        n,
        nv,
        opt_total,
        naive_total,
        rep.metrics.bytes_sent,
        rep.metrics.messages,
        rep.metrics.pad_waste,
        rep.metrics.batch_launches,
        rep.time,
        virt1,
        virt8,
        meas1,
        meas8
    );
    std::fs::write("target/overlap_summary.json", &summary).unwrap();
    println!("  summary written: target/overlap_summary.json");

    let row = h2opus::obs::trajectory::BenchRow::new(
        "overlap",
        &format!("N={n} nv={nv} P=8 t={transport}"),
    )
    .metric("virtual_p1_s", virt1)
    .metric("virtual_p8_s", virt8)
    .metric("measured_p1_s", meas1)
    .metric("measured_p8_s", meas8)
    .metric("overlap_speedup", overlap_speedup.unwrap_or(1.0))
    .metric("volume_reduction", naive_total as f64 / opt_total as f64);
    h2opus::obs::trajectory::append_and_report(&row);
}
