//! E10: pipelined, request-coalescing HGEMV serving throughput over the
//! resident socket session (the paper's `num_vectors` batching, driven
//! from concurrent clients instead of one wide caller).
//!
//! Axes:
//! - **concurrency** — closed-loop client threads submitting
//!   single-vector products back to back;
//! - **coalesce cap** — the widest fused product the
//!   [`SessionServer`] dispatcher will build;
//! - **pipeline depth** — products in flight on the session (depth 1 +
//!   cap 1 is the sequential barrier-per-product baseline).
//!
//! Every cell appends a row to `target/bench_e10.json` (`{concurrency,
//! cap, depth, requests, reqs_per_s, p50_ms, p99_ms, achieved_nv}` —
//! the achieved-width histogram shows how much coalescing actually
//! happened). A raw-session ablation (same products, barriers vs
//! pipeline) is priced against [`CostModel::pipeline`] and recorded in
//! `target/pipeline_summary.json` for the model-check harness.
//!
//! `H2OPUS_BENCH_TINY=1` shrinks the matrix and the sweep for CI smoke.
//! `H2OPUS_E10_ASSERT=1` (CI) additionally asserts the pipelined +
//! coalesced server beats the sequential baseline by >= 1.5x at
//! concurrency 8, and exits nonzero otherwise (skipped on single-core
//! machines).

#[cfg(unix)]
use std::collections::BTreeMap;
#[cfg(unix)]
use std::path::PathBuf;
#[cfg(unix)]
use std::time::Instant;

#[cfg(unix)]
use h2opus::dist::hgemv::CostModel;
#[cfg(unix)]
use h2opus::dist::transport::server::{ServerOptions, SessionServer};
#[cfg(unix)]
use h2opus::dist::transport::socket::{SocketOptions, SocketSession};
#[cfg(unix)]
use h2opus::dist::transport::{JobKind, MatrixJob};
#[cfg(unix)]
use h2opus::util::Prng;

#[cfg(unix)]
fn tiny() -> bool {
    std::env::var("H2OPUS_BENCH_TINY").is_ok()
}

#[cfg(unix)]
fn worker_opts() -> SocketOptions {
    SocketOptions {
        worker_exe: PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        ..SocketOptions::default()
    }
}

#[cfg(unix)]
fn percentile_ms(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = (q * (sorted.len() - 1) as f64).round() as usize;
    sorted[idx.min(sorted.len() - 1)] * 1e3
}

#[cfg(unix)]
struct Cell {
    concurrency: usize,
    cap: usize,
    depth: usize,
    requests: usize,
    reqs_per_s: f64,
    p50_ms: f64,
    p99_ms: f64,
    /// Server-side queue-wait percentiles (from the dispatcher's
    /// histogram — time a request sat in the queue before fusing, which
    /// the client-observed p50/p99 above include but don't isolate).
    queue_p50_ms: f64,
    queue_p99_ms: f64,
    achieved_nv: BTreeMap<usize, u64>,
    /// The server's own one-line summary, printed after the table.
    summary: String,
}

/// One sweep cell: a fresh server, `concurrency` closed-loop clients
/// each issuing `per_client` single-vector products. Spawn/teardown is
/// excluded from the timed section.
#[cfg(unix)]
fn run_cell(
    job: &MatrixJob,
    p: usize,
    concurrency: usize,
    cap: usize,
    depth: usize,
    per_client: usize,
) -> Cell {
    let server = SessionServer::start(
        job,
        p,
        worker_opts(),
        ServerOptions { max_coalesce: cap, pipeline_depth: depth },
    )
    .expect("server start");
    let n = server.n();
    // Warm the plan caches (width 1 and a fused width) off the clock.
    let warm = vec![0.1; n];
    server.submit(&warm).expect("warmup").wait().expect("warmup product");

    let t0 = Instant::now();
    let mut latencies: Vec<f64> = Vec::new();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..concurrency)
            .map(|c| {
                let server = &server;
                s.spawn(move || {
                    let mut rng = Prng::new(4200 + c as u64);
                    let mut lats = Vec::with_capacity(per_client);
                    for _ in 0..per_client {
                        let x = rng.normal_vec(n);
                        let tr = Instant::now();
                        let served = server.submit(&x).expect("submit").wait().expect("serve");
                        lats.push(tr.elapsed().as_secs_f64());
                        assert_eq!(served.y.len(), n);
                    }
                    lats
                })
            })
            .collect();
        for h in handles {
            latencies.extend(h.join().expect("client thread"));
        }
    });
    let elapsed = t0.elapsed().as_secs_f64();
    let requests = concurrency * per_client;
    latencies.sort_by(|a, b| a.partial_cmp(b).expect("finite latencies"));
    let stats = server.stats();
    Cell {
        concurrency,
        cap,
        depth,
        requests,
        reqs_per_s: requests as f64 / elapsed,
        p50_ms: percentile_ms(&latencies, 0.50),
        p99_ms: percentile_ms(&latencies, 0.99),
        queue_p50_ms: 1e3 * stats.queue_wait.quantile(0.50),
        queue_p99_ms: 1e3 * stats.queue_wait.quantile(0.99),
        summary: stats.summary(),
        achieved_nv: stats.nv_histogram,
    }
}

/// Raw-session ablation: the same B products run barrier-per-product
/// (`hgemv`) vs pipelined (`submit` all, `wait` all), next to the
/// `CostModel::pipeline` prediction. Writes
/// `target/pipeline_summary.json` for model_check.py.
#[cfg(unix)]
fn pipeline_ablation(job: &MatrixJob, p: usize, nv: usize, products: usize) {
    let opts = worker_opts();
    let mut session = SocketSession::start(job, p, nv, opts).expect("session start");
    let n = session.n();
    let mut rng = Prng::new(43);
    let xs: Vec<Vec<f64>> = (0..products).map(|_| rng.normal_vec(n * nv)).collect();
    let mut y = vec![0.0; n * nv];

    // Warm-up product: plan caches on both sides, and the metrics that
    // feed the model's compute term.
    let rep = session.hgemv(&xs[0], &mut y).expect("warmup");
    let cm = CostModel::host();
    let compute_s =
        rep.metrics.flops as f64 * cm.flop_time + rep.metrics.batch_launches as f64 * cm.t_launch;
    let ship_s = cm.xfer(n * nv * 8);
    let gather_s = cm.xfer(n * nv * 8);
    let (model_seq, model_pipe) = cm.pipeline(products, ship_s, compute_s, gather_s);

    let t0 = Instant::now();
    for x in &xs {
        session.hgemv(x, &mut y).expect("sequential product");
    }
    let seq = t0.elapsed().as_secs_f64();

    let t0 = Instant::now();
    let pids: Vec<u64> =
        xs.iter().map(|x| session.submit(x, nv).expect("submit")).collect();
    for pid in pids {
        session.wait(pid, &mut y).expect("wait");
    }
    let pipe = t0.elapsed().as_secs_f64();

    println!("\n-- raw-session pipeline ablation (P = {p}, nv = {nv}, B = {products}) --");
    println!("  sequential (barrier/product): {:.3} ms", seq * 1e3);
    println!("  pipelined  (submit/wait):     {:.3} ms ({:.2}x)", pipe * 1e3, seq / pipe);
    println!(
        "  CostModel::pipeline predicts: seq {:.3} ms, pipe {:.3} ms ({:.2}x)",
        model_seq * 1e3,
        model_pipe * 1e3,
        model_seq / model_pipe
    );

    let summary = format!(
        "{{\n  \"n\": {n},\n  \"ranks\": {p},\n  \"nv\": {nv},\n  \"products\": {products},\n  \
         \"ship_s\": {ship_s:.12},\n  \"compute_s\": {compute_s:.12},\n  \
         \"gather_s\": {gather_s:.12},\n  \
         \"measured_seq_s\": {seq:.9},\n  \"measured_pipe_s\": {pipe:.9},\n  \
         \"model_seq_s\": {model_seq:.9},\n  \"model_pipe_s\": {model_pipe:.9}\n}}\n"
    );
    std::fs::create_dir_all("target").ok();
    std::fs::write("target/pipeline_summary.json", &summary).expect("writing pipeline summary");
    println!("  summary written: target/pipeline_summary.json");
}

#[cfg(unix)]
fn main() {
    println!("E10 — pipelined, request-coalescing HGEMV serving (socket session)");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let (side, per_client) = if tiny() { (16usize, 6usize) } else { (64, 20) };
    let job = MatrixJob {
        dim: 2,
        n_side: side,
        leaf_size: 16,
        eta: 0.9,
        cheb_grid: 3,
        corr_len: 0.1,
        kind: JobKind::Exponential,
    };
    let p = 2usize;
    println!("N = {}, P = {p}, {cores} cores, {per_client} requests per client", side * side);

    // (cap, depth): depth 1 + cap 1 is the sequential barrier-per-product
    // baseline the speedup is measured against.
    let configs: &[(usize, usize)] =
        if tiny() { &[(1, 1), (16, 2)] } else { &[(1, 1), (4, 2), (16, 2)] };
    let concurrency_axis: &[usize] = if tiny() { &[2, 8] } else { &[1, 2, 4, 8] };

    let mut cells: Vec<Cell> = Vec::new();
    println!(
        "\n{:>11} {:>5} {:>6} {:>9} {:>10} {:>9} {:>9} {:>8} {:>8}  achieved nv",
        "concurrency", "cap", "depth", "requests", "reqs/s", "p50 ms", "p99 ms", "qw p50", "qw p99"
    );
    for &(cap, depth) in configs {
        for &c in concurrency_axis {
            let cell = run_cell(&job, p, c, cap, depth, per_client);
            let hist: String = cell
                .achieved_nv
                .iter()
                .map(|(nv, count)| format!("{nv}:{count}"))
                .collect::<Vec<_>>()
                .join(" ");
            println!(
                "{:>11} {:>5} {:>6} {:>9} {:>10.1} {:>9.3} {:>9.3} {:>8.3} {:>8.3}  {hist}",
                cell.concurrency,
                cell.cap,
                cell.depth,
                cell.requests,
                cell.reqs_per_s,
                cell.p50_ms,
                cell.p99_ms,
                cell.queue_p50_ms,
                cell.queue_p99_ms
            );
            cells.push(cell);
        }
    }
    if let Some(last) = cells.last() {
        println!("\nserver summary (last cell): {}", last.summary);
    }

    let rows: Vec<String> = cells
        .iter()
        .map(|c| {
            let hist: String = c
                .achieved_nv
                .iter()
                .map(|(nv, count)| format!("\"{nv}\": {count}"))
                .collect::<Vec<_>>()
                .join(", ");
            format!(
                "{{\"concurrency\": {}, \"cap\": {}, \"depth\": {}, \"requests\": {}, \
                 \"reqs_per_s\": {:.3}, \"p50_ms\": {:.4}, \"p99_ms\": {:.4}, \
                 \"queue_p50_ms\": {:.4}, \"queue_p99_ms\": {:.4}, \
                 \"achieved_nv\": {{{hist}}}}}",
                c.concurrency,
                c.cap,
                c.depth,
                c.requests,
                c.reqs_per_s,
                c.p50_ms,
                c.p99_ms,
                c.queue_p50_ms,
                c.queue_p99_ms
            )
        })
        .collect();
    std::fs::create_dir_all("target").ok();
    let path = "target/bench_e10.json";
    std::fs::write(path, format!("[\n{}\n]\n", rows.join(",\n"))).expect("writing E10 rows");
    println!("\nE10 rows written: {path}");

    for c in &cells {
        let row = h2opus::obs::trajectory::BenchRow::new(
            "serving",
            &format!("N={} P={p} c={} cap={} depth={}", side * side, c.concurrency, c.cap, c.depth),
        )
        .metric("reqs_per_s", c.reqs_per_s)
        .metric("latency_p50_ms", c.p50_ms)
        .metric("latency_p99_ms", c.p99_ms)
        .metric("queue_p50_ms", c.queue_p50_ms)
        .metric("queue_p99_ms", c.queue_p99_ms);
        h2opus::obs::trajectory::append_and_report(&row);
    }

    pipeline_ablation(&job, p, if tiny() { 2 } else { 4 }, 8);

    if std::env::var("H2OPUS_E10_ASSERT").is_ok() {
        if cores < 2 {
            println!("E10 assert: SKIP (single-core machine)");
            return;
        }
        let at = |cap: usize, depth: usize| {
            cells
                .iter()
                .filter(|c| c.cap == cap && c.depth == depth)
                .max_by_key(|c| c.concurrency)
                .map(|c| c.reqs_per_s)
                .expect("sweep covers the asserted configs")
        };
        let base = at(1, 1);
        let piped = at(16, 2);
        println!(
            "E10 assert: sequential {base:.1} reqs/s vs pipelined+coalesced {piped:.1} reqs/s \
             ({:.2}x, need >= 1.50x)",
            piped / base
        );
        if piped < base * 1.5 {
            println!("E10 assert: FAIL — serving pipeline did not clear 1.5x");
            std::process::exit(1);
        }
    }
}

#[cfg(not(unix))]
fn main() {
    println!("E10 requires the Unix-domain-socket transport; skipping on this platform");
}
