//! E9 (§6.1): sustained batched-kernel rates — the efficiency denominator
//! the paper measures with MAGMA's batched GEMM on 64×64 blocks (2.3
//! Tflop/s HGEMV, 670 Gflop/s compression come from these kernels).
//!
//! Axes:
//! - **threads** — the parallel native backend's pool width (the paper's
//!   analogue: how much of the GPU a batch occupies);
//! - **shape** — block shapes drawn from the real tree levels of the
//!   library's default configurations (leaf bases m×k, transfer stacks
//!   2k×k, coupling k×k×nv, dense m×m×nv) plus the paper's 64×64 block;
//! - **op** — GEMM / QR / SVD, native vs the XLA/PJRT AOT path.
//!
//! Every measured point appends a row to `target/bench_e9.json`
//! (`{op, nb, m, k, n, threads, cores, gflops}`) — the perf-trajectory
//! baseline for the batched hot path.
//!
//! `H2OPUS_BENCH_TINY=1` shrinks batch counts for CI smoke.
//! `H2OPUS_E9_ASSERT=1` (CI) additionally asserts the parallel dispatch
//! beats the serial loop on one large batch, and exits nonzero otherwise
//! (skipped on single-core machines).

use std::path::Path;

use h2opus::backend::native::NativeBackend;
use h2opus::backend::{contiguous_offsets, BatchRef, ComputeBackend, GemmDims};
use h2opus::metrics::Metrics;
use h2opus::runtime::XlaBackend;
use h2opus::util::parallel::ParallelPool;
use h2opus::util::timer::trimmed_mean_time;
use h2opus::util::Prng;

fn tiny() -> bool {
    std::env::var("H2OPUS_BENCH_TINY").is_ok()
}

/// One prepared batched-GEMM problem, reusable across timed runs.
struct GemmCase {
    dims: GemmDims,
    a: Vec<f64>,
    b: Vec<f64>,
    c: Vec<f64>,
    ao: Vec<usize>,
    bo: Vec<usize>,
    co: Vec<usize>,
}

impl GemmCase {
    fn new(nb: usize, m: usize, k: usize, n: usize) -> GemmCase {
        let mut rng = Prng::new(5);
        GemmCase {
            dims: GemmDims { nb, m, k, n, trans_a: false, trans_b: false, accumulate: false },
            a: rng.normal_vec(nb * m * k),
            b: rng.normal_vec(nb * k * n),
            c: vec![0.0; nb * m * n],
            ao: contiguous_offsets(nb, m * k),
            bo: contiguous_offsets(nb, k * n),
            co: contiguous_offsets(nb, m * n),
        }
    }

    fn flops(&self) -> f64 {
        let d = self.dims;
        2.0 * (d.nb * d.m * d.k * d.n) as f64
    }

    /// Gflop/s on the native backend over `pool`.
    fn native_rate(&mut self, pool: &ParallelPool, runs: usize) -> f64 {
        let be = NativeBackend;
        let (dims, a, b, ao, bo, co) = (self.dims, &self.a, &self.b, &self.ao, &self.bo, &self.co);
        let c = &mut self.c;
        let t = trimmed_mean_time(runs, || {
            let mut mt = Metrics::new();
            be.batched_gemm_on(
                pool,
                dims,
                BatchRef { data: a, offsets: ao },
                BatchRef { data: b, offsets: bo },
                &mut c[..],
                co,
                &mut mt,
            );
        });
        self.flops() / t / 1e9
    }

    /// Gflop/s through the `ComputeBackend` trait (XLA path).
    fn trait_rate(&mut self, be: &dyn ComputeBackend, runs: usize) -> f64 {
        let (dims, a, b, ao, bo, co) = (self.dims, &self.a, &self.b, &self.ao, &self.bo, &self.co);
        let c = &mut self.c;
        let t = trimmed_mean_time(runs, || {
            let mut mt = Metrics::new();
            be.batched_gemm(
                dims,
                BatchRef { data: a, offsets: ao },
                BatchRef { data: b, offsets: bo },
                &mut c[..],
                co,
                &mut mt,
            );
        });
        self.flops() / t / 1e9
    }
}

fn qr_rate(pool: &ParallelPool, nb: usize, rows: usize, cols: usize, runs: usize) -> f64 {
    let mut rng = Prng::new(6);
    let a = rng.normal_vec(nb * rows * cols);
    let mut q = vec![0.0; nb * rows * cols];
    let mut r = vec![0.0; nb * cols * cols];
    let be = NativeBackend;
    let t = trimmed_mean_time(runs, || {
        let mut mt = Metrics::new();
        be.batched_qr_on(pool, nb, rows, cols, &a, &mut q, &mut r, &mut mt);
    });
    (nb * 2 * rows * cols * cols) as f64 / t / 1e9
}

fn svd_rate(pool: &ParallelPool, nb: usize, rows: usize, cols: usize, runs: usize) -> f64 {
    let mut rng = Prng::new(7);
    let a = rng.normal_vec(nb * rows * cols);
    let mut u = vec![0.0; nb * rows * cols];
    let mut s = vec![0.0; nb * cols];
    let mut v = vec![0.0; nb * cols * cols];
    let be = NativeBackend;
    let t = trimmed_mean_time(runs, || {
        let mut mt = Metrics::new();
        be.batched_svd_on(pool, nb, rows, cols, &a, &mut u, &mut s, &mut v, &mut mt);
    });
    (nb * 14 * rows * cols * cols) as f64 / t / 1e9
}

/// CI gate: the pooled dispatch must beat the serial loop on one large
/// paper-shaped batch. Returns false (after printing why) on failure.
fn assert_parallel_beats_serial(pools: &[(usize, ParallelPool)], cores: usize) -> bool {
    if cores < 2 {
        println!("E9 assert: SKIP (single-core machine)");
        return true;
    }
    let nb = 2048;
    let (m, k, n) = (32, 32, 32);
    let mut case = GemmCase::new(nb, m, k, n);
    let serial = ParallelPool::new(1);
    let r1 = case.native_rate(&serial, 7);
    // The widest pool not exceeding the core count (wider pools only
    // timeshare on CI runners).
    let (w, pool) = pools
        .iter()
        .filter(|(w, _)| *w <= cores)
        .max_by_key(|(w, _)| *w)
        .expect("a pool within the core budget");
    let rp = case.native_rate(pool, 7);
    // With >= 4 real cores a 4-wide pool on 2048 blocks of 32^3 sits far
    // above parity (~2.5-3.5x), so a strict win is a safe gate; on 2-3
    // core runners the expected margin is thin enough that noisy-neighbor
    // contention could flip a strict comparison, so allow 10% slack there.
    let need = if cores >= 4 { 1.0 } else { 0.9 };
    println!(
        "E9 assert: serial {r1:.3} Gflop/s vs {w} threads {rp:.3} Gflop/s ({:.2}x, {cores} cores, need > {need:.2}x)",
        rp / r1
    );
    if rp > r1 * need {
        true
    } else {
        println!("E9 assert: FAIL — parallel dispatch did not beat the serial loop");
        false
    }
}

fn main() {
    println!("E9 / §6.1 — batched-kernel sustained rates (Gflop/s)");
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads_axis: &[usize] = &[1, 2, 4, 8];
    let pools: Vec<(usize, ParallelPool)> =
        threads_axis.iter().map(|&t| (t, ParallelPool::new(t))).collect();
    let runs = if tiny() { 3 } else { 5 };
    let scale = if tiny() { 4 } else { 1 };
    let mut rows: Vec<String> = Vec::new();
    let mut best: std::collections::BTreeMap<String, f64> = std::collections::BTreeMap::new();
    let mut push_row = |op: &str, nb: usize, m: usize, k: usize, n: usize, t: usize, g: f64| {
        rows.push(format!(
            "{{\"op\": \"{op}\", \"nb\": {nb}, \"m\": {m}, \"k\": {k}, \"n\": {n}, \
             \"threads\": {t}, \"cores\": {cores}, \"gflops\": {g:.4}}}"
        ));
        let e = best.entry(format!("{op}_gflops")).or_insert(0.0);
        *e = e.max(g);
    };

    let xla = if Path::new("artifacts/manifest.txt").exists() {
        Some(XlaBackend::new(Path::new("artifacts")).expect("loading artifacts"))
    } else {
        println!("(artifacts missing — run `make artifacts` to include the XLA column)");
        None
    };

    // Block shapes of the real tree levels: the 2D defaults (leaf m=32,
    // rank k=16), the 3D defaults (k=8), transfer stacks (2k×k), coupling
    // blocks (k×k) at nv ∈ {1, 16}, dense leaf blocks, and the paper's
    // 64×64 MAGMA reference shape.
    println!("\n-- batched GEMM (native, by pool width; {cores} cores) --");
    let header: String =
        threads_axis.iter().map(|t| format!("{:>10}", format!("t={t}"))).collect();
    println!("{:>6} {:>12} {:>10} {header}", "nb", "shape", "role");
    let gemm_shapes: &[(&str, usize, usize, usize, usize)] = &[
        ("leaf", 1024 / scale, 32, 16, 1),
        ("leaf", 1024 / scale, 32, 16, 16),
        ("transfer", 2048 / scale, 16, 16, 16),
        ("coupling", 2048 / scale, 16, 16, 1),
        ("coupling", 2048 / scale, 16, 16, 16),
        ("coupling3d", 4096 / scale, 8, 8, 16),
        ("dense", 512 / scale, 32, 32, 16),
        ("paper64", 256 / scale, 64, 64, 64),
    ];
    for &(role, nb, m, k, n) in gemm_shapes {
        let mut case = GemmCase::new(nb, m, k, n);
        let mut cols_out = String::new();
        for (t, pool) in &pools {
            let g = case.native_rate(pool, runs);
            push_row("gemm", nb, m, k, n, *t, g);
            cols_out.push_str(&format!("{g:>10.3}"));
        }
        println!("{:>6} {:>12} {:>10} {cols_out}", nb, format!("{m}x{k}x{n}"), role);
    }

    if let Some(xla) = xla.as_ref() {
        println!("\n-- batched GEMM (XLA AOT, for reference) --");
        for &(nb, m, k, n) in &[(256usize, 32usize, 32usize, 32usize), (1024, 16, 16, 16)] {
            let mut case = GemmCase::new(nb / scale, m, k, n);
            let g = case.trait_rate(xla, runs);
            push_row("gemm_xla", nb / scale, m, k, n, 1, g);
            println!("{:>6} {:>12} {:>10.3}", nb / scale, format!("{m}x{k}x{n}"), g);
        }
    }

    println!("\n-- batched QR (rows x cols, native, by pool width) --");
    println!("{:>6} {:>12} {:>10} {header}", "nb", "shape", "role");
    let qr_shapes: &[(&str, usize, usize, usize)] = &[
        ("leaf", 256 / scale, 32, 16),
        ("stack", 512 / scale, 32, 16),
        ("tall", 64 / scale, 128, 16),
    ];
    for &(role, nb, rows_n, cols_n) in qr_shapes {
        let mut cols_out = String::new();
        for (t, pool) in &pools {
            let g = qr_rate(pool, nb, rows_n, cols_n, runs);
            push_row("qr", nb, rows_n, cols_n, 0, *t, g);
            cols_out.push_str(&format!("{g:>10.3}"));
        }
        println!("{:>6} {:>12} {:>10} {cols_out}", nb, format!("{rows_n}x{cols_n}"), role);
    }

    println!("\n-- batched SVD (rows x cols, native, by pool width) --");
    println!("{:>6} {:>12} {:>10} {header}", "nb", "shape", "role");
    let svd_shapes: &[(&str, usize, usize, usize)] = &[
        ("trunc", 128 / scale, 16, 8),
        ("stack", 64 / scale, 32, 16),
    ];
    for &(role, nb, rows_n, cols_n) in svd_shapes {
        let mut cols_out = String::new();
        for (t, pool) in &pools {
            let g = svd_rate(pool, nb, rows_n, cols_n, runs);
            push_row("svd", nb, rows_n, cols_n, 0, *t, g);
            cols_out.push_str(&format!("{g:>10.3}"));
        }
        println!("{:>6} {:>12} {:>10} {cols_out}", nb, format!("{rows_n}x{cols_n}"), role);
    }
    println!("\n(The 32x16 SVD artifact is excluded: its unrolled Jacobi graph compiles");
    println!(" for minutes under XLA CPU — see DESIGN.md \"Substitutions\" for the stack notes.)");

    std::fs::create_dir_all("target").ok();
    let path = "target/bench_e9.json";
    std::fs::write(path, format!("[\n{}\n]\n", rows.join(",\n"))).expect("writing E9 rows");
    println!("\nE9 rows written: {path}");

    let mut traj = h2opus::obs::trajectory::BenchRow::new(
        "batched_backend",
        &format!("cores={cores} scale={scale}"),
    );
    for (key, g) in &best {
        traj.set_metric(key, *g);
    }
    h2opus::obs::trajectory::append_and_report(&traj);

    if std::env::var("H2OPUS_E9_ASSERT").is_ok() && !assert_parallel_beats_serial(&pools, cores) {
        std::process::exit(1);
    }
}
