//! E9 (§6.1): sustained batched-kernel rates — the efficiency denominator
//! the paper measures with MAGMA's batched GEMM on 64×64 blocks. Compares
//! the native backend against the XLA/PJRT AOT path (JAX/Pallas
//! artifacts) for GEMM, QR and SVD at the library's bucket shapes.

use std::path::Path;

use h2opus::backend::native::NativeBackend;
use h2opus::backend::{contiguous_offsets, BatchRef, ComputeBackend, GemmDims};
use h2opus::metrics::Metrics;
use h2opus::runtime::XlaBackend;
use h2opus::util::timer::trimmed_mean_time;
use h2opus::util::Prng;

fn gemm_rate(be: &dyn ComputeBackend, nb: usize, m: usize, k: usize, n: usize) -> f64 {
    let mut rng = Prng::new(5);
    let a = rng.normal_vec(nb * m * k);
    let b = rng.normal_vec(nb * k * n);
    let mut c = vec![0.0; nb * m * n];
    let dims = GemmDims { nb, m, k, n, trans_a: false, trans_b: false, accumulate: false };
    let ao = contiguous_offsets(nb, m * k);
    let bo = contiguous_offsets(nb, k * n);
    let co = contiguous_offsets(nb, m * n);
    let t = trimmed_mean_time(5, || {
        let mut mt = Metrics::new();
        be.batched_gemm(dims, BatchRef { data: &a, offsets: &ao }, BatchRef { data: &b, offsets: &bo }, &mut c, &co, &mut mt);
    });
    2.0 * (nb * m * k * n) as f64 / t / 1e9
}

fn qr_rate(be: &dyn ComputeBackend, nb: usize, rows: usize, cols: usize) -> f64 {
    let mut rng = Prng::new(6);
    let a = rng.normal_vec(nb * rows * cols);
    let mut q = vec![0.0; nb * rows * cols];
    let mut r = vec![0.0; nb * cols * cols];
    let t = trimmed_mean_time(5, || {
        let mut mt = Metrics::new();
        be.batched_qr(nb, rows, cols, &a, &mut q, &mut r, &mut mt);
    });
    let flops_per = 2 * rows * cols * cols;
    (nb * flops_per) as f64 / t / 1e9
}

fn svd_rate(be: &dyn ComputeBackend, nb: usize, rows: usize, cols: usize) -> f64 {
    let mut rng = Prng::new(7);
    let a = rng.normal_vec(nb * rows * cols);
    let mut u = vec![0.0; nb * rows * cols];
    let mut s = vec![0.0; nb * cols];
    let mut v = vec![0.0; nb * cols * cols];
    let t = trimmed_mean_time(3, || {
        let mut mt = Metrics::new();
        be.batched_svd(nb, rows, cols, &a, &mut u, &mut s, &mut v, &mut mt);
    });
    (nb * 14 * rows * cols * cols) as f64 / t / 1e9
}

fn main() {
    println!("E9 / §6.1 — batched-kernel sustained rates (Gflop/s), native vs XLA AOT");
    let xla = if Path::new("artifacts/manifest.txt").exists() {
        Some(XlaBackend::new(Path::new("artifacts")).expect("loading artifacts"))
    } else {
        println!("(artifacts missing — run `make artifacts` to include the XLA column)");
        None
    };

    println!("\n-- batched GEMM --");
    println!("{:>6} {:>12} {:>12} {:>12}", "nb", "shape", "native", "xla");
    for &(nb, m, k, n) in &[(256usize, 32usize, 32usize, 32usize), (1024, 16, 16, 16), (256, 32, 16, 64)] {
        let nat = gemm_rate(&NativeBackend, nb, m, k, n);
        let x = xla.as_ref().map(|b| gemm_rate(b, nb, m, k, n));
        println!(
            "{:>6} {:>12} {:>12.3} {:>12}",
            nb,
            format!("{m}x{k}x{n}"),
            nat,
            x.map(|v| format!("{v:.3}")).unwrap_or_else(|| "-".into())
        );
    }

    println!("\n-- batched QR (rows x cols) --");
    println!("{:>6} {:>12} {:>12} {:>12}", "nb", "shape", "native", "xla");
    for &(nb, rows, cols) in &[(256usize, 32usize, 16usize), (64, 128, 16)] {
        let nat = qr_rate(&NativeBackend, nb, rows, cols);
        let x = xla.as_ref().map(|b| qr_rate(b, nb, rows, cols));
        println!(
            "{:>6} {:>12} {:>12.3} {:>12}",
            nb,
            format!("{rows}x{cols}"),
            nat,
            x.map(|v| format!("{v:.3}")).unwrap_or_else(|| "-".into())
        );
    }

    println!("\n-- batched SVD (rows x cols) --");
    println!("{:>6} {:>12} {:>12} {:>12}", "nb", "shape", "native", "xla");
    for &(nb, rows, cols) in &[(64usize, 16usize, 8usize)] {
        let nat = svd_rate(&NativeBackend, nb, rows, cols);
        let x = xla.as_ref().map(|b| svd_rate(b, nb, rows, cols));
        println!(
            "{:>6} {:>12} {:>12.3} {:>12}",
            nb,
            format!("{rows}x{cols}"),
            nat,
            x.map(|v| format!("{v:.3}")).unwrap_or_else(|| "-".into())
        );
    }
    println!("\n(The 32x16 SVD artifact is excluded: its unrolled Jacobi graph compiles");
    println!(" for minutes under XLA CPU — see DESIGN.md \"Substitutions\" for the stack notes.)");
}
