//! E7 (§6.1 text): sampled relative accuracy of the H² approximation and
//! the sparsity constants, as a function of the interpolation order g.
//! The paper reports 1e-7 at k=64 (2D, C_sp=17) and 1e-3 (3D, C_sp=30);
//! the trend here must show the same exponential accuracy improvement
//! with k and O(1) sparsity constants.

use h2opus::backend::native::NativeBackend;
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, dense_kernel_matrix, ExponentialKernel};
use h2opus::geometry::PointSet;
use h2opus::matvec::{hgemv, HgemvPlan, HgemvWorkspace};
use h2opus::metrics::Metrics;
use h2opus::obs::trajectory::{append_and_report, BenchRow};
use h2opus::util::testing::rel_err;
use h2opus::util::timer::Timer;
use h2opus::util::Prng;

fn sampled_accuracy(a: &h2opus::tree::H2Matrix, kernel: &ExponentialKernel, samples: usize) -> f64 {
    let n = a.n();
    let dense = dense_kernel_matrix(&a.tree, kernel);
    let mut rng = Prng::new(77);
    let plan = HgemvPlan::new(a, 1);
    let mut ws = HgemvWorkspace::new(a, 1);
    let mut mt = Metrics::new();
    let mut worst = 0.0_f64;
    for _ in 0..samples {
        let x = rng.normal_vec(n);
        let mut y_dense = vec![0.0; n];
        h2opus::linalg::gemm_nn(n, n, 1, &dense.data, &x, &mut y_dense, false);
        let mut y = vec![0.0; n];
        hgemv(a, &NativeBackend, &plan, &x, &mut y, &mut ws, &mut mt);
        worst = worst.max(rel_err(&y, &y_dense));
    }
    worst
}

fn main() {
    println!("E7 / §6.1 — sampled accuracy ||Ax - A_H2 x||/||Ax|| and sparsity constants");
    let wall = Timer::start();
    let mut row = BenchRow::new("accuracy", "2D N=1024 + 3D N=512 sweep");
    println!("\n== 2D exponential kernel (corr 0.1a, eta 0.9), N = 1024 ==");
    println!("{:>3} {:>5} {:>12} {:>6} {:>14}", "g", "k", "accuracy", "C_sp", "mem (% dense)");
    for g in [2usize, 3, 4, 5] {
        let points = PointSet::grid_2d(32, 1.0);
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 32, eta: 0.9, cheb_grid: g };
        let a = build_h2(points, &kernel, &cfg);
        let acc = sampled_accuracy(&a, &kernel, 5);
        row.set_metric(&format!("acc_2d_g{g}"), acc);
        println!(
            "{:>3} {:>5} {:>12.3e} {:>6} {:>14.1}",
            g,
            g * g,
            acc,
            a.sparsity_constant(),
            100.0 * a.memory_words() as f64 / (a.n() as f64 * a.n() as f64)
        );
    }

    println!("\n== 3D exponential kernel (corr 0.2a, eta 0.95), N = 512 ==");
    println!("{:>3} {:>5} {:>12} {:>6} {:>14}", "g", "k", "accuracy", "C_sp", "mem (% dense)");
    for g in [2usize, 3] {
        let points = PointSet::grid_3d(8, 1.0);
        let kernel = ExponentialKernel { dim: 3, corr_len: 0.2 };
        let cfg = H2Config { leaf_size: 32, eta: 0.95, cheb_grid: g };
        let a = build_h2(points, &kernel, &cfg);
        let acc = sampled_accuracy(&a, &kernel, 5);
        row.set_metric(&format!("acc_3d_g{g}"), acc);
        println!(
            "{:>3} {:>5} {:>12.3e} {:>6} {:>14.1}",
            g,
            g * g * g,
            acc,
            a.sparsity_constant(),
            100.0 * a.memory_words() as f64 / (a.n() as f64 * a.n() as f64)
        );
    }

    // O(N) memory growth (Fig. 11 right panel's "ideal growth" line)
    println!("\n== memory growth, 2D g=4 ==");
    println!("{:>8} {:>14} {:>16}", "N", "mem (KW)", "words/point");
    for side in [16usize, 32, 64, 128] {
        let points = PointSet::grid_2d(side, 1.0);
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 32, eta: 0.9, cheb_grid: 4 };
        let a = build_h2(points, &kernel, &cfg);
        println!(
            "{:>8} {:>14.1} {:>16.1}",
            a.n(),
            a.memory_words() as f64 / 1e3,
            a.memory_words() as f64 / a.n() as f64
        );
    }
    row.set_metric("sweep_s", wall.elapsed());
    append_and_report(&row);
}
