//! E3 + E8 (Fig. 11): weak scalability and effectiveness of algebraic
//! compression. Reports orthogonalization and compression virtual times
//! separately (as the paper does), pre/post low-rank memory and the
//! reduction factor, for the 2D (Chebyshev 6×6 seed, k=36) and 3D
//! (g=3 seed) test sets at τ = 1e-3.

use h2opus::backend::native::NativeBackend;
use h2opus::config::{H2Config, NetworkModel};
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::compress::dist_compress;
use h2opus::dist::ExecMode;
use h2opus::geometry::PointSet;
use h2opus::obs::trajectory::{append_and_report, BenchRow};
use h2opus::util::timer::trimmed_mean;

fn bench_set(dim: usize, local_n: usize, ps: &[usize], cfg: H2Config) {
    println!(
        "\n== {dim}D compression weak scaling, pN = {local_n}/rank, k_seed = {} , tau = 1e-3 ==",
        cfg.rank(dim)
    );
    println!(
        "{:>4} {:>9} {:>12} {:>12} {:>12} {:>12} {:>8}",
        "P", "N", "orth (ms)", "compr (ms)", "pre (KW)", "post (KW)", "ratio"
    );
    for &p in ps {
        let n_target = local_n * p;
        let (points, corr) = if dim == 2 {
            let side = (n_target as f64).sqrt().ceil() as usize;
            (PointSet::grid_2d(side, 1.0), 0.1)
        } else {
            let side = (n_target as f64).cbrt().ceil() as usize;
            (PointSet::grid_3d(side, 1.0), 0.2)
        };
        let kernel = ExponentialKernel { dim, corr_len: corr };
        let a = build_h2(points, &kernel, &cfg);
        if a.depth() < p.trailing_zeros() as usize {
            continue;
        }
        let mut orth_times = Vec::new();
        let mut comp_times = Vec::new();
        let mut stats = None;
        for _ in 0..3 {
            let mut b = a.clone();
            let (_, rep) = dist_compress(&mut b, p, 1e-3, &NativeBackend, NetworkModel::default(), ExecMode::Virtual);
            orth_times.push(rep.orthogonalization_time);
            comp_times.push(rep.compression_time);
            stats = Some(rep.stats);
        }
        let st = stats.unwrap();
        println!(
            "{:>4} {:>9} {:>12.2} {:>12.2} {:>12.1} {:>12.1} {:>8.2}",
            p,
            a.n(),
            trimmed_mean(&orth_times) * 1e3,
            trimmed_mean(&comp_times) * 1e3,
            st.pre_words as f64 / 1e3,
            st.post_words as f64 / 1e3,
            st.ratio()
        );
        let row = BenchRow::new("compression_weak", &format!("{dim}D pN={local_n} P={p}"))
            .metric("orth_ms", trimmed_mean(&orth_times) * 1e3)
            .metric("compress_ms", trimmed_mean(&comp_times) * 1e3)
            .metric("mem_ratio", st.ratio());
        append_and_report(&row);
    }
}

fn main() {
    println!("E3+E8 / Fig. 11 — compression weak scalability & memory reduction (virtual time)");
    // paper 2D: m=64, 6x6 Chebyshev seed (k=36), tau=1e-3
    bench_set(2, 2048, &[1, 4, 16], H2Config { leaf_size: 64, eta: 0.9, cheb_grid: 6 });
    // paper 3D: tri-cubic seed; scaled here to g=3 (k=27), m=32
    bench_set(3, 1024, &[1, 4, 8], H2Config { leaf_size: 64, eta: 0.95, cheb_grid: 3 });
}
