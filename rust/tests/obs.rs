//! Observability integration: happens-before monotonicity of merged
//! traces (a caused span never starts before its cause, on the inproc
//! *and* socket transports), deterministic merging modulo timestamps,
//! strict-JSON validity of every emitted trace, and the live stats
//! endpoint round trip.

use std::sync::Mutex;

use h2opus::backend::native::NativeBackend;
use h2opus::dist::hgemv::{dist_hgemv, DistOptions, ExecMode};
use h2opus::obs;
use h2opus::obs::names as obs_names;
use h2opus::util::testing::{parse_json, JsonValue};
use h2opus::util::Prng;

/// Tests in this file toggle the process-global span recorder and drain
/// its thread-local rings; serialize them (integration tests share one
/// process across #[test] threads).
static OBS_LOCK: Mutex<()> = Mutex::new(());

/// Enable recording with the rings drained; restore the disabled state
/// (and empty rings) on drop so unrelated tests see a clean recorder.
struct Recording;

impl Recording {
    fn start() -> Recording {
        obs::set_enabled(true);
        let _ = obs::drain();
        Recording
    }
}

impl Drop for Recording {
    fn drop(&mut self) {
        obs::set_enabled(false);
        let _ = obs::drain();
        obs::set_lane(obs::LANE_UNSET);
    }
}

/// Inproc happens-before: the threaded executor runs every rank in this
/// process on one clock, and each branch's `boundary merge` span opens
/// only after the master's `Parent` message arrives — which the master
/// sends inside its `yhat scatter` span. A merge span starting before
/// the scatter span would violate causality.
#[test]
fn inproc_boundary_merge_never_precedes_yhat_scatter() {
    let _g = OBS_LOCK.lock().unwrap();
    let _rec = Recording::start();

    let points = h2opus::geometry::PointSet::grid_2d(32, 1.0);
    let kernel = h2opus::construct::ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = h2opus::config::H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
    let a = h2opus::construct::build_h2(points, &kernel, &cfg);
    let n = a.n();
    let mut rng = Prng::new(501);
    let x = rng.normal_vec(n);
    let mut y = vec![0.0; n];
    let p = 4;
    let opts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
    let _ = dist_hgemv(&a, &NativeBackend, p, 1, &x, &mut y, &opts);

    let (spans, dropped) = obs::drain();
    assert_eq!(dropped, 0, "ring overflow on a tiny product");
    let scatter_start = spans
        .iter()
        .filter(|s| s.name == obs_names::YHAT_SCATTER)
        .map(|s| s.start_ns)
        .min()
        .expect("master must record a yhat scatter span");
    let merges: Vec<_> =
        spans.iter().filter(|s| s.name == obs_names::BOUNDARY_MERGE).collect();
    assert!(!merges.is_empty(), "branches must record boundary merge spans");
    assert_eq!(
        merges.iter().map(|s| s.lane).collect::<std::collections::BTreeSet<_>>().len(),
        p,
        "every branch rank records its own merge"
    );
    for m in &merges {
        assert!(
            m.start_ns >= scatter_start,
            "rank {}: boundary merge at {} ns precedes the master's yhat scatter at {} ns",
            m.lane,
            m.start_ns,
            scatter_start
        );
    }
    // Branch phases also recorded, labeled with the rank's lane.
    for name in [obs_names::UPSWEEP, obs_names::DOWNSWEEP, obs_names::BOUNDARY_WAIT] {
        assert!(
            spans.iter().any(|s| s.name == name && s.lane < p as u32),
            "missing branch span {}",
            obs_names::info(name).label
        );
    }
}

/// Merging is deterministic modulo timestamps: span order within a part
/// and part order within the merge must not change the rendered JSON.
#[test]
fn merged_trace_deterministic_under_reordering() {
    let mk = |name, lane, tid, start, dur, arg| obs::Span {
        name,
        lane,
        tid,
        start_ns: start,
        dur_ns: dur,
        arg,
    };
    let coord = vec![
        mk(obs_names::SHIP_INPUT, obs::LANE_UNSET, 0, 1_000, 4_000, 0),
        mk(obs_names::COLLECT_OUTPUT, obs::LANE_UNSET, 0, 9_000, 2_000, 0),
    ];
    let worker = vec![
        mk(obs_names::PRODUCT, obs::LANE_UNSET, 1, 6_000, 2_500, 0),
        mk(obs_names::BATCH_GEMM, obs::LANE_UNSET, 1, 6_200, 300, 17),
    ];
    let part = |pid, offset, spans: &[obs::Span]| obs::TracePart {
        default_pid: pid,
        offset_ns: offset,
        spans: spans.to_vec(),
        ..obs::TracePart::default()
    };
    let forward = obs::merged_trace_json(&[part(2, 0, &coord), part(0, 500, &worker)]);
    let mut coord_rev = coord.clone();
    coord_rev.reverse();
    let mut worker_rev = worker.clone();
    worker_rev.reverse();
    let shuffled =
        obs::merged_trace_json(&[part(0, 500, &worker_rev), part(2, 0, &coord_rev)]);
    assert_eq!(forward, shuffled, "merge must not depend on input order");

    let parsed = parse_json(&forward).expect("merged trace must be strict JSON");
    let events = parsed
        .get("traceEvents")
        .and_then(JsonValue::as_arr)
        .expect("object form with a traceEvents array");
    assert_eq!(events.len(), 4);
    assert!(parsed.get("metadata").is_some(), "metadata block present");
    // The worker's offset (+500ns, worker clock ahead) maps its product
    // onto the coordinator timeline: 6_000 - 500 = 5_500ns = 5.5us.
    let product = events
        .iter()
        .find(|e| e.get("name").and_then(JsonValue::as_str) == Some("product #0"))
        .expect("product event present");
    assert_eq!(product.get("ts").unwrap().as_f64(), Some(5.5));
    assert_eq!(product.get("pid").unwrap().as_f64(), Some(0.0));
    assert!(events
        .iter()
        .any(|e| e.get("name").and_then(JsonValue::as_str) == Some("batch gemm x17")));
}

#[cfg(unix)]
mod socket {
    use super::*;
    use std::path::PathBuf;

    use h2opus::dist::transport::server::{
        fetch_stats, ServerOptions, SessionServer, StatsEndpoint,
    };
    use h2opus::dist::transport::socket::{SocketOptions, SocketSession};
    use h2opus::dist::transport::{JobKind, MatrixJob};

    fn conformance_job() -> MatrixJob {
        MatrixJob {
            dim: 2,
            n_side: 16,
            leaf_size: 16,
            eta: 0.9,
            cheb_grid: 3,
            corr_len: 0.1,
            kind: JobKind::Exponential,
        }
    }

    /// Worker subprocesses inherit recording through `H2OPUS_OBS`.
    fn traced_opts() -> SocketOptions {
        SocketOptions {
            worker_exe: PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
            extra_env: vec![(obs::OBS_ENV.into(), "1".into())],
            ..SocketOptions::default()
        }
    }

    /// Pull every event of a merged trace as `(name, pid, ts_us)`.
    fn events_of(json: &str) -> Vec<(String, usize, f64)> {
        let parsed = parse_json(json).expect("merged trace must be strict JSON");
        parsed
            .get("traceEvents")
            .and_then(JsonValue::as_arr)
            .expect("object form with a traceEvents array")
            .iter()
            .map(|e| {
                (
                    e.get("name").unwrap().as_str().unwrap().to_string(),
                    e.get("pid").unwrap().as_f64().unwrap() as usize,
                    e.get("ts").unwrap().as_f64().unwrap(),
                )
            })
            .collect()
    }

    /// Socket happens-before: each worker's `product #pid` span opens
    /// only after the coordinator ships that product's input, so on the
    /// merged (clock-aligned) timeline it must not start before the
    /// coordinator's `ship input #pid` span. Also checks the merged
    /// trace covers both processes' spans end to end: request transfer
    /// on the coordinator, HGEMV phases and compression sub-steps on
    /// the workers.
    #[test]
    fn socket_merged_trace_happens_before_and_coverage() {
        let _g = OBS_LOCK.lock().unwrap();
        let _rec = Recording::start();
        let p = 2usize;
        let job = conformance_job();
        let mut session =
            SocketSession::start(&job, p, 1, traced_opts()).expect("session start");
        let n = session.n();
        let mut rng = Prng::new(502);
        let x = rng.normal_vec(n);
        let mut y = vec![0.0; n];
        session.hgemv(&x, &mut y).expect("traced product");
        session.compress(1e-3).expect("traced compression");
        let json = session.collect_spans().expect("span flush");
        let events = events_of(&json);

        let ship = events
            .iter()
            .find(|(name, pid, _)| name == "ship input #0" && *pid == p)
            .unwrap_or_else(|| panic!("coordinator ship-input span missing"));
        for rank in 0..p {
            let product = events
                .iter()
                .find(|(name, pid, _)| name == "product #0" && *pid == rank)
                .unwrap_or_else(|| panic!("rank {rank} product span missing"));
            assert!(
                product.2 >= ship.2,
                "rank {rank}: product at {} us precedes ship input at {} us on the \
                 merged timeline (clock alignment broken)",
                product.2,
                ship.2
            );
        }
        // Coverage: worker HGEMV phases, compression compute sub-steps
        // per level, compression wire steps, and the coordinator's
        // collect side all present under their worker/coordinator pids.
        for (needle, pid) in [
            ("upsweep", 0),
            ("downsweep", 1),
            ("orth leaf qr", 0),
            ("truncate leaf", 1),
            ("cmp sigma reduce L", 0),
            ("collect output #0", p),
            ("span flush", p),
        ] {
            assert!(
                events.iter().any(|(name, epid, _)| name.starts_with(needle) && *epid == pid),
                "merged trace lacks '{needle}' under pid {pid}"
            );
        }
        // Leveled sub-steps render their level.
        assert!(
            events.iter().any(|(name, _, _)| name.starts_with("orth transfer L")),
            "leveled compression span missing"
        );

        // Metadata block: one part per process, with the product's work
        // counters embedded for drift pricing.
        let parsed = parse_json(&json).expect("strict JSON");
        let meta = parsed.get("metadata").expect("metadata block");
        let parts = meta.get("parts").unwrap().as_arr().unwrap();
        assert_eq!(parts.len(), p + 1, "one metadata part per process");
        assert!(
            parts.iter().any(|e| e.get("work").is_some()),
            "work counters embedded for drift analysis"
        );

        // End-to-end: the analyzer consumes this exact trace and reports
        // per-rank overlap efficiency, a named critical-path phase, and
        // cost-model drift priced from the embedded counters.
        let cm = h2opus::dist::hgemv::CostModel::default();
        let analysis = h2opus::obs::analyze_json(&json, &cm).expect("trace analysis");
        assert_eq!(analysis.ranks.len(), p + 1, "a report row per process");
        let eff = analysis.min_overlap_eff();
        assert!((0.0..=1.0).contains(&eff), "overlap efficiency {eff} out of range");
        assert!(
            !analysis.critical_path.bound_phase.is_empty(),
            "critical path must name its bounding phase"
        );
        assert!(!analysis.drift.is_empty(), "drift rows priced from work counters");
        assert_eq!(analysis.total_dropped, 0, "tiny run must not overflow rings");
        let report = analysis.render_text(10);
        assert!(report.contains("critical path"), "{report}");
        assert!(report.contains("overlap"), "{report}");
    }

    /// The stats endpoint round trip: a live server answers `Stats`
    /// requests over its control socket with the summary line plus the
    /// Prometheus-style registry rendering.
    #[test]
    fn stats_endpoint_serves_live_registry() {
        let _g = OBS_LOCK.lock().unwrap();
        let job = conformance_job();
        let server = SessionServer::start(
            &job,
            2,
            traced_opts(),
            ServerOptions { max_coalesce: 4, pipeline_depth: 2 },
        )
        .expect("server start");
        let n = server.n();
        let mut rng = Prng::new(503);
        for _ in 0..3 {
            let x = rng.normal_vec(n);
            server.submit(&x).expect("submit").wait().expect("serve");
        }

        let sock = std::env::temp_dir().join(format!("h2opus-stats-test-{}.sock", std::process::id()));
        let endpoint = StatsEndpoint::bind(&sock).expect("bind stats socket");
        let client = std::thread::spawn({
            let sock = sock.clone();
            move || fetch_stats(&sock)
        });
        let mut served = 0usize;
        while served == 0 {
            served = endpoint.poll(&server).expect("poll stats socket");
            if served == 0 {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
        }
        let text = client.join().expect("client thread").expect("stats fetch");
        std::fs::remove_file(&sock).ok();

        assert!(text.starts_with("# h2opus served 3 reqs"), "summary first: {text}");
        assert!(text.contains("queue wait p50"), "summary carries queue-wait percentiles");
        for metric in [
            "h2opus_server_products_total",
            "h2opus_server_requests_total 3",
            "h2opus_request_queue_wait_seconds_count 3",
        ] {
            assert!(text.contains(metric), "exposition lacks '{metric}':\n{text}");
        }
    }
}
