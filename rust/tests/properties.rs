//! Property-based tests (in-tree mini-harness, see util::testing):
//! randomized structural invariants of the coordinator layers — cluster
//! trees, admissibility structures, exchange plans, marshaling batches —
//! and algebraic invariants of the H^2 operations over random geometries.

use h2opus::admissibility::MatrixStructure;
use h2opus::backend::native::NativeBackend;
use h2opus::clustering::ClusterTree;
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::plan::ExchangePlan;
use h2opus::dist::Decomposition;
use h2opus::geometry::PointSet;
use h2opus::matvec::{HgemvPlan, HgemvWorkspace};
use h2opus::metrics::Metrics;
use h2opus::util::testing::{check, rel_err};
use h2opus::util::Prng;

fn random_points(rng: &mut Prng, min_n: usize, max_n: usize, dim: usize) -> PointSet {
    let n = min_n + rng.below(max_n - min_n);
    let mut ps = PointSet::new(dim);
    for _ in 0..n {
        let p: Vec<f64> = (0..dim).map(|_| rng.uniform()).collect();
        ps.push(&p);
    }
    ps
}

#[test]
fn prop_cluster_tree_partitions_points() {
    check("cluster-tree-partition", 0xC0FFEE, 25, |rng| {
        let dim = 1 + rng.below(3);
        (random_points(rng, 10, 400, dim), 4 + rng.below(29))
    }, |(ps, leaf)| {
        let n = ps.len();
        let t = ClusterTree::build(ps.clone(), *leaf);
        // perm is a permutation
        let mut seen = vec![false; n];
        for &p in &t.perm {
            if seen[p] {
                return Err(format!("duplicate perm entry {p}"));
            }
            seen[p] = true;
        }
        // every level's nodes partition [0, n)
        for l in 0..=t.depth {
            let mut covered = 0;
            for j in 0..t.nodes_at(l) {
                let node = t.node(l, j);
                if node.start != covered {
                    return Err(format!("gap at level {l} node {j}"));
                }
                covered = node.end;
            }
            if covered != n {
                return Err(format!("level {l} covers {covered} != {n}"));
            }
        }
        // leaf size bound
        if t.max_leaf_size() > *leaf {
            return Err(format!("leaf size {} > {}", t.max_leaf_size(), leaf));
        }
        Ok(())
    });
}

#[test]
fn prop_structure_partitions_and_csp_bounded() {
    check("structure-partition", 0xBEEF, 15, |rng| {
        let ps = random_points(rng, 64, 300, 2);
        let eta = rng.range(0.4, 1.5);
        (ps, eta)
    }, |(ps, eta)| {
        let t = ClusterTree::build(ps.clone(), 16);
        let s = MatrixStructure::build(&t, &t, *eta);
        s.validate_partition(&t, &t)?;
        if s.sparsity_constant() > 200 {
            return Err(format!("C_sp exploded: {}", s.sparsity_constant()));
        }
        Ok(())
    });
}

#[test]
fn prop_exchange_plans_complete_and_minimal() {
    check("exchange-plan", 0xD15C0, 10, |rng| {
        let ps = random_points(rng, 256, 700, 2);
        let p = 1usize << (1 + rng.below(3)); // 2, 4, 8
        (ps, p)
    }, |(ps, p)| {
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 2 };
        let a = build_h2(ps.clone(), &kernel, &cfg);
        if a.depth() < p.trailing_zeros() as usize {
            return Ok(()); // tree too shallow for this P
        }
        let d = Decomposition::new(*p, a.depth()).unwrap();
        let plan = ExchangePlan::build(&a, d);
        // completeness: every off-diagonal block's column node is receivable
        for (l, cl) in a.coupling.iter().enumerate() {
            if l < d.c_level {
                continue;
            }
            for &(t, s) in &cl.pairs {
                let (pt, ps_) = (d.owner(l, t as usize), d.owner(l, s as usize));
                if pt != ps_ {
                    let ok = plan.levels[l].recv[pt]
                        .iter()
                        .any(|(src, nodes)| *src == ps_ && nodes.contains(&s));
                    if !ok {
                        return Err(format!("missing ({t},{s})@{l}"));
                    }
                }
            }
        }
        // minimality: nothing in a recv list that no block needs
        for (l, le) in plan.levels.iter().enumerate() {
            for (pt, lists) in le.recv.iter().enumerate() {
                for (_, nodes) in lists {
                    for s in nodes {
                        let needed = a.coupling[l].pairs.iter().any(|&(t, ss)| {
                            ss == *s && d.owner(l, t as usize) == pt
                        });
                        if !needed {
                            return Err(format!("unneeded node {s}@{l} for rank {pt}"));
                        }
                    }
                }
            }
        }
        // volume below naive for P > 1
        if *p > 1 {
            for r in 0..*p {
                if plan.bytes_into(&a, r, 1) > plan.naive_bytes_into(&a, r, 1) {
                    return Err("optimized volume above naive".into());
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_hgemv_transpose_symmetry() {
    // For our symmetric kernels, A = Aᵀ, so xᵀ(Ay) == yᵀ(Ax) must hold to
    // rounding for arbitrary x, y — a strong end-to-end algebraic check on
    // all phases (upsweep/coupling/downsweep consistency between U and V).
    check("hgemv-symmetry", 0xFACE, 8, |rng| {
        let ps = random_points(rng, 100, 400, 2);
        let seed = rng.next_u64();
        (ps, seed)
    }, |(ps, seed)| {
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.2 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
        let a = build_h2(ps.clone(), &kernel, &cfg);
        let n = a.n();
        let mut rng = Prng::new(*seed);
        let x = rng.normal_vec(n);
        let y = rng.normal_vec(n);
        let plan = HgemvPlan::new(&a, 1);
        let mut ws = HgemvWorkspace::new(&a, 1);
        let mut mt = Metrics::new();
        let mut ax = vec![0.0; n];
        let mut ay = vec![0.0; n];
        h2opus::matvec::hgemv(&a, &NativeBackend, &plan, &x, &mut ax, &mut ws, &mut mt);
        h2opus::matvec::hgemv(&a, &NativeBackend, &plan, &y, &mut ay, &mut ws, &mut mt);
        let xt_ay: f64 = x.iter().zip(&ay).map(|(a, b)| a * b).sum();
        let yt_ax: f64 = y.iter().zip(&ax).map(|(a, b)| a * b).sum();
        let scale = xt_ay.abs().max(yt_ax.abs()).max(1e-300);
        if ((xt_ay - yt_ax) / scale).abs() > 1e-10 {
            return Err(format!("symmetry violated: {xt_ay} vs {yt_ax}"));
        }
        Ok(())
    });
}

#[test]
fn prop_distributed_equals_single_rank() {
    check("dist-vs-single", 0xABCD, 6, |rng| {
        let ps = random_points(rng, 300, 600, 2);
        let p = 1usize << (1 + rng.below(3));
        let seed = rng.next_u64();
        (ps, p, seed)
    }, |(ps, p, seed)| {
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
        let a = build_h2(ps.clone(), &kernel, &cfg);
        if a.depth() < p.trailing_zeros() as usize {
            return Ok(());
        }
        let n = a.n();
        let mut rng = Prng::new(*seed);
        let x = rng.normal_vec(n);
        let plan = HgemvPlan::new(&a, 1);
        let mut ws = HgemvWorkspace::new(&a, 1);
        let mut mt = Metrics::new();
        let mut y1 = vec![0.0; n];
        h2opus::matvec::hgemv(&a, &NativeBackend, &plan, &x, &mut y1, &mut ws, &mut mt);
        let mut yp = vec![0.0; n];
        let opts = h2opus::dist::hgemv::DistOptions::default();
        h2opus::dist::hgemv::dist_hgemv(&a, &NativeBackend, *p, 1, &x, &mut yp, &opts);
        let err = rel_err(&yp, &y1);
        if err > 1e-11 {
            return Err(format!("P={p}: dist vs single err {err}"));
        }
        Ok(())
    });
}

#[test]
fn prop_compression_error_bounded_by_tau() {
    check("compress-error", 0x7A0, 5, |rng| {
        let ps = random_points(rng, 200, 400, 2);
        let tau_exp = 3 + rng.below(4) as i32; // 1e-3 .. 1e-6
        let seed = rng.next_u64();
        (ps, tau_exp, seed)
    }, |(ps, tau_exp, seed)| {
        let tau = 10f64.powi(-*tau_exp);
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        // leaf_size must cover the rank (g=4 -> k=16) even for the padded
        // leaves of irregular point counts, so use 24 > 16
        let cfg = H2Config { leaf_size: 24, eta: 0.9, cheb_grid: 4 };
        let mut a = build_h2(ps.clone(), &kernel, &cfg);
        if a.tree.max_leaf_size() < cfg.rank(2) {
            return Ok(()); // degenerate tiny tree
        }
        let n = a.n();
        let mut rng = Prng::new(*seed);
        let x = rng.normal_vec(n);
        let before = h2opus::matvec::apply_original_order(&a, &NativeBackend, &x, 1);
        let mut mt = Metrics::new();
        let (c, stats) = h2opus::compression::compress_full(&mut a, tau, &NativeBackend, &mut mt);
        let after = h2opus::matvec::apply_original_order(&c, &NativeBackend, &x, 1);
        let err = rel_err(&after, &before);
        if err > tau * 500.0 {
            return Err(format!("tau={tau:e}: err {err} (ratio {})", stats.ratio()));
        }
        if stats.post_words > stats.pre_words {
            return Err("compression grew memory".into());
        }
        Ok(())
    });
}
