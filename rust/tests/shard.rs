//! `dist::shard` conformance suite: branch-scoped construction must be
//! bit-identical to slicing a global build; per-rank matrix storage must
//! actually be O(N/P) + replicated-top slack; sharded HGEMV must stay
//! bitwise serial-identical on both executors while workers never
//! materialize the global matrix (enforced by the
//! `H2OPUS_FORBID_FULL_MATRIX` guard); and the persistent socket session
//! must amortize worker spawn across products — including a full CG
//! solve driving one session.

use h2opus::backend::native::NativeBackend;
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::hgemv::{dist_hgemv, DistOptions, ExecMode};
#[cfg(unix)]
use h2opus::dist::transport::socket::{socket_hgemv, SocketOptions, SocketSession};
use h2opus::dist::transport::{JobKind, MatrixJob};
use h2opus::dist::{Decomposition, ShardedMatrix};
use h2opus::geometry::PointSet;
use h2opus::matvec::{hgemv, HgemvPlan, HgemvWorkspace};
use h2opus::metrics::Metrics;
use h2opus::util::Prng;

/// The conformance matrix: N = 256, depth 4 (so P = 8 splits at C = 3).
fn conformance_job() -> MatrixJob {
    MatrixJob {
        dim: 2,
        n_side: 16,
        leaf_size: 16,
        eta: 0.9,
        cheb_grid: 3,
        corr_len: 0.1,
        kind: JobKind::Exponential,
    }
}

fn serial_product(a: &h2opus::tree::H2Matrix, x: &[f64], nv: usize) -> Vec<f64> {
    let n = a.n();
    let plan = HgemvPlan::new(a, nv);
    let mut ws = HgemvWorkspace::new(a, nv);
    let mut metrics = Metrics::new();
    let mut y = vec![0.0; n * nv];
    hgemv(a, &NativeBackend, &plan, x, &mut y, &mut ws, &mut metrics);
    y
}

fn assert_shards_equal(a: &ShardedMatrix, b: &ShardedMatrix, what: &str) {
    assert_eq!(a.rank, b.rank, "{what}: rank");
    assert_eq!(a.decomp, b.decomp, "{what}: decomp");
    assert_eq!(a.u_ranks, b.u_ranks, "{what}: u_ranks");
    assert_eq!(a.v_ranks, b.v_ranks, "{what}: v_ranks");
    assert_eq!(a.leaf_dim, b.leaf_dim, "{what}: leaf_dim");
    assert_eq!(a.leaf_range, b.leaf_range, "{what}: leaf_range");
    assert_eq!(a.leaf_sizes, b.leaf_sizes, "{what}: leaf_sizes");
    assert_eq!(a.u_leaf_bases, b.u_leaf_bases, "{what}: u leaf bases");
    assert_eq!(a.v_leaf_bases, b.v_leaf_bases, "{what}: v leaf bases");
    assert_eq!(a.u_transfers, b.u_transfers, "{what}: u transfers");
    assert_eq!(a.v_transfers, b.v_transfers, "{what}: v transfers");
    assert_eq!(a.top_u_transfers, b.top_u_transfers, "{what}: top u transfers");
    assert_eq!(a.top_v_transfers, b.top_v_transfers, "{what}: top v transfers");
    assert_eq!(a.top_coupling.len(), b.top_coupling.len(), "{what}: top levels");
    for (l, (ca, cb)) in a.top_coupling.iter().zip(&b.top_coupling).enumerate() {
        assert_eq!(ca.pairs, cb.pairs, "{what}: top coupling pairs L{l}");
        assert_eq!(ca.data, cb.data, "{what}: top coupling data L{l}");
    }
    for l in 0..a.coupling.len() {
        let (ca, cb) = (&a.coupling[l], &b.coupling[l]);
        assert_eq!(ca.row_start, cb.row_start, "{what}: coupling row_start L{l}");
        assert_eq!(ca.level.pairs, cb.level.pairs, "{what}: coupling pairs L{l}");
        assert_eq!(ca.level.batches, cb.level.batches, "{what}: coupling batches L{l}");
        assert_eq!(ca.level.data, cb.level.data, "{what}: coupling data L{l}");
    }
    assert_eq!(a.dense.row_start, b.dense.row_start, "{what}: dense row_start");
    assert_eq!(a.dense.blocks.pairs, b.dense.blocks.pairs, "{what}: dense pairs");
    assert_eq!(a.dense.blocks.data, b.dense.blocks.data, "{what}: dense data");
}

/// Branch-scoped construction (what a worker runs, no global matrix)
/// must produce bit-identical shards to slicing a global build — for the
/// exponential test set and for the fractional solver kernel.
#[test]
fn branch_construction_matches_global_slicing() {
    let jobs = vec![
        conformance_job(),
        MatrixJob {
            dim: 2,
            n_side: 16,
            leaf_size: 16,
            eta: 0.9,
            cheb_grid: 4,
            corr_len: 0.0,
            kind: JobKind::Fractional { beta: 0.75 },
        },
    ];
    for job in jobs {
        let a = job.build();
        for p in [1usize, 2, 4] {
            let d = Decomposition::new(p, a.depth()).unwrap();
            for r in 0..p {
                let (direct, structure) =
                    job.build_branch(p, r).expect("branch construction");
                let sliced = ShardedMatrix::from_global(&a, d, r);
                assert_shards_equal(&direct, &sliced, &format!("{:?} P={p} rank {r}", job.kind));
                // The returned structure is the global one.
                assert_eq!(structure.dense, a.dense.pairs);
            }
            let (top_direct, _) = job.build_top(p).expect("top construction");
            let top_sliced = ShardedMatrix::top_from_global(&a, d);
            assert_shards_equal(&top_direct, &top_sliced, &format!("{:?} P={p} top", job.kind));
        }
    }
}

/// Out-of-core memory regression: per-rank matrix storage must fit in
/// serial/P plus the replicated-top + structural-imbalance slack, the
/// shards must exactly partition the serial matrix, and the per-rank
/// maximum must shrink as P grows.
#[test]
fn per_rank_matrix_storage_is_o_n_over_p() {
    // N = 1024, depth 6 — big enough that the replicated top is small
    // against 1/P.
    let points = PointSet::grid_2d(32, 1.0);
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
    let a = build_h2(points, &kernel, &cfg);
    let serial_bytes = a.memory_words() * 8;
    let mut prev_max = serial_bytes + 1;
    for p in [2usize, 4, 8] {
        let d = Decomposition::new(p, a.depth()).unwrap();
        let shards: Vec<ShardedMatrix> =
            (0..p).map(|r| ShardedMatrix::from_global(&a, d, r)).collect();
        // Partition identity: branch storage sums to the serial matrix
        // minus one copy of the replicated top.
        let branch_total: usize = shards.iter().map(|s| s.branch_words()).sum();
        let rep = shards[0].replication_words();
        assert_eq!(branch_total + rep, a.memory_words(), "P={p}: not a partition");
        for (r, s) in shards.iter().enumerate() {
            // serial/P + replicated-top/imbalance slack (imbalance is the
            // structure-dictated excess of this rank's rows over the even
            // share — C_sp variance, not shard overhead).
            let imbalance = s.branch_words().saturating_sub(branch_total / p);
            let slack = (rep + imbalance) * 8;
            assert!(
                s.matrix_bytes() <= serial_bytes / p + slack,
                "P={p} rank {r}: {} B > serial/P {} B + slack {} B",
                s.matrix_bytes(),
                serial_bytes / p,
                slack
            );
            assert!(
                s.matrix_bytes() < serial_bytes * 3 / 4,
                "P={p} rank {r}: shard not materially smaller than serial"
            );
            if p <= 4 {
                assert!(
                    slack < serial_bytes / p,
                    "P={p} rank {r}: slack {slack} B dominates serial/P — bound vacuous"
                );
            }
        }
        let max_bytes = shards.iter().map(|s| s.matrix_bytes()).max().unwrap();
        assert!(
            max_bytes < prev_max,
            "P={p}: peak shard {max_bytes} B did not shrink (prev {prev_max} B)"
        );
        prev_max = max_bytes;
    }
}

/// Sharded HGEMV stays bitwise serial-identical on the in-process
/// executor (which slices shards from the global matrix) and the socket
/// transport (whose workers construct shards branch-scoped under the
/// full-matrix guard), and both report the peak per-rank matrix bytes.
#[test]
fn sharded_hgemv_bitwise_identical_and_reports_matrix_bytes() {
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let serial_bytes = (a.memory_words() * 8) as u64;
    let mut rng = Prng::new(910);
    let nv = 2;
    let x = rng.normal_vec(n * nv);
    let y_serial = serial_product(&a, &x, nv);

    // In-process threaded executor over from_global shards.
    let topts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
    for p in [1usize, 2, 4, 8] {
        let mut y = vec![0.0; n * nv];
        let rep = dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y, &topts);
        assert_eq!(y, y_serial, "inproc P={p} not bitwise equal");
        let mb = rep.metrics.matrix_bytes;
        assert!(mb > 0, "inproc P={p}: matrix bytes not reported");
        let d = Decomposition::new(p, a.depth()).unwrap();
        let expect =
            (0..p).map(|r| ShardedMatrix::from_global(&a, d, r).matrix_bytes() as u64).max();
        assert_eq!(mb, expect.unwrap(), "inproc P={p}: peak shard bytes mismatch");
        if p >= 4 {
            assert!(mb < serial_bytes, "inproc P={p}: shard not below serial");
        }
    }

    // Socket transport: worker subprocesses with branch-built shards.
    #[cfg(unix)]
    {
        let opts = SocketOptions {
            worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
            ..SocketOptions::default()
        };
        for p in [1usize, 2, 4, 8] {
            let mut y = vec![0.0; n * nv];
            let rep = socket_hgemv(&job, p, nv, &x, &mut y, &opts)
                .unwrap_or_else(|e| panic!("socket P={p}: {e}"));
            assert_eq!(y, y_serial, "socket P={p} not bitwise equal");
            let d = Decomposition::new(p, a.depth()).unwrap();
            let expect = (0..p)
                .map(|r| ShardedMatrix::from_global(&a, d, r).matrix_bytes() as u64)
                .max()
                .unwrap();
            assert_eq!(
                rep.metrics.matrix_bytes, expect,
                "socket P={p}: workers must report their shard footprint"
            );
        }
    }
}

/// A worker that constructs the full matrix must abort the session with
/// an error (the `H2OPUS_FORBID_FULL_MATRIX` guard the coordinator sets),
/// promptly — not hang, not silently hold O(N) memory.
#[cfg(unix)]
#[test]
fn worker_full_matrix_build_fails_the_session() {
    use std::time::{Duration, Instant};
    let job = conformance_job();
    let n = job.n_points();
    let x = vec![1.0; n];
    let mut y = vec![0.0; n];
    let opts = SocketOptions {
        worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        timeout: Duration::from_secs(30),
        extra_env: vec![("H2OPUS_TEST_FORCE_FULL_BUILD".into(), "1".into())],
        ..SocketOptions::default()
    };
    let t0 = Instant::now();
    let err = socket_hgemv(&job, 2, 1, &x, &mut y, &opts)
        .expect_err("a worker that builds the global matrix must fail the product");
    let elapsed = t0.elapsed();
    assert!(elapsed < Duration::from_secs(25), "guard took {elapsed:?} — behaved like a hang");
    let msg = err.to_string();
    assert!(
        msg.contains("closed") || msg.contains("exited") || msg.contains("timeout"),
        "error must name the failure: {msg}"
    );
}

/// The persistent session serves many bitwise-correct products from one
/// worker spawn.
#[cfg(unix)]
#[test]
fn socket_session_amortizes_spawn_across_products() {
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let opts = SocketOptions {
        worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        ..SocketOptions::default()
    };
    let mut session = SocketSession::start(&job, 4, 1, opts).expect("session start");
    assert_eq!(session.ranks(), 4);
    assert_eq!(session.n(), n);
    let mut rng = Prng::new(911);
    for round in 0..3 {
        let x = rng.normal_vec(n);
        let y_serial = serial_product(&a, &x, 1);
        let mut y = vec![0.0; n];
        let rep = session.hgemv(&x, &mut y).expect("session product");
        assert_eq!(y, y_serial, "round {round} not bitwise equal");
        assert!(rep.measured > 0.0);
    }
    assert_eq!(session.products(), 3, "same workers must have served every product");
}

/// The fractional-diffusion CG solve over one persistent session: the
/// kernel matrix lives sharded in the worker processes for the whole
/// iteration history (one spawn, one branch-scoped construction, many
/// products), and the solve still converges to a physical solution.
#[cfg(unix)]
#[test]
fn session_solver_converges_with_one_spawn() {
    use h2opus::apps::fractional::{setup, solve_with_session, FractionalProblem};
    let problem = FractionalProblem {
        n_side: 16,
        beta: 0.75,
        h2: H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 4 },
        tau: 1e-6,
        ranks: 2,
    };
    let n_side = problem.n_side;
    let mut sys = setup(problem.clone(), &NativeBackend);
    let opts = SocketOptions {
        worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        ..SocketOptions::default()
    };
    let mut session =
        SocketSession::start(&problem.matrix_job(), 2, 1, opts).expect("session start");
    let sol = solve_with_session(&mut sys, &mut session, 1e-6);
    assert!(sol.result.converged, "session CG did not converge ({} its)", sol.result.iterations);
    // One distributed product per operator application, all on the same
    // spawned workers.
    assert!(
        session.products() >= sol.result.iterations as u64,
        "products {} < iterations {}",
        session.products(),
        sol.result.iterations
    );
    // Physics: u > 0 inside, decaying toward the constrained boundary.
    let center = (n_side / 2) * n_side + n_side / 2;
    assert!(sol.u[center] > 0.0, "u(center) = {}", sol.u[center]);
    assert!(sol.u[0] < sol.u[center]);
}
