//! Distributed-compression conformance suite: compressing an operator
//! that only ever exists as per-rank shards must be *bitwise identical*
//! to serial [`compress_full`] followed by re-sharding — every basis,
//! transfer, coupling block, rank vector and the reported stats — for
//! P ∈ {1, 2, 4, 8} on the in-process transport and for live worker
//! subprocesses over the socket transport (where every rank runs under
//! the `H2OPUS_FORBID_FULL_MATRIX` guard, so no process ever holds the
//! global matrix). A worker crash mid-compression must poison the
//! session cleanly, and compressed per-rank storage must stay O(N/P).

use h2opus::backend::native::NativeBackend;
use h2opus::compression::{compress_full, CompressionStats};
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::transport::{JobKind, MatrixJob};
use h2opus::dist::{compress_sharded, Decomposition, ShardedMatrix};
use h2opus::geometry::PointSet;
use h2opus::metrics::Metrics;

const TAU: f64 = 1e-4;

/// The conformance matrix: N = 256, depth 4 (so P = 8 splits at C = 3).
fn conformance_job() -> MatrixJob {
    MatrixJob {
        dim: 2,
        n_side: 16,
        leaf_size: 16,
        eta: 0.9,
        cheb_grid: 3,
        corr_len: 0.1,
        kind: JobKind::Exponential,
    }
}

/// The fractional solver's kernel, so the suite covers the operator the
/// session solver actually compresses.
fn fractional_job() -> MatrixJob {
    MatrixJob {
        dim: 2,
        n_side: 16,
        leaf_size: 16,
        eta: 0.9,
        cheb_grid: 4,
        corr_len: 0.0,
        kind: JobKind::Fractional { beta: 0.75 },
    }
}

/// Serial reference: compress a clone of `a` with [`compress_full`].
fn serial_compress(a: &h2opus::tree::H2Matrix) -> (h2opus::tree::H2Matrix, CompressionStats) {
    let mut work = a.clone();
    let mut metrics = Metrics::new();
    compress_full(&mut work, TAU, &NativeBackend, &mut metrics)
}

fn assert_shards_equal(a: &ShardedMatrix, b: &ShardedMatrix, what: &str) {
    assert_eq!(a.rank, b.rank, "{what}: rank");
    assert_eq!(a.decomp, b.decomp, "{what}: decomp");
    assert_eq!(a.u_ranks, b.u_ranks, "{what}: u_ranks");
    assert_eq!(a.v_ranks, b.v_ranks, "{what}: v_ranks");
    assert_eq!(a.leaf_dim, b.leaf_dim, "{what}: leaf_dim");
    assert_eq!(a.leaf_range, b.leaf_range, "{what}: leaf_range");
    assert_eq!(a.leaf_sizes, b.leaf_sizes, "{what}: leaf_sizes");
    assert_eq!(a.u_leaf_bases, b.u_leaf_bases, "{what}: u leaf bases");
    assert_eq!(a.v_leaf_bases, b.v_leaf_bases, "{what}: v leaf bases");
    assert_eq!(a.u_transfers, b.u_transfers, "{what}: u transfers");
    assert_eq!(a.v_transfers, b.v_transfers, "{what}: v transfers");
    assert_eq!(a.top_u_transfers, b.top_u_transfers, "{what}: top u transfers");
    assert_eq!(a.top_v_transfers, b.top_v_transfers, "{what}: top v transfers");
    assert_eq!(a.top_coupling.len(), b.top_coupling.len(), "{what}: top levels");
    for (l, (ca, cb)) in a.top_coupling.iter().zip(&b.top_coupling).enumerate() {
        assert_eq!(ca.pairs, cb.pairs, "{what}: top coupling pairs L{l}");
        assert_eq!(ca.batches, cb.batches, "{what}: top coupling batches L{l}");
        assert_eq!(ca.data, cb.data, "{what}: top coupling data L{l}");
    }
    for l in 0..a.coupling.len() {
        let (ca, cb) = (&a.coupling[l], &b.coupling[l]);
        assert_eq!(ca.row_start, cb.row_start, "{what}: coupling row_start L{l}");
        assert_eq!(ca.level.pairs, cb.level.pairs, "{what}: coupling pairs L{l}");
        assert_eq!(ca.level.batches, cb.level.batches, "{what}: coupling batches L{l}");
        assert_eq!(ca.level.data, cb.level.data, "{what}: coupling data L{l}");
    }
    assert_eq!(a.dense.row_start, b.dense.row_start, "{what}: dense row_start");
    assert_eq!(a.dense.blocks.pairs, b.dense.blocks.pairs, "{what}: dense pairs");
    assert_eq!(a.dense.blocks.data, b.dense.blocks.data, "{what}: dense data");
}

fn assert_stats_equal(got: &CompressionStats, want: &CompressionStats, what: &str) {
    assert_eq!(got.old_ranks, want.old_ranks, "{what}: old_ranks");
    assert_eq!(got.new_ranks, want.new_ranks, "{what}: new_ranks");
    assert_eq!(got.pre_words, want.pre_words, "{what}: pre_words");
    assert_eq!(got.post_words, want.post_words, "{what}: post_words");
    assert_eq!(
        got.sigma_ref.to_bits(),
        want.sigma_ref.to_bits(),
        "{what}: sigma_ref ({} vs {})",
        got.sigma_ref,
        want.sigma_ref
    );
}

/// In-process transport: branch ranks plus a coordinator compress the
/// sharded operator over messages only, and every resulting shard is
/// bit-identical to slicing the serially compressed matrix — including
/// the rank decisions (the per-branch σ_ref/k_new partials reduce to the
/// exact serial maxima) and the reported stats.
#[test]
fn sharded_compression_bitwise_matches_serial() {
    for (job, ps) in
        [(conformance_job(), &[1usize, 2, 4, 8][..]), (fractional_job(), &[2usize, 4][..])]
    {
        let a = job.build();
        let (ac, serial_stats) = serial_compress(&a);
        assert!(
            serial_stats.post_words < serial_stats.pre_words,
            "{:?}: serial compression must actually truncate for the test to bite",
            job.kind
        );
        for &p in ps {
            let what = format!("{:?} P={p}", job.kind);
            let (shards, top, stats) =
                compress_sharded(&a, p, TAU, &NativeBackend).expect("distributed compression");
            let d = Decomposition::new(p, a.depth()).unwrap();
            for (r, s) in shards.iter().enumerate() {
                let expect = ShardedMatrix::from_global(&ac, d, r);
                assert_shards_equal(s, &expect, &format!("{what} rank {r}"));
            }
            let top_expect = ShardedMatrix::top_from_global(&ac, d);
            assert_shards_equal(&top, &top_expect, &format!("{what} top"));
            assert_stats_equal(&stats, &serial_stats, &what);
        }
    }
}

/// Compressed per-rank storage stays O(N/P): the compressed shards
/// exactly partition the compressed serial matrix (one replicated top
/// apart), every rank fits in compressed-serial/P plus the replication +
/// imbalance slack, and the peak shrinks as P grows.
#[test]
fn compressed_shard_memory_stays_o_n_over_p() {
    // N = 1024, depth 6 — big enough that the replicated top is small
    // against 1/P.
    let points = PointSet::grid_2d(32, 1.0);
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
    let a = build_h2(points, &kernel, &cfg);
    let (ac, serial_stats) = serial_compress(&a);
    let serial_bytes = ac.memory_words() * 8;
    let mut prev_max = serial_bytes + 1;
    for p in [2usize, 4, 8] {
        let (shards, _top, stats) =
            compress_sharded(&a, p, TAU, &NativeBackend).expect("distributed compression");
        assert_eq!(stats.post_words, serial_stats.post_words, "P={p}: post_words");
        let branch_total: usize = shards.iter().map(|s| s.branch_words()).sum();
        let rep = shards[0].replication_words();
        assert_eq!(branch_total + rep, ac.memory_words(), "P={p}: not a partition");
        for (r, s) in shards.iter().enumerate() {
            let imbalance = s.branch_words().saturating_sub(branch_total / p);
            let slack = (rep + imbalance) * 8;
            assert!(
                s.matrix_bytes() <= serial_bytes / p + slack,
                "P={p} rank {r}: {} B > compressed serial/P {} B + slack {} B",
                s.matrix_bytes(),
                serial_bytes / p,
                slack
            );
        }
        let max_bytes = shards.iter().map(|s| s.matrix_bytes()).max().unwrap();
        assert!(
            max_bytes < prev_max,
            "P={p}: peak compressed shard {max_bytes} B did not shrink (prev {prev_max} B)"
        );
        prev_max = max_bytes;
    }
}

#[cfg(unix)]
mod socket {
    use super::*;
    use h2opus::dist::transport::socket::{SocketOptions, SocketSession};
    use h2opus::dist::transport::TransportError;
    use h2opus::matvec::{hgemv, HgemvPlan, HgemvWorkspace};
    use h2opus::util::Prng;
    use std::time::{Duration, Instant};

    fn serial_product(a: &h2opus::tree::H2Matrix, x: &[f64], nv: usize) -> Vec<f64> {
        let n = a.n();
        let plan = HgemvPlan::new(a, nv);
        let mut ws = HgemvWorkspace::new(a, nv);
        let mut metrics = Metrics::new();
        let mut y = vec![0.0; n * nv];
        hgemv(a, &NativeBackend, &plan, x, &mut y, &mut ws, &mut metrics);
        y
    }

    fn worker_opts() -> SocketOptions {
        SocketOptions {
            worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
            ..SocketOptions::default()
        }
    }

    /// Live worker subprocesses compress their shards in place — under
    /// the `H2OPUS_FORBID_FULL_MATRIX` guard the coordinator sets on
    /// every worker, so no process ever materializes the global matrix —
    /// and every subsequent product (synchronous and pipelined, at the
    /// original and at new widths) is bitwise identical to the serial
    /// product of the serially *compressed* matrix. The returned stats
    /// match serial compression exactly.
    #[test]
    fn socket_session_compression_bitwise_matches_serial() {
        let job = conformance_job();
        let a = job.build();
        let n = a.n();
        let (ac, serial_stats) = serial_compress(&a);
        let mut rng = Prng::new(4207);
        for p in [1usize, 2, 4, 8] {
            let mut session =
                SocketSession::start(&job, p, 1, worker_opts()).expect("session start");
            assert!(!session.is_compressed());

            // Pre-compression product: the session applies the
            // construction-accuracy operator.
            let x = rng.normal_vec(n);
            let mut y = vec![0.0; n];
            session.hgemv(&x, &mut y).expect("pre-compression product");
            assert_eq!(y, serial_product(&a, &x, 1), "P={p}: pre-compression product");

            // Compression cannot interleave with an in-flight product,
            // and the refusal must not poison the session.
            let pid = session.submit(&x, 1).expect("submit");
            let msg = session.compress(TAU).expect_err("compress mid-pipeline").to_string();
            assert!(msg.contains("in-flight"), "guard must name the reason: {msg}");
            session.wait(pid, &mut y).expect("wait after refused compress");

            let stats = session.compress(TAU).expect("distributed compression");
            assert_stats_equal(&stats, &serial_stats, &format!("socket P={p}"));
            assert!(session.is_compressed());
            let msg = session.compress(TAU).expect_err("second compress").to_string();
            assert!(msg.contains("already compressed"), "{msg}");

            // Post-compression products apply the compressed operator —
            // bitwise — at the old width and at a fresh width (plans are
            // rebuilt for the new ranks).
            for nv in [1usize, 2] {
                let x = rng.normal_vec(n * nv);
                let y_serial = serial_product(&ac, &x, nv);
                let mut y = vec![0.0; n * nv];
                let pid = session.submit(&x, nv).expect("post-compression submit");
                session.wait(pid, &mut y).expect("post-compression wait");
                assert_eq!(y, y_serial, "P={p} nv={nv}: post-compression product");
            }
        }
    }

    /// A worker crash mid-compression poisons the session promptly: the
    /// compress call surfaces an error (shards may be half-transformed,
    /// so there is no recovery), and the session refuses further
    /// products with `Closed` — nothing hangs on a reduction that will
    /// never complete.
    #[test]
    fn mid_compression_crash_poisons_session() {
        let job = conformance_job();
        let n = job.n_points();
        let opts = SocketOptions {
            worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
            timeout: Duration::from_secs(30),
            // Rank 1 exits the moment the compression start frame lands.
            extra_env: vec![("H2OPUS_TEST_CRASH_ON_COMPRESS".into(), "1".into())],
            ..SocketOptions::default()
        };
        let mut session = SocketSession::start(&job, 2, 1, opts).expect("session start");
        let t0 = Instant::now();
        let e = session.compress(TAU).expect_err("compression must fail after the crash");
        let elapsed = t0.elapsed();
        assert!(elapsed < Duration::from_secs(25), "crash took {elapsed:?} — behaved like a hang");
        assert!(!e.to_string().is_empty());
        assert!(!session.is_compressed(), "a failed compression must not mark the session");
        let x = vec![1.0; n];
        let mut y = vec![0.0; n];
        let e = session.hgemv(&x, &mut y).expect_err("poisoned session must refuse products");
        assert!(matches!(e, TransportError::Closed(_)), "got {e}");
    }
}
