//! Distributed-runtime integration: virtual-time behaviour (weak/strong
//! scaling trends, overlap gains, comm-volume optimization) on mid-size
//! problems — the qualitative shape of Figs. 8–12 as assertions.

use h2opus::backend::native::NativeBackend;
use h2opus::config::{H2Config, NetworkModel};
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::compress::dist_compress;
use h2opus::dist::hgemv::{dist_hgemv, DistOptions};
use h2opus::geometry::PointSet;
use h2opus::util::Prng;

fn build_2d(n_side: usize) -> h2opus::tree::H2Matrix {
    let points = PointSet::grid_2d(n_side, 1.0);
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
    build_h2(points, &kernel, &cfg)
}

/// Strong scaling: fixed N, growing P → virtual time must drop
/// substantially from P=1 to P=8 (Fig. 10's regime before the limit).
#[test]
fn strong_scaling_shape() {
    let a = build_2d(64); // N = 4096
    let n = a.n();
    let mut rng = Prng::new(400);
    let x = rng.normal_vec(n);
    let mut times = Vec::new();
    for p in [1usize, 8] {
        let mut y = vec![0.0; n];
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let rep = dist_hgemv(&a, &NativeBackend, p, 1, &x, &mut y, &DistOptions::default());
            best = best.min(rep.time);
        }
        times.push(best);
    }
    assert!(
        times[1] < times[0] * 0.45,
        "P=8 speedup too small: {times:?}"
    );
}

/// The comm-volume optimization (§4.1): optimized volume must be well
/// below the naive allgather volume on a refined matrix.
#[test]
fn comm_volume_optimized() {
    let a = build_2d(64);
    let d = h2opus::dist::Decomposition::new(8, a.depth());
    let plan = h2opus::dist::ExchangePlan::build(&a, d);
    for p in 0..8 {
        let opt = plan.bytes_into(&a, p, 1);
        let naive = plan.naive_bytes_into(&a, p, 1);
        assert!(
            (opt as f64) < 0.7 * naive as f64,
            "rank {p}: {opt} vs naive {naive}"
        );
    }
}

/// Overlap (§4.2): with a slow network, overlapping reduces virtual time;
/// the trace shows comm gaps shrinking (Fig. 8's effect).
#[test]
fn overlap_gains_on_slow_network() {
    let a = build_2d(64);
    let n = a.n();
    let mut rng = Prng::new(401);
    let nv = 8;
    let x = rng.normal_vec(n * nv);
    let slow = NetworkModel { alpha: 5e-4, beta: 1e-7 };
    let mut y = vec![0.0; n * nv];
    let run = |overlap: bool, y: &mut Vec<f64>| {
        let opts = DistOptions { net: slow, overlap, trace: false };
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            best = best.min(dist_hgemv(&a, &NativeBackend, 8, nv, &x, y, &opts).time);
        }
        best
    };
    let with = run(true, &mut y);
    let without = run(false, &mut y);
    assert!(with < without, "overlap {with} !< serial {without}");
}

/// Weak-scaling shape for compression (Fig. 11): virtual time per fixed
/// local size stays roughly flat when N and P grow together.
#[test]
fn compression_weak_scaling_shape() {
    // local size fixed at 1024 points/rank
    let cases = [(32usize, 1usize), (64, 4)];
    let mut times = Vec::new();
    for &(n_side, p) in &cases {
        let mut a = build_2d(n_side);
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let mut b = a.clone();
            let (_, rep) = dist_compress(&mut b, p, 1e-3, &NativeBackend, NetworkModel::default());
            best = best.min(rep.orthogonalization_time + rep.compression_time);
        }
        times.push(best);
        let _ = &mut a;
    }
    // allow generous slack (timing noise on 1 core), but reject gross
    // departures from weak scalability
    assert!(
        times[1] < times[0] * 3.0,
        "weak scaling broken: {times:?}"
    );
}

/// The trace output contains the three streams of Fig. 8 and valid JSON
/// bracketing.
#[test]
fn trace_has_fig8_structure() {
    let a = build_2d(32);
    let n = a.n();
    let x = vec![1.0; n];
    let mut y = vec![0.0; n];
    let opts = DistOptions { net: NetworkModel::default(), overlap: true, trace: true };
    let rep = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &opts);
    let json = rep.trace_json.unwrap();
    assert!(json.contains("\"cat\": \"compute\""));
    assert!(json.contains("\"cat\": \"comm\""));
    assert!(json.contains("\"cat\": \"lowprio\""));
    assert!(json.trim_start().starts_with('[') && json.trim_end().ends_with(']'));
}

/// Multi-vector products must get *more* aggregate flops per virtual
/// second than single-vector ones (the paper's arithmetic-intensity
/// argument, Fig. 9 nv sweep).
#[test]
fn multivector_improves_throughput() {
    let a = build_2d(64);
    let n = a.n();
    let mut rng = Prng::new(402);
    let mut rate = |nv: usize| {
        let x = rng.normal_vec(n * nv);
        let mut y = vec![0.0; n * nv];
        let mut best = f64::INFINITY;
        let mut flops = 0;
        for _ in 0..3 {
            let rep = dist_hgemv(&a, &NativeBackend, 4, nv, &x, &mut y, &DistOptions::default());
            best = best.min(rep.time);
            flops = rep.metrics.flops;
        }
        flops as f64 / best
    };
    let r1 = rate(1);
    let r16 = rate(16);
    assert!(r16 > 1.5 * r1, "nv=16 rate {r16:.3e} vs nv=1 {r1:.3e}");
}
