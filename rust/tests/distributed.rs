//! Distributed-runtime integration: virtual-time behaviour (weak/strong
//! scaling trends, overlap gains, comm-volume optimization) on mid-size
//! problems — the qualitative shape of Figs. 8–12 as assertions.

use h2opus::backend::native::NativeBackend;
use h2opus::config::{H2Config, NetworkModel};
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::compress::dist_compress;
use h2opus::dist::hgemv::{dist_hgemv, DistOptions, ExecMode};
use h2opus::geometry::PointSet;
use h2opus::matvec::{hgemv, HgemvPlan, HgemvWorkspace};
use h2opus::metrics::Metrics;
use h2opus::util::Prng;

fn build_2d(n_side: usize) -> h2opus::tree::H2Matrix {
    let points = PointSet::grid_2d(n_side, 1.0);
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
    build_h2(points, &kernel, &cfg)
}

/// Strong scaling: fixed N, growing P → virtual time must drop
/// substantially from P=1 to P=8 (Fig. 10's regime before the limit).
#[test]
fn strong_scaling_shape() {
    let a = build_2d(64); // N = 4096
    let n = a.n();
    let mut rng = Prng::new(400);
    let x = rng.normal_vec(n);
    let mut times = Vec::new();
    for p in [1usize, 8] {
        let mut y = vec![0.0; n];
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let rep = dist_hgemv(&a, &NativeBackend, p, 1, &x, &mut y, &DistOptions::default());
            best = best.min(rep.time);
        }
        times.push(best);
    }
    assert!(
        times[1] < times[0] * 0.45,
        "P=8 speedup too small: {times:?}"
    );
}

/// The comm-volume optimization (§4.1): optimized volume must be well
/// below the naive allgather volume on a refined matrix.
#[test]
fn comm_volume_optimized() {
    let a = build_2d(64);
    let d = h2opus::dist::Decomposition::new(8, a.depth()).unwrap();
    let plan = h2opus::dist::ExchangePlan::build(&a, d);
    for p in 0..8 {
        let opt = plan.bytes_into(&a, p, 1);
        let naive = plan.naive_bytes_into(&a, p, 1);
        assert!(
            (opt as f64) < 0.7 * naive as f64,
            "rank {p}: {opt} vs naive {naive}"
        );
    }
}

/// Overlap (§4.2): with a slow network, overlapping reduces virtual time;
/// the trace shows comm gaps shrinking (Fig. 8's effect).
#[test]
fn overlap_gains_on_slow_network() {
    let a = build_2d(64);
    let n = a.n();
    let mut rng = Prng::new(401);
    let nv = 8;
    let x = rng.normal_vec(n * nv);
    let slow = NetworkModel { alpha: 5e-4, beta: 1e-7 };
    let mut y = vec![0.0; n * nv];
    let run = |overlap: bool, y: &mut Vec<f64>| {
        let opts = DistOptions {
            net: slow,
            overlap,
            trace: false,
            mode: ExecMode::Virtual,
            ..DistOptions::default()
        };
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            best = best.min(dist_hgemv(&a, &NativeBackend, 8, nv, &x, y, &opts).time);
        }
        best
    };
    let with = run(true, &mut y);
    let without = run(false, &mut y);
    assert!(with < without, "overlap {with} !< serial {without}");
}

/// Weak-scaling shape for compression (Fig. 11): virtual time per fixed
/// local size stays roughly flat when N and P grow together.
#[test]
fn compression_weak_scaling_shape() {
    // local size fixed at 1024 points/rank
    let cases = [(32usize, 1usize), (64, 4)];
    let mut times = Vec::new();
    for &(n_side, p) in &cases {
        let mut a = build_2d(n_side);
        let mut best = f64::INFINITY;
        for _ in 0..3 {
            let mut b = a.clone();
            let (_, rep) = dist_compress(
                &mut b,
                p,
                1e-3,
                &NativeBackend,
                NetworkModel::default(),
                ExecMode::Virtual,
            );
            best = best.min(rep.orthogonalization_time + rep.compression_time);
        }
        times.push(best);
        let _ = &mut a;
    }
    // allow generous slack (timing noise on 1 core), but reject gross
    // departures from weak scalability
    assert!(
        times[1] < times[0] * 3.0,
        "weak scaling broken: {times:?}"
    );
}

/// The trace output contains the three streams of Fig. 8 and valid JSON
/// bracketing.
#[test]
fn trace_has_fig8_structure() {
    let a = build_2d(32);
    let n = a.n();
    let x = vec![1.0; n];
    let mut y = vec![0.0; n];
    let opts =
        DistOptions {
            net: NetworkModel::default(),
            overlap: true,
            trace: true,
            mode: ExecMode::Virtual,
            ..DistOptions::default()
        };
    let rep = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &opts);
    let json = rep.trace_json.unwrap();
    assert!(json.contains("\"cat\": \"compute\""));
    assert!(json.contains("\"cat\": \"comm\""));
    assert!(json.contains("\"cat\": \"lowprio\""));
    assert!(json.trim_start().starts_with('[') && json.trim_end().ends_with(']'));
}

/// The real thread-parallel executor must reproduce the serial product
/// *bitwise* for every supported rank count (the tentpole invariant: same
/// phase functions, same branch slices, same accumulation order).
#[test]
fn threaded_executor_bitwise_identical_for_all_p() {
    let a = build_2d(32); // N = 1024, depth 6
    let n = a.n();
    let mut rng = Prng::new(403);
    let nv = 2;
    let x = rng.normal_vec(n * nv);
    let plan = HgemvPlan::new(&a, nv);
    let mut ws = HgemvWorkspace::new(&a, nv);
    let mut mt = Metrics::new();
    let mut y_serial = vec![0.0; n * nv];
    hgemv(&a, &NativeBackend, &plan, &x, &mut y_serial, &mut ws, &mut mt);
    let opts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
    for p in [1usize, 2, 4, 8] {
        let mut y_thr = vec![0.0; n * nv];
        let rep = dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y_thr, &opts);
        assert_eq!(y_thr, y_serial, "P={p}: threaded result differs from serial");
        let measured = rep.measured.expect("threaded mode must report wall-clock");
        assert!(measured > 0.0);
        assert!(rep.time > 0.0, "virtual time must still be priced");
        assert_eq!(rep.metrics.flops, h2opus::matvec::hgemv_flops(&a, nv));
    }
}

/// Acceptance: measured wall-clock for P = 4 beats P = 1 on the E2
/// strong-scaling size — real threads must deliver real speedup, not just
/// a cheaper virtual-time estimate. (Debug builds use a smaller problem
/// and a softer bound; `cargo test --release` runs the full criterion.)
#[test]
fn threaded_executor_speeds_up_wall_clock() {
    // A single-core environment (cgroup-limited CI) physically cannot show
    // wall-clock speedup; the bitwise tests still cover correctness there.
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores < 2 {
        eprintln!("SKIP: only {cores} core(s) available — no parallel speedup to measure");
        return;
    }
    // With the parallel batched backend enabled, the P = 1 baseline is no
    // longer serial (its batches already fan out across the backend pool),
    // so "4 ranks beat 1 rank" stops being the premise under test. The
    // bitwise conformance tests cover that configuration; this criterion
    // is about rank parallelism over a serial backend.
    if h2opus::backend::backend_threads() > 1 {
        eprintln!("SKIP: H2OPUS_BACKEND_THREADS > 1 — P=1 baseline is already parallel");
        return;
    }
    let (n_side, nv, max_ratio) = if cfg!(debug_assertions) {
        (64usize, 2usize, 0.80) // >= 1.25x
    } else if cores < 4 {
        // Fewer cores than ranks: 4 threads time-slice, so demand only a
        // modest win — the full 1.5x criterion needs >= 4 real cores.
        (128, 8, 0.80)
    } else {
        (128, 8, 1.0 / 1.5) // the E2 size (N = 2^14), >= 1.5x
    };
    let points = PointSet::grid_2d(n_side, 1.0);
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: 32, eta: 0.9, cheb_grid: 4 };
    let a = build_h2(points, &kernel, &cfg);
    let n = a.n();
    let mut rng = Prng::new(404);
    let x = rng.normal_vec(n * nv);
    let mut y = vec![0.0; n * nv];
    let opts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
    let mut best = |p: usize, y: &mut Vec<f64>| {
        let mut t = f64::INFINITY;
        // warmup + best-of-3: the minimum is the least noisy wall-clock
        // statistic on a shared CI runner.
        for _ in 0..4 {
            let rep = dist_hgemv(&a, &NativeBackend, p, nv, &x, y, &opts);
            t = t.min(rep.measured.unwrap());
        }
        t
    };
    let t1 = best(1, &mut y);
    let t4 = best(4, &mut y);
    assert!(
        t4 < t1 * max_ratio,
        "P=4 measured {t4:.4}s not {:.2}x faster than P=1 {t1:.4}s",
        1.0 / max_ratio
    );
}

/// One parsed Chrome-trace event.
struct Ev {
    name: String,
    cat: String,
    pid: usize,
    tid: usize,
    ts: f64,
    dur: f64,
}

/// Parse the hand-rolled one-event-per-line Chrome trace JSON emitted by
/// `TraceCollector::to_json` (no serde in the offline image).
fn parse_trace(json: &str) -> Vec<Ev> {
    fn str_field(line: &str, key: &str) -> String {
        let pat = format!("\"{key}\": \"");
        let start = line.find(&pat).expect("string field present") + pat.len();
        let end = line[start..].find('"').expect("terminated string") + start;
        line[start..end].to_string()
    }
    fn num_field(line: &str, key: &str) -> f64 {
        let pat = format!("\"{key}\": ");
        let start = line.find(&pat).expect("numeric field present") + pat.len();
        let end = line[start..]
            .find(|ch: char| ch == ',' || ch == '}')
            .expect("terminated number")
            + start;
        line[start..end].trim().parse().expect("parsable number")
    }
    json.lines()
        .filter(|l| l.trim_start().starts_with('{'))
        .map(|l| Ev {
            name: str_field(l, "name"),
            cat: str_field(l, "cat"),
            pid: num_field(l, "pid") as usize,
            tid: num_field(l, "tid") as usize,
            ts: num_field(l, "ts"),
            dur: num_field(l, "dur"),
        })
        .collect()
}

/// Golden-trace regression: the Fig. 8 schedule's structural invariants —
/// stream layout, comm overlapped under the dense phase, the low-priority
/// top subtree on the master — pinned down so future scheduler refactors
/// can't silently break them. The trace is also byte-identical across
/// runs (fixed seed, deterministic scheduler).
#[test]
fn golden_trace_structure() {
    let a = build_2d(32); // N = 1024, depth 6, P=4 -> C-level 2
    let n = a.n();
    let mut rng = Prng::new(405);
    let x = rng.normal_vec(n);
    let mut y = vec![0.0; n];
    let opts =
        DistOptions {
            net: NetworkModel::default(),
            overlap: true,
            trace: true,
            mode: ExecMode::Virtual,
            ..DistOptions::default()
        };
    let p = 4usize;
    let json = dist_hgemv(&a, &NativeBackend, p, 1, &x, &mut y, &opts).trace_json.unwrap();
    let events = parse_trace(&json);
    assert!(!events.is_empty());

    // Stream layout: tid 0 = compute, 1 = comm, 2 = lowprio, and nothing
    // else; every rank has a compute stream.
    for e in &events {
        let want_tid = match e.cat.as_str() {
            "compute" => 0,
            "comm" => 1,
            "lowprio" => 2,
            other => panic!("unexpected stream category {other}"),
        };
        assert_eq!(e.tid, want_tid, "event {} on wrong stream", e.name);
        assert!(e.pid < p, "event {} on unknown rank {}", e.name, e.pid);
        assert!(e.dur >= 0.0 && e.ts >= 0.0);
    }
    for r in 0..p {
        assert!(
            events.iter().any(|e| e.pid == r && e.cat == "compute"),
            "rank {r} has no compute stream"
        );
    }

    // Overlap: each rank's x̂-exchange comm interval must overlap its
    // dense/diagonal compute interval (§4.2 — the Fig. 8 signature).
    let mut overlap_pairs = 0usize;
    for r in 0..p {
        let comm = events.iter().find(|e| e.pid == r && e.name == "xhat exchange");
        let dense = events.iter().find(|e| e.pid == r && e.name == "dense + diagonal mult");
        if let (Some(comm), Some(dense)) = (comm, dense) {
            assert!(
                comm.ts < dense.ts + dense.dur && dense.ts < comm.ts + comm.dur,
                "rank {r}: comm [{}, {}] does not overlap dense [{}, {}]",
                comm.ts,
                comm.ts + comm.dur,
                dense.ts,
                dense.ts + dense.dur
            );
            overlap_pairs += 1;
        }
    }
    assert!(overlap_pairs >= 2, "overlap invariant vacuous: {overlap_pairs} rank(s) checked");

    // Low-priority top subtree: exactly one event, on the master, started
    // after the gather that feeds it.
    let lowprio: Vec<&Ev> = events.iter().filter(|e| e.cat == "lowprio").collect();
    assert_eq!(lowprio.len(), 1, "exactly one top-subtree block expected");
    let top = lowprio[0];
    assert_eq!(top.pid, 0, "top subtree must run on the master");
    assert_eq!(top.name, "top subtree");
    let gather = events
        .iter()
        .find(|e| e.name == "xhat gather")
        .expect("P=4 with C=2 must gather to the master");
    assert_eq!(gather.pid, 0);
    assert!(
        top.ts >= gather.ts + gather.dur - 1e-9,
        "top subtree ({}) must start after the gather ends ({})",
        top.ts,
        gather.ts + gather.dur
    );

    // Downsweeps close each rank's timeline after the scatter-dependent
    // barrier: every rank's downsweep is the last compute event.
    for r in 0..p {
        let last = events
            .iter()
            .filter(|e| e.pid == r && e.cat == "compute")
            .max_by(|a, b| (a.ts + a.dur).partial_cmp(&(b.ts + b.dur)).unwrap())
            .unwrap();
        assert_eq!(last.name, "downsweep", "rank {r} timeline must end in its downsweep");
    }

    // Determinism: a second run yields a byte-identical trace.
    let json2 = dist_hgemv(&a, &NativeBackend, p, 1, &x, &mut y, &opts).trace_json.unwrap();
    assert_eq!(json, json2, "trace must be deterministic for a fixed input");
}

/// Multi-vector products must get *more* aggregate flops per virtual
/// second than single-vector ones (the paper's arithmetic-intensity
/// argument, Fig. 9 nv sweep).
#[test]
fn multivector_improves_throughput() {
    let a = build_2d(64);
    let n = a.n();
    let mut rng = Prng::new(402);
    let mut rate = |nv: usize| {
        let x = rng.normal_vec(n * nv);
        let mut y = vec![0.0; n * nv];
        let mut best = f64::INFINITY;
        let mut flops = 0;
        for _ in 0..3 {
            let rep = dist_hgemv(&a, &NativeBackend, 4, nv, &x, &mut y, &DistOptions::default());
            best = best.min(rep.time);
            flops = rep.metrics.flops;
        }
        flops as f64 / best
    };
    let r1 = rate(1);
    let r16 = rate(16);
    assert!(r16 > 1.5 * r1, "nv=16 rate {r16:.3e} vs nv=1 {r1:.3e}");
}
