//! Property/fuzz tests for the §4.1 communication-volume-optimized
//! [`ExchangePlan`]: over randomized geometries, leaf sizes, admissibility
//! parameters and rank counts, the plan must (a) never exceed the naive
//! allgather volume, (b) be a perfect send/recv transpose per (level,
//! rank), and (c) cover every remote source node any owned coupling row
//! references — the exact guarantee the threaded executor relies on when
//! it ships x̂ blocks through channels.

use h2opus::config::H2Config;
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::{Decomposition, ExchangePlan};
use h2opus::geometry::PointSet;
use h2opus::tree::H2Matrix;
use h2opus::util::Prng;

/// A randomized point cloud in the unit box.
fn random_points(rng: &mut Prng, dim: usize, n: usize) -> PointSet {
    let mut ps = PointSet::new(dim);
    for _ in 0..n {
        let mut p = [0.0f64; 3];
        for coord in p.iter_mut().take(dim) {
            *coord = rng.uniform();
        }
        ps.push(&p[..dim]);
    }
    ps
}

/// One randomized (matrix, decomposition) instance.
fn random_case(rng: &mut Prng, trial: usize) -> H2Matrix {
    let dim = if trial % 2 == 0 { 2 } else { 3 };
    let n = 80 + rng.below(320);
    let leaf_size = [8usize, 16, 32][rng.below(3)];
    let eta = rng.range(0.55, 1.4);
    let corr_len = rng.range(0.05, 0.3);
    let cfg = H2Config { leaf_size, eta, cheb_grid: 2 };
    let kernel = ExponentialKernel { dim, corr_len };
    let points = random_points(rng, dim, n);
    build_h2(points, &kernel, &cfg)
}

fn plans_of(a: &H2Matrix) -> Vec<(usize, ExchangePlan)> {
    [1usize, 2, 4, 8]
        .into_iter()
        .filter_map(|p| {
            let d = Decomposition::new(p, a.depth()).ok()?;
            Some((p, ExchangePlan::build(a, d)))
        })
        .collect()
}

#[test]
fn optimized_volume_never_exceeds_naive() {
    let mut rng = Prng::new(5150);
    for trial in 0..10 {
        let a = random_case(&mut rng, trial);
        for (p, plan) in plans_of(&a) {
            for r in 0..p {
                for nv in [1usize, 4] {
                    let opt = plan.bytes_into(&a, r, nv);
                    let naive = plan.naive_bytes_into(&a, r, nv);
                    assert!(
                        opt <= naive,
                        "trial {trial} P={p} rank {r} nv={nv}: opt {opt} > naive {naive}"
                    );
                }
            }
        }
    }
}

#[test]
fn send_and_recv_are_exact_transposes_per_level_and_rank() {
    let mut rng = Prng::new(5151);
    for trial in 0..10 {
        let a = random_case(&mut rng, trial);
        for (p, plan) in plans_of(&a) {
            for (l, le) in plan.levels.iter().enumerate() {
                assert_eq!(le.recv.len(), p);
                assert_eq!(le.send.len(), p);
                // recv -> send direction.
                for (dst, lists) in le.recv.iter().enumerate() {
                    for (src, nodes) in lists {
                        let sent = le.send[*src]
                            .iter()
                            .find(|(d2, _)| *d2 == dst)
                            .map(|(_, n)| n.as_slice());
                        assert_eq!(
                            sent,
                            Some(nodes.as_slice()),
                            "trial {trial} P={p} level {l}: recv[{dst}] from {src} unmatched"
                        );
                    }
                }
                // send -> recv direction (no phantom sends), plus volume
                // symmetry: total nodes shipped equals total received.
                let mut sent_total = 0usize;
                let mut recv_total = 0usize;
                for (src, lists) in le.send.iter().enumerate() {
                    for (dst, nodes) in lists {
                        sent_total += nodes.len();
                        let got = le.recv[*dst]
                            .iter()
                            .find(|(s2, _)| *s2 == src)
                            .map(|(_, n)| n.as_slice());
                        assert_eq!(
                            got,
                            Some(nodes.as_slice()),
                            "trial {trial} P={p} level {l}: send[{src}] to {dst} unmatched"
                        );
                    }
                }
                for lists in &le.recv {
                    recv_total += lists.iter().map(|(_, n)| n.len()).sum::<usize>();
                }
                assert_eq!(sent_total, recv_total, "trial {trial} P={p} level {l}");
            }
            // messages_into agrees with the per-level recv sets.
            for r in 0..p {
                let count: usize = plan.levels.iter().map(|le| le.recv[r].len()).sum();
                assert_eq!(plan.messages_into(r), count);
            }
        }
    }
}

#[test]
fn every_remote_coupling_source_is_covered() {
    let mut rng = Prng::new(5152);
    for trial in 0..10 {
        let a = random_case(&mut rng, trial);
        for (p, plan) in plans_of(&a) {
            let d = plan.decomp;
            for l in 0..=a.depth() {
                if l < d.c_level {
                    // Top levels are the master's replicated subtree: the
                    // plan must not schedule point-to-point traffic there.
                    for r in 0..p {
                        assert!(
                            plan.levels[l].recv[r].is_empty(),
                            "trial {trial} P={p}: traffic above the C-level"
                        );
                    }
                    continue;
                }
                for &(t, s) in &a.coupling[l].pairs {
                    let pt = d.owner(l, t as usize);
                    let ps = d.owner(l, s as usize);
                    if pt == ps {
                        continue;
                    }
                    let covered = plan.levels[l].recv[pt]
                        .iter()
                        .any(|(src, nodes)| *src == ps && nodes.binary_search(&s).is_ok());
                    assert!(
                        covered,
                        "trial {trial} P={p} level {l}: row {t} needs node {s} \
                         from rank {ps}, absent from rank {pt}'s recv set"
                    );
                }
                // And nothing superfluous: every shipped node is actually
                // referenced by some owned coupling row of the receiver.
                for r in 0..p {
                    for (src, nodes) in &plan.levels[l].recv[r] {
                        for &node in nodes {
                            let referenced = a.coupling[l].pairs.iter().any(|&(t, s)| {
                                d.owner(l, t as usize) == r
                                    && s == node
                                    && d.owner(l, s as usize) == *src
                            });
                            assert!(
                                referenced,
                                "trial {trial} P={p} level {l}: rank {r} receives \
                                 unreferenced node {node} from {src}"
                            );
                        }
                    }
                }
            }
        }
    }
}
