//! Pipelined serving conformance suite: pipelined `submit`/`wait`
//! products must stay bitwise serial-identical across varying widths
//! while the FIFO/interleave rules hold; the [`SessionServer`] must
//! serve concurrent clients with bitwise-correct demuxed columns under
//! randomized widths and timings; and a worker crash mid-pipeline must
//! fail *every* in-flight product cleanly (poisoned, not hung).

#![cfg(unix)]

use std::path::PathBuf;
use std::time::{Duration, Instant};

use h2opus::backend::native::NativeBackend;
use h2opus::dist::transport::server::{ServerOptions, SessionServer};
use h2opus::dist::transport::socket::{SocketOptions, SocketSession};
use h2opus::dist::transport::{JobKind, MatrixJob, TransportError};
use h2opus::matvec::{hgemv, HgemvPlan, HgemvWorkspace};
use h2opus::metrics::Metrics;
use h2opus::util::Prng;

/// The conformance matrix: N = 256, depth 4 (same as tests/shard.rs).
fn conformance_job() -> MatrixJob {
    MatrixJob {
        dim: 2,
        n_side: 16,
        leaf_size: 16,
        eta: 0.9,
        cheb_grid: 3,
        corr_len: 0.1,
        kind: JobKind::Exponential,
    }
}

fn serial_product(a: &h2opus::tree::H2Matrix, x: &[f64], nv: usize) -> Vec<f64> {
    let n = a.n();
    let plan = HgemvPlan::new(a, nv);
    let mut ws = HgemvWorkspace::new(a, nv);
    let mut metrics = Metrics::new();
    let mut y = vec![0.0; n * nv];
    hgemv(a, &NativeBackend, &plan, x, &mut y, &mut ws, &mut metrics);
    y
}

fn worker_opts() -> SocketOptions {
    SocketOptions {
        worker_exe: PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        ..SocketOptions::default()
    }
}

/// Pipelined products of *varying* width, two in flight at a time, are
/// bitwise identical to the serial product — the workers rebuild their
/// branch plans per width and the double-buffered workspaces never leak
/// one product's accumulators into the next. Also pins the pipeline's
/// bookkeeping: FIFO completion, per-product width echo, and the
/// hgemv/submit interleaving guard.
#[test]
fn pipelined_varying_nv_bitwise_identical() {
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let mut session = SocketSession::start(&job, 2, 1, worker_opts()).expect("session start");
    let mut rng = Prng::new(9100);

    // Validation errors must not consume a pid or poison the session.
    assert!(session.submit(&[], 0).is_err(), "nv = 0 must be rejected");
    assert!(session.submit(&[1.0; 7], 2).is_err(), "length mismatch must be rejected");
    assert_eq!(session.in_flight(), 0);

    let widths = [1usize, 3, 2, 1, 4];
    let xs: Vec<Vec<f64>> = widths.iter().map(|&w| rng.normal_vec(n * w)).collect();
    let expected: Vec<Vec<f64>> = widths
        .iter()
        .zip(&xs)
        .map(|(&w, x)| serial_product(&a, x, w))
        .collect();

    // Keep two products in flight: submit k+1 before collecting k.
    let mut pids = Vec::new();
    for (k, (&w, x)) in widths.iter().zip(&xs).enumerate() {
        let pid = session.submit(x, w).expect("submit");
        pids.push(pid);
        assert!(session.in_flight() <= 2);
        if k == 0 {
            // The synchronous path must refuse to interleave with the
            // pipeline (its barrier would deadlock against in-flight
            // products).
            let xe = vec![0.0; n];
            let mut ye = vec![0.0; n];
            let msg = session.hgemv(&xe, &mut ye).expect_err("hgemv mid-pipeline").to_string();
            assert!(msg.contains("in-flight"), "guard must name the reason: {msg}");
            // Out-of-order wait is a recoverable protocol error, not a
            // poisoning one.
            let mut yw = vec![0.0; n];
            let msg = session.wait(pid + 999, &mut yw).expect_err("bogus pid").to_string();
            assert!(msg.contains("submission order") || msg.contains("not in flight"), "{msg}");
        }
        if session.in_flight() == 2 {
            let j = k - 1;
            let mut y = vec![0.0; n * widths[j]];
            let rep = session.wait(pids[j], &mut y).expect("wait");
            assert_eq!(y, expected[j], "product {j} (nv {}) not bitwise equal", widths[j]);
            assert_eq!(rep.coalesced_nv, widths[j] as u64, "product {j} width echo");
            assert!(rep.queue_wait_s >= 0.0);
        }
    }
    // Drain the tail.
    let j = widths.len() - 1;
    let mut y = vec![0.0; n * widths[j]];
    session.wait(pids[j], &mut y).expect("tail wait");
    assert_eq!(y, expected[j], "tail product not bitwise equal");
    assert_eq!(session.in_flight(), 0);
    assert_eq!(session.products(), widths.len() as u64);

    // The synchronous path still works once the pipeline is drained.
    let x = rng.normal_vec(n);
    let mut ys = vec![0.0; n];
    session.hgemv(&x, &mut ys).expect("post-pipeline hgemv");
    assert_eq!(ys, serial_product(&a, &x, 1));
}

/// Multi-client fuzz: concurrent threads submit requests of random
/// widths with random pauses; the server coalesces them into fused
/// products, and every demuxed answer must be bitwise identical to the
/// serial product of that client's own input. Afterwards the aggregate
/// counters must account for every request and every fused column.
#[test]
fn server_fuzz_multi_client_bitwise() {
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let server = SessionServer::start(
        &job,
        2,
        worker_opts(),
        ServerOptions { max_coalesce: 6, pipeline_depth: 2 },
    )
    .expect("server start");
    assert_eq!(server.n(), n);
    assert_eq!(server.max_coalesce(), 6);

    const CLIENTS: usize = 6;
    const ROUNDS: usize = 4;
    let mut total_cols = 0u64;
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..CLIENTS)
            .map(|c| {
                let server = &server;
                let a = &a;
                s.spawn(move || {
                    let mut rng = Prng::new(7000 + c as u64);
                    let mut cols = 0u64;
                    for round in 0..ROUNDS {
                        let w = 1 + rng.below(3);
                        let x = rng.normal_vec(n * w);
                        let handle = server.submit(&x).expect("submit");
                        std::thread::sleep(Duration::from_millis(rng.below(4) as u64));
                        let served = handle.wait().unwrap_or_else(|e| {
                            panic!("client {c} round {round}: {e}")
                        });
                        assert_eq!(
                            served.y,
                            serial_product(a, &x, w),
                            "client {c} round {round} (w = {w}) not bitwise equal"
                        );
                        assert!(served.stats.coalesced_nv >= w, "fused width below own width");
                        assert!(served.stats.queue_wait_s >= 0.0);
                        cols += w as u64;
                    }
                    cols
                })
            })
            .collect();
        for h in handles {
            total_cols += h.join().expect("client thread");
        }
    });

    let st = server.stats();
    assert_eq!(st.requests, (CLIENTS * ROUNDS) as u64, "every request counted");
    assert!(st.products >= 1 && st.products <= st.requests, "products {}", st.products);
    let hist_products: u64 = st.nv_histogram.values().sum();
    assert_eq!(hist_products, st.products, "histogram counts every product");
    let hist_cols: u64 = st.nv_histogram.iter().map(|(&nv, &c)| nv as u64 * c).sum();
    assert_eq!(hist_cols, total_cols, "histogram accounts for every fused column");
    assert!(st.nv_histogram.keys().all(|&nv| (1..=6).contains(&nv)));
    assert!(st.sum_queue_wait_s >= 0.0 && st.sum_measured_s > 0.0);

    // Oversized and ragged requests are rejected up front.
    assert!(server.submit(&vec![0.0; n * 7]).is_err(), "width above the cap");
    assert!(server.submit(&vec![0.0; n + 1]).is_err(), "not a multiple of N");
}

/// The fused coalesce width can never exceed the wire format's 10-bit
/// nv field: a server configured wider is clamped to [`MAX_WIRE_NV`],
/// requests whose widths sum past the boundary are split into multiple
/// fused products (each ≤ 1023 columns, checked via the width
/// histogram), every demuxed answer stays bitwise correct at the
/// boundary, and a single request of 1024 columns is rejected up front.
#[test]
fn fused_width_capped_at_wire_boundary() {
    use h2opus::dist::transport::socket::MAX_WIRE_NV;
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let server = SessionServer::start(
        &job,
        2,
        worker_opts(),
        // Ask for unbounded coalescing; the server must clamp to what
        // the wire can express.
        ServerOptions { max_coalesce: usize::MAX, pipeline_depth: 2 },
    )
    .expect("server start");
    assert_eq!(server.max_coalesce(), MAX_WIRE_NV, "cap must clamp to the wire field");
    assert!(
        server.submit(&vec![0.0; n * (MAX_WIRE_NV + 1)]).is_err(),
        "a single request one past the wire boundary must be rejected"
    );

    // 511 + 512 fills the wire field exactly; the trailing 600 cannot
    // join that product without overflowing the 10-bit nv.
    let widths = [511usize, 512, 600];
    let mut rng = Prng::new(1023);
    let xs: Vec<Vec<f64>> = widths.iter().map(|&w| rng.normal_vec(n * w)).collect();
    let handles: Vec<_> = xs.iter().map(|x| server.submit(x).expect("submit")).collect();
    for ((&w, x), h) in widths.iter().zip(&xs).zip(handles) {
        let served = h.wait().expect("boundary-width request");
        assert_eq!(served.y, serial_product(&a, x, w), "w = {w} not bitwise equal");
        assert!(
            (w as u64..=MAX_WIRE_NV as u64).contains(&served.stats.coalesced_nv),
            "w = {w}: fused width {} outside [{w}, {MAX_WIRE_NV}]",
            served.stats.coalesced_nv
        );
    }
    let st = server.stats();
    assert_eq!(st.requests, widths.len() as u64);
    assert!(
        st.nv_histogram.keys().all(|&nv| nv <= MAX_WIRE_NV),
        "a fused product exceeded the wire field: {:?}",
        st.nv_histogram
    );
    let hist_cols: u64 = st.nv_histogram.iter().map(|(&nv, &c)| nv as u64 * c).sum();
    assert_eq!(hist_cols, widths.iter().sum::<usize>() as u64, "every column accounted for");
}

/// A worker crash while two products are in flight must fail *both*
/// cleanly and promptly: the first wait names the poisoned product, the
/// second reports the session closed/lost — nothing hangs on a barrier
/// that will never complete.
#[test]
fn mid_pipeline_crash_fails_both_inflight_products() {
    let job = conformance_job();
    let n = job.n_points();
    let opts = SocketOptions {
        worker_exe: PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        timeout: Duration::from_secs(30),
        // Rank 1 exits the moment it receives product 0's input.
        extra_env: vec![("H2OPUS_TEST_CRASH_ON_PRODUCT".into(), "0@1".into())],
        ..SocketOptions::default()
    };
    let mut session = SocketSession::start(&job, 2, 1, opts).expect("session start");
    let x = vec![1.0; n];
    let t0 = Instant::now();
    let pid0 = session.submit(&x, 1).expect("first submit ships before the crash lands");
    // The second submit races the crash: the write may already have
    // failed (poisoning at submit) or still queue (poisoning at wait).
    let pid1 = session.submit(&x, 1);
    let mut y = vec![0.0; n];
    let e0 = session.wait(pid0, &mut y).expect_err("product 0 must fail");
    let e1 = match pid1 {
        Ok(pid) => session.wait(pid, &mut y).expect_err("product 1 must fail"),
        Err(e) => e,
    };
    let elapsed = t0.elapsed();
    assert!(elapsed < Duration::from_secs(25), "crash took {elapsed:?} — behaved like a hang");
    let (m0, m1) = (e0.to_string(), e1.to_string());
    assert!(
        m0.contains("poisoned") || m0.contains("not in flight"),
        "first error must surface the poisoning: {m0}"
    );
    assert!(
        m0.contains("poisoned") || m1.contains("poisoned"),
        "some error must name the poisoned product: {m0} / {m1}"
    );
    // The poisoned session refuses further work with `Closed`.
    let e = session.hgemv(&x, &mut y).expect_err("poisoned session must refuse products");
    assert!(matches!(e, TransportError::Closed(_)), "got {e}");
}

/// The same crash through the server front end: every outstanding
/// request's handle resolves to an error (no hang), and the server
/// fast-fails later submissions as poisoned.
#[test]
fn server_crash_fails_all_requests_cleanly() {
    let job = conformance_job();
    let n = job.n_points();
    let opts = SocketOptions {
        worker_exe: PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        timeout: Duration::from_secs(30),
        extra_env: vec![("H2OPUS_TEST_CRASH_ON_PRODUCT".into(), "0@1".into())],
        ..SocketOptions::default()
    };
    let server = SessionServer::start(
        &job,
        2,
        opts,
        ServerOptions { max_coalesce: 4, pipeline_depth: 2 },
    )
    .expect("server start");
    let t0 = Instant::now();
    let x = vec![1.0; n];
    let handles: Vec<_> = (0..3).map(|_| server.submit(&x).expect("submit")).collect();
    for (i, h) in handles.into_iter().enumerate() {
        let e = h.wait().expect_err("request must fail after the crash");
        assert!(!e.to_string().is_empty(), "request {i}");
    }
    let elapsed = t0.elapsed();
    assert!(elapsed < Duration::from_secs(25), "crash took {elapsed:?} — behaved like a hang");
    // After the dispatcher poisons the queue, submissions fail fast; a
    // submission racing the poisoning may enqueue, but its handle still
    // resolves to the error rather than hanging.
    match server.submit(&x) {
        Err(_) => {}
        Ok(h) => {
            h.wait().expect_err("request into a poisoned server must fail");
        }
    }
    // The ledger must balance even when the failures land on the wait
    // path (the popped batch is no longer in flight, so `fail_all` never
    // sees it): every crashed request is counted into `failed`.
    let st = server.stats();
    assert!(st.failed >= 3, "all crashed requests must be counted: {}", st.summary());
    assert_eq!(
        st.submitted,
        st.completed + st.failed,
        "ledger must balance under failures: {}",
        st.summary()
    );
}
