//! Cross-module integration tests: the full §6 workflows on small
//! problems, plus failure-injection checks on the public API.

use h2opus::backend::native::NativeBackend;
use h2opus::compression::{compress_full, orthogonalize, tree_is_orthogonal};
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, dense_kernel_matrix, ExponentialKernel};
use h2opus::geometry::PointSet;
use h2opus::matvec::{apply_original_order, hgemv, hgemv_flops, HgemvPlan, HgemvWorkspace};
use h2opus::metrics::Metrics;
use h2opus::util::testing::rel_err;
use h2opus::util::Prng;

fn build_2d(n_side: usize, m: usize, g: usize) -> h2opus::tree::H2Matrix {
    let points = PointSet::grid_2d(n_side, 1.0);
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: m, eta: 0.9, cheb_grid: g };
    build_h2(points, &kernel, &cfg)
}

/// §6.1 workflow: construct, measure sampled accuracy, check C_sp bounded.
#[test]
fn covariance_pipeline_2d() {
    let a = build_2d(32, 32, 5); // N = 1024, k = 25
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let n = a.n();
    let dense = dense_kernel_matrix(&a.tree, &kernel);
    let mut rng = Prng::new(300);
    let x = rng.normal_vec(n);
    let mut y_dense = vec![0.0; n];
    h2opus::linalg::gemm_nn(n, n, 1, &dense.data, &x, &mut y_dense, false);

    let plan = HgemvPlan::new(&a, 1);
    let mut ws = HgemvWorkspace::new(&a, 1);
    let mut y = vec![0.0; n];
    let mut mt = Metrics::new();
    hgemv(&a, &NativeBackend, &plan, &x, &mut y, &mut ws, &mut mt);
    let err = rel_err(&y, &y_dense);
    assert!(err < 5e-3, "sampled accuracy {err}");
    assert!(a.sparsity_constant() <= 40);
    // at N = 1024 with k = 25 the asymptotic O(N) regime is only starting;
    // require a 2x saving here (the accuracy bench shows the O(N) trend)
    assert!(a.memory_words() * 2 < n * n);
}

/// §6.3 workflow: Chebyshev seed -> orthogonalize -> compress at 1e-3,
/// validating accuracy against the *dense* matrix and memory reduction.
#[test]
fn compression_pipeline_2d() {
    let mut a = build_2d(32, 64, 6); // uniform rank 36 (needs m >= 36), the paper's 2D seed
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let dense = dense_kernel_matrix(&a.tree, &kernel);
    let n = a.n();
    let pre = a.low_rank_memory_words();

    let mut mt = Metrics::new();
    let (c, stats) = compress_full(&mut a, 1e-3, &NativeBackend, &mut mt);
    assert!(stats.post_words < pre, "no memory reduction");
    // paper sees ~6x on its 2D set; at this tiny N the tree is shallow, so
    // accept anything >= 1.5x while requiring accuracy to hold
    assert!(stats.ratio() > 1.5, "ratio {}", stats.ratio());

    let mut rng = Prng::new(301);
    let x = rng.normal_vec(n);
    let mut y_dense = vec![0.0; n];
    h2opus::linalg::gemm_nn(n, n, 1, &dense.data, &x, &mut y_dense, false);
    let plan = HgemvPlan::new(&c, 1);
    let mut ws = HgemvWorkspace::new(&c, 1);
    let mut y = vec![0.0; n];
    hgemv(&c, &NativeBackend, &plan, &x, &mut y, &mut ws, &mut mt);
    let err = rel_err(&y, &y_dense);
    assert!(err < 5e-2, "compressed accuracy {err}");
}

/// 3D Gaussian-process set (§6.1): build + matvec + compress.
#[test]
fn gaussian_process_pipeline_3d() {
    let points = PointSet::grid_3d(8, 1.0); // 512 points
    let kernel = ExponentialKernel { dim: 3, corr_len: 0.2 };
    let cfg = H2Config { leaf_size: 32, eta: 0.95, cheb_grid: 3 }; // k = 27
    let mut a = build_h2(points, &kernel, &cfg);
    let n = a.n();
    let dense = dense_kernel_matrix(&a.tree, &kernel);
    let mut rng = Prng::new(302);
    let x = rng.normal_vec(n);
    let mut y_dense = vec![0.0; n];
    h2opus::linalg::gemm_nn(n, n, 1, &dense.data, &x, &mut y_dense, false);
    let y = apply_original_order(&a, &NativeBackend, &{
        // convert x (permuted oracle) to original order for the wrapper
        let mut xo = vec![0.0; n];
        for pos in 0..n {
            xo[a.tree.perm[pos]] = x[pos];
        }
        xo
    }, 1);
    let y_perm: Vec<f64> = (0..n).map(|pos| y[a.tree.perm[pos]]).collect();
    let err = rel_err(&y_perm, &y_dense);
    assert!(err < 5e-2, "3D accuracy {err}");

    let mut mt = Metrics::new();
    let (_c, stats) = compress_full(&mut a, 1e-3, &NativeBackend, &mut mt);
    assert!(stats.ratio() >= 1.0);
    assert!(tree_is_orthogonal(&a.u, 1e-8)); // orthogonalized in place
}

/// Orthogonalization alone must be exactly memory-neutral and invariant.
#[test]
fn orthogonalize_is_exact() {
    let mut a = build_2d(16, 16, 4);
    let n = a.n();
    let mut rng = Prng::new(303);
    let x = rng.normal_vec(n);
    let before = apply_original_order(&a, &NativeBackend, &x, 1);
    let mut mt = Metrics::new();
    orthogonalize(&mut a, &NativeBackend, &mut mt);
    let after = apply_original_order(&a, &NativeBackend, &x, 1);
    assert!(rel_err(&after, &before) < 1e-11);
}

/// hgemv flop model sanity across configurations.
#[test]
fn flops_scale_linearly_with_nv() {
    let a = build_2d(16, 16, 3);
    let f1 = hgemv_flops(&a, 1);
    let f8 = hgemv_flops(&a, 8);
    assert_eq!(f8, 8 * f1);
}

/// Failure injection: plan/workspace mismatches must panic, not corrupt.
#[test]
#[should_panic(expected = "plan built for different nv")]
fn plan_nv_mismatch_panics() {
    let a = build_2d(8, 16, 3);
    let plan = HgemvPlan::new(&a, 2);
    let mut ws = HgemvWorkspace::new(&a, 1);
    let x = vec![0.0; a.n()];
    let mut y = vec![0.0; a.n()];
    let mut mt = Metrics::new();
    hgemv(&a, &NativeBackend, &plan, &x, &mut y, &mut ws, &mut mt);
}

#[test]
#[should_panic]
fn wrong_vector_length_panics() {
    let a = build_2d(8, 16, 3);
    let plan = HgemvPlan::new(&a, 1);
    let mut ws = HgemvWorkspace::new(&a, 1);
    let x = vec![0.0; a.n() - 1];
    let mut y = vec![0.0; a.n()];
    let mut mt = Metrics::new();
    hgemv(&a, &NativeBackend, &plan, &x, &mut y, &mut ws, &mut mt);
}

/// Non-power-of-two N: padding paths throughout.
#[test]
fn irregular_point_count() {
    let mut ps = PointSet::new(2);
    let mut rng = Prng::new(304);
    for _ in 0..777 {
        ps.push(&[rng.uniform(), rng.uniform()]);
    }
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: 24, eta: 0.9, cheb_grid: 4 };
    let a = build_h2(ps, &kernel, &cfg);
    assert_eq!(a.n(), 777);
    let dense = dense_kernel_matrix(&a.tree, &kernel);
    let x = rng.normal_vec(777);
    let mut y_dense = vec![0.0; 777];
    h2opus::linalg::gemm_nn(777, 777, 1, &dense.data, &x, &mut y_dense, false);
    let plan = HgemvPlan::new(&a, 1);
    let mut ws = HgemvWorkspace::new(&a, 1);
    let mut y = vec![0.0; 777];
    let mut mt = Metrics::new();
    hgemv(&a, &NativeBackend, &plan, &x, &mut y, &mut ws, &mut mt);
    assert!(rel_err(&y, &y_dense) < 5e-2);
}
