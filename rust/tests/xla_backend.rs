//! XLA/PJRT backend integration: the AOT JAX/Pallas artifacts must
//! reproduce the native backend bit-for-bit-ish on every operation class,
//! and the full HGEMV/compression pipelines must run end-to-end on the XLA
//! backend. Skipped (with a notice) when `make artifacts` has not run.

use std::path::Path;

use h2opus::backend::native::NativeBackend;
use h2opus::backend::{contiguous_offsets, BatchRef, ComputeBackend, GemmDims};
use h2opus::compression::compress_full;
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::geometry::PointSet;
use h2opus::matvec::{hgemv, HgemvPlan, HgemvWorkspace};
use h2opus::metrics::Metrics;
use h2opus::runtime::XlaBackend;
use h2opus::util::testing::{assert_allclose, rel_err};
use h2opus::util::Prng;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::env::var("H2OPUS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
    let p = Path::new(&dir).to_path_buf();
    if p.join("manifest.txt").exists() {
        Some(p)
    } else {
        eprintln!("SKIP: no artifacts at {p:?} — run `make artifacts`");
        None
    }
}

#[test]
fn gemm_matches_native_exact_bucket() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaBackend::new(&dir).unwrap();
    let mut rng = Prng::new(200);
    // exact catalog shape (16,16,4) and padded shape (5,9,3)
    for (m, k, n) in [(16usize, 16usize, 4usize), (5, 9, 3), (32, 16, 1), (17, 31, 33)] {
        for op in [(false, false), (true, false), (false, true)] {
            let nb = 7;
            let (ta, tb) = op;
            let a_sz = m * k;
            let b_sz = k * n;
            let a = rng.normal_vec(nb * a_sz);
            let b = rng.normal_vec(nb * b_sz);
            let dims = GemmDims { nb, m, k, n, trans_a: ta, trans_b: tb, accumulate: false };
            let mut mt = Metrics::new();
            let mut c_xla = vec![0.0; nb * m * n];
            xla.batched_gemm(
                dims,
                BatchRef { data: &a, offsets: &contiguous_offsets(nb, a_sz) },
                BatchRef { data: &b, offsets: &contiguous_offsets(nb, b_sz) },
                &mut c_xla,
                &contiguous_offsets(nb, m * n),
                &mut mt,
            );
            let mut c_nat = vec![0.0; nb * m * n];
            NativeBackend.batched_gemm(
                dims,
                BatchRef { data: &a, offsets: &contiguous_offsets(nb, a_sz) },
                BatchRef { data: &b, offsets: &contiguous_offsets(nb, b_sz) },
                &mut c_nat,
                &contiguous_offsets(nb, m * n),
                &mut mt,
            );
            assert_allclose(&c_xla, &c_nat, 1e-12, 1e-12, &format!("gemm {m}x{k}x{n} ta={ta} tb={tb}"));
        }
    }
}

#[test]
fn gemm_accumulate_and_large_batch_chunking() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaBackend::new(&dir).unwrap();
    let mut rng = Prng::new(201);
    let (nb, m, k, n) = (150usize, 8usize, 8usize, 4usize); // chunks over b64
    let a = rng.normal_vec(nb * m * k);
    let b = rng.normal_vec(nb * k * n);
    let dims = GemmDims { nb, m, k, n, trans_a: false, trans_b: false, accumulate: true };
    let mut mt = Metrics::new();
    let mut c_xla = rng.normal_vec(nb * m * n);
    let mut c_nat = c_xla.clone();
    xla.batched_gemm(
        dims,
        BatchRef { data: &a, offsets: &contiguous_offsets(nb, m * k) },
        BatchRef { data: &b, offsets: &contiguous_offsets(nb, k * n) },
        &mut c_xla,
        &contiguous_offsets(nb, m * n),
        &mut mt,
    );
    NativeBackend.batched_gemm(
        dims,
        BatchRef { data: &a, offsets: &contiguous_offsets(nb, m * k) },
        BatchRef { data: &b, offsets: &contiguous_offsets(nb, k * n) },
        &mut c_nat,
        &contiguous_offsets(nb, m * n),
        &mut mt,
    );
    assert_allclose(&c_xla, &c_nat, 1e-12, 1e-12, "chunked accumulate gemm");
    assert!(xla.stats.lock().unwrap().launches >= 3, "expected chunked launches");
}

#[test]
fn qr_and_svd_match_native_semantics() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaBackend::new(&dir).unwrap();
    let mut rng = Prng::new(202);
    let (nb, rows, cols) = (5usize, 24usize, 10usize); // padded into (32,16)
    let a = rng.normal_vec(nb * rows * cols);
    let mut mt = Metrics::new();

    let mut q = vec![0.0; nb * rows * cols];
    let mut r = vec![0.0; nb * cols * cols];
    xla.batched_qr(nb, rows, cols, &a, &mut q, &mut r, &mut mt);
    // QR reconstructs
    for i in 0..nb {
        let mut qr = vec![0.0; rows * cols];
        h2opus::linalg::gemm_nn(rows, cols, cols, &q[i * rows * cols..], &r[i * cols * cols..], &mut qr, false);
        assert_allclose(&qr, &a[i * rows * cols..(i + 1) * rows * cols], 1e-9, 1e-9, "xla qr");
    }

    let mut u = vec![0.0; nb * rows * cols];
    let mut s = vec![0.0; nb * cols];
    let mut v = vec![0.0; nb * cols * cols];
    xla.batched_svd(nb, rows, cols, &a, &mut u, &mut s, &mut v, &mut mt);
    // singular values match native
    let mut un = vec![0.0; nb * rows * cols];
    let mut sn = vec![0.0; nb * cols];
    let mut vn = vec![0.0; nb * cols * cols];
    NativeBackend.batched_svd(nb, rows, cols, &a, &mut un, &mut sn, &mut vn, &mut mt);
    assert_allclose(&s, &sn, 1e-8, 1e-10, "xla svd singular values");
}

#[test]
fn full_hgemv_on_xla_backend() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaBackend::new(&dir).unwrap();
    let points = PointSet::grid_2d(16, 1.0);
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 4 };
    let a = build_h2(points, &kernel, &cfg);
    let n = a.n();
    let mut rng = Prng::new(203);
    for nv in [1usize, 3] {
        let x = rng.normal_vec(n * nv);
        let plan = HgemvPlan::new(&a, nv);
        let mut ws = HgemvWorkspace::new(&a, nv);
        let mut mt = Metrics::new();
        let mut y_xla = vec![0.0; n * nv];
        hgemv(&a, &xla, &plan, &x, &mut y_xla, &mut ws, &mut mt);
        let mut y_nat = vec![0.0; n * nv];
        hgemv(&a, &NativeBackend, &plan, &x, &mut y_nat, &mut ws, &mut mt);
        let err = rel_err(&y_xla, &y_nat);
        assert!(err < 1e-11, "nv={nv}: XLA vs native hgemv err {err}");
    }
    assert_eq!(xla.stats.lock().unwrap().fallbacks, 0, "hgemv should never fall back");
}

#[test]
fn full_compression_on_xla_backend() {
    let Some(dir) = artifacts_dir() else { return };
    let xla = XlaBackend::new(&dir).unwrap();
    let points = PointSet::grid_2d(16, 1.0);
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 4 };
    let base = build_h2(points, &kernel, &cfg);
    let mut mt = Metrics::new();

    let mut a_xla = base.clone();
    let (c_xla, stats_xla) = compress_full(&mut a_xla, 1e-3, &xla, &mut mt);
    let mut a_nat = base.clone();
    let (c_nat, stats_nat) = compress_full(&mut a_nat, 1e-3, &NativeBackend, &mut mt);

    assert_eq!(stats_xla.new_ranks, stats_nat.new_ranks, "rank selection must agree");
    // compare the compressed operators through a matvec
    let n = base.n();
    let mut rng = Prng::new(204);
    let x = rng.normal_vec(n);
    let apply = |m: &h2opus::tree::H2Matrix| {
        let plan = HgemvPlan::new(m, 1);
        let mut ws = HgemvWorkspace::new(m, 1);
        let mut y = vec![0.0; n];
        let mut mt = Metrics::new();
        hgemv(m, &NativeBackend, &plan, &x, &mut y, &mut ws, &mut mt);
        y
    };
    let err = rel_err(&apply(&c_xla), &apply(&c_nat));
    assert!(err < 1e-6, "XLA vs native compressed operators differ: {err}");
}
