//! Solver-substrate coverage: the Fig. 13 prerequisite that V-cycle
//! preconditioned CG converges in a mesh-independent number of iterations
//! as the grid refines (the property that makes the fractional-diffusion
//! solve O(N) per digit), plus CG behaviour guarantees the app relies on.

use h2opus::solver::cg::{pcg, Identity};
use h2opus::solver::multigrid::{five_point_operator, Multigrid};
use h2opus::solver::Csr;
use h2opus::util::Prng;

fn hierarchy(n0: usize, kappa: &dyn Fn(f64, f64) -> f64, shift: f64) -> Multigrid {
    let mut ops = Vec::new();
    let mut sides = Vec::new();
    let mut n = n0;
    while n >= 4 {
        ops.push(five_point_operator(n, -1.0, 1.0, 1.0, shift, kappa));
        sides.push(n);
        n /= 2;
    }
    Multigrid::new(ops, sides)
}

fn mg_cg_iterations(n0: usize, kappa: &dyn Fn(f64, f64) -> f64, shift: f64) -> usize {
    let n = n0 * n0;
    let a = five_point_operator(n0, -1.0, 1.0, 1.0, shift, kappa);
    let mut mg = hierarchy(n0, kappa, shift);
    let mut rng = Prng::new(1300 + n0 as u64);
    let b = rng.normal_vec(n);
    let mut x = vec![0.0; n];
    let mut op = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
    let res = pcg(&mut op, &mut mg, &b, &mut x, 1e-8, 300);
    assert!(res.converged, "MG-CG must converge on the {n0}x{n0} grid: {res:?}");
    res.iterations
}

/// Constant-coefficient Poisson: iteration counts across three grid sizes
/// must stay flat — the defining property of an optimal preconditioner.
#[test]
fn vcycle_cg_iterations_mesh_independent_constant_coefficient() {
    let kappa = |_: f64, _: f64| 1.0;
    let iters: Vec<usize> = [16usize, 32, 64].iter().map(|&n0| mg_cg_iterations(n0, &kappa, 0.0)).collect();
    // Mesh independence: the finest grid may cost at most a small additive
    // slack over the coarsest, and never more than a fixed constant.
    assert!(
        iters[2] <= iters[0] + 5,
        "iterations grew with refinement: {iters:?}"
    );
    assert!(iters.iter().all(|&it| it <= 40), "iteration counts not bounded: {iters:?}");
}

/// Variable (smooth) coefficients — the regularization operator of the
/// fractional application has a(x, y) varying over the domain; the V-cycle
/// must stay mesh independent there too.
#[test]
fn vcycle_cg_iterations_mesh_independent_variable_coefficient() {
    let kappa = |x: f64, y: f64| 1.0 + 0.5 * (x * x + y * y);
    let iters: Vec<usize> = [16usize, 32, 64].iter().map(|&n0| mg_cg_iterations(n0, &kappa, 0.0)).collect();
    assert!(
        iters[2] <= iters[0] + 6,
        "variable-coefficient iterations grew with refinement: {iters:?}"
    );
    assert!(iters.iter().all(|&it| it <= 45), "iteration counts not bounded: {iters:?}");
}

/// A zeroth-order (shift) term — present in the paper's shifted
/// regularization operator — only helps conditioning; counts stay flat.
#[test]
fn vcycle_cg_iterations_mesh_independent_with_shift() {
    let kappa = |_: f64, _: f64| 1.0;
    let iters: Vec<usize> = [16usize, 32, 64].iter().map(|&n0| mg_cg_iterations(n0, &kappa, 1.0)).collect();
    assert!(iters[2] <= iters[0] + 5, "shifted iterations grew: {iters:?}");
}

/// The preconditioner must actually pay for itself: on the finest test
/// grid, MG-CG needs far fewer iterations than unpreconditioned CG, and
/// both reach the same solution.
#[test]
fn vcycle_preconditioner_beats_identity_and_agrees() {
    let n0 = 64usize;
    let n = n0 * n0;
    let kappa = |_: f64, _: f64| 1.0;
    let a = five_point_operator(n0, -1.0, 1.0, 1.0, 0.0, &kappa);
    let mut rng = Prng::new(1301);
    let b = rng.normal_vec(n);

    let mut x_plain = vec![0.0; n];
    let mut op1 = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
    let plain = pcg(&mut op1, &mut Identity(n), &b, &mut x_plain, 1e-8, 4000);

    let mut x_mg = vec![0.0; n];
    let mut mg = hierarchy(n0, &kappa, 0.0);
    let mut op2 = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
    let pre = pcg(&mut op2, &mut mg, &b, &mut x_mg, 1e-8, 4000);

    assert!(plain.converged && pre.converged);
    assert!(
        pre.iterations * 4 < plain.iterations,
        "MG ({}) must beat identity ({}) by >= 4x",
        pre.iterations,
        plain.iterations
    );
    let diff: f64 = x_plain
        .iter()
        .zip(&x_mg)
        .map(|(p, m)| (p - m) * (p - m))
        .sum::<f64>()
        .sqrt();
    let norm: f64 = x_mg.iter().map(|v| v * v).sum::<f64>().sqrt();
    assert!(diff / norm < 1e-5, "solutions disagree: rel {}", diff / norm);
}

/// CG on an SPD system tracks its own residual history faithfully: the
/// reported final relative residual matches a recomputed one.
#[test]
fn cg_residual_history_is_faithful() {
    let n0 = 32usize;
    let n = n0 * n0;
    let a: Csr = five_point_operator(n0, -1.0, 1.0, 1.0, 0.0, &|_, _| 1.0);
    let mut rng = Prng::new(1302);
    let b = rng.normal_vec(n);
    let mut x = vec![0.0; n];
    let mut op = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
    let res = pcg(&mut op, &mut Identity(n), &b, &mut x, 1e-9, 4000);
    assert!(res.converged);
    let mut ax = vec![0.0; n];
    a.spmv(&x, &mut ax);
    let rnorm: f64 =
        b.iter().zip(&ax).map(|(bi, ai)| (bi - ai) * (bi - ai)).sum::<f64>().sqrt();
    let bnorm: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt();
    let reported = *res.residuals.last().unwrap();
    let actual = rnorm / bnorm;
    assert!(
        (actual - reported).abs() <= 1e-6 + 0.5 * reported.max(actual),
        "reported {reported:e} vs recomputed {actual:e}"
    );
    assert!(actual <= 1e-8, "recomputed residual too large: {actual:e}");
}
