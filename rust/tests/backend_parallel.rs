//! Conformance suite for the parallel native backend: the pool-dispatched
//! batched kernels must be *bitwise identical* to the serial loop for any
//! dims/offsets/transpose/accumulate combination (the §3.2 conflict-free
//! contract is the only thing the parallel path may assume), safe under
//! concurrent use from multiple rank threads, and allocation-free in the
//! GEMM dispatch.

use std::alloc::{GlobalAlloc, Layout, System};
use std::cell::Cell;

use h2opus::backend::native::NativeBackend;
use h2opus::backend::{contiguous_offsets, BatchRef, GemmDims};
use h2opus::metrics::Metrics;
use h2opus::util::parallel::ParallelPool;
use h2opus::util::testing::check;
use h2opus::util::Prng;

// ---- thread-local allocation counting (for the zero-alloc dispatch test)

thread_local! {
    static THREAD_ALLOCS: Cell<u64> = const { Cell::new(0) };
}

/// System allocator wrapper counting allocations *per thread*: the
/// zero-alloc assertion must not be confused by sibling tests running
/// concurrently in this binary.
struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let _ = THREAD_ALLOCS.try_with(|n| n.set(n.get() + 1));
        System.alloc(layout)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

fn my_allocs() -> u64 {
    THREAD_ALLOCS.with(|n| n.get())
}

// ---- randomized bitwise conformance -----------------------------------

/// One randomized batched-GEMM case. Output offsets are a random
/// permutation of disjoint slots (the §3.2 guarantee); A/B offsets are
/// contiguous reads.
struct Case {
    dims: GemmDims,
    a: Vec<f64>,
    b: Vec<f64>,
    c0: Vec<f64>,
    ao: Vec<usize>,
    bo: Vec<usize>,
    co: Vec<usize>,
}

impl std::fmt::Debug for Case {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        // The buffers are regenerable from the seed; print the shape only.
        write!(f, "Case {{ dims: {:?}, co: {:?}.. }}", self.dims, &self.co[..self.co.len().min(8)])
    }
}

fn gen_case(rng: &mut Prng, big: bool) -> Case {
    // `big` cases clear the backend's parallel-dispatch threshold, so the
    // pool path is genuinely exercised; small cases cover the serial
    // fallback of the same entry point.
    let (nb, lo, hi) = if big { (100 + rng.below(150), 8, 20) } else { (1 + rng.below(6), 1, 6) };
    let m = lo + rng.below(hi - lo + 1);
    let k = lo + rng.below(hi - lo + 1);
    let n = lo + rng.below(hi - lo + 1);
    let dims = GemmDims {
        nb,
        m,
        k,
        n,
        trans_a: rng.below(2) == 1,
        trans_b: rng.below(2) == 1,
        accumulate: rng.below(2) == 1,
    };
    // Storage sizes are trans-independent: op(A) is m×k from an m·k block
    // however it is stored, etc.
    let (a_sz, b_sz, c_sz) = (m * k, k * n, m * n);
    // Scatter the C blocks: a Fisher-Yates permutation of disjoint slots.
    let mut slots: Vec<usize> = (0..nb).collect();
    for i in (1..nb).rev() {
        slots.swap(i, rng.below(i + 1));
    }
    Case {
        dims,
        a: rng.normal_vec(nb * a_sz),
        b: rng.normal_vec(nb * b_sz),
        c0: rng.normal_vec(nb * c_sz),
        ao: contiguous_offsets(nb, a_sz),
        bo: contiguous_offsets(nb, b_sz),
        co: slots.into_iter().map(|s| s * c_sz).collect(),
    }
}

fn run_case(case: &Case, pool: &ParallelPool) -> Vec<f64> {
    let be = NativeBackend;
    let mut c = case.c0.clone();
    let mut mt = Metrics::new();
    be.batched_gemm_on(
        pool,
        case.dims,
        BatchRef { data: &case.a, offsets: &case.ao },
        BatchRef { data: &case.b, offsets: &case.bo },
        &mut c,
        &case.co,
        &mut mt,
    );
    c
}

#[test]
fn parallel_gemm_bitwise_identical_to_serial_property() {
    let serial = ParallelPool::new(1);
    let wide = ParallelPool::new(4);
    check(
        "parallel gemm == serial gemm (bitwise)",
        71,
        40,
        |rng| {
            let big = rng.below(2) == 1;
            gen_case(rng, big)
        },
        |case| {
            let want = run_case(case, &serial);
            let got = run_case(case, &wide);
            if want == got {
                Ok(())
            } else {
                let i = want.iter().zip(&got).position(|(x, y)| x != y).unwrap();
                Err(format!(
                    "dims {:?}: element {i} differs: serial {} vs parallel {}",
                    case.dims, want[i], got[i]
                ))
            }
        },
    );
}

#[test]
fn parallel_qr_and_svd_bitwise_identical_to_serial() {
    let serial = ParallelPool::new(1);
    let wide = ParallelPool::new(4);
    let be = NativeBackend;
    let mut rng = Prng::new(72);
    // Batch large enough to dispatch in parallel.
    let (nb, rows, cols) = (96, 24, 12);
    let a = rng.normal_vec(nb * rows * cols);
    let mut mt = Metrics::new();

    let run_qr = |pool: &ParallelPool, mt: &mut Metrics| {
        let mut q = vec![0.0; nb * rows * cols];
        let mut r = vec![0.0; nb * cols * cols];
        be.batched_qr_on(pool, nb, rows, cols, &a, &mut q, &mut r, mt);
        (q, r)
    };
    let (q1, r1) = run_qr(&serial, &mut mt);
    let (q4, r4) = run_qr(&wide, &mut mt);
    assert_eq!(q1, q4, "parallel QR Q differs from serial");
    assert_eq!(r1, r4, "parallel QR R differs from serial");

    let run_qr_r = |pool: &ParallelPool, mt: &mut Metrics| {
        let mut r = vec![0.0; nb * cols * cols];
        be.batched_qr_r_on(pool, nb, rows, cols, &a, &mut r, mt);
        r
    };
    assert_eq!(
        run_qr_r(&serial, &mut mt),
        run_qr_r(&wide, &mut mt),
        "parallel R-only QR differs from serial"
    );

    let run_svd = |pool: &ParallelPool, mt: &mut Metrics| {
        let mut u = vec![0.0; nb * rows * cols];
        let mut s = vec![0.0; nb * cols];
        let mut v = vec![0.0; nb * cols * cols];
        be.batched_svd_on(pool, nb, rows, cols, &a, &mut u, &mut s, &mut v, mt);
        (u, s, v)
    };
    let (u1, s1, v1) = run_svd(&serial, &mut mt);
    let (u4, s4, v4) = run_svd(&wide, &mut mt);
    assert_eq!(u1, u4, "parallel SVD U differs from serial");
    assert_eq!(s1, s4, "parallel SVD S differs from serial");
    assert_eq!(v1, v4, "parallel SVD V differs from serial");
}

#[test]
fn one_backend_is_safe_from_concurrent_rank_threads() {
    // The threaded executor shares one backend across its per-rank OS
    // threads; with the parallel backend those ranks contend for one pool
    // (winner parallelizes, losers run inline). Every rank's product must
    // still be bitwise-correct, every time.
    let pool = ParallelPool::new(3);
    let serial = ParallelPool::new(1);
    let mut rng = Prng::new(73);
    let cases: Vec<Case> = (0..4).map(|i| gen_case(&mut rng, i % 2 == 0)).collect();
    let expected: Vec<Vec<f64>> = cases.iter().map(|c| run_case(c, &serial)).collect();
    std::thread::scope(|s| {
        for (case, want) in cases.iter().zip(&expected) {
            let pool = &pool;
            s.spawn(move || {
                for round in 0..20 {
                    let got = run_case(case, pool);
                    assert_eq!(&got, want, "round {round}: concurrent result differs");
                }
            });
        }
    });
}

#[test]
fn gemm_dispatch_makes_zero_allocations() {
    // The acceptance bar for the hot path: once the pool exists and the
    // buffers are built, a batched GEMM call allocates nothing on the
    // dispatching thread — any size, any transpose combination (the
    // trans_a+trans_b case used to build an explicit Aᵀ temporary per
    // block). Debug builds are exempt: the dispatch's conflict-free-offset
    // verifier (`debug_assertions` only) sorts a copy of the offsets.
    if cfg!(debug_assertions) {
        println!("skipped: the debug-build disjointness verifier allocates by design");
        return;
    }
    let be = NativeBackend;
    let pool = ParallelPool::new(4);
    let (nb, m, k, n) = (256, 16, 16, 16);
    let mut rng = Prng::new(74);
    let a = rng.normal_vec(nb * m * k);
    let b = rng.normal_vec(nb * k * n);
    let mut c = vec![0.0; nb * m * n];
    let ao = contiguous_offsets(nb, m * k);
    let bo = contiguous_offsets(nb, k * n);
    let co = contiguous_offsets(nb, m * n);
    let mut mt = Metrics::new();
    for &(ta, tb) in &[(false, false), (true, false), (false, true), (true, true)] {
        let dims =
            GemmDims { nb, m, k, n, trans_a: ta, trans_b: tb, accumulate: true };
        let call = |c: &mut [f64], mt: &mut Metrics| {
            be.batched_gemm_on(
                &pool,
                dims,
                BatchRef { data: &a, offsets: &ao },
                BatchRef { data: &b, offsets: &bo },
                c,
                &co,
                mt,
            );
        };
        call(&mut c, &mut mt); // warmup: first dispatch wakes the parked workers
        let before = my_allocs();
        for _ in 0..10 {
            call(&mut c, &mut mt);
        }
        let after = my_allocs();
        assert_eq!(
            after - before,
            0,
            "batched_gemm (trans_a={ta}, trans_b={tb}) allocated on the hot path"
        );
    }
}
