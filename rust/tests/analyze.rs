//! Analyzer property tests on synthetic span DAGs with *known* critical
//! paths and overlap fractions, determinism under input reordering, and
//! trajectory regression-gate round trips through serialized rows.

use h2opus::dist::hgemv::CostModel;
use h2opus::obs::analyze::{analyze_events, analyze_json, AEvent};
use h2opus::obs::trajectory::{
    apply_slowdown, check_regressions, metric_direction, parse_rows, BenchRow, Direction,
    DEFAULT_BAND,
};
use h2opus::util::testing::{check, parse_json};

fn ev(name: &str, cat: &str, pid: usize, tid: usize, ts: f64, dur: f64) -> AEvent {
    AEvent { name: name.to_string(), cat: cat.to_string(), pid, tid, ts_us: ts, dur_us: dur }
}

fn cm() -> CostModel {
    CostModel::default()
}

/// A zero-slack chain across ranks: span i starts exactly when span i-1
/// ends, each on its own stream, so the happens-before walk must recover
/// the whole chain — total time = makespan, coverage = 1, bound phase =
/// the longest link.
#[test]
fn critical_path_recovers_a_known_chain() {
    check(
        "chain critical path",
        0xC41A,
        64,
        |rng| {
            let k = 3 + rng.below(9);
            let mut evs = Vec::new();
            let mut durs = Vec::new();
            let mut t = 0.0;
            for i in 0..k {
                let d = rng.range(1.0, 10.0);
                let cat = if i % 2 == 0 { "compute" } else { "comm" };
                // Unique (pid, tid) per span: every link waits on the
                // previous one through a wait-release edge.
                evs.push(ev(&format!("step {i}"), cat, i % 3, 10 + i, t, d));
                durs.push(d);
                t += d;
            }
            (evs, durs, t)
        },
        |(evs, durs, makespan)| {
            let a = analyze_events(evs.clone(), &[], &cm());
            let cp = &a.critical_path;
            if cp.len != evs.len() {
                return Err(format!("path covers {} of {} spans", cp.len, evs.len()));
            }
            if (cp.total_us - makespan).abs() > 1e-6 * makespan {
                return Err(format!("path time {} != makespan {makespan}", cp.total_us));
            }
            if (cp.coverage - 1.0).abs() > 1e-6 {
                return Err(format!("coverage {} != 1", cp.coverage));
            }
            let longest = durs
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .map(|(i, _)| format!("step {i}"))
                .unwrap();
            if cp.bound_phase != longest {
                return Err(format!("bound '{}' != longest link '{longest}'", cp.bound_phase));
            }
            Ok(())
        },
    );
}

/// Overlap efficiency at the two analytic extremes: communication with no
/// concurrent compute anywhere scores 0; communication fully nested in
/// another rank's compute scores 1.
#[test]
fn overlap_extremes_score_zero_and_one() {
    check(
        "overlap extremes",
        0x0E0E,
        64,
        |rng| (rng.range(5.0, 20.0), rng.range(0.1, 3.0), rng.range(5.0, 20.0)),
        |&(c, gap, w)| {
            // Zero: the only compute starts strictly after the wire span ends.
            let evs = vec![
                ev("ship input #0", "comm", 0, 0, 0.0, c),
                ev("upsweep", "compute", 1, 1, c + gap, w),
            ];
            let a = analyze_events(evs, &[], &cm());
            let r0 = a.ranks.iter().find(|r| r.pid == 0).unwrap();
            if r0.overlap_eff != 0.0 {
                return Err(format!("zero case: eff={}", r0.overlap_eff));
            }
            // Full: the wire span is nested inside compute on another rank.
            let evs = vec![
                ev("ship input #0", "comm", 0, 0, 1.0, c),
                ev("upsweep", "compute", 1, 1, 0.5, c + w),
            ];
            let a = analyze_events(evs, &[], &cm());
            let r0 = a.ranks.iter().find(|r| r.pid == 0).unwrap();
            if (r0.overlap_eff - 1.0).abs() > 1e-12 {
                return Err(format!("full case: eff={}", r0.overlap_eff));
            }
            // The fleet minimum is the one comm-bearing rank's score.
            if (a.min_overlap_eff() - r0.overlap_eff).abs() > 1e-12 {
                return Err(format!("min {} != rank0 {}", a.min_overlap_eff(), r0.overlap_eff));
            }
            Ok(())
        },
    );
}

/// Shuffling the input event order must not change a single byte of
/// either report: the analyzer normalizes to a total order first.
#[test]
fn reports_are_byte_identical_under_reordering() {
    let names: [(&str, &str); 5] = [
        ("product #1", "compute"),
        ("upsweep L2", "compute"),
        ("ship input #3", "comm"),
        ("orth transfer x64", "transfer"),
        ("wait", "lowprio"),
    ];
    check(
        "report determinism",
        0xD37E,
        32,
        |rng| {
            let n = 2 + rng.below(24);
            let mut evs = Vec::new();
            for _ in 0..n {
                let (name, cat) = names[rng.below(names.len())];
                evs.push(ev(
                    name,
                    cat,
                    rng.below(3),
                    rng.below(2),
                    rng.range(0.0, 100.0),
                    rng.range(0.1, 10.0),
                ));
            }
            // Fisher-Yates with the same deterministic generator.
            let mut shuffled = evs.clone();
            for i in (1..shuffled.len()).rev() {
                shuffled.swap(i, rng.below(i + 1));
            }
            (evs, shuffled)
        },
        |(evs, shuffled)| {
            let a = analyze_events(evs.clone(), &[], &cm());
            let b = analyze_events(shuffled.clone(), &[], &cm());
            if a.render_text(8) != b.render_text(8) {
                return Err("text reports differ under reordering".into());
            }
            if a.to_json() != b.to_json() {
                return Err("JSON reports differ under reordering".into());
            }
            Ok(())
        },
    );
}

/// Object-form traces feed metadata through to truncation warnings and
/// CostModel drift rows, and the JSON report stays strict.
#[test]
fn object_form_metadata_drives_dropped_and_drift() {
    let json = r#"{
      "traceEvents": [
        {"name": "product #0", "cat": "compute", "ph": "X", "pid": 0, "tid": 0, "ts": 0.0, "dur": 100.0},
        {"name": "ship input #0", "cat": "comm", "ph": "X", "pid": 0, "tid": 1, "ts": 10.0, "dur": 50.0}
      ],
      "metadata": {"total_dropped": 7, "parts": [
        {"pid": 0, "dropped": 7,
         "work": {"flops": 1000000.0, "bytes_sent": 4096.0, "messages": 2.0,
                  "launches": 3.0, "gemm_words": 2000.0}}
      ]}
    }"#;
    let a = analyze_json(json, &cm()).unwrap();
    assert_eq!(a.total_dropped, 7);
    assert_eq!(a.dropped, vec![(0, 7)]);
    assert_eq!(a.drift.len(), 2, "compute + wire drift rows");
    let text = a.render_text(5);
    assert!(text.contains("truncated"), "truncation warning missing:\n{text}");
    let report = parse_json(&a.to_json()).expect("report must be strict JSON");
    assert_eq!(report.get("total_dropped").and_then(|v| v.as_f64()), Some(7.0));
    assert!(report.get("critical_path").is_some());
    assert!(report.get("drift").is_some());

    // The bare-array form is accepted too, with no metadata.
    let bare = r#"[{"name": "upsweep", "cat": "compute", "ph": "X",
                   "pid": 0, "tid": 0, "ts": 0.0, "dur": 5.0}]"#;
    let a = analyze_json(bare, &cm()).unwrap();
    assert_eq!(a.events, 1);
    assert_eq!(a.total_dropped, 0);
    assert!(!a.render_text(5).contains("truncated"));
}

#[test]
fn metric_directions_follow_key_conventions() {
    assert_eq!(metric_direction("rows_per_s"), Direction::HigherBetter);
    assert_eq!(metric_direction("effective_gflops"), Direction::HigherBetter);
    assert_eq!(metric_direction("elapsed_s"), Direction::LowerBetter);
    assert_eq!(metric_direction("latency_p99_us"), Direction::LowerBetter);
    assert_eq!(metric_direction("peak_bytes"), Direction::LowerBetter);
    assert_eq!(metric_direction("ranks"), Direction::Info);
}

/// The gate passes two identical appended runs and fails when the
/// injected-slowdown hook doubles every directional metric — exercised
/// through the serialized line format, as CI uses it.
#[test]
fn regression_gate_round_trips_through_serialized_rows() {
    let mk = |t: f64, rate: f64| {
        let mut r = BenchRow::new("hgemv_weak", "p=4 n=4096");
        r.set_metric("elapsed_s", t);
        r.set_metric("rows_per_s", rate);
        r
    };
    let flat = format!("{}\n{}\n", mk(1.0, 100.0).to_json_line(), mk(1.0, 100.0).to_json_line());
    let rep = check_regressions(&parse_rows(&flat).unwrap(), DEFAULT_BAND);
    assert_eq!(rep.failures(), 0, "{}", rep.render_text());
    assert_eq!(rep.checks.len(), 2);

    let mut slow = mk(1.0, 100.0);
    apply_slowdown(&mut slow, 2.0);
    let text = format!("{}\n{}\n", mk(1.0, 100.0).to_json_line(), slow.to_json_line());
    let rep = check_regressions(&parse_rows(&text).unwrap(), DEFAULT_BAND);
    assert_eq!(rep.failures(), 2, "{}", rep.render_text());
    assert!(rep.render_text().contains("FAIL hgemv_weak"));
}

/// End to end through the filesystem: append under `H2OPUS_TRAJECTORY`,
/// reload, gate. Kept as the single env-touching test in this binary so
/// parallel test threads cannot race on the variable.
#[test]
fn append_row_honors_env_override_and_slowdown_hook() {
    use h2opus::obs::trajectory::{append_row, load_rows, SLOWDOWN_ENV, TRAJECTORY_ENV};
    let path = std::env::temp_dir().join(format!("h2opus_traj_{}.jsonl", std::process::id()));
    let _ = std::fs::remove_file(&path);
    std::env::set_var(TRAJECTORY_ENV, &path);

    let row = BenchRow::new("overlap", "p=2").metric("product_s", 0.5);
    append_row(&row).unwrap();
    std::env::set_var(SLOWDOWN_ENV, "2.0");
    append_row(&row).unwrap();
    std::env::remove_var(SLOWDOWN_ENV);
    std::env::remove_var(TRAJECTORY_ENV);

    let rows = load_rows(&path).unwrap();
    assert_eq!(rows.len(), 2);
    assert_eq!(rows[0].metrics[0], ("product_s".to_string(), 0.5));
    assert_eq!(rows[1].metrics[0], ("product_s".to_string(), 1.0));
    let rep = check_regressions(&rows, DEFAULT_BAND);
    assert_eq!(rep.failures(), 1, "{}", rep.render_text());
    let _ = std::fs::remove_file(&path);
}

/// Pre-existing shape from the paper's Fig. 8 story: upsweep / downsweep
/// compute with interleaved wire spans; perfect pipelining means every
/// wire second is hidden and the analyzer's rank table says so.
#[test]
fn pipelined_trace_reports_full_overlap_and_compute_bound_path() {
    let evs = vec![
        // Rank 0 computes back to back on stream (0,0).
        ev("upsweep", "compute", 0, 0, 0.0, 40.0),
        ev("downsweep", "compute", 0, 0, 40.0, 60.0),
        // Rank 1's sends sit entirely under rank 0's compute.
        ev("ship input #0", "comm", 1, 1, 5.0, 20.0),
        ev("ship input #1", "comm", 1, 1, 50.0, 30.0),
    ];
    let a = analyze_events(evs, &[], &cm());
    let r1 = a.ranks.iter().find(|r| r.pid == 1).unwrap();
    assert!((r1.overlap_eff - 1.0).abs() < 1e-12, "wire fully hidden, eff={}", r1.overlap_eff);
    assert_eq!(a.critical_path.bound_pid, 0, "compute rank bounds the makespan");
    assert_eq!(a.makespan_us, 100.0);
}
