//! Fault-injection (chaos) suite: deterministic `FaultPlan`s drive
//! worker-side drops, duplicates, corruption, delays and kills, and the
//! robustness layers must hold the line —
//!
//! - corrupt frames surface as *typed* `Protocol` errors (CRC framing),
//!   never as silent garbage or hangs;
//! - duplicated frames are absorbed by the idempotent collect path;
//! - a [`SessionSupervisor`] reaps the dead crew, respawns it from the
//!   recorded job and replays in-flight products exactly-once, so every
//!   recovered product is **bitwise identical** to the serial reference;
//! - the request-coalescing server keeps its ledger balanced
//!   (`submitted == completed + failed`) whatever the fault;
//! - stalls are bounded: shutdown reaps within the configured grace,
//!   handshake crashes and silent stats sockets surface errors promptly.

#![cfg(unix)]

use std::path::PathBuf;
use std::time::{Duration, Instant};

use h2opus::backend::native::NativeBackend;
use h2opus::compression::compress_full;
use h2opus::dist::supervisor::{SessionSupervisor, SupervisorOptions};
use h2opus::dist::transport::chaos::{FaultPlan, CHAOS_PLAN_ENV};
use h2opus::dist::transport::server::{fetch_stats_within, ServerOptions, SessionServer};
use h2opus::dist::transport::socket::{SocketOptions, SocketSession};
use h2opus::dist::transport::{JobKind, MatrixJob, TransportError};
use h2opus::matvec::{hgemv, HgemvPlan, HgemvWorkspace};
use h2opus::metrics::Metrics;
use h2opus::util::Prng;

/// The conformance matrix: N = 256, depth 4 (same as tests/serving.rs).
fn conformance_job() -> MatrixJob {
    MatrixJob {
        dim: 2,
        n_side: 16,
        leaf_size: 16,
        eta: 0.9,
        cheb_grid: 3,
        corr_len: 0.1,
        kind: JobKind::Exponential,
    }
}

/// Compression tolerance for the recovery-of-compressed-sessions tests
/// (same as tests/compress_dist.rs — it genuinely truncates this
/// operator).
const TAU: f64 = 1e-4;

/// Serial reference for the compressed operator: `compress_full` on a
/// clone, exactly what the distributed compression is bitwise-conformant
/// to.
fn serial_compressed(a: &h2opus::tree::H2Matrix) -> h2opus::tree::H2Matrix {
    let mut work = a.clone();
    let mut metrics = Metrics::new();
    compress_full(&mut work, TAU, &NativeBackend, &mut metrics).0
}

fn serial_product(a: &h2opus::tree::H2Matrix, x: &[f64], nv: usize) -> Vec<f64> {
    let n = a.n();
    let plan = HgemvPlan::new(a, nv);
    let mut ws = HgemvWorkspace::new(a, nv);
    let mut metrics = Metrics::new();
    let mut y = vec![0.0; n * nv];
    hgemv(a, &NativeBackend, &plan, x, &mut y, &mut ws, &mut metrics);
    y
}

/// Worker options tuned for fault tests: a short recv deadline so
/// dropped frames surface as `Timeout` in seconds (not the default
/// minute), a tight shutdown grace so reaping a dead crew is fast, and
/// the chaos plan armed on the workers via their inherited environment.
fn chaos_opts(plan: &str) -> SocketOptions {
    let mut extra_env = Vec::new();
    if !plan.is_empty() {
        extra_env.push((CHAOS_PLAN_ENV.to_string(), plan.to_string()));
    }
    SocketOptions {
        worker_exe: PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        timeout: Duration::from_secs(6),
        extra_env,
        shutdown_grace: Duration::from_millis(400),
        ..SocketOptions::default()
    }
}

/// A worker killed by the plan mid-pipeline is reaped; the supervisor
/// respawns the crew and replays the in-flight product — every one of
/// the six products is bitwise identical to the serial reference, and
/// the recovery is visible in [`RecoveryStats`].
#[test]
fn supervisor_recovers_from_a_worker_kill_bitwise() {
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let mut sup = SessionSupervisor::start(
        &job,
        2,
        1,
        chaos_opts("kill,src=1,nth=4"),
        SupervisorOptions { max_rebuilds: 2 },
    )
    .expect("supervised start");
    assert_eq!(sup.n(), n);
    let mut rng = Prng::new(4242);
    for k in 0..6 {
        let x = rng.normal_vec(n);
        let mut y = vec![0.0; n];
        sup.hgemv(&x, &mut y).expect("supervised product");
        assert_eq!(y, serial_product(&a, &x, 1), "product {k} not bitwise equal");
    }
    let st = sup.recovery_stats();
    assert!(st.recoveries >= 1, "the kill must have forced a recovery: {st:?}");
    assert!(st.last_recovery_s > 0.0 && st.total_recovery_s >= st.last_recovery_s, "{st:?}");
    assert!(!sup.is_degraded(), "budget of 2 must absorb one kill");
    assert_eq!(sup.in_flight(), 0);
}

/// In-flight pipelined products survive the crash: three products are
/// submitted before any is collected, the kill lands mid-pipeline, and
/// the replay delivers all three bitwise-correct, exactly once each.
#[test]
fn supervisor_replays_in_flight_products_exactly_once() {
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let mut sup = SessionSupervisor::start(
        &job,
        2,
        1,
        chaos_opts("kill,src=0,nth=3"),
        SupervisorOptions { max_rebuilds: 2 },
    )
    .expect("supervised start");
    let mut rng = Prng::new(515);
    let xs: Vec<Vec<f64>> = (0..3).map(|_| rng.normal_vec(n)).collect();
    let pids: Vec<u64> =
        xs.iter().map(|x| sup.submit(x, 1).expect("supervised submit")).collect();
    assert_eq!(sup.in_flight(), 3);
    for (k, (pid, x)) in pids.iter().zip(&xs).enumerate() {
        let mut y = vec![0.0; n];
        sup.wait(*pid, &mut y).expect("supervised wait");
        assert_eq!(y, serial_product(&a, x, 1), "replayed product {k} not bitwise equal");
    }
    let st = sup.recovery_stats();
    assert!(st.recoveries >= 1, "{st:?}");
    assert!(st.replayed_products >= 1, "replay must be recorded: {st:?}");
}

/// Past the rebuild budget the supervisor degrades to fail-fast: the
/// triggering call reports the exhausted budget and every later call
/// returns the same typed error immediately instead of respawning.
#[test]
fn supervisor_degrades_to_fail_fast_past_the_budget() {
    let job = conformance_job();
    let n = job.build().n();
    let mut sup = SessionSupervisor::start(
        &job,
        2,
        1,
        chaos_opts("kill,src=1,nth=2"),
        SupervisorOptions { max_rebuilds: 0 },
    )
    .expect("supervised start");
    let x = vec![1.0; n];
    let mut y = vec![0.0; n];
    let msg = sup.hgemv(&x, &mut y).expect_err("budget 0 cannot recover").to_string();
    assert!(msg.contains("exhausted"), "error must name the budget: {msg}");
    assert!(sup.is_degraded());
    let t0 = Instant::now();
    let again = sup.hgemv(&x, &mut y).expect_err("degraded supervisor fails fast");
    assert!(t0.elapsed() < Duration::from_secs(1), "fail-fast must not respawn");
    assert!(again.to_string().contains("exhausted"), "{again}");
}

/// A duplicated `Output` frame (chaos `dup`) is absorbed by the
/// idempotent collect path on a *plain* session: both products complete
/// bitwise-correct, nothing errors, nothing hangs.
#[test]
fn duplicate_output_frames_are_deduped() {
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let mut session =
        SocketSession::start(&job, 2, 1, chaos_opts("dup,src=0,kind=output,nth=1"))
            .expect("session start");
    let mut rng = Prng::new(77);
    for k in 0..2 {
        let x = rng.normal_vec(n);
        let mut y = vec![0.0; n];
        session.hgemv(&x, &mut y).expect("product under duplication");
        assert_eq!(y, serial_product(&a, &x, 1), "product {k} not bitwise equal");
    }
    assert_eq!(session.products(), 2);
}

/// A bit flipped below the checksums surfaces as a typed `Protocol`
/// error naming the CRC on a plain session — and the same fault under a
/// supervisor is absorbed, with the recovered product bitwise-correct.
#[test]
fn corrupt_frames_are_typed_errors_and_recoverable() {
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    // Bit 300 lands in the payload (the header is bits 0..256), so the
    // payload CRC must catch it.
    let plan = "flip=300,src=1,kind=output,nth=1";
    let mut session = SocketSession::start(&job, 2, 1, chaos_opts(plan)).expect("start");
    let x = vec![1.0; n];
    let mut y = vec![0.0; n];
    let err = session.hgemv(&x, &mut y).expect_err("corruption must not pass");
    assert!(
        matches!(err, TransportError::Protocol(_)),
        "corruption must be a typed Protocol error, got: {err}"
    );
    assert!(err.to_string().contains("checksum"), "error must name the CRC: {err}");
    drop(session);

    let mut sup = SessionSupervisor::start(
        &job,
        2,
        1,
        chaos_opts(plan),
        SupervisorOptions { max_rebuilds: 2 },
    )
    .expect("supervised start");
    let mut yr = vec![0.0; n];
    sup.hgemv(&x, &mut yr).expect("supervised product under corruption");
    assert_eq!(yr, serial_product(&a, &x, 1), "recovered product not bitwise equal");
    assert!(sup.recovery_stats().recoveries >= 1);
}

/// A rank that dies at the compression start frame poisons the compress
/// call; the supervisor respawns the crew with the crash hook *cleared*
/// (an empty override, which the worker must treat as "disabled", never
/// "crash every rank") and the retried compression succeeds — every
/// product after it applies the compressed operator bitwise.
#[test]
fn supervisor_recovers_a_crash_during_compression() {
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let ac = serial_compressed(&a);
    let mut opts = chaos_opts("");
    // Rank 1 exits the moment the compression start frame lands.
    opts.extra_env.push(("H2OPUS_TEST_CRASH_ON_COMPRESS".to_string(), "1".to_string()));
    let mut sup = SessionSupervisor::start(
        &job,
        2,
        1,
        opts,
        SupervisorOptions { max_rebuilds: 2 },
    )
    .expect("supervised start");
    sup.compress(TAU).expect("supervised compression must survive the crash");
    assert!(
        sup.recovery_stats().recoveries >= 1,
        "the crash must have forced a recovery: {:?}",
        sup.recovery_stats()
    );
    let mut rng = Prng::new(9401);
    for k in 0..3 {
        let x = rng.normal_vec(n);
        let mut y = vec![0.0; n];
        sup.hgemv(&x, &mut y).expect("post-compression product");
        assert_eq!(
            y,
            serial_product(&ac, &x, 1),
            "product {k} not bitwise equal to compressed serial"
        );
    }
    assert!(!sup.is_degraded(), "budget of 2 must absorb one compression crash");
}

/// A kill landing *after* a successful compression forces a rebuild of a
/// compressed session: the recorded τ is re-applied on the fresh crew —
/// whose fault hooks are all cleared with empty overrides — and the
/// replayed + subsequent products apply the compressed operator bitwise.
/// Regression: a rebuild that re-compresses must not trip the cleared
/// `H2OPUS_TEST_CRASH_ON_COMPRESS` hook on the respawned workers.
#[test]
fn rebuild_of_a_compressed_session_recompresses_to_tau() {
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let ac = serial_compressed(&a);
    // Compression traffic carries no `Output` frames, so the kill is
    // armed safely past it: rank 1 dies sending its second product
    // output.
    let mut sup = SessionSupervisor::start(
        &job,
        2,
        1,
        chaos_opts("kill,src=1,kind=output,nth=2"),
        SupervisorOptions { max_rebuilds: 2 },
    )
    .expect("supervised start");
    sup.compress(TAU).expect("compression completes before the kill fires");
    assert_eq!(
        sup.recovery_stats().recoveries,
        0,
        "an output-keyed kill must not fire during compression"
    );
    let mut rng = Prng::new(625);
    for k in 0..4 {
        let x = rng.normal_vec(n);
        let mut y = vec![0.0; n];
        sup.hgemv(&x, &mut y).expect("supervised product");
        assert_eq!(
            y,
            serial_product(&ac, &x, 1),
            "product {k} not bitwise equal to compressed serial"
        );
    }
    let st = sup.recovery_stats();
    assert!(st.recoveries >= 1, "the kill must have forced a recovery: {st:?}");
    assert!(!sup.is_degraded(), "budget of 2 must absorb one kill");
}

/// A non-empty `H2OPUS_CHAOS_PLAN` that fails to parse must abort the
/// run loudly — a typo'd plan silently disabling fault injection would
/// turn a chaos run into a test of nothing.
#[test]
fn a_typo_in_the_chaos_plan_is_a_loud_error() {
    let job = conformance_job();
    let n = job.build().n();
    let mut opts = chaos_opts("kil,src=1,nth=1"); // typo: "kil"
    opts.timeout = Duration::from_secs(5);
    let x = vec![1.0; n];
    let mut y = vec![0.0; n];
    let t0 = Instant::now();
    let result = SocketSession::start(&job, 2, 1, opts)
        .and_then(|mut session| session.hgemv(&x, &mut y).map(|_| ()));
    let elapsed = t0.elapsed();
    result.expect_err("a typo'd chaos plan must fail the session, not run without faults");
    assert!(elapsed < Duration::from_secs(20), "took {elapsed:?} — behaved like a hang");
}

/// The soak matrix: explicit fault plans × P ∈ {2, 4} through the
/// supervised request-coalescing server. Every request must come back
/// bitwise-identical to the serial reference and the server ledger must
/// balance with zero failures — recovery is invisible to clients.
#[test]
fn chaos_soak_explicit_plans_server_conformance() {
    let cases: &[(&str, usize)] = &[
        ("kill,src=1,nth=5", 2),
        ("kill,src=3,nth=6", 4),
        ("trunc=16,src=1,kind=output,nth=2", 2),
        ("drop,src=0,kind=xhat,nth=3", 2),
        ("delay=25,src=0,nth=2", 4),
        ("dup,src=1,kind=output,nth=2", 4),
    ];
    for &(plan, p) in cases {
        soak_one(plan, p);
    }
}

fn soak_one(plan: &str, p: usize) {
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let server = SessionServer::start_supervised(
        &job,
        p,
        chaos_opts(plan),
        ServerOptions { max_coalesce: 4, pipeline_depth: 2 },
        SupervisorOptions { max_rebuilds: 3 },
    )
    .unwrap_or_else(|e| panic!("supervised server start (plan {plan:?}, P = {p}): {e}"));
    let mut rng = Prng::new(1900 + p as u64);
    let xs: Vec<Vec<f64>> = (0..4).map(|_| rng.normal_vec(n)).collect();
    let handles: Vec<_> = xs
        .iter()
        .map(|x| server.submit(x).expect("submit under chaos"))
        .collect();
    for (k, (h, x)) in handles.into_iter().zip(&xs).enumerate() {
        let served = h
            .wait()
            .unwrap_or_else(|e| panic!("request {k} failed (plan {plan:?}, P = {p}): {e}"));
        assert_eq!(
            served.y,
            serial_product(&a, x, 1),
            "request {k} not bitwise equal (plan {plan:?}, P = {p})"
        );
    }
    let st = server.stats();
    assert_eq!(st.submitted, 4, "ledger (plan {plan:?}, P = {p}): {}", st.summary());
    assert_eq!(
        st.submitted,
        st.completed + st.failed,
        "ledger must balance (plan {plan:?}, P = {p}): {}",
        st.summary()
    );
    assert_eq!(st.failed, 0, "recovery must be client-invisible (plan {plan:?}, P = {p})");
}

/// Seeded soak: fault plans derived from `FaultPlan::from_seed` over the
/// seeds in `H2OPUS_CHAOS_SOAK_SEEDS` (comma-separated; CI pins two and
/// adds one randomized, printed seed). Whatever the plan, requests must
/// come back bitwise-correct with a balanced, failure-free ledger.
#[test]
fn seeded_soak_is_reproducible() {
    let seeds = std::env::var("H2OPUS_CHAOS_SOAK_SEEDS").unwrap_or_else(|_| "190841,77".into());
    for tok in seeds.split(',').map(str::trim).filter(|t| !t.is_empty()) {
        let seed: u64 = tok.parse().unwrap_or_else(|e| panic!("bad soak seed {tok:?}: {e}"));
        let plan = FaultPlan::from_seed(seed, 2);
        println!("chaos soak seed {seed} -> plan \"{plan}\"");
        soak_one(&plan.to_string(), 2);
    }
}

/// Satellite: a worker that ignores `Shutdown` (stall hook) is reaped
/// within the configured grace — dropping the session is bounded, not a
/// 120 s hang on the stalled child.
#[test]
fn stalled_workers_are_reaped_within_the_grace_bound() {
    let job = conformance_job();
    let n = job.build().n();
    let mut opts = chaos_opts("");
    opts.extra_env.push(("H2OPUS_TEST_STALL_ON_SHUTDOWN".to_string(), "1".to_string()));
    opts.shutdown_grace = Duration::from_millis(300);
    let mut session = SocketSession::start(&job, 2, 1, opts).expect("session start");
    let x = vec![1.0; n];
    let mut y = vec![0.0; n];
    session.hgemv(&x, &mut y).expect("product");
    let t0 = Instant::now();
    drop(session);
    let reaped_in = t0.elapsed();
    assert!(
        reaped_in < Duration::from_secs(5),
        "stalled workers must be reaped within the grace bound, took {reaped_in:?}"
    );
}

/// Satellite: a rank that dies *during* the clock-sync handshake (the
/// 8-ping exchange) surfaces a prompt typed error from
/// `SocketSession::start` — the session deadline covers the handshake,
/// so setup never hangs on a half-connected crew.
#[test]
fn handshake_crash_is_a_prompt_typed_error() {
    let job = conformance_job();
    let mut opts = chaos_opts("");
    opts.timeout = Duration::from_secs(5);
    opts.extra_env.push(("H2OPUS_TEST_CRASH_RANK".to_string(), "1@handshake".to_string()));
    let t0 = Instant::now();
    let err = match SocketSession::start(&job, 2, 1, opts) {
        Ok(_) => panic!("start must fail when rank 1 dies in the handshake"),
        Err(e) => e,
    };
    let elapsed = t0.elapsed();
    assert!(
        matches!(err, TransportError::Closed(_) | TransportError::Timeout(_)),
        "handshake death must be Closed or Timeout, got: {err}"
    );
    assert!(
        elapsed < Duration::from_secs(25),
        "handshake failure must surface within the deadline, took {elapsed:?}"
    );
}

/// Satellite: `fetch_stats_within` against a socket that accepts but
/// never answers returns a typed `Timeout` within the budget — the
/// stats client honors its deadline instead of hanging.
#[test]
fn fetch_stats_honors_its_deadline_against_a_silent_server() {
    let path = std::env::temp_dir().join(format!("h2opus-chaos-stats-{}.sock", std::process::id()));
    let _ = std::fs::remove_file(&path);
    // Bind but never accept/answer: the client's write lands in the
    // backlog buffer and the read must hit its deadline.
    let listener = std::os::unix::net::UnixListener::bind(&path).expect("bind silent socket");
    let t0 = Instant::now();
    let err = match fetch_stats_within(&path, Duration::from_millis(400)) {
        Ok(text) => panic!("silent server cannot produce a snapshot: {text:?}"),
        Err(e) => e,
    };
    let elapsed = t0.elapsed();
    drop(listener);
    let _ = std::fs::remove_file(&path);
    assert!(
        matches!(err, TransportError::Timeout(_)),
        "silent stats socket must be a typed Timeout, got: {err}"
    );
    assert!(elapsed < Duration::from_secs(5), "deadline not honored: {elapsed:?}");
}
