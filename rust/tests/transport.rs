//! Transport conformance suite + the O(N/P) memory regression test.
//!
//! Every transport must drive the distributed HGEMV to a *bitwise*
//! serial-identical result for P ∈ {1, 2, 4, 8}; deliveries may be
//! reordered across sources (tag matching must absorb that); a dead
//! worker process must surface as an error, not a hang; and the
//! branch-local workspaces must actually realize the O(N/P) memory
//! footprint the distributed format promises (≤ serial/P plus the level-C
//! boundary slack).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use h2opus::backend::native::NativeBackend;
use h2opus::config::H2Config;
use h2opus::construct::{build_h2, ExponentialKernel};
use h2opus::dist::hgemv::{dist_hgemv, DistOptions, ExecMode};
use h2opus::dist::transport::{inproc, Endpoint, JobKind, Mailbox, MatrixJob, Message, MsgKind};
use h2opus::dist::{BranchPlan, BranchWorkspace, Decomposition, ExchangePlan, ShardedMatrix};
use h2opus::geometry::PointSet;
use h2opus::matvec::{hgemv, HgemvPlan, HgemvWorkspace};
use h2opus::metrics::Metrics;
use h2opus::util::Prng;

/// The conformance matrix: N = 256, depth 4 (so P = 8 splits at C = 3).
fn conformance_job() -> MatrixJob {
    MatrixJob {
        dim: 2,
        n_side: 16,
        leaf_size: 16,
        eta: 0.9,
        cheb_grid: 3,
        corr_len: 0.1,
        kind: JobKind::Exponential,
    }
}

fn serial_product(a: &h2opus::tree::H2Matrix, x: &[f64], nv: usize) -> Vec<f64> {
    let n = a.n();
    let plan = HgemvPlan::new(a, nv);
    let mut ws = HgemvWorkspace::new(a, nv);
    let mut metrics = Metrics::new();
    let mut y = vec![0.0; n * nv];
    hgemv(a, &NativeBackend, &plan, x, &mut y, &mut ws, &mut metrics);
    y
}

/// InProc transport (pooled rank threads, branch-local workspaces):
/// bitwise identical to serial for every supported P.
#[test]
fn inproc_transport_bitwise_identical_all_p() {
    let a = conformance_job().build();
    let n = a.n();
    let mut rng = Prng::new(900);
    for nv in [1usize, 3] {
        let x = rng.normal_vec(n * nv);
        let y_serial = serial_product(&a, &x, nv);
        let opts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
        for p in [1usize, 2, 4, 8] {
            let mut y = vec![0.0; n * nv];
            let rep = dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y, &opts);
            assert_eq!(y, y_serial, "inproc P={p} nv={nv} not bitwise equal");
            assert!(rep.measured.unwrap() > 0.0);
        }
    }
}

/// The recording transport (active stamping wrapped around every
/// endpoint) stays bitwise-identical to serial for every P and produces a
/// measured Chrome trace with compute phases, message events and valid
/// bracketing.
#[test]
fn recording_transport_emits_measured_trace() {
    let a = conformance_job().build();
    let n = a.n();
    let mut rng = Prng::new(903);
    let x = rng.normal_vec(n);
    let y_serial = serial_product(&a, &x, 1);
    let opts = DistOptions {
        mode: ExecMode::Threaded,
        measured_trace: true,
        ..DistOptions::default()
    };
    for p in [1usize, 2, 8] {
        let mut y = vec![0.0; n];
        dist_hgemv(&a, &NativeBackend, p, 1, &x, &mut y, &opts);
        assert_eq!(y, y_serial, "recording P={p} not bitwise equal to serial");
    }
    let mut y = vec![0.0; n];
    let rep = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &opts);
    assert_eq!(y, y_serial, "recording P=4 not bitwise equal to serial");
    let json = rep.measured_trace_json.expect("measured trace requested");
    assert!(json.trim_start().starts_with('[') && json.trim_end().ends_with(']'));
    for needle in ["upsweep", "dense + diagonal mult", "downsweep", "send xhat", "top subtree"] {
        assert!(json.contains(needle), "measured trace missing {needle:?}");
    }
    // Without the flag the trace is not built.
    let opts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
    let rep = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &opts);
    assert!(rep.measured_trace_json.is_none());
}

/// Tag-matched receives must absorb arbitrary cross-source delivery
/// order: a Parent overtaking the Xhat exchange, levels arriving
/// scrambled.
#[test]
fn out_of_order_tag_delivery_is_absorbed() {
    let mut eps = inproc::mesh(2).into_iter();
    let mut a = eps.next().unwrap();
    let mut b = eps.next().unwrap();
    // Delivery order: Parent, Xhat L4, Xhat L3, Gather — consumed as
    // Xhat L3, Xhat L4, Parent, Gather.
    a.send(1, Message::new(MsgKind::Parent, 0, 0, vec![7.0])).unwrap();
    a.send(1, Message::new(MsgKind::Xhat, 4, 0, vec![4.0])).unwrap();
    a.send(1, Message::new(MsgKind::Xhat, 3, 0, vec![3.0])).unwrap();
    a.send(1, Message::new(MsgKind::Gather, 2, 0, vec![2.0])).unwrap();
    let mut mb = Mailbox::new();
    let m = mb.recv_where(&mut b, |t| t.kind == MsgKind::Xhat && t.level == 3).unwrap();
    assert_eq!(m.data, vec![3.0]);
    let m = mb.recv_where(&mut b, |t| t.kind == MsgKind::Xhat && t.level == 4).unwrap();
    assert_eq!(m.data, vec![4.0]);
    let m = mb.recv_kind(&mut b, MsgKind::Parent).unwrap();
    assert_eq!(m.data, vec![7.0]);
    let m = mb.recv_kind(&mut b, MsgKind::Gather).unwrap();
    assert_eq!(m.data, vec![2.0]);
    assert_eq!(mb.stashed(), 0, "nothing may be left behind");
}

/// The collective barrier releases every endpoint only after all arrived.
#[test]
fn inproc_barrier_synchronizes_all_endpoints() {
    let n = 4;
    let eps = inproc::mesh(n);
    let arrived = Arc::new(AtomicUsize::new(0));
    let handles: Vec<_> = eps
        .into_iter()
        .map(|mut ep| {
            let arrived = arrived.clone();
            std::thread::spawn(move || {
                arrived.fetch_add(1, Ordering::SeqCst);
                ep.barrier().unwrap();
                // After the barrier, every endpoint must have arrived.
                assert_eq!(arrived.load(Ordering::SeqCst), 4);
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

/// O(N/P) memory regression: the per-rank branch workspace must fit in
/// serial/P plus the level-C boundary slack (x̂ halo + dense leaf halo +
/// parent block), and actually shrink as P grows.
#[test]
fn per_rank_workspace_is_o_n_over_p() {
    // N = 1024, depth 6 — big enough that the halo is small against 1/P.
    let points = PointSet::grid_2d(32, 1.0);
    let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
    let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
    let a = build_h2(points, &kernel, &cfg);
    let nv = 2;
    let serial_bytes = HgemvWorkspace::new(&a, nv).memory_bytes();
    for p in [2usize, 4, 8] {
        let d = Decomposition::new(p, a.depth()).unwrap();
        let ex = ExchangePlan::build(&a, d);
        for r in 0..p {
            let sm = ShardedMatrix::from_global(&a, d, r);
            let bp = BranchPlan::build(&sm, &ex, nv);
            let bw = BranchWorkspace::new(&sm, &bp);
            let slack = bp.halo_bytes(&sm);
            assert!(
                bw.memory_bytes() <= serial_bytes / p + slack,
                "P={p} rank {r}: {} B > serial/P {} B + slack {} B",
                bw.memory_bytes(),
                serial_bytes / p,
                slack
            );
            assert!(
                bw.memory_bytes() < serial_bytes,
                "P={p} rank {r}: branch workspace not smaller than serial"
            );
            if p <= 4 {
                assert!(
                    slack < serial_bytes / p,
                    "P={p} rank {r}: slack {} B dominates serial/P {} B — bound vacuous",
                    slack,
                    serial_bytes / p
                );
            }
        }
    }
    // The master's top-only workspace is O(P), far below serial.
    let top = HgemvWorkspace::top_only(&a, nv, 3).memory_bytes();
    assert!(top < serial_bytes / 4, "top-only workspace {top} B not O(P)");
}

/// Socket transport: real worker subprocesses produce bitwise-identical
/// output to serial for P ∈ {1, 2, 4, 8}.
#[cfg(unix)]
#[test]
fn socket_transport_bitwise_identical_all_p() {
    use h2opus::dist::transport::socket::{socket_hgemv, SocketOptions};
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let mut rng = Prng::new(901);
    let nv = 1;
    let x = rng.normal_vec(n * nv);
    let y_serial = serial_product(&a, &x, nv);
    let opts = SocketOptions {
        worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        ..SocketOptions::default()
    };
    for p in [1usize, 2, 4, 8] {
        let mut y = vec![0.0; n * nv];
        let rep = socket_hgemv(&job, p, nv, &x, &mut y, &opts)
            .unwrap_or_else(|e| panic!("socket P={p}: {e}"));
        assert_eq!(y, y_serial, "socket P={p} not bitwise equal to serial");
        assert!(rep.measured > 0.0);
        assert_eq!(rep.per_rank.len(), p);
        assert!(rep.metrics.flops > 0);
    }
}

/// Socket transport with nv > 1 and a measured trace.
#[cfg(unix)]
#[test]
fn socket_transport_multivector_and_trace() {
    use h2opus::dist::transport::socket::{socket_hgemv, SocketOptions};
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let mut rng = Prng::new(902);
    let nv = 3;
    let x = rng.normal_vec(n * nv);
    let y_serial = serial_product(&a, &x, nv);
    let opts = SocketOptions {
        worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        measured_trace: true,
        ..SocketOptions::default()
    };
    let mut y = vec![0.0; n * nv];
    let rep = socket_hgemv(&job, 4, nv, &x, &mut y, &opts).expect("socket run");
    assert_eq!(y, y_serial, "socket nv=3 not bitwise equal");
    let json = rep.measured_trace_json.expect("trace requested");
    assert!(json.contains("upsweep") && json.contains("top subtree"));
}

/// A crashed worker must turn into a transport error at the coordinator —
/// promptly, not as a hang until some external timeout.
#[cfg(unix)]
#[test]
fn socket_worker_crash_propagates_error_not_hang() {
    use h2opus::dist::transport::socket::{socket_hgemv, SocketOptions};
    use std::time::{Duration, Instant};
    let job = conformance_job();
    let a = job.build();
    let n = a.n();
    let x = vec![1.0; n];
    let mut y = vec![0.0; n];
    let opts = SocketOptions {
        worker_exe: std::path::PathBuf::from(env!("CARGO_BIN_EXE_h2opus")),
        timeout: Duration::from_secs(30),
        extra_env: vec![("H2OPUS_TEST_CRASH_RANK".into(), "1".into())],
        ..SocketOptions::default()
    };
    let t0 = Instant::now();
    let err = socket_hgemv(&job, 2, 1, &x, &mut y, &opts)
        .expect_err("a crashed rank must fail the product");
    let elapsed = t0.elapsed();
    assert!(
        elapsed < Duration::from_secs(25),
        "crash took {elapsed:?} to surface — behaved like a hang"
    );
    let msg = err.to_string();
    assert!(
        msg.contains("closed") || msg.contains("exited") || msg.contains("timeout"),
        "error must name the failure: {msg}"
    );
}
