//! Geometric admissibility and the dual-tree traversal that builds the
//! structure of the matrix tree (§2.1).
//!
//! A cluster pair (t, s) is *admissible* (representable as a low-rank block)
//! when `η ||C_t − C_s|| ≥ (D_t + D_s) / 2`, where C and D are the centers
//! and bounding-box diagonals (§6.1). Inadmissible pairs are refined until
//! the leaf level, where they become dense blocks.

use crate::clustering::ClusterTree;

/// Structure (not data) of an H^2 matrix: which (t, s) pairs are low-rank
/// leaves at each level, and which leaf-level pairs are dense.
#[derive(Clone, Debug, Default)]
pub struct MatrixStructure {
    /// `coupling[l]` = admissible (low-rank) leaf blocks at level l, as
    /// (row node j, col node j) pairs sorted by (row, col).
    pub coupling: Vec<Vec<(u32, u32)>>,
    /// Dense blocks at the leaf level, sorted by (row, col).
    pub dense: Vec<(u32, u32)>,
}

impl MatrixStructure {
    /// Build the structure by dual-tree traversal of (row tree × col tree).
    /// Both trees must have the same depth (we use the same tree for rows
    /// and columns throughout, as the paper's square kernel matrices do).
    pub fn build(rows: &ClusterTree, cols: &ClusterTree, eta: f64) -> Self {
        assert_eq!(rows.depth, cols.depth, "row/col trees must share depth");
        let depth = rows.depth;
        let mut s = MatrixStructure {
            coupling: vec![Vec::new(); depth + 1],
            dense: Vec::new(),
        };
        s.traverse(rows, cols, eta, 0, 0, 0);
        for lvl in s.coupling.iter_mut() {
            lvl.sort_unstable();
        }
        s.dense.sort_unstable();
        s
    }

    fn traverse(&mut self, rows: &ClusterTree, cols: &ClusterTree, eta: f64, l: usize, t: usize, sj: usize) {
        let bt = &rows.node(l, t).bbox;
        let bs = &cols.node(l, sj).bbox;
        if is_admissible(eta, bt, bs) {
            self.coupling[l].push((t as u32, sj as u32));
        } else if l == rows.depth {
            self.dense.push((t as u32, sj as u32));
        } else {
            for ct in [2 * t, 2 * t + 1] {
                for cs in [2 * sj, 2 * sj + 1] {
                    self.traverse(rows, cols, eta, l + 1, ct, cs);
                }
            }
        }
    }

    /// The sparsity constant C_sp: the maximum number of blocks (coupling at
    /// any level, or dense) in any block row. Bounded by an O(1) constant
    /// for geometric admissibility (§3.2); the paper reports 17 (2D) and
    /// 30 (3D) for its test sets.
    pub fn sparsity_constant(&self) -> usize {
        let mut best = 0;
        for (l, lvl) in self.coupling.iter().enumerate() {
            best = best.max(max_row_count(lvl, 1usize << l));
        }
        if let Some(last_level) = self.coupling.len().checked_sub(1) {
            // dense blocks live at the leaf level
            best = best.max(max_row_count(&self.dense, 1usize << last_level));
        }
        best
    }

    /// Total number of low-rank leaves across levels.
    pub fn num_coupling(&self) -> usize {
        self.coupling.iter().map(|l| l.len()).sum()
    }

    /// Check that the leaves exactly tile the full matrix: every (row
    /// point, col point) position is covered by exactly one leaf block.
    /// O(num_blocks) using per-level aggregation; used in tests.
    pub fn validate_partition(&self, rows: &ClusterTree, cols: &ClusterTree) -> Result<(), String> {
        // Sum of block areas must equal N^2, and blocks must be disjoint.
        // Disjointness for a tree partition follows if no leaf block's
        // ancestor pair is also a leaf block; we check via area + ancestor
        // set membership.
        let n = rows.num_points() as u128;
        let mut area: u128 = 0;
        use std::collections::HashSet;
        let mut leafset: Vec<HashSet<(u32, u32)>> = vec![HashSet::new(); self.coupling.len()];
        for (l, lvl) in self.coupling.iter().enumerate() {
            for &(t, s) in lvl {
                leafset[l].insert((t, s));
                let rt = rows.node(l, t as usize).size() as u128;
                let cs = cols.node(l, s as usize).size() as u128;
                area += rt * cs;
            }
        }
        let leaf = self.coupling.len() - 1;
        for &(t, s) in &self.dense {
            let rt = rows.node(leaf, t as usize).size() as u128;
            let cs = cols.node(leaf, s as usize).size() as u128;
            area += rt * cs;
        }
        if area != n * n {
            return Err(format!("leaf blocks cover area {area}, expected {}", n * n));
        }
        // ancestor check
        for (l, lvl) in self.coupling.iter().enumerate() {
            for &(t, s) in lvl {
                let (mut tt, mut ss) = (t, s);
                for al in (0..l).rev() {
                    tt /= 2;
                    ss /= 2;
                    if leafset[al].contains(&(tt, ss)) {
                        return Err(format!("nested leaves: ({t},{s})@{l} under ({tt},{ss})@{al}"));
                    }
                }
            }
        }
        Ok(())
    }
}

/// The paper's §6.1 admissibility condition.
#[inline]
pub fn is_admissible(eta: f64, bt: &crate::geometry::BBox, bs: &crate::geometry::BBox) -> bool {
    eta * bt.center_dist(bs) >= 0.5 * (bt.diameter() + bs.diameter())
}

fn max_row_count(pairs: &[(u32, u32)], nrows: usize) -> usize {
    let mut counts = vec![0usize; nrows];
    for &(t, _) in pairs {
        counts[t as usize] += 1;
    }
    counts.into_iter().max().unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::PointSet;

    fn tree_2d(n: usize, m: usize) -> ClusterTree {
        ClusterTree::build(PointSet::grid_2d(n, 1.0), m)
    }

    #[test]
    fn structure_partitions_matrix() {
        let t = tree_2d(16, 16); // 256 points
        let s = MatrixStructure::build(&t, &t, 0.9);
        s.validate_partition(&t, &t).unwrap();
        assert!(s.num_coupling() > 0, "expected low-rank blocks");
        assert!(!s.dense.is_empty(), "diagonal blocks must be dense");
    }

    #[test]
    fn diagonal_blocks_never_admissible() {
        let t = tree_2d(16, 16);
        let s = MatrixStructure::build(&t, &t, 0.9);
        for lvl in &s.coupling {
            for &(a, b) in lvl {
                assert_ne!(a, b, "self-interaction cannot be admissible");
            }
        }
        // every diagonal leaf pair must be dense
        let leaves = t.nodes_at(t.depth) as u32;
        for j in 0..leaves {
            assert!(s.dense.contains(&(j, j)), "missing dense diagonal ({j},{j})");
        }
    }

    #[test]
    fn sparsity_constant_bounded() {
        // C_sp should be O(1) as N grows (paper: 17 in 2D at eta=0.9).
        let csp: Vec<usize> = [8usize, 16, 32]
            .iter()
            .map(|&n| {
                let t = tree_2d(n, 16);
                MatrixStructure::build(&t, &t, 0.9).sparsity_constant()
            })
            .collect();
        assert!(csp[2] <= 40, "C_sp blew up: {csp:?}");
        // non-trivial structure
        assert!(csp[2] >= 3, "C_sp suspiciously small: {csp:?}");
    }

    #[test]
    fn eta_zero_means_all_dense() {
        // eta = 0 can never satisfy the condition (distances are finite and
        // diameters positive), so everything refines to dense leaves.
        let t = tree_2d(8, 16);
        let s = MatrixStructure::build(&t, &t, 0.0);
        assert_eq!(s.num_coupling(), 0);
        let leaves = t.nodes_at(t.depth);
        assert_eq!(s.dense.len(), leaves * leaves);
    }

    #[test]
    fn larger_eta_admits_more() {
        // A more permissive eta admits blocks at coarser levels: the number
        // of *dense* blocks shrinks, and low-rank leaves move up the tree
        // (so their total count may also shrink — one coarse block replaces
        // four finer ones).
        let t = tree_2d(16, 16);
        let weak = MatrixStructure::build(&t, &t, 0.5);
        let strong = MatrixStructure::build(&t, &t, 2.0);
        assert!(strong.dense.len() < weak.dense.len());
        let coarsest = |s: &MatrixStructure| {
            s.coupling.iter().position(|l| !l.is_empty()).unwrap_or(usize::MAX)
        };
        assert!(coarsest(&strong) <= coarsest(&weak));
    }

    #[test]
    fn blocks_sorted_by_row() {
        let t = tree_2d(16, 16);
        let s = MatrixStructure::build(&t, &t, 0.9);
        for lvl in &s.coupling {
            for w in lvl.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }

    #[test]
    fn structure_3d() {
        let t = ClusterTree::build(PointSet::grid_3d(6, 1.0), 27); // 216 pts
        let s = MatrixStructure::build(&t, &t, 0.95);
        s.validate_partition(&t, &t).unwrap();
        // 3D has a larger sparsity constant than 2D at similar sizes (§6.1)
        assert!(s.sparsity_constant() >= 3);
    }
}
