//! Operation counters: floating-point work, communication volume and batch
//! launch counts — the quantities behind the paper's Gflop/s and
//! communication-optimization claims (§4, §6).

/// Mutable counters threaded through the execution paths.
#[derive(Clone, Debug, Default)]
pub struct Metrics {
    /// Floating point operations executed (2mnk per GEMM, etc.).
    pub flops: u64,
    /// Bytes sent over the (simulated) network.
    pub bytes_sent: u64,
    /// Number of point-to-point messages.
    pub messages: u64,
    /// Number of batched-kernel launches.
    pub batch_launches: u64,
    /// Elements of padding waste in batched launches (padded - actual).
    pub pad_waste: u64,
    /// Operand/result f64 words touched by batched GEMMs
    /// (nb·(m·k + k·n + m·n) per launch) — the memory-traffic term of the
    /// [`crate::dist::hgemv::CostModel`], recorded so measured runs can
    /// calibrate `byte_time` (`python/tests/model_check.py --fit`).
    pub gemm_words: u64,
    /// Peak per-rank H² *matrix* storage in bytes
    /// ([`crate::dist::ShardedMatrix::matrix_bytes`]): each rank of the
    /// sharded executors records its own shard's footprint, and merging
    /// keeps the **maximum** (a per-rank peak, not a sum) — the quantity
    /// the out-of-core memory trajectory is benchmarked by (E1/E2 rows).
    pub matrix_bytes: u64,
    /// Width (number of right-hand-side columns) of the product these
    /// counters were recorded for. Under the request-coalescing session
    /// server this is the *achieved* batch width — several concurrent
    /// submissions fused into one N×nv product — so it is the
    /// GEMV→GEMM conversion factor the serving path is benchmarked by
    /// (E10). Merging keeps the maximum (all ranks of one product see
    /// the same width; merged reports answer "how wide did we batch").
    pub coalesced_nv: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one batched GEMM: nb blocks of (m × k)·(k × n).
    pub fn gemm(&mut self, nb: usize, m: usize, k: usize, n: usize) {
        self.flops += 2 * (nb * m * k * n) as u64;
        self.gemm_words += (nb * (m * k + k * n + m * n)) as u64;
        self.batch_launches += 1;
    }

    /// Record a batched QR of nb (rows × cols) blocks (2mn² − 2n³/3 each).
    pub fn qr(&mut self, nb: usize, rows: usize, cols: usize) {
        let per = 2 * rows * cols * cols - 2 * cols * cols * cols / 3;
        self.flops += (nb * per) as u64;
        self.batch_launches += 1;
    }

    /// Record a batched SVD of nb (rows × cols) blocks. One-sided Jacobi is
    /// O(rows·cols²) per sweep; we count the conventional ~14·m·n² estimate.
    pub fn svd(&mut self, nb: usize, rows: usize, cols: usize) {
        self.flops += (nb * 14 * rows * cols * cols) as u64;
        self.batch_launches += 1;
    }

    /// Record a message of `bytes` to another rank.
    pub fn send(&mut self, bytes: usize) {
        self.bytes_sent += bytes as u64;
        self.messages += 1;
    }

    pub fn merge(&mut self, other: &Metrics) {
        self.flops += other.flops;
        self.bytes_sent += other.bytes_sent;
        self.messages += other.messages;
        self.batch_launches += other.batch_launches;
        self.pad_waste += other.pad_waste;
        self.gemm_words += other.gemm_words;
        // Peak per-rank storage: the merged value answers "how big was
        // the largest rank", so it maxes instead of summing.
        self.matrix_bytes = self.matrix_bytes.max(other.matrix_bytes);
        // Achieved batch width: every rank of a product records the same
        // nv, so the merged value is that width (max, not sum).
        self.coalesced_nv = self.coalesced_nv.max(other.coalesced_nv);
    }

    /// Aggregate per-rank counters without data races: each thread of the
    /// threaded executor records into its own `Metrics`, and the joined
    /// results are folded here in rank order — so equal per-rank inputs
    /// give identical totals regardless of thread completion order.
    pub fn merge_all<'a>(parts: impl IntoIterator<Item = &'a Metrics>) -> Metrics {
        let mut total = Metrics::new();
        for part in parts {
            total.merge(part);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gemm_flop_count() {
        let mut m = Metrics::new();
        m.gemm(10, 4, 5, 6);
        assert_eq!(m.flops, 2 * 10 * 4 * 5 * 6);
        assert_eq!(m.batch_launches, 1);
    }

    #[test]
    fn merge_all_is_order_independent_on_totals() {
        let mut a = Metrics::new();
        a.gemm(2, 3, 3, 1);
        a.send(64);
        let mut b = Metrics::new();
        b.gemm(5, 2, 2, 2);
        let fwd = Metrics::merge_all([&a, &b]);
        let rev = Metrics::merge_all([&b, &a]);
        assert_eq!(fwd.flops, rev.flops);
        assert_eq!(fwd.bytes_sent, 64);
        assert_eq!(fwd.batch_launches, 2);
    }

    #[test]
    fn matrix_bytes_merges_as_peak() {
        let mut a = Metrics::new();
        a.matrix_bytes = 100;
        let mut b = Metrics::new();
        b.matrix_bytes = 250;
        let merged = Metrics::merge_all([&a, &b]);
        assert_eq!(merged.matrix_bytes, 250, "peak, not sum");
    }

    #[test]
    fn merge_adds() {
        let mut a = Metrics::new();
        a.send(100);
        let mut b = Metrics::new();
        b.send(50);
        b.gemm(1, 2, 2, 2);
        a.merge(&b);
        assert_eq!(a.bytes_sent, 150);
        assert_eq!(a.messages, 2);
        assert_eq!(a.flops, 16);
    }
}
