//! Artifact catalog: parses `artifacts/manifest.txt` (written by aot.py)
//! and resolves shape requests to the smallest covering bucket.

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

/// One GEMM artifact entry.
#[derive(Clone, Debug)]
pub struct GemmEntry {
    pub nb: usize,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    pub path: PathBuf,
}

/// One QR or SVD artifact entry.
#[derive(Clone, Debug)]
pub struct FactorEntry {
    pub nb: usize,
    pub rows: usize,
    pub cols: usize,
    pub path: PathBuf,
}

/// Parsed manifest.
#[derive(Clone, Debug, Default)]
pub struct Catalog {
    /// op ("nn"/"tn"/"nt") -> entries
    pub gemm: HashMap<String, Vec<GemmEntry>>,
    pub qr: Vec<FactorEntry>,
    pub svd: Vec<FactorEntry>,
}

impl Catalog {
    /// Load `manifest.txt` from the artifacts directory. Lines:
    /// `kind op nb m k n file` (op/n are placeholders for qr/svd).
    pub fn load(dir: &Path) -> Result<Self> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest)
            .with_context(|| format!("reading {manifest:?} — run `make artifacts` first"))?;
        let mut cat = Catalog::default();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let f: Vec<&str> = line.split_whitespace().collect();
            if f.len() != 7 {
                bail!("manifest line {}: expected 7 fields, got {}", lineno + 1, f.len());
            }
            let nb: usize = f[2].parse()?;
            let (a, b, c): (usize, usize, usize) = (f[3].parse()?, f[4].parse()?, f[5].parse()?);
            let path = dir.join(f[6]);
            match f[0] {
                "gemm" => cat
                    .gemm
                    .entry(f[1].to_string())
                    .or_default()
                    .push(GemmEntry { nb, m: a, k: b, n: c, path }),
                "qr" => cat.qr.push(FactorEntry { nb, rows: a, cols: b, path }),
                "svd" => cat.svd.push(FactorEntry { nb, rows: a, cols: b, path }),
                other => bail!("manifest line {}: unknown kind {other}", lineno + 1),
            }
        }
        // smallest-first so find() picks the tightest bucket
        for v in cat.gemm.values_mut() {
            v.sort_by_key(|e| e.m * e.k * e.n);
        }
        cat.qr.sort_by_key(|e| e.rows * e.cols);
        cat.svd.sort_by_key(|e| e.rows * e.cols);
        Ok(cat)
    }

    /// Smallest GEMM bucket covering (m, k, n) for `op`, if any.
    pub fn find_gemm(&self, op: &str, m: usize, k: usize, n: usize) -> Option<&GemmEntry> {
        self.gemm.get(op)?.iter().find(|e| e.m >= m && e.k >= k && e.n >= n)
    }

    /// Smallest QR bucket covering (rows, cols).
    pub fn find_qr(&self, rows: usize, cols: usize) -> Option<&FactorEntry> {
        self.qr.iter().find(|e| e.rows >= rows && e.cols >= cols)
    }

    /// Smallest SVD bucket covering (rows, cols).
    pub fn find_svd(&self, rows: usize, cols: usize) -> Option<&FactorEntry> {
        self.svd.iter().find(|e| e.rows >= rows && e.cols >= cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, content: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.txt"), content).unwrap();
    }

    #[test]
    fn parse_and_bucket_selection() {
        let dir = std::env::temp_dir().join("h2opus_cat_test1");
        write_manifest(
            &dir,
            "gemm nn 64 16 16 4 a.hlo.txt\n\
             gemm nn 64 32 32 4 b.hlo.txt\n\
             qr - 16 32 16 0 q.hlo.txt\n\
             svd - 16 32 16 0 s.hlo.txt\n",
        );
        let cat = Catalog::load(&dir).unwrap();
        // exact fit
        assert_eq!(cat.find_gemm("nn", 16, 16, 4).unwrap().m, 16);
        // rounds up to the smallest covering bucket
        assert_eq!(cat.find_gemm("nn", 17, 9, 2).unwrap().m, 32);
        // no bucket large enough
        assert!(cat.find_gemm("nn", 64, 16, 4).is_none());
        assert!(cat.find_gemm("tn", 16, 16, 4).is_none());
        assert_eq!(cat.find_qr(20, 10).unwrap().rows, 32);
        assert!(cat.find_svd(64, 16).is_none());
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let dir = std::env::temp_dir().join("h2opus_cat_missing");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        let err = Catalog::load(&dir).unwrap_err().to_string();
        assert!(err.contains("make artifacts"), "{err}");
    }

    #[test]
    fn malformed_line_rejected() {
        let dir = std::env::temp_dir().join("h2opus_cat_bad");
        write_manifest(&dir, "gemm nn 64 16\n");
        assert!(Catalog::load(&dir).is_err());
    }
}
