//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compiles them lazily on the PJRT CPU client,
//! and exposes them as a [`crate::backend::ComputeBackend`].
//!
//! The backend pads every request into the catalog's shape buckets
//! (rounding (m, k, n) up and chunking/padding the batch dimension), which
//! is numerically exact for zero padding — the property both the Python
//! and Rust test suites verify. Shapes outside the catalog fall back to
//! the native backend (counted, so benches can report the fallback rate).
//! Python never runs here: the Rust binary is self-contained once
//! `make artifacts` has produced the catalog.

pub mod catalog;
pub mod xla_backend;

pub use catalog::Catalog;
pub use xla_backend::XlaBackend;
