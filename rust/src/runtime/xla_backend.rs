//! The XLA/PJRT compute backend: executes the AOT JAX/Pallas artifacts.
//!
//! Requests are padded into catalog buckets: blocks are gathered from their
//! offsets into zero-padded contiguous batch buffers (the host-side analog
//! of the paper's device marshaling + transfer), executed through PJRT, and
//! scattered back. Chunking over the fixed artifact batch size bounds the
//! number of compiled executables; lazy compilation caches one executable
//! per (artifact) file.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{Context, Result};

use super::catalog::Catalog;
use crate::backend::native::NativeBackend;
use crate::backend::{BatchRef, ComputeBackend, GemmDims};
use crate::metrics::Metrics;

/// Execution statistics of the XLA backend (padding waste, fallbacks).
#[derive(Clone, Debug, Default)]
pub struct XlaStats {
    pub launches: u64,
    pub fallbacks: u64,
    /// elements transferred host->device and back
    pub elements_moved: u64,
}

/// PJRT-backed [`ComputeBackend`].
///
/// Interior mutability (executable cache, stats) is behind `Mutex`es so
/// the backend satisfies the `ComputeBackend: Sync` bound and can be
/// shared across the threaded executor's rank threads.
pub struct XlaBackend {
    client: xla::PjRtClient,
    catalog: Catalog,
    cache: Mutex<HashMap<PathBuf, Arc<xla::PjRtLoadedExecutable>>>,
    fallback: NativeBackend,
    pub stats: Mutex<XlaStats>,
}

impl XlaBackend {
    /// Create from an artifacts directory (must contain manifest.txt).
    pub fn new(artifacts_dir: &Path) -> Result<Self> {
        let catalog = Catalog::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(XlaBackend {
            client,
            catalog,
            cache: Mutex::new(HashMap::new()),
            fallback: NativeBackend,
            stats: Mutex::new(XlaStats::default()),
        })
    }

    /// Default artifacts location (repo-root/artifacts), overridable with
    /// H2OPUS_ARTIFACTS.
    pub fn from_env() -> Result<Self> {
        let dir = std::env::var("H2OPUS_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string());
        Self::new(Path::new(&dir))
    }

    /// Fetch (lazily compiling) the executable for `path`. Returns a
    /// cloned handle so the cache lock is *not* held across device
    /// execution — rank threads of the threaded executor would otherwise
    /// serialize on it.
    fn executable(&self, path: &Path) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(path) {
            return Ok(Arc::clone(exe));
        }
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client.compile(&comp).with_context(|| format!("compiling {path:?}"))?,
        );
        // A racing thread may have compiled concurrently; keep whichever
        // entry wins, the handles are equivalent.
        Ok(Arc::clone(
            self.cache.lock().unwrap().entry(path.to_path_buf()).or_insert(exe),
        ))
    }

    fn run(&self, path: &Path, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(path)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        self.stats.lock().unwrap().launches += 1;
        Ok(result.to_tuple()?)
    }
}

/// Gather blocks (rows x cols each) from `data`+`offsets` into a zero-padded
/// (nb_pad, rows_pad, cols_pad) buffer, for the chunk `items`.
fn gather_padded(
    data: &[f64],
    offsets: &[usize],
    items: std::ops::Range<usize>,
    rows: usize,
    cols: usize,
    nb_pad: usize,
    rows_pad: usize,
    cols_pad: usize,
) -> Vec<f64> {
    let mut buf = vec![0.0; nb_pad * rows_pad * cols_pad];
    for (slot, item) in items.enumerate() {
        let src = &data[offsets[item]..offsets[item] + rows * cols];
        let dst = &mut buf[slot * rows_pad * cols_pad..];
        for r in 0..rows {
            dst[r * cols_pad..r * cols_pad + cols].copy_from_slice(&src[r * cols..(r + 1) * cols]);
        }
    }
    buf
}

impl ComputeBackend for XlaBackend {
    fn name(&self) -> &str {
        "xla-pjrt"
    }

    fn batched_gemm(
        &self,
        dims: GemmDims,
        a: BatchRef<'_>,
        b: BatchRef<'_>,
        c_data: &mut [f64],
        c_offsets: &[usize],
        metrics: &mut Metrics,
    ) {
        let GemmDims { nb, m, k, n, trans_a, trans_b, accumulate } = dims;
        if nb == 0 {
            return;
        }
        let op = match (trans_a, trans_b) {
            (false, false) => "nn",
            (true, false) => "tn",
            (false, true) => "nt",
            (true, true) => {
                // never emitted by the phases; keep native
                self.stats.lock().unwrap().fallbacks += 1;
                return self.fallback.batched_gemm(dims, a, b, c_data, c_offsets, metrics);
            }
        };
        let Some(entry) = self.catalog.find_gemm(op, m, k, n) else {
            self.stats.lock().unwrap().fallbacks += 1;
            return self.fallback.batched_gemm(dims, a, b, c_data, c_offsets, metrics);
        };
        let (mp, kp, np_, nbp) = (entry.m, entry.k, entry.n, entry.nb);
        // block storage shapes (rows, cols) as laid out in memory
        let (a_rows, a_cols, a_rp, a_cp) =
            if trans_a { (k, m, kp, mp) } else { (m, k, mp, kp) };
        let (b_rows, b_cols, b_rp, b_cp) =
            if trans_b { (n, k, np_, kp) } else { (k, n, kp, np_) };

        let mut chunk_start = 0;
        while chunk_start < nb {
            let chunk = (nb - chunk_start).min(nbp);
            let items = chunk_start..chunk_start + chunk;
            let a_buf =
                gather_padded(a.data, a.offsets, items.clone(), a_rows, a_cols, nbp, a_rp, a_cp);
            let b_buf =
                gather_padded(b.data, b.offsets, items.clone(), b_rows, b_cols, nbp, b_rp, b_cp);
            let a_lit = xla::Literal::vec1(&a_buf)
                .reshape(&[nbp as i64, a_rp as i64, a_cp as i64])
                .expect("reshape a");
            let b_lit = xla::Literal::vec1(&b_buf)
                .reshape(&[nbp as i64, b_rp as i64, b_cp as i64])
                .expect("reshape b");
            let out = self.run(&entry.path, &[a_lit, b_lit]).expect("gemm artifact execution");
            let c_full: Vec<f64> = out[0].to_vec().expect("gemm output");
            {
                let mut st = self.stats.lock().unwrap();
                st.elements_moved += (a_buf.len() + b_buf.len() + c_full.len()) as u64;
            }
            // scatter (unpad) into destinations
            for (slot, item) in items.enumerate() {
                let src = &c_full[slot * mp * np_..];
                let dst = &mut c_data[c_offsets[item]..c_offsets[item] + m * n];
                for r in 0..m {
                    for cix in 0..n {
                        let v = src[r * np_ + cix];
                        if accumulate {
                            dst[r * n + cix] += v;
                        } else {
                            dst[r * n + cix] = v;
                        }
                    }
                }
            }
            chunk_start += chunk;
        }
        metrics.gemm(nb, m, k, n);
        metrics.pad_waste += ((mp * kp * np_) as u64).saturating_sub((m * k * n) as u64) * nb as u64;
    }

    fn batched_qr(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        q: &mut [f64],
        r: &mut [f64],
        metrics: &mut Metrics,
    ) {
        if nb == 0 {
            return;
        }
        let Some(entry) = self.catalog.find_qr(rows, cols) else {
            self.stats.lock().unwrap().fallbacks += 1;
            return self.fallback.batched_qr(nb, rows, cols, a, q, r, metrics);
        };
        let (rp, cp, nbp) = (entry.rows, entry.cols, entry.nb);
        let offsets: Vec<usize> = (0..nb).map(|i| i * rows * cols).collect();
        let mut chunk_start = 0;
        while chunk_start < nb {
            let chunk = (nb - chunk_start).min(nbp);
            let items = chunk_start..chunk_start + chunk;
            let buf = gather_padded(a, &offsets, items.clone(), rows, cols, nbp, rp, cp);
            let lit = xla::Literal::vec1(&buf)
                .reshape(&[nbp as i64, rp as i64, cp as i64])
                .expect("reshape qr input");
            let out = self.run(&entry.path, &[lit]).expect("qr artifact execution");
            let qf: Vec<f64> = out[0].to_vec().expect("q output");
            let rf: Vec<f64> = out[1].to_vec().expect("r output");
            for (slot, item) in items.enumerate() {
                for i in 0..rows {
                    for j in 0..cols {
                        q[item * rows * cols + i * cols + j] = qf[slot * rp * cp + i * cp + j];
                    }
                }
                for i in 0..cols {
                    for j in 0..cols {
                        r[item * cols * cols + i * cols + j] = rf[slot * cp * cp + i * cp + j];
                    }
                }
            }
            chunk_start += chunk;
        }
        metrics.qr(nb, rows, cols);
    }

    fn batched_qr_r(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        r: &mut [f64],
        metrics: &mut Metrics,
    ) {
        // reuse the full-QR artifact, discard Q
        let mut q = vec![0.0; nb * rows * cols];
        self.batched_qr(nb, rows, cols, a, &mut q, r, metrics);
    }

    fn batched_svd(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        u: &mut [f64],
        s: &mut [f64],
        v: &mut [f64],
        metrics: &mut Metrics,
    ) {
        if nb == 0 {
            return;
        }
        let Some(entry) = self.catalog.find_svd(rows, cols) else {
            self.stats.lock().unwrap().fallbacks += 1;
            return self.fallback.batched_svd(nb, rows, cols, a, u, s, v, metrics);
        };
        let (rp, cp, nbp) = (entry.rows, entry.cols, entry.nb);
        let offsets: Vec<usize> = (0..nb).map(|i| i * rows * cols).collect();
        let mut chunk_start = 0;
        while chunk_start < nb {
            let chunk = (nb - chunk_start).min(nbp);
            let items = chunk_start..chunk_start + chunk;
            let buf = gather_padded(a, &offsets, items.clone(), rows, cols, nbp, rp, cp);
            let lit = xla::Literal::vec1(&buf)
                .reshape(&[nbp as i64, rp as i64, cp as i64])
                .expect("reshape svd input");
            let out = self.run(&entry.path, &[lit]).expect("svd artifact execution");
            let uf: Vec<f64> = out[0].to_vec().expect("u output");
            let sf: Vec<f64> = out[1].to_vec().expect("s output");
            let vf: Vec<f64> = out[2].to_vec().expect("v output");
            // Padded zero columns produce zero singular values sorted last,
            // so the leading `cols` triplets are exactly the unpadded SVD.
            for (slot, item) in items.enumerate() {
                for i in 0..rows {
                    for j in 0..cols {
                        u[item * rows * cols + i * cols + j] = uf[slot * rp * cp + i * cp + j];
                    }
                }
                s[item * cols..(item + 1) * cols].copy_from_slice(&sf[slot * cp..slot * cp + cols]);
                for i in 0..cols {
                    for j in 0..cols {
                        v[item * cols * cols + i * cols + j] = vf[slot * cp * cp + i * cp + j];
                    }
                }
            }
            chunk_start += chunk;
        }
        metrics.svd(nb, rows, cols);
    }
}
