//! The static span-name table.
//!
//! Span records carry a `u16` name id instead of a string so the hot path
//! never allocates and the cross-process flush ships pure numbers: the
//! coordinator and every `h2opus worker` run the same binary, so the ids
//! mean the same thing on both sides. Display strings ("upsweep L3",
//! "request #42 queued") are rendered only at serialization time from
//! `(id, arg)`.

/// A span name id — an index into the static table below.
pub type NameId = u16;

/// How a span's `arg` word should be rendered next to its label.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArgRole {
    /// `arg` is unused.
    None,
    /// `arg` is a tree level: rendered as `L{arg}`.
    Level,
    /// `arg` is a product/request pid: rendered as `#{arg}`.
    Pid,
    /// `arg` is a batch size: rendered as `x{arg}`.
    Batch,
}

/// Static metadata of one span name.
#[derive(Debug)]
pub struct NameInfo {
    /// Base label, e.g. "upsweep".
    pub label: &'static str,
    /// Chrome-trace category ("compute", "comm", "transfer", "lowprio",
    /// "server").
    pub cat: &'static str,
    /// How to render the span's `arg`.
    pub arg: ArgRole,
}

macro_rules! name_table {
    ($( $id:ident => $label:expr, $cat:expr, $role:expr; )*) => {
        name_table!(@consts 0; $( $id )*);
        /// All registered span names, indexed by [`NameId`].
        pub static TABLE: &[NameInfo] = &[
            $( NameInfo { label: $label, cat: $cat, arg: $role }, )*
        ];
    };
    (@consts $n:expr; $id:ident $( $rest:ident )*) => {
        pub const $id: NameId = $n;
        name_table!(@consts $n + 1; $( $rest )*);
    };
    (@consts $n:expr;) => {
        /// Number of registered names (== `TABLE.len()`).
        pub const NAME_COUNT: NameId = $n;
    };
}

name_table! {
    // HGEMV branch/master phases (mirrors `dist::threaded::PHASES`).
    INPUT_GATHER    => "input gather",          "compute", ArgRole::None;
    UPSWEEP         => "upsweep",               "compute", ArgRole::None;
    XHAT_SEND       => "xhat send",             "comm",    ArgRole::None;
    DENSE_MULT      => "dense + diagonal mult", "compute", ArgRole::None;
    XHAT_RECV       => "xhat recv",             "comm",    ArgRole::None;
    COUPLING_MULT   => "coupling mult",         "compute", ArgRole::None;
    BOUNDARY_WAIT   => "boundary wait",         "comm",    ArgRole::None;
    BOUNDARY_MERGE  => "boundary merge",        "compute", ArgRole::None;
    DOWNSWEEP       => "downsweep",             "compute", ArgRole::None;
    OUTPUT_SCATTER  => "output scatter",        "compute", ArgRole::None;
    TOP_GATHER      => "xhat gather",           "comm",    ArgRole::None;
    TOP_SUBTREE     => "top subtree",           "lowprio", ArgRole::None;
    YHAT_SCATTER    => "yhat scatter",          "comm",    ArgRole::None;
    // Session / worker lifecycle.
    PRODUCT         => "product",               "transfer", ArgRole::Pid;
    SHIP_INPUT      => "ship input",            "comm",     ArgRole::Pid;
    COLLECT_OUTPUT  => "collect output",        "comm",     ArgRole::Pid;
    COMPRESS_PASS   => "compress pass",         "transfer", ArgRole::None;
    CLOCK_SYNC      => "clock sync",            "comm",     ArgRole::None;
    SPAN_FLUSH      => "span flush",            "comm",     ArgRole::None;
    // Backend batch launches.
    BATCH_GEMM      => "batch gemm",            "compute", ArgRole::Batch;
    BATCH_QR        => "batch qr",              "compute", ArgRole::Batch;
    BATCH_SVD       => "batch svd",             "compute", ArgRole::Batch;
    // Server request lifecycle (queued -> fused -> shipped -> gathered),
    // keyed by pid so one request is traceable across processes.
    REQ_QUEUED      => "request queued",        "server", ArgRole::Pid;
    REQ_FUSED       => "request fused",         "server", ArgRole::Pid;
    REQ_SHIPPED     => "request shipped",       "server", ArgRole::Pid;
    REQ_GATHERED    => "request gathered",      "server", ArgRole::Pid;
    // Distributed-compression compute phases.
    ORTH_LEAF       => "orth leaf qr",          "compute", ArgRole::None;
    ORTH_TRANSFER   => "orth transfer",         "compute", ArgRole::Level;
    ABSORB          => "absorb coupling",       "compute", ArgRole::Level;
    WEIGHT_DOWNSWEEP => "weight downsweep",     "compute", ArgRole::Level;
    TRUNC_LEAF      => "truncate leaf",         "compute", ArgRole::None;
    TRUNC_INNER     => "truncate inner",        "compute", ArgRole::Level;
    PROJECT         => "project",               "compute", ArgRole::Level;
    // Distributed-compression wire sub-steps: one name per `STEP_*` tag of
    // `dist::compress` (the `(step << 8) | level` wire word maps here).
    STEP_RC         => "cmp rc gather",         "comm", ArgRole::Level;
    STEP_TOPORTH    => "cmp top-orth bcast",    "comm", ArgRole::Level;
    STEP_RV         => "cmp rv halo",           "comm", ArgRole::Level;
    STEP_ZU         => "cmp zu bcast",          "comm", ArgRole::Level;
    STEP_ZV         => "cmp zv bcast",          "comm", ArgRole::Level;
    STEP_SBLK       => "cmp s-block halo",      "comm", ArgRole::Level;
    STEP_SIGMA      => "cmp sigma reduce",      "comm", ArgRole::Level;
    STEP_TOL        => "cmp tol bcast",         "comm", ArgRole::Level;
    STEP_KLEAF      => "cmp k-leaf reduce",     "comm", ArgRole::Level;
    STEP_KLEAF_BC   => "cmp k-leaf bcast",      "comm", ArgRole::Level;
    STEP_KINNER     => "cmp k-inner reduce",    "comm", ArgRole::Level;
    STEP_KINNER_BC  => "cmp k-inner bcast",     "comm", ArgRole::Level;
    STEP_PC         => "cmp pc gather",         "comm", ArgRole::Level;
    STEP_TOPRES     => "cmp top-res bcast",     "comm", ArgRole::Level;
    STEP_PV         => "cmp pv halo",           "comm", ArgRole::Level;
    STEP_STATS      => "cmp stats ack",         "comm", ArgRole::Level;
    // Supervisor recovery lifecycle: a full session rebuild after a
    // poison, and each exactly-once product replay inside it — so MTTR is
    // visible in merged traces and `h2opus analyze`.
    RECOVERY        => "session recovery",      "server", ArgRole::None;
    REPLAY          => "replay product",        "server", ArgRole::Pid;
}

static UNKNOWN: NameInfo = NameInfo { label: "unknown", cat: "lowprio", arg: ArgRole::None };

/// Metadata of a name id (a safe `unknown` entry for out-of-range ids, so
/// decoding a flush payload from a mismatched binary cannot panic).
pub fn info(id: NameId) -> &'static NameInfo {
    TABLE.get(id as usize).unwrap_or(&UNKNOWN)
}

/// The span name of compression wire sub-step `step` (1-based `STEP_*`
/// constant of `dist::compress`).
pub fn comp_step(step: u32) -> NameId {
    let idx = STEP_RC as u32 + step.saturating_sub(1);
    // Bounded by the last STEP_* entry, not NAME_COUNT: names appended
    // after the step block must not become reachable through step ids.
    if step == 0 || idx > STEP_STATS as u32 {
        NAME_COUNT // out of range -> renders as "unknown"
    } else {
        idx as NameId
    }
}

/// Render the display string of a span `(id, arg)` pair.
pub fn render(id: NameId, arg: u64) -> String {
    let i = info(id);
    match i.arg {
        ArgRole::None => i.label.to_string(),
        ArgRole::Level => format!("{} L{}", i.label, arg),
        ArgRole::Pid => format!("{} #{}", i.label, arg),
        ArgRole::Batch => format!("{} x{}", i.label, arg),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_is_dense_and_consistent() {
        assert_eq!(TABLE.len(), NAME_COUNT as usize);
        assert_eq!(info(UPSWEEP).label, "upsweep");
        assert_eq!(info(STEP_STATS).label, "cmp stats ack");
        assert_eq!(info(NAME_COUNT).label, "unknown");
    }

    #[test]
    fn comp_step_maps_all_sixteen() {
        assert_eq!(comp_step(1), STEP_RC);
        assert_eq!(comp_step(7), STEP_SIGMA);
        assert_eq!(comp_step(16), STEP_STATS);
        assert_eq!(info(comp_step(0)).label, "unknown");
        assert_eq!(info(comp_step(17)).label, "unknown");
    }

    #[test]
    fn render_uses_arg_role() {
        assert_eq!(render(ORTH_TRANSFER, 3), "orth transfer L3");
        assert_eq!(render(PRODUCT, 42), "product #42");
        assert_eq!(render(BATCH_GEMM, 128), "batch gemm x128");
        assert_eq!(render(DENSE_MULT, 9), "dense + diagonal mult");
    }
}
