//! Unified cross-rank observability: a span runtime, cross-process trace
//! merging, and a live metrics registry.
//!
//! The paper's performance story is *seen* through per-rank timelines
//! (Fig. 8) and per-phase flop attribution (§6); in GPU H2Opus that role
//! is played by NVTX ranges + Nsight Systems. Here it is:
//!
//! - [`span`] — a per-rank span recorder: preallocated thread-local ring
//!   buffers behind one `AtomicBool`, RAII guards, numeric name ids from
//!   the static [`names`] table. Instrumented layers: HGEMV phases per
//!   level ([`crate::dist::threaded`]), compression sub-steps
//!   ([`crate::dist::compress`]), backend batch launches
//!   ([`crate::backend::native`]), session ship/collect and the server
//!   request lifecycle ([`crate::dist::transport::server`]).
//! - [`clock`] — NTP-style per-worker clock-offset estimation (min-RTT
//!   ping filter over the socket handshake) and the merged Chrome/Perfetto
//!   JSON across all P processes (`pid` = rank, `tid` = stream).
//! - [`registry`] — named counters/gauges/histograms with
//!   Prometheus-style exposition, absorbing `Metrics`, `ServerStats` and
//!   `RequestStats` as views; served live over the socket protocol's
//!   `Stats` request (`h2opus stats`).
//! - [`analyze`] — the performance referee: ingests a merged trace and
//!   reports per-rank phase aggregates, communication/computation overlap
//!   efficiency (the Fig. 8 metric), the critical path through the
//!   send/recv happens-before graph, and measured-vs-predicted cost-model
//!   drift (`h2opus analyze`).
//! - [`trajectory`] — the unified `BenchRow` schema all benches append to
//!   `BENCH_TRAJECTORY.jsonl`, plus the cross-commit regression gate.
//!
//! Enable recording with `H2OPUS_OBS=1` (or [`set_enabled`]); disabled
//! overhead is one atomic load per site, gated by `benches/obs_overhead`.

pub mod analyze;
pub mod clock;
pub mod names;
pub mod registry;
pub mod span;
pub mod trajectory;

pub use analyze::{analyze_json, Analysis};
pub use clock::{
    estimate_offset_ns, merged_trace_json, ClockSample, PartMeta, TracePart, WorkCounters,
    CLOCK_SYNC_PINGS,
};
pub use registry::{Counter, FixedHistogram, Gauge, Histogram, Registry};
pub use span::{
    decode_spans, drain, enabled, encode_spans, init_from_env, now_ns, record, set_enabled,
    set_lane, span, span_arg, Span, SpanGuard, LANE_UNSET, OBS_ENV,
};
