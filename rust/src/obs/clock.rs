//! Cross-process clock alignment and trace merging.
//!
//! Every process stamps spans against its own `Instant` epoch, so worker
//! timelines are mutually unaligned. The socket session runs a small
//! NTP-style handshake per worker right after its `Hello`: the coordinator
//! sends `K` pings, the worker echoes each with its own clock reading, and
//! the sample with the smallest round trip wins — its offset estimate is
//! wrong by at most `rtt/2` (the classic bound), which for a loopback Unix
//! socket is microseconds against phase spans of milliseconds.
//!
//! `merged_trace_json` then maps every span onto the coordinator timeline
//! (`coord_ns = span.start_ns - offset_ns`) and renders one Chrome/Perfetto
//! JSON object: `traceEvents` with `pid` = rank (coordinator = P) and
//! `tid` = recording stream, plus a `metadata` block carrying each part's
//! dropped-span count and (when the session tracked them) its cumulative
//! work counters — what `h2opus analyze` prices with the `CostModel`.

use std::fmt::Write as _;

use super::names;
use super::span::{Span, LANE_UNSET};
use crate::util::trace::TraceCollector;

/// Ping round trips per worker during the alignment handshake.
pub const CLOCK_SYNC_PINGS: usize = 8;

/// One ping measurement: coordinator send/receive stamps bracketing the
/// remote clock reading.
#[derive(Clone, Copy, Debug)]
pub struct ClockSample {
    pub t_send_ns: u64,
    pub t_remote_ns: u64,
    pub t_recv_ns: u64,
}

/// Estimate the remote clock's offset (`remote_now - local_now`, ns) from
/// ping samples: NTP-style, keep the minimum-RTT sample and assume the
/// remote stamp sits at its midpoint. Returns 0 for an empty sample set.
pub fn estimate_offset_ns(samples: &[ClockSample]) -> i64 {
    samples
        .iter()
        .min_by_key(|s| s.t_recv_ns.saturating_sub(s.t_send_ns))
        .map(|s| {
            let midpoint = (s.t_send_ns + s.t_recv_ns) / 2;
            s.t_remote_ns as i64 - midpoint as i64
        })
        .unwrap_or(0)
}

/// Per-process work counters embedded in trace metadata (f64: all counts
/// stay far below 2^53, so the JSON round trip is exact). The analyzer
/// prices these with [`crate::dist::hgemv::CostModel`] to report
/// measured-vs-predicted drift per rank.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkCounters {
    pub flops: f64,
    pub bytes_sent: f64,
    pub messages: f64,
    pub launches: f64,
    pub gemm_words: f64,
}

impl WorkCounters {
    pub fn is_zero(&self) -> bool {
        *self == WorkCounters::default()
    }
}

impl From<&crate::metrics::Metrics> for WorkCounters {
    fn from(m: &crate::metrics::Metrics) -> Self {
        WorkCounters {
            flops: m.flops as f64,
            bytes_sent: m.bytes_sent as f64,
            messages: m.messages as f64,
            launches: m.batch_launches as f64,
            gemm_words: m.gemm_words as f64,
        }
    }
}

/// The metadata of one part as it appears in (and parses back out of) a
/// merged trace's `metadata.parts` array.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PartMeta {
    pub pid: usize,
    /// Spans this process's rings overwrote since the last flush.
    pub dropped: u64,
    pub work: Option<WorkCounters>,
}

/// One process's contribution to a merged trace.
#[derive(Clone, Debug, Default)]
pub struct TracePart {
    /// The pid assigned to spans with no explicit lane (worker rank, or P
    /// for the coordinator process).
    pub default_pid: usize,
    /// This process's clock offset relative to the merge timeline
    /// (`remote_now - coord_now`); 0 for the coordinator itself.
    pub offset_ns: i64,
    pub spans: Vec<Span>,
    /// Spans this process's rings overwrote (counted in `obs/span.rs`,
    /// carried on the `Flush` wire) — surfaced in the merged trace's
    /// metadata so truncation is never silent.
    pub dropped: u64,
    /// Cumulative work counters since the last flush, when the session
    /// tracked them (socket sessions do; ad-hoc merges may not).
    pub work: Option<WorkCounters>,
}

/// Merge span sets from several processes into one Chrome-trace JSON
/// object: `{"traceEvents": [...], "metadata": {...}}`.
///
/// Spans recorded on a thread labeled with [`super::set_lane`] keep that
/// lane as their pid (the in-process executor runs all ranks in one
/// process); unlabeled spans fall to the part's `default_pid`. Events are
/// sorted by `(pid, tid, start, name)` so the output is deterministic for
/// a deterministic span set, modulo the timestamp values themselves.
///
/// The `metadata` block carries one entry per part (sorted by pid) with
/// its dropped-span count and optional [`WorkCounters`], plus the summed
/// `total_dropped` — so trace consumers can warn about ring truncation
/// and `h2opus analyze` can price the trace against the cost model.
pub fn merged_trace_json(parts: &[TracePart]) -> String {
    let mut events: Vec<(usize, u32, u64, Span)> = Vec::new();
    for part in parts {
        for s in &part.spans {
            let pid = if s.lane == LANE_UNSET { part.default_pid } else { s.lane as usize };
            let start = (s.start_ns as i64 - part.offset_ns).max(0) as u64;
            events.push((pid, s.tid, start, *s));
        }
    }
    events.sort_by_key(|(pid, tid, start, s)| (*pid, *tid, *start, s.name, s.arg));
    let mut tc = TraceCollector::new();
    for (pid, tid, start, s) in events {
        let info = names::info(s.name);
        tc.add(
            &names::render(s.name, s.arg),
            info.cat,
            pid,
            tid as usize,
            start as f64 * 1e-9,
            s.dur_ns as f64 * 1e-9,
        );
    }

    let mut metas: Vec<&TracePart> = parts.iter().collect();
    metas.sort_by_key(|p| p.default_pid);
    let total_dropped: u64 = metas.iter().map(|p| p.dropped).sum();
    let mut out = String::from("{\n\"traceEvents\":\n");
    out.push_str(&tc.to_json());
    out.push_str(",\n\"metadata\": {");
    let _ = write!(out, "\"total_dropped\": {total_dropped}, \"parts\": [");
    for (i, p) in metas.iter().enumerate() {
        let comma = if i + 1 == metas.len() { "" } else { ", " };
        let _ = write!(out, "{{\"pid\": {}, \"dropped\": {}", p.default_pid, p.dropped);
        if let Some(w) = &p.work {
            let _ = write!(
                out,
                ", \"work\": {{\"flops\": {}, \"bytes_sent\": {}, \"messages\": {}, \
                 \"launches\": {}, \"gemm_words\": {}}}",
                w.flops, w.bytes_sent, w.messages, w.launches, w.gemm_words
            );
        }
        let _ = write!(out, "}}{comma}");
    }
    out.push_str("]}\n}");
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(name: u16, lane: u32, tid: u32, start: u64, dur: u64) -> Span {
        Span { name, lane, tid, start_ns: start, dur_ns: dur, arg: 0 }
    }

    #[test]
    fn min_rtt_sample_wins() {
        // Noisy sample (rtt 10_000) vs clean sample (rtt 100): the clean
        // one determines the estimate.
        let samples = [
            ClockSample { t_send_ns: 0, t_remote_ns: 9_000, t_recv_ns: 10_000 },
            ClockSample { t_send_ns: 20_000, t_remote_ns: 25_050, t_recv_ns: 20_100 },
        ];
        assert_eq!(estimate_offset_ns(&samples), 25_050 - 20_050);
        assert_eq!(estimate_offset_ns(&[]), 0);
    }

    #[test]
    fn negative_offsets_are_representable() {
        let s = ClockSample { t_send_ns: 1_000, t_remote_ns: 100, t_recv_ns: 1_100 };
        assert_eq!(estimate_offset_ns(&[s]), 100 - 1_050);
    }

    #[test]
    fn merge_applies_offsets_and_lanes() {
        let coord = TracePart {
            default_pid: 2,
            offset_ns: 0,
            spans: vec![sp(names::SHIP_INPUT, LANE_UNSET, 0, 1_000, 100)],
            ..TracePart::default()
        };
        // Worker clock runs 500ns ahead of the coordinator's.
        let worker = TracePart {
            default_pid: 0,
            offset_ns: 500,
            spans: vec![sp(names::PRODUCT, LANE_UNSET, 0, 1_700, 300)],
            ..TracePart::default()
        };
        let json = merged_trace_json(&[coord, worker]);
        // Worker span lands at 1_200ns = 1.2us on the merged timeline.
        assert!(json.contains("\"pid\": 0"), "worker pid mapped: {json}");
        assert!(json.contains("\"ts\": 1.200"), "offset applied: {json}");
        assert!(json.contains("\"pid\": 2"), "coordinator pid kept: {json}");
    }

    #[test]
    fn lane_overrides_default_pid() {
        let part = TracePart {
            default_pid: 9,
            offset_ns: 0,
            spans: vec![sp(names::UPSWEEP, 3, 1, 0, 10)],
            ..TracePart::default()
        };
        let json = merged_trace_json(&[part]);
        // The event itself carries the lane pid; only the metadata part
        // entry mentions the default pid 9.
        let events_part = json.split("\"metadata\"").next().unwrap();
        assert!(events_part.contains("\"pid\": 3"));
        assert!(!events_part.contains("\"pid\": 9"));
        assert!(json.contains("\"pid\": 9"), "metadata keeps the rank id");
    }

    #[test]
    fn metadata_carries_dropped_and_work() {
        use crate::util::testing::{parse_json, JsonValue};
        let mut m = crate::metrics::Metrics::new();
        m.gemm(4, 8, 8, 2);
        m.send(1024);
        let parts = [
            TracePart {
                default_pid: 1,
                dropped: 3,
                spans: vec![sp(names::UPSWEEP, LANE_UNSET, 0, 0, 10)],
                work: Some(WorkCounters::from(&m)),
                ..TracePart::default()
            },
            TracePart { default_pid: 0, dropped: 0, ..TracePart::default() },
        ];
        let json = merged_trace_json(&parts);
        let parsed = parse_json(&json).expect("merged trace must be strict JSON");
        let meta = parsed.get("metadata").expect("metadata block");
        assert_eq!(meta.get("total_dropped").unwrap().as_f64(), Some(3.0));
        let entries = meta.get("parts").unwrap().as_arr().unwrap();
        // Sorted by pid regardless of input order.
        assert_eq!(entries[0].get("pid").unwrap().as_f64(), Some(0.0));
        assert_eq!(entries[1].get("pid").unwrap().as_f64(), Some(1.0));
        assert_eq!(entries[1].get("dropped").unwrap().as_f64(), Some(3.0));
        let work = entries[1].get("work").expect("work counters present");
        assert_eq!(work.get("flops").unwrap().as_f64(), Some(m.flops as f64));
        assert_eq!(work.get("bytes_sent").unwrap().as_f64(), Some(1024.0));
        assert!(entries[0].get("work").is_none(), "no counters -> no work block");
        // Events still present under traceEvents.
        let events = parsed.get("traceEvents").and_then(JsonValue::as_arr).unwrap();
        assert_eq!(events.len(), 1);
    }
}
