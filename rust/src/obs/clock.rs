//! Cross-process clock alignment and trace merging.
//!
//! Every process stamps spans against its own `Instant` epoch, so worker
//! timelines are mutually unaligned. The socket session runs a small
//! NTP-style handshake per worker right after its `Hello`: the coordinator
//! sends `K` pings, the worker echoes each with its own clock reading, and
//! the sample with the smallest round trip wins — its offset estimate is
//! wrong by at most `rtt/2` (the classic bound), which for a loopback Unix
//! socket is microseconds against phase spans of milliseconds.
//!
//! `merged_trace_json` then maps every span onto the coordinator timeline
//! (`coord_ns = span.start_ns - offset_ns`) and renders one Chrome/Perfetto
//! JSON with `pid` = rank (coordinator = P) and `tid` = recording stream.

use super::names;
use super::span::{Span, LANE_UNSET};
use crate::util::trace::TraceCollector;

/// Ping round trips per worker during the alignment handshake.
pub const CLOCK_SYNC_PINGS: usize = 8;

/// One ping measurement: coordinator send/receive stamps bracketing the
/// remote clock reading.
#[derive(Clone, Copy, Debug)]
pub struct ClockSample {
    pub t_send_ns: u64,
    pub t_remote_ns: u64,
    pub t_recv_ns: u64,
}

/// Estimate the remote clock's offset (`remote_now - local_now`, ns) from
/// ping samples: NTP-style, keep the minimum-RTT sample and assume the
/// remote stamp sits at its midpoint. Returns 0 for an empty sample set.
pub fn estimate_offset_ns(samples: &[ClockSample]) -> i64 {
    samples
        .iter()
        .min_by_key(|s| s.t_recv_ns.saturating_sub(s.t_send_ns))
        .map(|s| {
            let midpoint = (s.t_send_ns + s.t_recv_ns) / 2;
            s.t_remote_ns as i64 - midpoint as i64
        })
        .unwrap_or(0)
}

/// One process's contribution to a merged trace.
#[derive(Clone, Debug)]
pub struct TracePart {
    /// The pid assigned to spans with no explicit lane (worker rank, or P
    /// for the coordinator process).
    pub default_pid: usize,
    /// This process's clock offset relative to the merge timeline
    /// (`remote_now - coord_now`); 0 for the coordinator itself.
    pub offset_ns: i64,
    pub spans: Vec<Span>,
}

/// Merge span sets from several processes into one Chrome-trace JSON.
///
/// Spans recorded on a thread labeled with [`super::set_lane`] keep that
/// lane as their pid (the in-process executor runs all ranks in one
/// process); unlabeled spans fall to the part's `default_pid`. Events are
/// sorted by `(pid, tid, start, name)` so the output is deterministic for
/// a deterministic span set, modulo the timestamp values themselves.
pub fn merged_trace_json(parts: &[TracePart]) -> String {
    let mut events: Vec<(usize, u32, u64, Span)> = Vec::new();
    for part in parts {
        for s in &part.spans {
            let pid = if s.lane == LANE_UNSET { part.default_pid } else { s.lane as usize };
            let start = (s.start_ns as i64 - part.offset_ns).max(0) as u64;
            events.push((pid, s.tid, start, *s));
        }
    }
    events.sort_by_key(|(pid, tid, start, s)| (*pid, *tid, *start, s.name, s.arg));
    let mut tc = TraceCollector::new();
    for (pid, tid, start, s) in events {
        let info = names::info(s.name);
        tc.add(
            &names::render(s.name, s.arg),
            info.cat,
            pid,
            tid as usize,
            start as f64 * 1e-9,
            s.dur_ns as f64 * 1e-9,
        );
    }
    tc.to_json()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sp(name: u16, lane: u32, tid: u32, start: u64, dur: u64) -> Span {
        Span { name, lane, tid, start_ns: start, dur_ns: dur, arg: 0 }
    }

    #[test]
    fn min_rtt_sample_wins() {
        // Noisy sample (rtt 10_000) vs clean sample (rtt 100): the clean
        // one determines the estimate.
        let samples = [
            ClockSample { t_send_ns: 0, t_remote_ns: 9_000, t_recv_ns: 10_000 },
            ClockSample { t_send_ns: 20_000, t_remote_ns: 25_050, t_recv_ns: 20_100 },
        ];
        assert_eq!(estimate_offset_ns(&samples), 25_050 - 20_050);
        assert_eq!(estimate_offset_ns(&[]), 0);
    }

    #[test]
    fn negative_offsets_are_representable() {
        let s = ClockSample { t_send_ns: 1_000, t_remote_ns: 100, t_recv_ns: 1_100 };
        assert_eq!(estimate_offset_ns(&[s]), 100 - 1_050);
    }

    #[test]
    fn merge_applies_offsets_and_lanes() {
        let coord = TracePart {
            default_pid: 2,
            offset_ns: 0,
            spans: vec![sp(names::SHIP_INPUT, LANE_UNSET, 0, 1_000, 100)],
        };
        // Worker clock runs 500ns ahead of the coordinator's.
        let worker = TracePart {
            default_pid: 0,
            offset_ns: 500,
            spans: vec![sp(names::PRODUCT, LANE_UNSET, 0, 1_700, 300)],
        };
        let json = merged_trace_json(&[coord, worker]);
        // Worker span lands at 1_200ns = 1.2us on the merged timeline.
        assert!(json.contains("\"pid\": 0"), "worker pid mapped: {json}");
        assert!(json.contains("\"ts\": 1.200"), "offset applied: {json}");
        assert!(json.contains("\"pid\": 2"), "coordinator pid kept: {json}");
    }

    #[test]
    fn lane_overrides_default_pid() {
        let part = TracePart {
            default_pid: 9,
            offset_ns: 0,
            spans: vec![sp(names::UPSWEEP, 3, 1, 0, 10)],
        };
        let json = merged_trace_json(&[part]);
        assert!(json.contains("\"pid\": 3"));
        assert!(!json.contains("\"pid\": 9"));
    }
}
