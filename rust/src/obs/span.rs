//! The span recorder: preallocated thread-local ring buffers behind one
//! process-wide enable flag.
//!
//! Design constraints (ISSUE 8):
//!
//! - **Disabled path ~zero**: [`enabled`] is a single relaxed
//!   `AtomicBool` load; a disabled [`span`] constructs an inert guard
//!   without reading the clock, and its `Drop` is one branch. The
//!   `obs_overhead` bench gates this in CI.
//! - **Zero-alloc hot path**: each thread owns a preallocated ring of
//!   [`RING_CAPACITY`] spans; recording is one (uncontended) mutex lock +
//!   a slot write. When the ring wraps, the oldest spans are overwritten
//!   and counted as dropped rather than ever allocating.
//! - **Numeric records**: spans carry a [`names::NameId`] and a raw `arg`
//!   word instead of strings, so the cross-process flush ships pure f64s
//!   (see [`encode_spans`]) and rendering happens only at serialization.
//!
//! Timestamps are nanoseconds since a process-wide [`std::time::Instant`]
//! epoch; cross-process alignment is [`super::clock`]'s job.

use std::cell::{Cell, OnceCell};
use std::sync::atomic::{AtomicBool, AtomicU32, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use super::names::NameId;

/// Spans each thread can hold before the ring wraps (oldest overwritten).
pub const RING_CAPACITY: usize = 1 << 14;

/// Lane value of spans recorded on a thread with no [`set_lane`] call:
/// they are attributed to the enclosing process at merge time.
pub const LANE_UNSET: u32 = u32::MAX;

/// Environment variable enabling span recording at process start
/// (`H2OPUS_OBS=1`); the coordinator forwards it to worker processes.
pub const OBS_ENV: &str = "H2OPUS_OBS";

static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is span recording on? One relaxed atomic load — this is the whole
/// disabled-path cost at every instrumentation site.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn span recording on or off (also pins the clock epoch on enable so
/// `now_ns` is monotone across the toggle).
pub fn set_enabled(on: bool) {
    if on {
        let _ = epoch();
    }
    ENABLED.store(on, Ordering::SeqCst);
}

/// Enable recording if [`OBS_ENV`] is set to anything but `0`/empty.
pub fn init_from_env() {
    if std::env::var(OBS_ENV).map(|v| !v.is_empty() && v != "0").unwrap_or(false) {
        set_enabled(true);
    }
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Nanoseconds since the process-local epoch (first observability use).
#[inline]
pub fn now_ns() -> u64 {
    epoch().elapsed().as_nanos() as u64
}

/// One recorded span. `lane` is the logical rank the recording thread was
/// labeled with ([`set_lane`]), or [`LANE_UNSET`]; `tid` is a stable
/// per-thread stream id; times are process-local nanoseconds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub name: NameId,
    pub lane: u32,
    pub tid: u32,
    pub start_ns: u64,
    pub dur_ns: u64,
    pub arg: u64,
}

struct Ring {
    spans: Vec<Span>,
    /// Next slot to write once `spans.len() == RING_CAPACITY`.
    next: usize,
    dropped: u64,
}

impl Ring {
    fn new() -> Self {
        Ring { spans: Vec::with_capacity(RING_CAPACITY), next: 0, dropped: 0 }
    }

    fn push(&mut self, s: Span) {
        if self.spans.len() < RING_CAPACITY {
            self.spans.push(s);
        } else {
            self.spans[self.next] = s;
            self.next = (self.next + 1) % RING_CAPACITY;
            self.dropped += 1;
        }
    }

    fn drain(&mut self) -> (Vec<Span>, u64) {
        let mut out = std::mem::take(&mut self.spans);
        // Restore chronological order if the ring wrapped.
        out.rotate_left(self.next.min(out.len()));
        self.spans = Vec::with_capacity(RING_CAPACITY);
        self.next = 0;
        let dropped = std::mem::take(&mut self.dropped);
        (out, dropped)
    }
}

struct ThreadBuf {
    tid: u32,
    ring: Mutex<Ring>,
}

static THREADS: Mutex<Vec<Arc<ThreadBuf>>> = Mutex::new(Vec::new());
static NEXT_TID: AtomicU32 = AtomicU32::new(0);

thread_local! {
    static TL_BUF: OnceCell<Arc<ThreadBuf>> = const { OnceCell::new() };
    static TL_LANE: Cell<u32> = const { Cell::new(LANE_UNSET) };
}

/// Label the calling thread with logical rank `lane`; every span it
/// records from now on carries it. The in-process executor calls this at
/// the top of each rank job so merged traces attribute pool threads to
/// ranks; worker processes don't need it (their whole process maps to one
/// rank at flush time).
pub fn set_lane(lane: u32) {
    TL_LANE.with(|l| l.set(lane));
}

/// Record a complete span with explicit timestamps (for lifecycle events
/// whose start was stamped on a different code path than their end). No-op
/// while disabled.
pub fn record(name: NameId, arg: u64, start_ns: u64, dur_ns: u64) {
    if !enabled() {
        return;
    }
    let lane = TL_LANE.with(|l| l.get());
    TL_BUF.with(|cell| {
        let buf = cell.get_or_init(|| {
            let tid = NEXT_TID.fetch_add(1, Ordering::Relaxed);
            let b = Arc::new(ThreadBuf { tid, ring: Mutex::new(Ring::new()) });
            THREADS.lock().unwrap().push(Arc::clone(&b));
            b
        });
        let mut ring = buf.ring.lock().unwrap();
        ring.push(Span { name, lane, tid: buf.tid, start_ns, dur_ns, arg });
    });
}

/// RAII span: records `[construction, drop)` on the calling thread's ring.
/// Inert (no clock read, no record) while recording is disabled.
pub struct SpanGuard {
    name: NameId,
    arg: u64,
    start_ns: u64,
    armed: bool,
}

impl SpanGuard {
    /// A guard that records nothing (what [`span`] returns when disabled).
    pub fn inert() -> Self {
        SpanGuard { name: 0, arg: 0, start_ns: 0, armed: false }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if self.armed {
            let end = now_ns();
            record(self.name, self.arg, self.start_ns, end.saturating_sub(self.start_ns));
        }
    }
}

/// Open a span with no argument word.
#[inline]
pub fn span(name: NameId) -> SpanGuard {
    span_arg(name, 0)
}

/// Open a span carrying `arg` (level, pid, or batch size per the name's
/// [`names::ArgRole`]).
#[inline]
pub fn span_arg(name: NameId, arg: u64) -> SpanGuard {
    if !enabled() {
        return SpanGuard::inert();
    }
    SpanGuard { name, arg, start_ns: now_ns(), armed: true }
}

/// Drain every thread's ring: returns all recorded spans (sorted by start
/// time) plus the total overwritten-span count, and leaves the rings
/// empty. Threads keep their registration, so recording continues
/// afterwards.
pub fn drain() -> (Vec<Span>, u64) {
    let threads = THREADS.lock().unwrap();
    let mut all = Vec::new();
    let mut dropped = 0;
    for buf in threads.iter() {
        let (spans, d) = buf.ring.lock().unwrap().drain();
        all.extend(spans);
        dropped += d;
    }
    all.sort_by_key(|s| (s.start_ns, s.tid, s.name));
    (all, dropped)
}

/// Encode spans for the wire `Flush` reply: `[dropped, count, then 6 f64
/// words per span]`. Every field is exactly representable (all values are
/// < 2^53 for any realistic process lifetime).
pub fn encode_spans(spans: &[Span], dropped: u64) -> Vec<f64> {
    let mut out = Vec::with_capacity(2 + spans.len() * 6);
    out.push(dropped as f64);
    out.push(spans.len() as f64);
    for s in spans {
        out.push(s.name as f64);
        out.push(s.lane as f64);
        out.push(s.tid as f64);
        out.push(s.start_ns as f64);
        out.push(s.dur_ns as f64);
        out.push(s.arg as f64);
    }
    out
}

/// Decode a `Flush` payload back into `(spans, dropped)`.
pub fn decode_spans(data: &[f64]) -> Result<(Vec<Span>, u64), String> {
    if data.len() < 2 {
        return Err(format!("flush payload too short: {} words", data.len()));
    }
    let dropped = data[0] as u64;
    let count = data[1] as usize;
    let body = &data[2..];
    if body.len() != count * 6 {
        return Err(format!("flush payload: expected {} span words, got {}", count * 6, body.len()));
    }
    let spans = body
        .chunks_exact(6)
        .map(|c| Span {
            name: c[0] as NameId,
            lane: c[1] as u32,
            tid: c[2] as u32,
            start_ns: c[3] as u64,
            dur_ns: c[4] as u64,
            arg: c[5] as u64,
        })
        .collect();
    Ok((spans, dropped))
}

/// Best-effort span count currently buffered (tests / diagnostics).
pub fn buffered() -> usize {
    THREADS.lock().unwrap().iter().map(|b| b.ring.lock().unwrap().spans.len()).sum()
}

/// The enable flag and thread rings are process-global, so unit tests that
/// flip them serialize on this lock (cargo runs tests on threads of one
/// process).
#[cfg(test)]
pub(crate) static OBS_TEST_LOCK: Mutex<()> = Mutex::new(());

#[cfg(test)]
mod tests {
    use super::super::names;
    use super::*;

    #[test]
    fn disabled_records_nothing() {
        let _g = OBS_TEST_LOCK.lock().unwrap();
        set_enabled(false);
        let before = buffered();
        {
            let _s = span(names::UPSWEEP);
        }
        record(names::UPSWEEP, 0, 0, 10);
        assert_eq!(buffered(), before);
    }

    #[test]
    fn spans_record_and_drain() {
        let _g = OBS_TEST_LOCK.lock().unwrap();
        set_enabled(true);
        let _ = drain();
        set_lane(7);
        {
            let _s = span_arg(names::UPSWEEP, 3);
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        record(names::REQ_QUEUED, 42, 100, 50);
        let (spans, dropped) = drain();
        set_enabled(false);
        set_lane(LANE_UNSET);
        assert_eq!(dropped, 0);
        let up = spans.iter().find(|s| s.name == names::UPSWEEP).expect("upsweep span");
        assert_eq!(up.arg, 3);
        assert_eq!(up.lane, 7);
        assert!(up.dur_ns >= 1_000_000, "slept 1ms, got {}ns", up.dur_ns);
        let rq = spans.iter().find(|s| s.name == names::REQ_QUEUED).expect("queued span");
        assert_eq!((rq.start_ns, rq.dur_ns, rq.arg), (100, 50, 42));
        assert_eq!(buffered(), 0);
    }

    #[test]
    fn ring_wraps_and_counts_dropped() {
        let mut r = Ring::new();
        for i in 0..(RING_CAPACITY as u64 + 10) {
            r.push(Span { name: 0, lane: 0, tid: 0, start_ns: i, dur_ns: 0, arg: 0 });
        }
        let (spans, dropped) = r.drain();
        assert_eq!(spans.len(), RING_CAPACITY);
        assert_eq!(dropped, 10);
        // Oldest 10 were overwritten; order restored chronologically.
        assert_eq!(spans[0].start_ns, 10);
        assert!(spans.windows(2).all(|w| w[0].start_ns < w[1].start_ns));
    }

    #[test]
    fn encode_decode_roundtrip() {
        let spans = vec![
            Span { name: 5, lane: LANE_UNSET, tid: 2, start_ns: 123, dur_ns: 456, arg: 9 },
            Span { name: 40, lane: 3, tid: 0, start_ns: 1 << 40, dur_ns: 7, arg: u32::MAX as u64 },
        ];
        let wire = encode_spans(&spans, 11);
        let (back, dropped) = decode_spans(&wire).unwrap();
        assert_eq!(back, spans);
        assert_eq!(dropped, 11);
        assert!(decode_spans(&wire[..wire.len() - 1]).is_err());
        assert!(decode_spans(&[]).is_err());
    }
}
