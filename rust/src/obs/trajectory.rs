//! Persistent bench trajectory: one schema for every bench, one
//! append-only file, one regression gate.
//!
//! Each bench run emits a [`BenchRow`] — bench id, config fingerprint,
//! git revision, wall-clock stamp, and a flat metrics map — through
//! [`append_row`] into `BENCH_TRAJECTORY.jsonl` (one strict-JSON object
//! per line, found by walking up from the CWD to the repo root, or set
//! explicitly with `H2OPUS_TRAJECTORY`). The file is append-only history:
//! rows accumulate across commits, so `h2opus analyze
//! --assert-no-regression` can compare the newest row of every
//! `(bench, config)` series against its immediate predecessor with a
//! noise band, and CI can fail the build when a phase slows down.
//!
//! Metric keys carry their own direction: `*_per_s` / throughput-like
//! keys are higher-better, `*_s`/`*_ms`/`*_us`/`*_ns`/`*_bytes` and
//! latency-like keys are lower-better, anything else is informational
//! and never gated. The `H2OPUS_TEST_SLOWDOWN` hook multiplies
//! lower-better metrics at append time so the gate's failure path stays
//! testable without a real regression.

use std::env;
use std::fmt::Write as _;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use crate::util::testing::{parse_json, JsonValue};
use crate::util::trace::escape_json;

/// Name of the append-only trajectory file at the repo root.
pub const TRAJECTORY_FILE: &str = "BENCH_TRAJECTORY.jsonl";

/// Env override for the trajectory file location.
pub const TRAJECTORY_ENV: &str = "H2OPUS_TRAJECTORY";

/// Test hook: multiply lower-better metrics (divide higher-better ones)
/// by this factor at append time, simulating a uniform slowdown.
pub const SLOWDOWN_ENV: &str = "H2OPUS_TEST_SLOWDOWN";

/// Default fractional noise band for the regression gate: a lower-better
/// metric may grow by up to 75% (and a higher-better one shrink by the
/// same factor) before the gate fails. Tiny CI smokes are noisy; the
/// band is wide enough for scheduler jitter yet catches a 2x slowdown.
pub const DEFAULT_BAND: f64 = 0.75;

/// How a metric is compared across runs, derived from its key.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Direction {
    HigherBetter,
    LowerBetter,
    /// Not a performance metric (sizes, ranks, error norms with their own
    /// gates elsewhere) — recorded but never regression-checked.
    Info,
}

/// Classify a metric key. Higher-better patterns are checked first so
/// `gflops_per_s` is not caught by the lower-better `_s` suffix.
pub fn metric_direction(key: &str) -> Direction {
    let k = key.to_ascii_lowercase();
    if k.ends_with("_per_s")
        || k.contains("gflop")
        || k.contains("throughput")
        || k.contains("speedup")
        || k.contains("bandwidth")
    {
        return Direction::HigherBetter;
    }
    if k.ends_with("_s")
        || k.ends_with("_ms")
        || k.ends_with("_us")
        || k.ends_with("_ns")
        || k.ends_with("_bytes")
        || k.ends_with("_waste")
        || k.contains("time")
        || k.contains("latency")
        || k.contains("_p50")
        || k.contains("_p99")
    {
        return Direction::LowerBetter;
    }
    Direction::Info
}

/// One bench observation: the unified schema all ten benches emit.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchRow {
    /// Bench id, e.g. `hgemv_weak` or `serving`.
    pub bench: String,
    /// Config fingerprint: a stable `k=v` string identifying the problem
    /// shape, so rows are only compared within one series.
    pub config: String,
    /// Git revision the row was produced at (short hash, or `unknown`).
    pub git_rev: String,
    /// Wall-clock stamp, milliseconds since the Unix epoch.
    pub unix_ms: u64,
    /// Metric map, sorted by key; values are finite by construction
    /// (non-finite values are dropped at insert).
    pub metrics: Vec<(String, f64)>,
}

impl BenchRow {
    /// Start a row for `bench` with the given config fingerprint; stamps
    /// the current git revision and wall clock.
    pub fn new(bench: &str, config: &str) -> Self {
        let unix_ms = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_millis() as u64)
            .unwrap_or(0);
        BenchRow {
            bench: bench.to_string(),
            config: config.to_string(),
            git_rev: git_rev(),
            unix_ms,
            metrics: Vec::new(),
        }
    }

    /// Insert (or overwrite) a metric, keeping the map key-sorted.
    /// Non-finite values are silently dropped — the trajectory file must
    /// stay strict JSON.
    pub fn metric(mut self, key: &str, value: f64) -> Self {
        self.set_metric(key, value);
        self
    }

    /// Non-consuming form of [`BenchRow::metric`].
    pub fn set_metric(&mut self, key: &str, value: f64) {
        if !value.is_finite() {
            return;
        }
        match self.metrics.binary_search_by(|(k, _)| k.as_str().cmp(key)) {
            Ok(i) => self.metrics[i].1 = value,
            Err(i) => self.metrics.insert(i, (key.to_string(), value)),
        }
    }

    /// Render the row as one strict-JSON line (no trailing newline).
    pub fn to_json_line(&self) -> String {
        let mut out = String::new();
        let _ = write!(
            out,
            "{{\"bench\": \"{}\", \"config\": \"{}\", \"git_rev\": \"{}\", \"unix_ms\": {}, \"metrics\": {{",
            escape_json(&self.bench),
            escape_json(&self.config),
            escape_json(&self.git_rev),
            self.unix_ms
        );
        for (i, (k, v)) in self.metrics.iter().enumerate() {
            let comma = if i + 1 == self.metrics.len() { "" } else { ", " };
            let _ = write!(out, "\"{}\": {}{}", escape_json(k), fmt_f64(*v), comma);
        }
        out.push_str("}}");
        out
    }

    /// Parse one trajectory line back into a row.
    pub fn from_json_line(line: &str) -> Result<BenchRow, String> {
        let v = parse_json(line)?;
        let get_str = |key: &str| -> Result<String, String> {
            v.get(key)
                .and_then(JsonValue::as_str)
                .map(str::to_string)
                .ok_or_else(|| format!("bench row missing string field '{key}'"))
        };
        let mut row = BenchRow {
            bench: get_str("bench")?,
            config: get_str("config")?,
            git_rev: get_str("git_rev")?,
            unix_ms: v
                .get("unix_ms")
                .and_then(JsonValue::as_f64)
                .ok_or("bench row missing 'unix_ms'")? as u64,
            metrics: Vec::new(),
        };
        match v.get("metrics") {
            Some(JsonValue::Obj(members)) => {
                for (k, mv) in members {
                    let x = mv
                        .as_f64()
                        .ok_or_else(|| format!("metric '{k}' is not a number"))?;
                    row.set_metric(k, x);
                }
            }
            _ => return Err("bench row missing 'metrics' object".into()),
        }
        Ok(row)
    }
}

/// Format an f64 for the trajectory file: plain decimal (Rust's `{}`
/// never emits exponents or non-finite tokens for finite inputs), so the
/// strict parser round-trips it.
fn fmt_f64(x: f64) -> String {
    format!("{x}")
}

/// Apply the injected-slowdown test hook to a row: lower-better metrics
/// are multiplied by `factor`, higher-better metrics divided.
pub fn apply_slowdown(row: &mut BenchRow, factor: f64) {
    for (k, v) in &mut row.metrics {
        match metric_direction(k) {
            Direction::LowerBetter => *v *= factor,
            Direction::HigherBetter => *v /= factor,
            Direction::Info => {}
        }
    }
}

/// Resolve the trajectory file path: `H2OPUS_TRAJECTORY` if set, else
/// the first ancestor of the CWD containing an existing trajectory file
/// or a `.git` directory (the repo root), else the CWD itself.
pub fn trajectory_path() -> PathBuf {
    if let Ok(p) = env::var(TRAJECTORY_ENV) {
        return PathBuf::from(p);
    }
    let mut dir = env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        if dir.join(TRAJECTORY_FILE).exists() || dir.join(".git").exists() {
            return dir.join(TRAJECTORY_FILE);
        }
        if !dir.pop() {
            return PathBuf::from(TRAJECTORY_FILE);
        }
    }
}

/// Current git revision, short form: `H2OPUS_GIT_REV` if set, else
/// resolved by hand from `.git/HEAD` (the image has git, but benches
/// should not have to shell out), else `unknown`.
pub fn git_rev() -> String {
    if let Ok(r) = env::var("H2OPUS_GIT_REV") {
        return shorten(r.trim());
    }
    let mut dir = env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let git = dir.join(".git");
        if git.is_dir() {
            return read_git_head(&git).unwrap_or_else(|| "unknown".into());
        }
        if !dir.pop() {
            return "unknown".into();
        }
    }
}

fn shorten(hash: &str) -> String {
    hash.chars().take(12).collect()
}

fn read_git_head(git: &Path) -> Option<String> {
    let head = fs::read_to_string(git.join("HEAD")).ok()?;
    let head = head.trim();
    if let Some(refname) = head.strip_prefix("ref: ") {
        if let Ok(h) = fs::read_to_string(git.join(refname)) {
            return Some(shorten(h.trim()));
        }
        // Ref may only exist packed.
        if let Ok(packed) = fs::read_to_string(git.join("packed-refs")) {
            for line in packed.lines() {
                if let Some(hash) = line.strip_suffix(refname) {
                    return Some(shorten(hash.trim()));
                }
            }
        }
        None
    } else {
        Some(shorten(head))
    }
}

/// Append one row to the trajectory file (creating it if needed),
/// honoring the `H2OPUS_TEST_SLOWDOWN` hook. Returns the path written.
pub fn append_row(row: &BenchRow) -> std::io::Result<PathBuf> {
    let mut row = row.clone();
    if let Some(f) = env::var(SLOWDOWN_ENV).ok().and_then(|s| s.parse::<f64>().ok()) {
        apply_slowdown(&mut row, f);
    }
    let path = trajectory_path();
    let mut file = fs::OpenOptions::new().create(true).append(true).open(&path)?;
    writeln!(file, "{}", row.to_json_line())?;
    Ok(path)
}

/// Append a row and report the destination on stdout — the common tail
/// of every bench binary. Failures are reported but never fatal: a bench
/// must still print its table on a read-only checkout.
pub fn append_and_report(row: &BenchRow) {
    match append_row(row) {
        Ok(path) => {
            println!("trajectory += {} [{}] -> {}", row.bench, row.config, path.display())
        }
        Err(e) => eprintln!("trajectory append failed for {}: {e}", row.bench),
    }
}

/// Parse a whole trajectory file body (blank lines ignored). Malformed
/// lines are errors: the trajectory is committed history, so corruption
/// should fail loudly, not silently shrink the comparison set.
pub fn parse_rows(text: &str) -> Result<Vec<BenchRow>, String> {
    let mut rows = Vec::new();
    for (i, line) in text.lines().enumerate() {
        if line.trim().is_empty() {
            continue;
        }
        rows.push(
            BenchRow::from_json_line(line).map_err(|e| format!("line {}: {e}", i + 1))?,
        );
    }
    Ok(rows)
}

/// Load and parse the trajectory file; a missing file is an empty
/// trajectory, not an error.
pub fn load_rows(path: &Path) -> Result<Vec<BenchRow>, String> {
    match fs::read_to_string(path) {
        Ok(text) => parse_rows(&text),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => Ok(Vec::new()),
        Err(e) => Err(format!("{}: {e}", path.display())),
    }
}

/// One gated comparison: the newest row of a series against its
/// immediate predecessor, for one directional metric.
#[derive(Clone, Debug)]
pub struct RegressionCheck {
    pub bench: String,
    pub config: String,
    pub metric: String,
    pub prior: f64,
    pub current: f64,
    /// Slowdown ratio normalized so >1 is worse regardless of direction.
    pub ratio: f64,
    pub failed: bool,
}

/// Result of gating the newest rows against their predecessors.
#[derive(Clone, Debug, Default)]
pub struct RegressionReport {
    pub band: f64,
    pub checks: Vec<RegressionCheck>,
    /// Series with only one row (nothing to compare against yet).
    pub fresh_series: usize,
}

impl RegressionReport {
    pub fn failures(&self) -> usize {
        self.checks.iter().filter(|c| c.failed).count()
    }

    pub fn render_text(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(
            out,
            "regression gate: {} checks, {} failures (band {:.0}%, {} fresh series)",
            self.checks.len(),
            self.failures(),
            self.band * 100.0,
            self.fresh_series
        );
        for c in &self.checks {
            if c.failed {
                let _ = writeln!(
                    out,
                    "  FAIL {} [{}] {}: {} -> {} ({:.2}x slowdown > {:.2}x band)",
                    c.bench,
                    c.config,
                    c.metric,
                    fmt_f64(c.prior),
                    fmt_f64(c.current),
                    c.ratio,
                    1.0 + self.band
                );
            }
        }
        if self.failures() == 0 && !self.checks.is_empty() {
            out.push_str("  all series within band\n");
        }
        out
    }
}

/// Compare the newest row of every `(bench, config)` series against its
/// immediate predecessor in file order. A lower-better metric fails when
/// `current > prior * (1 + band)`; a higher-better one when
/// `current < prior / (1 + band)`. Info metrics and non-positive priors
/// are skipped.
pub fn check_regressions(rows: &[BenchRow], band: f64) -> RegressionReport {
    // Series key -> indices, in file (append) order.
    let mut series: Vec<((&str, &str), Vec<usize>)> = Vec::new();
    for (i, r) in rows.iter().enumerate() {
        let key = (r.bench.as_str(), r.config.as_str());
        match series.iter_mut().find(|(k, _)| *k == key) {
            Some((_, idxs)) => idxs.push(i),
            None => series.push((key, vec![i])),
        }
    }
    let mut report = RegressionReport { band, ..RegressionReport::default() };
    for (_, idxs) in &series {
        if idxs.len() < 2 {
            report.fresh_series += 1;
            continue;
        }
        let prior = &rows[idxs[idxs.len() - 2]];
        let current = &rows[idxs[idxs.len() - 1]];
        for (key, cur) in &current.metrics {
            let cur = *cur;
            let dir = metric_direction(key);
            if dir == Direction::Info {
                continue;
            }
            let Some(&(_, prev)) =
                prior.metrics.iter().find(|(k, _)| k == key)
            else {
                continue;
            };
            if prev <= 0.0 || cur <= 0.0 {
                continue;
            }
            let ratio = match dir {
                Direction::LowerBetter => cur / prev,
                Direction::HigherBetter => prev / cur,
                Direction::Info => unreachable!(),
            };
            report.checks.push(RegressionCheck {
                bench: current.bench.clone(),
                config: current.config.clone(),
                metric: key.clone(),
                prior: prev,
                current: cur,
                ratio,
                failed: ratio > 1.0 + band,
            });
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(bench: &str, config: &str, metrics: &[(&str, f64)]) -> BenchRow {
        let mut r = BenchRow {
            bench: bench.into(),
            config: config.into(),
            git_rev: "deadbeef".into(),
            unix_ms: 1_700_000_000_000,
            metrics: Vec::new(),
        };
        for (k, v) in metrics {
            r.set_metric(k, *v);
        }
        r
    }

    #[test]
    fn direction_classification() {
        assert_eq!(metric_direction("gflops_per_s"), Direction::HigherBetter);
        assert_eq!(metric_direction("matvec_gflops"), Direction::HigherBetter);
        assert_eq!(metric_direction("speedup_vs_dense"), Direction::HigherBetter);
        assert_eq!(metric_direction("matvec_s"), Direction::LowerBetter);
        assert_eq!(metric_direction("latency_p99_us"), Direction::LowerBetter);
        assert_eq!(metric_direction("bytes_sent_bytes"), Direction::LowerBetter);
        assert_eq!(metric_direction("pad_waste"), Direction::LowerBetter);
        assert_eq!(metric_direction("rank"), Direction::Info);
        assert_eq!(metric_direction("rel_err"), Direction::Info);
    }

    #[test]
    fn row_round_trips_through_strict_parser() {
        let r = row("hgemv_weak", "n=4096 p=4", &[("matvec_s", 0.0125), ("gflops_per_s", 3.5)]);
        let line = r.to_json_line();
        let back = BenchRow::from_json_line(&line).unwrap();
        assert_eq!(back, r);
        // Keys come back sorted regardless of insertion order.
        let r2 = row("b", "c", &[("z_s", 1.0), ("a_s", 2.0)]);
        assert_eq!(r2.metrics[0].0, "a_s");
    }

    #[test]
    fn non_finite_metrics_are_dropped() {
        let r = row("b", "c", &[("ok_s", 1.0), ("bad_s", f64::NAN), ("worse_s", f64::INFINITY)]);
        assert_eq!(r.metrics.len(), 1);
        assert!(parse_json(&r.to_json_line()).is_ok());
    }

    #[test]
    fn identical_runs_pass_the_gate() {
        let rows = vec![
            row("hgemv_weak", "n=4096", &[("matvec_s", 0.01), ("gflops_per_s", 3.0)]),
            row("hgemv_weak", "n=4096", &[("matvec_s", 0.01), ("gflops_per_s", 3.0)]),
        ];
        let rep = check_regressions(&rows, DEFAULT_BAND);
        assert_eq!(rep.checks.len(), 2);
        assert_eq!(rep.failures(), 0);
    }

    #[test]
    fn injected_2x_slowdown_fails_the_gate() {
        let base = row("serving", "p=4", &[("latency_p50_us", 120.0), ("req_per_s", 900.0)]);
        let mut slow = base.clone();
        apply_slowdown(&mut slow, 2.0);
        assert_eq!(slow.metrics.iter().find(|(k, _)| k == "latency_p50_us").unwrap().1, 240.0);
        assert_eq!(slow.metrics.iter().find(|(k, _)| k == "req_per_s").unwrap().1, 450.0);
        let rep = check_regressions(&[base, slow], DEFAULT_BAND);
        assert_eq!(rep.failures(), 2, "{}", rep.render_text());
        assert!(rep.render_text().contains("FAIL serving"));
    }

    #[test]
    fn series_are_isolated_by_config_and_only_last_pair_is_gated() {
        let rows = vec![
            row("b", "n=1", &[("t_s", 1.0)]),
            row("b", "n=2", &[("t_s", 100.0)]), // different series: no comparison
            row("b", "n=1", &[("t_s", 10.0)]),  // old regression...
            row("b", "n=1", &[("t_s", 10.0)]),  // ...but newest pair is flat
        ];
        let rep = check_regressions(&rows, DEFAULT_BAND);
        assert_eq!(rep.failures(), 0);
        assert_eq!(rep.fresh_series, 1);
    }

    #[test]
    fn parse_rows_rejects_corruption_and_skips_blanks() {
        let good = row("a", "c", &[("t_s", 1.0)]).to_json_line();
        let text = format!("{good}\n\n{good}\n");
        assert_eq!(parse_rows(&text).unwrap().len(), 2);
        assert!(parse_rows("not json\n").is_err());
        assert!(load_rows(Path::new("/nonexistent/trajectory.jsonl")).unwrap().is_empty());
    }

    #[test]
    fn info_metrics_never_gate() {
        let rows = vec![
            row("b", "c", &[("rank", 16.0)]),
            row("b", "c", &[("rank", 64.0)]),
        ];
        assert_eq!(check_regressions(&rows, DEFAULT_BAND).checks.len(), 0);
    }
}
