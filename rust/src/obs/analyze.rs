//! Span-derived performance attribution: the analysis engine behind
//! `h2opus analyze`.
//!
//! The paper's performance claims are *interpreted* telemetry — Fig. 8
//! reads per-rank timelines to show communication hidden under local
//! compute, and §6 attributes Gflop/s phase by phase. This module computes
//! those readings mechanically from a merged cross-rank span trace
//! ([`super::clock::merged_trace_json`], or any Chrome-trace JSON the repo
//! emits):
//!
//! - **Phase aggregates** per rank and per level: leaf-span time grouped
//!   by rendered phase label (level suffixes like `L3` are kept; per-call
//!   arguments like `#42` / `x128` are stripped by [`phase_key`]).
//! - **Idle/wait breakdown** per rank: compute / wire / other busy time
//!   (interval union) against the global makespan.
//! - **Overlap efficiency** (the Fig. 8 metric): the fraction of each
//!   rank's wire time during which *some* compute span was open anywhere
//!   in the system — communication that cost no wall-clock.
//! - **Critical path**: a walk back through the happens-before graph
//!   induced by span timing — program order within each `(pid, tid)`
//!   stream, send/recv rendezvous between same-named wire spans on
//!   different pids, and wait-release edges from the last span to finish
//!   before an idle gap — reporting which phase on which rank bounds
//!   wall-clock.
//! - **Model drift**: the same trace priced with
//!   [`crate::dist::hgemv::CostModel`] against the per-rank work counters
//!   embedded in the trace metadata, as measured-vs-predicted deviation
//!   rows (consumed by `python/tests/model_check.py --analyze`).
//!
//! Every collection is fully sorted with total tie-breakers and every
//! number is rendered with a fixed precision, so reordered input spans
//! yield **byte-identical** text and JSON reports (tested).

use std::collections::BTreeMap;

use super::clock::PartMeta;
use crate::dist::hgemv::CostModel;
use crate::util::testing::{parse_json, JsonValue};
use crate::util::trace::escape_json;

/// Start-gap (µs) below which consecutive spans on one stream count as
/// back-to-back; larger gaps mean the stream *waited* and get a
/// wait-release happens-before edge. Merged traces carry 3 decimals of µs,
/// so anything above one printed ulp is a real gap.
const GAP_EPS_US: f64 = 0.002;

/// One event on the merged timeline (µs on the coordinator clock) — the
/// parsed form of a Chrome-trace `"X"` event.
#[derive(Clone, Debug, PartialEq)]
pub struct AEvent {
    pub name: String,
    pub cat: String,
    pub pid: usize,
    pub tid: usize,
    pub ts_us: f64,
    pub dur_us: f64,
}

impl AEvent {
    fn end_us(&self) -> f64 {
        self.ts_us + self.dur_us
    }

    /// The total order every pass sorts by — ties broken all the way down
    /// so shuffled inputs normalize to one sequence.
    fn sort_key(&self) -> (f64, f64, usize, usize, &str, &str) {
        (self.ts_us, self.dur_us, self.pid, self.tid, &self.name, &self.cat)
    }
}

fn cmp_events(a: &AEvent, b: &AEvent) -> std::cmp::Ordering {
    let (ats, adur, apid, atid, an, ac) = a.sort_key();
    let (bts, bdur, bpid, btid, bn, bc) = b.sort_key();
    ats.total_cmp(&bts)
        .then(adur.total_cmp(&bdur))
        .then(apid.cmp(&bpid))
        .then(atid.cmp(&btid))
        .then(an.cmp(bn))
        .then(ac.cmp(bc))
}

/// Strip the per-call argument suffix (`#42` product/request ids, `x128`
/// batch sizes) from a rendered span name, keeping level suffixes (`L3`)
/// — the aggregation key for "per rank and per level" phase tables.
pub fn phase_key(name: &str) -> String {
    if let Some((base, tail)) = name.rsplit_once(' ') {
        let arg_like = matches!(tail.as_bytes().first(), Some(b'#') | Some(b'x'))
            && tail.len() > 1
            && tail.bytes().skip(1).all(|b| b.is_ascii_digit());
        if arg_like {
            return base.to_string();
        }
    }
    name.to_string()
}

/// Per-rank busy/idle/overlap summary.
#[derive(Clone, Debug)]
pub struct RankReport {
    pub pid: usize,
    /// Leaf compute-span time (sum of durations), µs.
    pub compute_us: f64,
    /// Leaf wire-span ("comm" category) time, µs.
    pub comm_us: f64,
    /// Leaf transfer/server/lowprio time, µs.
    pub other_us: f64,
    /// Union length of all leaf spans on this pid (any category), µs.
    pub busy_us: f64,
    /// Makespan minus busy, µs.
    pub idle_us: f64,
    /// Fraction of this rank's wire time hidden under concurrent compute
    /// (anywhere in the system); 1.0 for a rank with no wire time.
    pub overlap_eff: f64,
}

/// One `(phase, rank)` aggregate row.
#[derive(Clone, Debug)]
pub struct PhaseAgg {
    pub phase: String,
    pub cat: String,
    pub pid: usize,
    pub total_us: f64,
    pub count: usize,
}

/// One span on the critical path, aggregated by `(phase, pid)`.
#[derive(Clone, Debug)]
pub struct PathStep {
    pub phase: String,
    pub pid: usize,
    pub us: f64,
    pub count: usize,
}

/// The happens-before chain that bounds wall-clock.
#[derive(Clone, Debug, Default)]
pub struct CriticalPath {
    /// Sum of span durations along the path, µs.
    pub total_us: f64,
    /// `total_us / makespan` — how much of the wall-clock the path
    /// explains (can slightly exceed 1 when chained spans overlap).
    pub coverage: f64,
    /// Phase with the largest time share on the path.
    pub bound_phase: String,
    /// The rank that phase ran on.
    pub bound_pid: usize,
    /// Number of spans on the path.
    pub len: usize,
    /// `(phase, pid)` contributions, largest first.
    pub steps: Vec<PathStep>,
}

/// One measured-vs-predicted deviation row: the trace's per-rank work
/// counters priced with the [`CostModel`] against the rank's measured
/// span time in the same class.
#[derive(Clone, Debug)]
pub struct DriftRow {
    pub pid: usize,
    /// `"compute"` (batched-kernel work) or `"wire"` (message traffic).
    pub class: &'static str,
    pub measured_s: f64,
    pub predicted_s: f64,
    /// measured / predicted.
    pub ratio: f64,
}

/// The full analysis of one merged trace.
#[derive(Clone, Debug)]
pub struct Analysis {
    /// Earliest span start on the merged timeline, µs.
    pub t0_us: f64,
    /// Last span end minus earliest start, µs.
    pub makespan_us: f64,
    /// Number of events analyzed.
    pub events: usize,
    pub ranks: Vec<RankReport>,
    /// Sorted by total time, largest first.
    pub phases: Vec<PhaseAgg>,
    pub critical_path: CriticalPath,
    pub drift: Vec<DriftRow>,
    /// Per-pid dropped-span counts from the trace metadata (pids with
    /// drops only), plus the total.
    pub dropped: Vec<(usize, u64)>,
    pub total_dropped: u64,
}

/// Parse a trace JSON into events + part metadata. Accepts both the
/// object form [`super::clock::merged_trace_json`] emits (`traceEvents` +
/// `metadata`) and the bare array form of
/// [`crate::util::trace::TraceCollector::to_json`].
pub fn parse_trace(json: &str) -> Result<(Vec<AEvent>, Vec<PartMeta>), String> {
    let parsed = parse_json(json)?;
    let (events_json, meta) = match parsed.as_arr() {
        Some(arr) => (arr, Vec::new()),
        None => {
            let arr = parsed
                .get("traceEvents")
                .and_then(JsonValue::as_arr)
                .ok_or("trace is neither an event array nor a traceEvents object")?;
            (arr, parse_meta(&parsed))
        }
    };
    let mut events = Vec::with_capacity(events_json.len());
    for e in events_json {
        let field = |k: &str| {
            e.get(k).and_then(JsonValue::as_f64).ok_or_else(|| format!("event lacks '{k}'"))
        };
        events.push(AEvent {
            name: e
                .get("name")
                .and_then(JsonValue::as_str)
                .ok_or("event lacks 'name'")?
                .to_string(),
            cat: e.get("cat").and_then(JsonValue::as_str).unwrap_or("").to_string(),
            pid: field("pid")? as usize,
            tid: field("tid")? as usize,
            ts_us: field("ts")?,
            dur_us: field("dur")?,
        });
    }
    Ok((events, meta))
}

fn parse_meta(parsed: &JsonValue) -> Vec<PartMeta> {
    let mut out = Vec::new();
    let parts = parsed
        .get("metadata")
        .and_then(|m| m.get("parts"))
        .and_then(JsonValue::as_arr)
        .unwrap_or(&[]);
    for p in parts {
        let num = |v: &JsonValue, k: &str| v.get(k).and_then(JsonValue::as_f64).unwrap_or(0.0);
        let mut meta = PartMeta {
            pid: num(p, "pid") as usize,
            dropped: num(p, "dropped") as u64,
            work: None,
        };
        if let Some(w) = p.get("work") {
            meta.work = Some(super::clock::WorkCounters {
                flops: num(w, "flops"),
                bytes_sent: num(w, "bytes_sent"),
                messages: num(w, "messages"),
                launches: num(w, "launches"),
                gemm_words: num(w, "gemm_words"),
            });
        }
        out.push(meta);
    }
    out
}

/// Analyze a trace JSON string (see [`parse_trace`] for accepted forms),
/// pricing drift with `cm`.
pub fn analyze_json(json: &str, cm: &CostModel) -> Result<Analysis, String> {
    let (events, meta) = parse_trace(json)?;
    Ok(analyze_events(events, &meta, cm))
}

/// Interval-union length helper: `intervals` need not be sorted.
fn union_len(mut intervals: Vec<(f64, f64)>) -> f64 {
    intervals.retain(|(a, b)| b > a);
    intervals.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.total_cmp(&y.1)));
    let mut total = 0.0;
    let mut cur: Option<(f64, f64)> = None;
    for (a, b) in intervals {
        match &mut cur {
            Some((_, ce)) if a <= *ce => *ce = ce.max(b),
            _ => {
                if let Some((cs, ce)) = cur {
                    total += ce - cs;
                }
                cur = Some((a, b));
            }
        }
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total
}

/// Overlap length between one interval and a sorted, disjoint union.
fn overlap_with_union(a: f64, b: f64, union: &[(f64, f64)]) -> f64 {
    let mut hidden = 0.0;
    for &(ua, ub) in union {
        if ub <= a {
            continue;
        }
        if ua >= b {
            break;
        }
        hidden += ub.min(b) - ua.max(a);
    }
    hidden
}

/// Merge intervals into a sorted disjoint union.
fn merge_intervals(mut intervals: Vec<(f64, f64)>) -> Vec<(f64, f64)> {
    intervals.retain(|(a, b)| b > a);
    intervals.sort_by(|x, y| x.0.total_cmp(&y.0).then(x.1.total_cmp(&y.1)));
    let mut out: Vec<(f64, f64)> = Vec::new();
    for (a, b) in intervals {
        match out.last_mut() {
            Some((_, ce)) if a <= *ce => *ce = ce.max(b),
            _ => out.push((a, b)),
        }
    }
    out
}

/// The core pass: normalize, find leaf spans, aggregate, walk the
/// critical path and price the drift rows.
pub fn analyze_events(mut events: Vec<AEvent>, meta: &[PartMeta], cm: &CostModel) -> Analysis {
    events.sort_by(cmp_events);
    let n = events.len();
    let t0_us = events.iter().map(|e| e.ts_us).fold(f64::INFINITY, f64::min);
    let t_end = events.iter().map(|e| e.end_us()).fold(f64::NEG_INFINITY, f64::max);
    let (t0_us, makespan_us) =
        if n == 0 { (0.0, 0.0) } else { (t0_us, (t_end - t0_us).max(0.0)) };

    // Leaf detection per (pid, tid) stream: a span that strictly contains
    // another span on its own stream is a *container* (e.g. the worker's
    // `product #k` wrapping its phases) — containers summarize their
    // children, so only leaves enter busy time, overlap and the critical
    // path (no double counting).
    let mut is_leaf = vec![true; n];
    {
        let mut by_stream: BTreeMap<(usize, usize), Vec<usize>> = BTreeMap::new();
        for (i, e) in events.iter().enumerate() {
            by_stream.entry((e.pid, e.tid)).or_default().push(i);
        }
        for idxs in by_stream.values() {
            // Sorted by (start asc, dur asc) globally; containment wants
            // (start asc, end desc) so parents precede children.
            let mut order = idxs.clone();
            order.sort_by(|&a, &b| {
                events[a]
                    .ts_us
                    .total_cmp(&events[b].ts_us)
                    .then(events[b].end_us().total_cmp(&events[a].end_us()))
                    .then(a.cmp(&b))
            });
            let mut stack: Vec<usize> = Vec::new();
            for &i in &order {
                while let Some(&top) = stack.last() {
                    if events[top].end_us() <= events[i].ts_us {
                        stack.pop();
                    } else {
                        break;
                    }
                }
                if let Some(&top) = stack.last() {
                    if events[top].end_us() >= events[i].end_us() {
                        is_leaf[top] = false;
                    }
                }
                stack.push(i);
            }
        }
    }
    let leaves: Vec<usize> = (0..n).filter(|&i| is_leaf[i]).collect();

    // Global compute union — the "somebody is computing" timeline the
    // overlap metric measures wire spans against.
    let compute_union = merge_intervals(
        leaves
            .iter()
            .filter(|&&i| events[i].cat == "compute")
            .map(|&i| (events[i].ts_us, events[i].end_us()))
            .collect(),
    );

    // Per-rank aggregates.
    let pids: Vec<usize> = {
        let mut p: Vec<usize> = events.iter().map(|e| e.pid).collect();
        p.sort_unstable();
        p.dedup();
        p
    };
    let mut ranks = Vec::with_capacity(pids.len());
    for &pid in &pids {
        let mut compute_us = 0.0;
        let mut comm_us = 0.0;
        let mut other_us = 0.0;
        let mut hidden_us = 0.0;
        let mut intervals = Vec::new();
        for &i in &leaves {
            let e = &events[i];
            if e.pid != pid {
                continue;
            }
            intervals.push((e.ts_us, e.end_us()));
            match e.cat.as_str() {
                "compute" => compute_us += e.dur_us,
                "comm" => {
                    comm_us += e.dur_us;
                    hidden_us += overlap_with_union(e.ts_us, e.end_us(), &compute_union);
                }
                _ => other_us += e.dur_us,
            }
        }
        let busy_us = union_len(intervals);
        let overlap_eff = if comm_us > 0.0 { (hidden_us / comm_us).clamp(0.0, 1.0) } else { 1.0 };
        ranks.push(RankReport {
            pid,
            compute_us,
            comm_us,
            other_us,
            busy_us,
            idle_us: (makespan_us - busy_us).max(0.0),
            overlap_eff,
        });
    }

    // Phase aggregates: leaf time grouped by (phase key, pid).
    let mut agg: BTreeMap<(String, usize), (String, f64, usize)> = BTreeMap::new();
    for &i in &leaves {
        let e = &events[i];
        let entry = agg
            .entry((phase_key(&e.name), e.pid))
            .or_insert_with(|| (e.cat.clone(), 0.0, 0));
        entry.1 += e.dur_us;
        entry.2 += 1;
    }
    let mut phases: Vec<PhaseAgg> = agg
        .into_iter()
        .map(|((phase, pid), (cat, total_us, count))| PhaseAgg {
            phase,
            cat,
            pid,
            total_us,
            count,
        })
        .collect();
    phases.sort_by(|a, b| {
        b.total_us
            .total_cmp(&a.total_us)
            .then(a.phase.cmp(&b.phase))
            .then(a.pid.cmp(&b.pid))
    });

    let critical_path = critical_path(&events, &leaves, makespan_us);
    let drift = drift_rows(meta, &ranks, cm);

    let mut dropped: Vec<(usize, u64)> =
        meta.iter().filter(|m| m.dropped > 0).map(|m| (m.pid, m.dropped)).collect();
    dropped.sort_unstable();
    let total_dropped = dropped.iter().map(|(_, d)| d).sum();

    Analysis {
        t0_us,
        makespan_us,
        events: n,
        ranks,
        phases,
        critical_path,
        drift,
        dropped,
        total_dropped,
    }
}

/// Walk the happens-before chain back from the last span to finish.
///
/// Predecessor candidates of a span `e` (all restricted to earlier sort
/// positions, so the walk strictly descends and terminates):
///
/// 1. **Program order**: the previous leaf on `e`'s `(pid, tid)` stream.
/// 2. **Send/recv rendezvous**: earlier spans with the *same rendered
///    name* in the `"comm"` category on a *different* pid — the two ends
///    of one wire step (`cmp rc gather L3` on sender and receiver, etc.).
/// 3. **Wait release**: if the stream was idle for more than
///    [`GAP_EPS_US`] before `e` started, the leaf anywhere in the system
///    whose *end* is latest but still ≤ `e`'s start — the event whose
///    completion plausibly released the wait (a `ship input #k` on the
///    coordinator releasing the worker's first phase, a worker's last
///    phase releasing the coordinator's collect).
///
/// At each step the candidate with the latest end wins (ties broken by
/// sort position): the chain follows whatever *directly gated* each
/// span's start, which is exactly "what bounds wall-clock".
fn critical_path(events: &[AEvent], leaves: &[usize], makespan_us: f64) -> CriticalPath {
    if leaves.is_empty() {
        return CriticalPath::default();
    }
    // Stream predecessor per leaf.
    let mut stream_prev: BTreeMap<usize, usize> = BTreeMap::new();
    {
        let mut last_on: BTreeMap<(usize, usize), usize> = BTreeMap::new();
        for &i in leaves {
            let key = (events[i].pid, events[i].tid);
            if let Some(&prev) = last_on.get(&key) {
                stream_prev.insert(i, prev);
            }
            last_on.insert(key, i);
        }
    }
    // Rendezvous groups: same rendered name, "comm" category.
    let mut comm_groups: BTreeMap<&str, Vec<usize>> = BTreeMap::new();
    for &i in leaves {
        if events[i].cat == "comm" {
            comm_groups.entry(&events[i].name).or_default().push(i);
        }
    }
    // Leaves ordered by end time (for wait-release lookups): position k
    // holds the leaf with the k-th smallest (end, sort index).
    let mut by_end: Vec<usize> = leaves.to_vec();
    by_end.sort_by(|&a, &b| events[a].end_us().total_cmp(&events[b].end_us()).then(a.cmp(&b)));

    // Start at the leaf that finishes last.
    let mut cur = *by_end.last().expect("non-empty");
    let mut path = vec![cur];
    loop {
        let e = &events[cur];
        let mut candidates: Vec<usize> = Vec::new();
        let stream_pred = stream_prev.get(&cur).copied();
        if let Some(p) = stream_pred {
            candidates.push(p);
        }
        for &j in comm_groups.get(e.name.as_str()).into_iter().flatten() {
            if j < cur && events[j].pid != e.pid {
                candidates.push(j);
            }
        }
        let gap = e.ts_us - stream_pred.map(|p| events[p].end_us()).unwrap_or(e.ts_us);
        let waited = stream_pred.is_none() || gap > GAP_EPS_US;
        if waited {
            // Latest-ending leaf with end <= e.ts (binary search over the
            // end-sorted order).
            let k = by_end.partition_point(|&j| events[j].end_us() <= e.ts_us);
            if let Some(&release) = by_end[..k].last() {
                if release != cur {
                    candidates.push(release);
                }
            }
        }
        candidates.retain(|&j| j < cur);
        // Latest end wins; ties by sort position.
        let Some(&next) = candidates
            .iter()
            .max_by(|&&a, &&b| events[a].end_us().total_cmp(&events[b].end_us()).then(a.cmp(&b)))
        else {
            break;
        };
        path.push(next);
        cur = next;
    }

    let total_us: f64 = path.iter().map(|&i| events[i].dur_us).sum();
    let mut steps_map: BTreeMap<(String, usize), (f64, usize)> = BTreeMap::new();
    for &i in &path {
        let entry =
            steps_map.entry((phase_key(&events[i].name), events[i].pid)).or_insert((0.0, 0));
        entry.0 += events[i].dur_us;
        entry.1 += 1;
    }
    let mut steps: Vec<PathStep> = steps_map
        .into_iter()
        .map(|((phase, pid), (us, count))| PathStep { phase, pid, us, count })
        .collect();
    steps.sort_by(|a, b| {
        b.us.total_cmp(&a.us).then(a.phase.cmp(&b.phase)).then(a.pid.cmp(&b.pid))
    });
    let (bound_phase, bound_pid) =
        steps.first().map(|s| (s.phase.clone(), s.pid)).unwrap_or_default();
    CriticalPath {
        total_us,
        coverage: if makespan_us > 0.0 { total_us / makespan_us } else { 0.0 },
        bound_phase,
        bound_pid,
        len: path.len(),
        steps,
    }
}

/// Price the embedded per-rank work counters with the cost model and pair
/// them with the measured span time of the same class.
fn drift_rows(meta: &[PartMeta], ranks: &[RankReport], cm: &CostModel) -> Vec<DriftRow> {
    let mut rows = Vec::new();
    let mut meta_sorted: Vec<&PartMeta> = meta.iter().filter(|m| m.work.is_some()).collect();
    meta_sorted.sort_by_key(|m| m.pid);
    for m in meta_sorted {
        let w = m.work.as_ref().expect("filtered");
        let Some(rank) = ranks.iter().find(|r| r.pid == m.pid) else { continue };
        // Compute: every batched launch priced exactly as CostModel::gemm
        // prices it — launch latency + flop term + operand-word traffic.
        let predicted_compute =
            w.launches * cm.t_launch + w.flops * cm.flop_time + 8.0 * w.gemm_words * cm.byte_time;
        // Wire: every message priced as CostModel::xfer — launch latency
        // per message + the bandwidth term over total bytes.
        let predicted_wire = w.messages * cm.t_launch + w.bytes_sent * cm.byte_time;
        for (class, predicted_s, measured_s) in [
            ("compute", predicted_compute, rank.compute_us * 1e-6),
            ("wire", predicted_wire, rank.comm_us * 1e-6),
        ] {
            if predicted_s > 0.0 {
                rows.push(DriftRow {
                    pid: m.pid,
                    class,
                    measured_s,
                    predicted_s,
                    ratio: measured_s / predicted_s,
                });
            }
        }
    }
    rows
}

impl Analysis {
    /// The smallest per-rank overlap efficiency among ranks that did any
    /// wire communication (the `--assert-overlap` gate's subject); 1.0
    /// when no rank communicated.
    pub fn min_overlap_eff(&self) -> f64 {
        self.ranks
            .iter()
            .filter(|r| r.comm_us > 0.0)
            .map(|r| r.overlap_eff)
            .fold(f64::INFINITY, f64::min)
            .min(1.0)
    }

    /// Human-readable report (deterministic byte-for-byte for a given
    /// span set; `top` caps the phase table).
    pub fn render_text(&self, top: usize) -> String {
        use std::fmt::Write as _;
        let mut out = String::new();
        let _ = writeln!(
            out,
            "makespan {:.3} ms over {} processes ({} events)",
            self.makespan_us * 1e-3,
            self.ranks.len(),
            self.events
        );
        if self.total_dropped > 0 {
            let per: Vec<String> =
                self.dropped.iter().map(|(p, d)| format!("pid {p}: {d}")).collect();
            let _ = writeln!(
                out,
                "WARNING: trace truncated — {} spans dropped by ring overflow ({}); \
                 aggregates and the critical path undercount the missing spans",
                self.total_dropped,
                per.join(", ")
            );
        }
        let _ = writeln!(
            out,
            "  {:>4} {:>11} {:>11} {:>11} {:>11} {:>8}",
            "pid", "compute_ms", "wire_ms", "other_ms", "idle_ms", "overlap"
        );
        for r in &self.ranks {
            let _ = writeln!(
                out,
                "  {:>4} {:>11.3} {:>11.3} {:>11.3} {:>11.3} {:>7.1}%",
                r.pid,
                r.compute_us * 1e-3,
                r.comm_us * 1e-3,
                r.other_us * 1e-3,
                r.idle_us * 1e-3,
                r.overlap_eff * 100.0
            );
        }
        let cp = &self.critical_path;
        if cp.len > 0 {
            let _ = writeln!(
                out,
                "critical path: {} spans, {:.3} ms = {:.1}% of makespan; bound by '{}' on \
                 pid {}",
                cp.len,
                cp.total_us * 1e-3,
                cp.coverage * 100.0,
                cp.bound_phase,
                cp.bound_pid
            );
            for s in cp.steps.iter().take(top) {
                let _ = writeln!(
                    out,
                    "    {:<28} pid {:>3}  {:>11.3} ms  ({} spans)",
                    s.phase,
                    s.pid,
                    s.us * 1e-3,
                    s.count
                );
            }
        }
        if !self.drift.is_empty() {
            let _ = writeln!(out, "model drift (measured / CostModel-predicted):");
            for d in &self.drift {
                let _ = writeln!(
                    out,
                    "    pid {:>3} {:<8} measured {:>10.3} ms, predicted {:>10.3} ms \
                     ({:>8.2}x)",
                    d.pid,
                    d.class,
                    d.measured_s * 1e3,
                    d.predicted_s * 1e3,
                    d.ratio
                );
            }
        }
        let _ = writeln!(out, "phase aggregates (top {top}):");
        for p in self.phases.iter().take(top) {
            let _ = writeln!(
                out,
                "    {:<28} {:<8} pid {:>3}  {:>11.3} ms  ({} spans)",
                p.phase,
                p.cat,
                p.pid,
                p.total_us * 1e-3,
                p.count
            );
        }
        out
    }

    /// Machine-readable report (strict JSON, deterministic byte-for-byte
    /// for a given span set) — what `model_check.py --analyze` consumes.
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::from("{\n");
        let _ = write!(
            out,
            "  \"makespan_us\": {:.3},\n  \"events\": {},\n  \"total_dropped\": {},\n",
            self.makespan_us, self.events, self.total_dropped
        );
        let _ = write!(out, "  \"dropped\": [");
        for (i, (pid, d)) in self.dropped.iter().enumerate() {
            let comma = if i + 1 == self.dropped.len() { "" } else { ", " };
            let _ = write!(out, "{{\"pid\": {pid}, \"dropped\": {d}}}{comma}");
        }
        let _ = writeln!(out, "],");
        let _ = writeln!(out, "  \"ranks\": [");
        for (i, r) in self.ranks.iter().enumerate() {
            let comma = if i + 1 == self.ranks.len() { "" } else { "," };
            let _ = writeln!(
                out,
                "    {{\"pid\": {}, \"compute_us\": {:.3}, \"comm_us\": {:.3}, \
                 \"other_us\": {:.3}, \"busy_us\": {:.3}, \"idle_us\": {:.3}, \
                 \"overlap_eff\": {:.6}}}{}",
                r.pid, r.compute_us, r.comm_us, r.other_us, r.busy_us, r.idle_us,
                r.overlap_eff, comma
            );
        }
        let _ = writeln!(out, "  ],");
        let cp = &self.critical_path;
        let _ = writeln!(
            out,
            "  \"critical_path\": {{\"total_us\": {:.3}, \"coverage\": {:.6}, \"len\": {}, \
             \"bound_phase\": \"{}\", \"bound_pid\": {}, \"steps\": [",
            cp.total_us,
            cp.coverage,
            cp.len,
            escape_json(&cp.bound_phase),
            cp.bound_pid
        );
        for (i, s) in cp.steps.iter().enumerate() {
            let comma = if i + 1 == cp.steps.len() { "" } else { "," };
            let _ = writeln!(
                out,
                "    {{\"phase\": \"{}\", \"pid\": {}, \"us\": {:.3}, \"count\": {}}}{}",
                escape_json(&s.phase),
                s.pid,
                s.us,
                s.count,
                comma
            );
        }
        let _ = writeln!(out, "  ]}},");
        let _ = writeln!(out, "  \"drift\": [");
        for (i, d) in self.drift.iter().enumerate() {
            let comma = if i + 1 == self.drift.len() { "" } else { "," };
            let _ = writeln!(
                out,
                "    {{\"pid\": {}, \"class\": \"{}\", \"measured_s\": {:.9}, \
                 \"predicted_s\": {:.9}, \"ratio\": {:.6}}}{}",
                d.pid, d.class, d.measured_s, d.predicted_s, d.ratio, comma
            );
        }
        let _ = writeln!(out, "  ],");
        let _ = writeln!(out, "  \"phases\": [");
        for (i, p) in self.phases.iter().enumerate() {
            let comma = if i + 1 == self.phases.len() { "" } else { "," };
            let _ = writeln!(
                out,
                "    {{\"phase\": \"{}\", \"cat\": \"{}\", \"pid\": {}, \"total_us\": {:.3}, \
                 \"count\": {}}}{}",
                escape_json(&p.phase),
                escape_json(&p.cat),
                p.pid,
                p.total_us,
                p.count,
                comma
            );
        }
        let _ = writeln!(out, "  ]");
        out.push('}');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(name: &str, cat: &str, pid: usize, tid: usize, ts: f64, dur: f64) -> AEvent {
        AEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            pid,
            tid,
            ts_us: ts,
            dur_us: dur,
        }
    }

    #[test]
    fn phase_key_strips_call_args_keeps_levels() {
        assert_eq!(phase_key("product #42"), "product");
        assert_eq!(phase_key("batch gemm x128"), "batch gemm");
        assert_eq!(phase_key("orth transfer L3"), "orth transfer L3");
        assert_eq!(phase_key("upsweep"), "upsweep");
        assert_eq!(phase_key("cmp rc gather L11"), "cmp rc gather L11");
        // Not an arg suffix: no digits / lone marker.
        assert_eq!(phase_key("max x"), "max x");
        assert_eq!(phase_key("a #x1"), "a #x1");
    }

    #[test]
    fn union_and_overlap_math() {
        assert_eq!(union_len(vec![(0.0, 2.0), (1.0, 3.0), (5.0, 6.0)]), 4.0);
        assert_eq!(union_len(vec![]), 0.0);
        let u = merge_intervals(vec![(4.0, 6.0), (0.0, 2.0), (1.0, 3.0)]);
        assert_eq!(u, vec![(0.0, 3.0), (4.0, 6.0)]);
        assert_eq!(overlap_with_union(1.0, 5.0, &u), 3.0);
        assert_eq!(overlap_with_union(10.0, 11.0, &u), 0.0);
    }

    #[test]
    fn containers_are_excluded_from_busy_and_phases() {
        // A `product` wrapping two phases on one stream: busy time must
        // count the leaves once, not the container plus the leaves.
        let events = vec![
            ev("product #0", "transfer", 0, 0, 0.0, 100.0),
            ev("upsweep", "compute", 0, 0, 0.0, 40.0),
            ev("downsweep", "compute", 0, 0, 50.0, 50.0),
        ];
        let a = analyze_events(events, &[], &CostModel::default());
        let r = &a.ranks[0];
        assert_eq!(r.compute_us, 90.0);
        assert_eq!(r.other_us, 0.0, "container excluded");
        assert_eq!(r.busy_us, 90.0);
        assert_eq!(r.idle_us, 10.0);
        assert!(a.phases.iter().all(|p| p.phase != "product"));
    }

    #[test]
    fn overlap_extremes() {
        // Zero overlap: the wire span runs while nothing computes.
        let zero = analyze_events(
            vec![
                ev("upsweep", "compute", 0, 0, 0.0, 10.0),
                ev("xhat send", "comm", 0, 0, 10.0, 5.0),
            ],
            &[],
            &CostModel::default(),
        );
        assert_eq!(zero.ranks[0].overlap_eff, 0.0);
        assert_eq!(zero.min_overlap_eff(), 0.0);

        // Full overlap: rank 0's wire span is entirely under rank 1's
        // compute span.
        let full = analyze_events(
            vec![
                ev("xhat send", "comm", 0, 0, 2.0, 4.0),
                ev("upsweep", "compute", 1, 1, 0.0, 10.0),
            ],
            &[],
            &CostModel::default(),
        );
        let r0 = full.ranks.iter().find(|r| r.pid == 0).unwrap();
        assert_eq!(r0.overlap_eff, 1.0);
        // Rank 1 had no wire time: efficiency defaults to 1.
        assert_eq!(full.min_overlap_eff(), 1.0);
    }

    #[test]
    fn critical_path_follows_rendezvous_chain() {
        // rank 0: A computes, then sends; rank 1: receives, then computes
        // until the makespan. Known path: A -> send -> recv -> B.
        let events = vec![
            ev("prep", "compute", 0, 0, 0.0, 10.0),
            ev("link L1", "comm", 0, 0, 10.0, 4.0),
            ev("link L1", "comm", 1, 1, 12.0, 4.0),
            ev("crunch", "compute", 1, 1, 16.0, 14.0),
        ];
        let a = analyze_events(events, &[], &CostModel::default());
        let cp = &a.critical_path;
        assert_eq!(cp.len, 4, "all four spans on the path: {cp:?}");
        assert_eq!(cp.total_us, 32.0);
        assert_eq!(cp.bound_phase, "crunch");
        assert_eq!(cp.bound_pid, 1);
        assert_eq!(a.makespan_us, 30.0);
    }

    #[test]
    fn critical_path_uses_wait_release_when_no_rendezvous_matches() {
        // rank 1 idles until rank 0's differently-named span completes:
        // the wait-release edge must bridge the gap.
        let events = vec![
            ev("ship input #0", "comm", 0, 0, 0.0, 20.0),
            ev("input gather", "compute", 1, 1, 20.0, 10.0),
        ];
        let a = analyze_events(events, &[], &CostModel::default());
        assert_eq!(a.critical_path.len, 2);
        assert_eq!(a.critical_path.total_us, 30.0);
        assert_eq!(a.critical_path.bound_phase, "ship input");
        assert_eq!(a.critical_path.bound_pid, 0);
    }

    #[test]
    fn drift_prices_work_counters() {
        let cm = CostModel::default();
        let meta = vec![PartMeta {
            pid: 0,
            dropped: 0,
            work: Some(super::super::clock::WorkCounters {
                flops: 1e9,
                bytes_sent: 1e6,
                messages: 10.0,
                launches: 100.0,
                gemm_words: 1e6,
            }),
        }];
        let events = vec![
            ev("upsweep", "compute", 0, 0, 0.0, 500_000.0),
            ev("xhat send", "comm", 0, 0, 500_000.0, 100.0),
        ];
        let a = analyze_events(events, &meta, &cm);
        assert_eq!(a.drift.len(), 2);
        let compute = &a.drift[0];
        assert_eq!(compute.class, "compute");
        let want = 100.0 * cm.t_launch + 1e9 * cm.flop_time + 8e6 * cm.byte_time;
        assert!((compute.predicted_s - want).abs() < 1e-12);
        assert!((compute.measured_s - 0.5).abs() < 1e-12);
        assert!((compute.ratio - 0.5 / want).abs() < 1e-9);
        let wire = &a.drift[1];
        assert_eq!(wire.class, "wire");
        assert!((wire.predicted_s - (10.0 * cm.t_launch + 1e6 * cm.byte_time)).abs() < 1e-15);
    }

    #[test]
    fn empty_trace_is_harmless() {
        let a = analyze_events(vec![], &[], &CostModel::default());
        assert_eq!(a.makespan_us, 0.0);
        assert_eq!(a.critical_path.len, 0);
        assert_eq!(a.min_overlap_eff(), 1.0);
        // Reports render without panicking.
        assert!(a.render_text(8).contains("makespan"));
        crate::util::testing::parse_json(&a.to_json()).expect("strict JSON");
    }

    #[test]
    fn json_report_is_strict_and_carries_sections() {
        let events = vec![
            ev("prep", "compute", 0, 0, 0.0, 10.0),
            ev("link L1", "comm", 0, 0, 10.0, 4.0),
            ev("link L1", "comm", 1, 1, 12.0, 4.0),
        ];
        let meta = vec![PartMeta { pid: 0, dropped: 7, work: None }];
        let a = analyze_events(events, &meta, &CostModel::default());
        assert_eq!(a.total_dropped, 7);
        let parsed = crate::util::testing::parse_json(&a.to_json()).expect("strict JSON");
        assert_eq!(parsed.get("total_dropped").unwrap().as_f64(), Some(7.0));
        assert!(parsed.get("ranks").unwrap().as_arr().unwrap().len() == 2);
        assert!(parsed.get("critical_path").unwrap().get("coverage").is_some());
        let text = a.render_text(8);
        assert!(text.contains("WARNING: trace truncated"), "{text}");
        assert!(text.contains("pid 0: 7"), "{text}");
    }
}
