//! A dependency-free metrics registry: named counters, gauges and
//! fixed-bucket histograms with Prometheus-style text exposition.
//!
//! The registry unifies the three reporting surfaces that grew
//! independently — [`crate::metrics::Metrics`] (per-product work
//! counters), `ServerStats` (serving aggregates) and `RequestStats`
//! (per-request latencies) — as *views*: the execution paths keep their
//! structs, and the session/server layers absorb them into the global
//! registry so one `stats` request answers for all of them.
//!
//! All handles are `Arc`s of atomics: recording never takes the registry
//! lock (only name lookup/creation does), so counters are safe to bump
//! from the dispatcher and client threads concurrently.

use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// A monotonically increasing counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn add(&self, v: u64) {
        self.0.fetch_add(v, Ordering::Relaxed);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value (or peak) gauge holding an `f64` as raw bits.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    /// Keep the maximum of the current and given value (peak tracking).
    pub fn set_max(&self, v: f64) {
        let mut cur = self.0.load(Ordering::Relaxed);
        while f64::from_bits(cur) < v {
            match self.0.compare_exchange_weak(
                cur,
                v.to_bits(),
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// A plain (non-atomic) fixed-bucket histogram — the snapshot/aggregation
/// form, also embedded directly in single-writer stats structs like
/// `ServerStats`.
#[derive(Clone, Debug, PartialEq)]
pub struct FixedHistogram {
    /// Upper bounds of the finite buckets (ascending); one implicit +Inf
    /// bucket follows.
    bounds: Vec<f64>,
    /// `counts.len() == bounds.len() + 1`.
    counts: Vec<u64>,
    count: u64,
    sum: f64,
}

impl FixedHistogram {
    pub fn new(bounds: Vec<f64>) -> Self {
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bucket bounds must be ascending");
        let n = bounds.len();
        FixedHistogram { bounds, counts: vec![0; n + 1], count: 0, sum: 0.0 }
    }

    /// Exponential latency buckets: 1µs … ~67s in powers of 4.
    pub fn latency() -> Self {
        FixedHistogram::new(latency_bounds())
    }

    /// Power-of-two width buckets for achieved-nv histograms (1 … 1024).
    pub fn widths() -> Self {
        FixedHistogram::new((0..=10).map(|i| (1u64 << i) as f64).collect())
    }

    pub fn observe(&mut self, v: f64) {
        let i = self.bounds.partition_point(|&b| b < v);
        self.counts[i] += 1;
        self.count += 1;
        self.sum += v;
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum / self.count as f64
        }
    }

    /// Estimate the `q`-quantile (0..=1) from bucket counts: the upper
    /// bound of the bucket containing the target rank (+Inf bucket falls
    /// back to the largest finite bound). 0 for an empty histogram.
    pub fn quantile(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut seen = 0;
        for (i, &c) in self.counts.iter().enumerate() {
            seen += c;
            if seen >= target {
                return if i < self.bounds.len() {
                    self.bounds[i]
                } else {
                    self.bounds.last().copied().unwrap_or(f64::INFINITY)
                };
            }
        }
        f64::INFINITY
    }

    pub fn merge(&mut self, other: &FixedHistogram) {
        assert_eq!(self.bounds, other.bounds, "merging histograms with different buckets");
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
    }

    /// `(upper_bound, count)` pairs of the non-empty finite buckets plus
    /// (+Inf, count) if the overflow bucket is non-empty.
    pub fn nonzero_buckets(&self) -> Vec<(f64, u64)> {
        let mut out = Vec::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                let bound =
                    if i < self.bounds.len() { self.bounds[i] } else { f64::INFINITY };
                out.push((bound, c));
            }
        }
        out
    }
}

/// Bounds of [`FixedHistogram::latency`] — also used to register the
/// matching atomic histograms by name in the global registry.
pub fn latency_bounds() -> Vec<f64> {
    let mut bounds = Vec::new();
    let mut b = 1e-6;
    while b < 100.0 {
        bounds.push(b);
        b *= 4.0;
    }
    bounds
}

/// A concurrent fixed-bucket histogram (atomic counts); `snapshot` yields
/// the plain form for quantile math and rendering.
#[derive(Debug)]
pub struct Histogram {
    bounds: Vec<f64>,
    counts: Vec<AtomicU64>,
    count: AtomicU64,
    /// Sum of observations, f64 bits, CAS-accumulated.
    sum_bits: AtomicU64,
}

impl Histogram {
    pub fn new(bounds: Vec<f64>) -> Self {
        assert!(bounds.windows(2).all(|w| w[0] < w[1]), "bucket bounds must be ascending");
        let n = bounds.len();
        Histogram {
            bounds,
            counts: (0..=n).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_bits: AtomicU64::new(0.0_f64.to_bits()),
        }
    }

    pub fn observe(&self, v: f64) {
        let i = self.bounds.partition_point(|&b| b < v);
        self.counts[i].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        let mut cur = self.sum_bits.load(Ordering::Relaxed);
        loop {
            let next = (f64::from_bits(cur) + v).to_bits();
            match self.sum_bits.compare_exchange_weak(
                cur,
                next,
                Ordering::Relaxed,
                Ordering::Relaxed,
            ) {
                Ok(_) => break,
                Err(seen) => cur = seen,
            }
        }
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn snapshot(&self) -> FixedHistogram {
        FixedHistogram {
            bounds: self.bounds.clone(),
            counts: self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum: f64::from_bits(self.sum_bits.load(Ordering::Relaxed)),
        }
    }
}

#[derive(Clone)]
enum Metric {
    Counter(Arc<Counter>),
    Gauge(Arc<Gauge>),
    Histogram(Arc<Histogram>),
}

/// A named-metric registry with get-or-create handles and text exposition.
#[derive(Default)]
pub struct Registry {
    metrics: Mutex<BTreeMap<String, Metric>>,
}

impl Registry {
    pub fn new() -> Self {
        Registry::default()
    }

    /// The process-wide registry every layer records into.
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::new)
    }

    /// Get or create the counter `name`. Panics if `name` exists with a
    /// different metric type (a naming bug, not a runtime condition).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Counter(Arc::new(Counter::default())))
        {
            Metric::Counter(c) => Arc::clone(c),
            _ => panic!("metric '{name}' already registered with a different type"),
        }
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        let mut m = self.metrics.lock().unwrap();
        match m.entry(name.to_string()).or_insert_with(|| Metric::Gauge(Arc::new(Gauge::default())))
        {
            Metric::Gauge(g) => Arc::clone(g),
            _ => panic!("metric '{name}' already registered with a different type"),
        }
    }

    /// Get or create the histogram `name` with the given finite bucket
    /// bounds (ignored when the histogram already exists).
    pub fn histogram(&self, name: &str, bounds: &[f64]) -> Arc<Histogram> {
        let mut m = self.metrics.lock().unwrap();
        match m
            .entry(name.to_string())
            .or_insert_with(|| Metric::Histogram(Arc::new(Histogram::new(bounds.to_vec()))))
        {
            Metric::Histogram(h) => Arc::clone(h),
            _ => panic!("metric '{name}' already registered with a different type"),
        }
    }

    /// Fold one product's merged work counters into the registry.
    pub fn absorb_metrics(&self, m: &crate::metrics::Metrics) {
        self.counter("h2opus_flops_total").add(m.flops);
        self.counter("h2opus_comm_bytes_total").add(m.bytes_sent);
        self.counter("h2opus_comm_messages_total").add(m.messages);
        self.counter("h2opus_batch_launches_total").add(m.batch_launches);
        self.counter("h2opus_batch_pad_waste_total").add(m.pad_waste);
        self.counter("h2opus_gemm_words_total").add(m.gemm_words);
        self.gauge("h2opus_rank_matrix_bytes_peak").set_max(m.matrix_bytes as f64);
        if m.coalesced_nv > 0 {
            let widths: Vec<f64> = (0..=10).map(|i| (1u64 << i) as f64).collect();
            self.histogram("h2opus_product_nv", &widths).observe(m.coalesced_nv as f64);
        }
    }

    /// Prometheus-style text exposition of every metric, in name order.
    pub fn render_text(&self) -> String {
        let metrics: Vec<(String, Metric)> = {
            let m = self.metrics.lock().unwrap();
            m.iter().map(|(k, v)| (k.clone(), v.clone())).collect()
        };
        let mut out = String::new();
        for (name, metric) in metrics {
            match metric {
                Metric::Counter(c) => {
                    writeln!(out, "# TYPE {name} counter").unwrap();
                    writeln!(out, "{name} {}", c.get()).unwrap();
                }
                Metric::Gauge(g) => {
                    writeln!(out, "# TYPE {name} gauge").unwrap();
                    writeln!(out, "{name} {}", prom_value(g.get())).unwrap();
                }
                Metric::Histogram(h) => {
                    let snap = h.snapshot();
                    writeln!(out, "# TYPE {name} histogram").unwrap();
                    let mut cum = 0;
                    for (bound, c) in
                        snap.bounds.iter().copied().zip(snap.counts.iter().copied())
                    {
                        cum += c;
                        writeln!(out, "{name}_bucket{{le=\"{}\"}} {cum}", prom_value(bound))
                            .unwrap();
                    }
                    writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {}", snap.count()).unwrap();
                    writeln!(out, "{name}_sum {}", prom_value(snap.sum())).unwrap();
                    writeln!(out, "{name}_count {}", snap.count()).unwrap();
                }
            }
        }
        out
    }

    /// Remove every registered metric (tests; existing handles keep
    /// working but are no longer rendered).
    pub fn clear(&self) {
        self.metrics.lock().unwrap().clear();
    }
}

/// Render one sample value per the Prometheus text exposition format:
/// non-finite floats become the canonical `+Inf` / `-Inf` / `NaN` tokens
/// (Rust's `Display` would emit `inf`, which scrapers reject).
fn prom_value(x: f64) -> String {
    if x.is_nan() {
        "NaN".to_string()
    } else if x == f64::INFINITY {
        "+Inf".to_string()
    } else if x == f64::NEG_INFINITY {
        "-Inf".to_string()
    } else {
        format!("{x}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_gauge_roundtrip() {
        let r = Registry::new();
        let c = r.counter("x_total");
        c.add(3);
        c.inc();
        assert_eq!(r.counter("x_total").get(), 4, "same handle by name");
        let g = r.gauge("x_peak");
        g.set_max(2.0);
        g.set_max(1.0);
        assert_eq!(g.get(), 2.0, "peak keeps max");
        g.set(0.5);
        assert_eq!(g.get(), 0.5);
    }

    #[test]
    #[should_panic(expected = "different type")]
    fn type_confusion_panics() {
        let r = Registry::new();
        let _ = r.counter("m");
        let _ = r.gauge("m");
    }

    #[test]
    fn histogram_quantiles() {
        let mut h = FixedHistogram::new(vec![1.0, 2.0, 4.0, 8.0]);
        for v in [0.5, 0.5, 1.5, 3.0, 3.0, 3.0, 3.0, 3.0, 3.0, 7.0] {
            h.observe(v);
        }
        assert_eq!(h.count(), 10);
        // p50 -> 5th observation -> bucket (2,4] -> bound 4.0.
        assert_eq!(h.quantile(0.5), 4.0);
        assert_eq!(h.quantile(0.99), 8.0);
        assert_eq!(h.quantile(0.0), 1.0);
        assert_eq!(FixedHistogram::latency().quantile(0.5), 0.0, "empty -> 0");
    }

    #[test]
    fn histogram_overflow_bucket() {
        let mut h = FixedHistogram::new(vec![1.0]);
        h.observe(100.0);
        assert_eq!(h.nonzero_buckets(), vec![(f64::INFINITY, 1)]);
        // Overflow quantile falls back to the largest finite bound.
        assert_eq!(h.quantile(0.5), 1.0);
    }

    #[test]
    fn atomic_histogram_snapshot_matches() {
        let r = Registry::new();
        let h = r.histogram("lat_seconds", &[0.001, 0.01, 0.1]);
        h.observe(0.0005);
        h.observe(0.05);
        h.observe(5.0);
        let snap = h.snapshot();
        assert_eq!(snap.count(), 3);
        assert!((snap.sum() - 5.0505).abs() < 1e-12);
        assert_eq!(snap.quantile(1.0), 0.1, "overflow clamps to top bound");
    }

    #[test]
    fn exposition_format() {
        let r = Registry::new();
        r.counter("a_total").add(7);
        r.gauge("b_bytes").set(12.5);
        r.histogram("c_seconds", &[0.5, 1.0]).observe(0.25);
        let text = r.render_text();
        assert!(text.contains("# TYPE a_total counter\na_total 7\n"), "{text}");
        assert!(text.contains("b_bytes 12.5"), "{text}");
        assert!(text.contains("c_seconds_bucket{le=\"0.5\"} 1"), "{text}");
        assert!(text.contains("c_seconds_bucket{le=\"+Inf\"} 1"), "{text}");
        assert!(text.contains("c_seconds_count 1"), "{text}");
    }

    #[test]
    fn non_finite_values_round_trip_through_strict_parser() {
        use crate::util::testing::parse_prometheus_text;
        let r = Registry::new();
        r.gauge("g_nan").set(f64::NAN);
        r.gauge("g_pinf").set(f64::INFINITY);
        r.gauge("g_ninf").set(f64::NEG_INFINITY);
        r.gauge("g_fin").set(-2.5);
        r.counter("c_total").add(3);
        let h = r.histogram("h_seconds", &[1.0]);
        h.observe(f64::INFINITY); // lands in the +Inf bucket, poisons the sum
        let text = r.render_text();
        assert!(text.contains("g_pinf +Inf"), "{text}");
        assert!(text.contains("g_ninf -Inf"), "{text}");
        assert!(text.contains("g_nan NaN"), "{text}");
        assert!(text.contains("h_seconds_sum +Inf"), "{text}");
        let samples = parse_prometheus_text(&text).expect("exposition must be strictly valid");
        let find = |n: &str| samples.iter().find(|(k, _)| k == n).map(|(_, v)| *v);
        assert!(find("g_nan").unwrap().is_nan());
        assert_eq!(find("g_pinf"), Some(f64::INFINITY));
        assert_eq!(find("g_ninf"), Some(f64::NEG_INFINITY));
        assert_eq!(find("g_fin"), Some(-2.5));
        assert_eq!(find("c_total"), Some(3.0));
        assert_eq!(find("h_seconds_bucket{le=\"+Inf\"}"), Some(1.0));
    }

    #[test]
    fn absorb_metrics_views() {
        let r = Registry::new();
        let mut m = crate::metrics::Metrics::new();
        m.gemm(4, 8, 8, 2);
        m.send(1024);
        m.matrix_bytes = 4096;
        m.coalesced_nv = 8;
        r.absorb_metrics(&m);
        r.absorb_metrics(&m);
        assert_eq!(r.counter("h2opus_flops_total").get(), 2 * m.flops);
        assert_eq!(r.counter("h2opus_comm_bytes_total").get(), 2048);
        assert_eq!(r.gauge("h2opus_rank_matrix_bytes_peak").get(), 4096.0);
        let text = r.render_text();
        assert!(text.contains("h2opus_product_nv_count 2"), "{text}");
    }
}
