//! Kernel functions for the paper's test problems.

use crate::geometry::MAX_DIM;

/// A (symmetric or not) kernel function κ(x, y).
pub trait Kernel {
    /// Spatial dimension the kernel expects.
    fn dim(&self) -> usize;
    /// Evaluate κ(x, y). Coordinates beyond `dim()` are zero.
    fn eval(&self, x: &[f64; MAX_DIM], y: &[f64; MAX_DIM]) -> f64;
}

/// The exponential covariance kernel exp(−r/ℓ) used by both §6.1 test sets
/// (2D spatial statistics with ℓ = 0.1a, 3D Gaussian process with ℓ = 0.2a).
#[derive(Clone, Copy, Debug)]
pub struct ExponentialKernel {
    pub dim: usize,
    /// Correlation length ℓ.
    pub corr_len: f64,
}

impl Kernel for ExponentialKernel {
    fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn eval(&self, x: &[f64; MAX_DIM], y: &[f64; MAX_DIM]) -> f64 {
        let mut r2 = 0.0;
        for d in 0..self.dim {
            let diff = x[d] - y[d];
            r2 += diff * diff;
        }
        (-r2.sqrt() / self.corr_len).exp()
    }
}

/// Gaussian (squared-exponential) kernel exp(−r²/(2ℓ²)) — a second smooth
/// kernel useful for exercising rank behaviour in tests and examples.
#[derive(Clone, Copy, Debug)]
pub struct GaussianKernel {
    pub dim: usize,
    pub corr_len: f64,
}

impl Kernel for GaussianKernel {
    fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn eval(&self, x: &[f64; MAX_DIM], y: &[f64; MAX_DIM]) -> f64 {
        let mut r2 = 0.0;
        for d in 0..self.dim {
            let diff = x[d] - y[d];
            r2 += diff * diff;
        }
        (-r2 / (2.0 * self.corr_len * self.corr_len)).exp()
    }
}

/// The paper's §6.4 bump diffusivity field over coordinates:
/// κ(x) = 1 + f(x₁; 0, 1.5)·f(x₂; 0, 2.0) (Eqs. 6–7). A plain `fn` (no
/// closure state) so a [`FractionalKernel`] over it round-trips through
/// worker CLI flags — every process of a distributed session evaluates
/// the identical diffusivity.
pub fn paper_kappa(p: &[f64; MAX_DIM]) -> f64 {
    1.0 + kappa_bump(p[0], 0.0, 1.5) * kappa_bump(p[1], 0.0, 2.0)
}

/// The compactly supported bump f(x; c, ℓ) of Eq. 7.
pub fn kappa_bump(x: f64, c: f64, ell: f64) -> f64 {
    let r = (x - c) / (ell / 2.0);
    if r.abs() < 1.0 {
        (-1.0 / (1.0 - r * r)).exp()
    } else {
        0.0
    }
}

/// The singular fractional-diffusion kernel
/// K(x, y) = −2 a(x,y) / |y − x|^{2 + 2β} with a(x,y) = √κ(x)√κ(y)
/// (§6.4, Eq. 11). The diagonal (x = y) is zero by construction of K.
/// Diffusivity κ is supplied as a closure over coordinates.
pub struct FractionalKernel<F: Fn(&[f64; MAX_DIM]) -> f64> {
    pub dim: usize,
    /// Fractional order β ∈ (0.5, 1).
    pub beta: f64,
    /// Pointwise diffusivity κ(x).
    pub kappa: F,
}

impl<F: Fn(&[f64; MAX_DIM]) -> f64> Kernel for FractionalKernel<F> {
    fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    fn eval(&self, x: &[f64; MAX_DIM], y: &[f64; MAX_DIM]) -> f64 {
        let mut r2 = 0.0;
        for d in 0..self.dim {
            let diff = x[d] - y[d];
            r2 += diff * diff;
        }
        if r2 == 0.0 {
            return 0.0; // K has zero diagonal (§6.4)
        }
        let a = ((self.kappa)(x) * (self.kappa)(y)).sqrt();
        let exponent = 0.5 * (self.dim as f64 + 2.0 * self.beta);
        -2.0 * a / r2.powf(exponent)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exponential_at_zero_distance_is_one() {
        let k = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let p = [0.3, 0.4, 0.0];
        assert_eq!(k.eval(&p, &p), 1.0);
    }

    #[test]
    fn exponential_decays() {
        let k = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let a = [0.0; 3];
        let near = [0.05, 0.0, 0.0];
        let far = [0.5, 0.0, 0.0];
        assert!(k.eval(&a, &near) > k.eval(&a, &far));
        assert!((k.eval(&a, &near) - (-0.5f64).exp()).abs() < 1e-14);
    }

    #[test]
    fn kernels_symmetric() {
        let k = ExponentialKernel { dim: 3, corr_len: 0.2 };
        let g = GaussianKernel { dim: 3, corr_len: 0.2 };
        let a = [0.1, 0.2, 0.3];
        let b = [0.9, 0.5, 0.1];
        assert_eq!(k.eval(&a, &b), k.eval(&b, &a));
        assert_eq!(g.eval(&a, &b), g.eval(&b, &a));
    }

    #[test]
    fn fractional_zero_diagonal_and_sign() {
        let k = FractionalKernel { dim: 2, beta: 0.75, kappa: |_: &[f64; 3]| 1.0 };
        let a = [0.0; 3];
        let b = [0.25, 0.0, 0.0];
        assert_eq!(k.eval(&a, &a), 0.0);
        assert!(k.eval(&a, &b) < 0.0);
        // |y-x|^{-(2+2beta)} with r=0.25, beta=0.75: r^{-3.5}
        let want = -2.0 * 0.25f64.powf(-3.5);
        assert!((k.eval(&a, &b) - want).abs() < 1e-9 * want.abs());
    }

    #[test]
    fn fractional_uses_kappa_geometric_mean() {
        let k = FractionalKernel { dim: 2, beta: 0.75, kappa: |p: &[f64; 3]| 1.0 + p[0] };
        let a = [0.0, 0.0, 0.0]; // kappa = 1
        let b = [3.0, 0.0, 0.0]; // kappa = 4
        let plain = FractionalKernel { dim: 2, beta: 0.75, kappa: |_: &[f64; 3]| 1.0 };
        assert!((k.eval(&a, &b) / plain.eval(&a, &b) - 2.0).abs() < 1e-12);
    }
}
