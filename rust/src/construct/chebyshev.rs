//! Chebyshev tensor-grid interpolation on cluster bounding boxes.
//!
//! Low-rank blocks are seeded as A_ts ≈ U_t S_ts V_sᵀ where U_t holds the
//! tensor Lagrange-Chebyshev basis polynomials of cluster t's bounding box
//! evaluated at t's points, and S_ts is the kernel evaluated on the two
//! clusters' Chebyshev grids. Because degree-(g−1) polynomials are
//! reproduced exactly by interpolation on g Chebyshev nodes, the transfer
//! matrices (parent basis evaluated at child grid points) make the basis
//! *exactly* nested — the property the upsweep/downsweep algorithms rely on.

use crate::geometry::{BBox, MAX_DIM};

/// Minimum half-width used when a bounding box degenerates along an axis
/// (e.g. a grid line): keeps Lagrange denominators nonzero.
const MIN_HALF_WIDTH: f64 = 1e-12;

/// 1D Chebyshev nodes of the first kind on [-1, 1], g points.
pub fn cheb_nodes_unit(g: usize) -> Vec<f64> {
    (0..g)
        .map(|i| ((2 * i + 1) as f64 * std::f64::consts::PI / (2 * g) as f64).cos())
        .collect()
}

/// The tensor Chebyshev grid of a bounding box: g^dim points, stored as
/// full MAX_DIM coordinates. Point index α enumerates dimension 0 fastest.
pub fn cheb_grid(bbox: &BBox, g: usize) -> Vec<[f64; MAX_DIM]> {
    let dim = bbox.dim;
    let unit = cheb_nodes_unit(g);
    // per-dimension mapped nodes
    let mut nodes = vec![vec![0.0; g]; dim];
    for d in 0..dim {
        let c = 0.5 * (bbox.lo[d] + bbox.hi[d]);
        let h = (0.5 * (bbox.hi[d] - bbox.lo[d])).max(MIN_HALF_WIDTH);
        for (i, &u) in unit.iter().enumerate() {
            nodes[d][i] = c + h * u;
        }
    }
    let k = g.pow(dim as u32);
    let mut grid = Vec::with_capacity(k);
    for alpha in 0..k {
        let mut p = [0.0; MAX_DIM];
        let mut rem = alpha;
        for d in 0..dim {
            p[d] = nodes[d][rem % g];
            rem /= g;
        }
        grid.push(p);
    }
    grid
}

/// Evaluator for the tensor Lagrange basis of a box's Chebyshev grid.
pub struct ChebBasis {
    dim: usize,
    g: usize,
    /// per-dimension node positions
    nodes: Vec<Vec<f64>>,
    /// per-dimension barycentric-style denominators: denom[d][j] =
    /// prod_{i != j} (nodes[d][j] - nodes[d][i])
    denom: Vec<Vec<f64>>,
}

impl ChebBasis {
    pub fn new(bbox: &BBox, g: usize) -> Self {
        let dim = bbox.dim;
        let unit = cheb_nodes_unit(g);
        let mut nodes = vec![vec![0.0; g]; dim];
        for d in 0..dim {
            let c = 0.5 * (bbox.lo[d] + bbox.hi[d]);
            let h = (0.5 * (bbox.hi[d] - bbox.lo[d])).max(MIN_HALF_WIDTH);
            for (i, &u) in unit.iter().enumerate() {
                nodes[d][i] = c + h * u;
            }
        }
        let mut denom = vec![vec![1.0; g]; dim];
        for d in 0..dim {
            for j in 0..g {
                for i in 0..g {
                    if i != j {
                        denom[d][j] *= nodes[d][j] - nodes[d][i];
                    }
                }
            }
        }
        ChebBasis { dim, g, nodes, denom }
    }

    /// Rank k = g^dim.
    pub fn rank(&self) -> usize {
        self.g.pow(self.dim as u32)
    }

    /// Evaluate all k tensor Lagrange polynomials at point x, writing into
    /// `out` (len k, same α ordering as [`cheb_grid`]).
    pub fn eval_all(&self, x: &[f64; MAX_DIM], out: &mut [f64]) {
        debug_assert_eq!(out.len(), self.rank());
        // 1D Lagrange values per dimension.
        let g = self.g;
        let mut l1 = vec![0.0; self.dim * g];
        for d in 0..self.dim {
            // full products (g is small: <= 8)
            for j in 0..g {
                let mut num = 1.0;
                for i in 0..g {
                    if i != j {
                        num *= x[d] - self.nodes[d][i];
                    }
                }
                l1[d * g + j] = num / self.denom[d][j];
            }
        }
        for (alpha, o) in out.iter_mut().enumerate() {
            let mut v = 1.0;
            let mut rem = alpha;
            for d in 0..self.dim {
                v *= l1[d * g + rem % g];
                rem /= g;
            }
            *o = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::PointSet;

    fn unit_box_2d() -> BBox {
        let ps = PointSet::grid_2d(2, 1.0);
        BBox::of(&ps, &[0, 1, 2, 3])
    }

    #[test]
    fn nodes_in_interval_and_distinct() {
        let nodes = cheb_nodes_unit(5);
        for w in nodes.windows(2) {
            assert!(w[0] > w[1]); // strictly decreasing
        }
        assert!(nodes.iter().all(|&x| (-1.0..=1.0).contains(&x)));
    }

    #[test]
    fn grid_size_is_g_pow_dim() {
        let bb = unit_box_2d();
        assert_eq!(cheb_grid(&bb, 3).len(), 9);
        assert_eq!(ChebBasis::new(&bb, 3).rank(), 9);
    }

    #[test]
    fn lagrange_cardinal_property() {
        // L_alpha(grid point beta) = delta_{alpha beta}
        let bb = unit_box_2d();
        let g = 3;
        let grid = cheb_grid(&bb, g);
        let basis = ChebBasis::new(&bb, g);
        let k = basis.rank();
        let mut vals = vec![0.0; k];
        for (beta, p) in grid.iter().enumerate() {
            basis.eval_all(p, &mut vals);
            for (alpha, &v) in vals.iter().enumerate() {
                let want = if alpha == beta { 1.0 } else { 0.0 };
                assert!((v - want).abs() < 1e-10, "L_{alpha}(x_{beta}) = {v}");
            }
        }
    }

    #[test]
    fn partition_of_unity() {
        // sum_alpha L_alpha(x) = 1 for any x (interpolation of constant 1).
        let bb = unit_box_2d();
        let basis = ChebBasis::new(&bb, 4);
        let mut vals = vec![0.0; basis.rank()];
        for &x in &[[0.3, 0.7, 0.0], [0.0, 0.0, 0.0], [0.95, 0.1, 0.0]] {
            basis.eval_all(&x, &mut vals);
            let s: f64 = vals.iter().sum();
            assert!((s - 1.0).abs() < 1e-10, "sum = {s}");
        }
    }

    #[test]
    fn interpolation_reproduces_polynomials() {
        // interpolating x^2*y on a g=3 grid must be exact (degree 2 < 3).
        let bb = unit_box_2d();
        let g = 3;
        let grid = cheb_grid(&bb, g);
        let basis = ChebBasis::new(&bb, g);
        let f = |p: &[f64; 3]| p[0] * p[0] * p[1];
        let coeffs: Vec<f64> = grid.iter().map(f).collect();
        let mut vals = vec![0.0; basis.rank()];
        let x = [0.37, 0.81, 0.0];
        basis.eval_all(&x, &mut vals);
        let approx: f64 = vals.iter().zip(&coeffs).map(|(l, c)| l * c).sum();
        assert!((approx - f(&x)).abs() < 1e-12);
    }

    #[test]
    fn degenerate_box_does_not_blow_up() {
        // all points on a line x=0.5: zero extent in dim 0.
        let mut ps = PointSet::new(2);
        ps.push(&[0.5, 0.0]);
        ps.push(&[0.5, 1.0]);
        let bb = BBox::of(&ps, &[0, 1]);
        let basis = ChebBasis::new(&bb, 3);
        let mut vals = vec![0.0; basis.rank()];
        basis.eval_all(&[0.5, 0.25, 0.0], &mut vals);
        assert!(vals.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn interpolation_error_decreases_with_g() {
        // exp(-r/l) on well-separated boxes: error should drop fast with g.
        let ps_t = PointSet::grid_2d(8, 1.0);
        let idx: Vec<usize> = (0..64).collect();
        let bb_t = BBox::of(&ps_t, &idx);
        let errs: Vec<f64> = [2usize, 4, 6]
            .iter()
            .map(|&g| {
                let basis = ChebBasis::new(&bb_t, g);
                let grid = cheb_grid(&bb_t, g);
                // target kernel against a far point y0
                let y0 = [5.0, 5.0, 0.0];
                let f = |p: &[f64; 3]| {
                    let dx = p[0] - y0[0];
                    let dy = p[1] - y0[1];
                    (-(dx * dx + dy * dy).sqrt() / 1.0).exp()
                };
                let coeffs: Vec<f64> = grid.iter().map(f).collect();
                let mut vals = vec![0.0; basis.rank()];
                let mut err = 0.0_f64;
                for i in 0..64 {
                    let x = ps_t.get(i);
                    basis.eval_all(&x, &mut vals);
                    let approx: f64 = vals.iter().zip(&coeffs).map(|(l, c)| l * c).sum();
                    err = err.max((approx - f(&x)).abs());
                }
                err
            })
            .collect();
        assert!(errs[1] < errs[0] * 0.5, "{errs:?}");
        assert!(errs[2] < errs[1] * 0.5, "{errs:?}");
    }
}
