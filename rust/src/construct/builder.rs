//! H^2 matrix assembly: cluster the points, build the admissibility
//! structure, then populate bases/transfers/couplings by Chebyshev
//! interpolation and the dense leaves by direct kernel evaluation (§2.2).

use crate::admissibility::MatrixStructure;
use crate::clustering::ClusterTree;
use crate::config::H2Config;
use crate::construct::chebyshev::{cheb_grid, ChebBasis};
use crate::construct::kernels::Kernel;
use crate::geometry::{PointSet, MAX_DIM};
use crate::linalg::Mat;
use crate::tree::H2Matrix;

/// Build an H^2 approximation of the kernel matrix K[i,j] = κ(x_i, x_j)
/// over `points` (square, same row/column point set).
pub fn build_h2(points: PointSet, kernel: &dyn Kernel, cfg: &H2Config) -> H2Matrix {
    let dim = points.dim;
    assert_eq!(dim, kernel.dim(), "kernel/point dimension mismatch");
    // Leaves must be able to hold the rank (m_pad >= k) or downstream
    // orthogonalization/compression would face wide QRs.
    let tree = ClusterTree::build_with_min_leaf(points, cfg.leaf_size, cfg.rank(dim));
    let structure = MatrixStructure::build(&tree, &tree, cfg.eta);
    build_h2_with_structure(tree, &structure, kernel, cfg)
}

/// Assembly given a pre-built cluster tree + structure (used by the
/// distributed constructor, which builds branch structures separately).
pub fn build_h2_with_structure(
    tree: ClusterTree,
    structure: &MatrixStructure,
    kernel: &dyn Kernel,
    cfg: &H2Config,
) -> H2Matrix {
    let dim = tree.points.dim;
    let k = cfg.rank(dim);
    let depth = tree.depth;
    let ranks = vec![k; depth + 1];
    let m_pad = tree.max_leaf_size();
    let mut h2 = H2Matrix::from_structure(tree, structure, &ranks, m_pad);

    // Per-node Chebyshev grids, cached level by level (heap order).
    let grids: Vec<Vec<[f64; MAX_DIM]>> =
        h2.tree.nodes.iter().map(|n| cheb_grid(&n.bbox, cfg.cheb_grid)).collect();

    // Leaf bases: U_t[i, alpha] = L^t_alpha(x_i). U == V numerically.
    let leaf_level = depth;
    for j in 0..h2.u.num_leaves() {
        let node = h2.tree.node(leaf_level, j).clone();
        let basis = ChebBasis::new(&node.bbox, cfg.cheb_grid);
        let mut vals = vec![0.0; k];
        for i in 0..node.size() {
            let orig = h2.tree.perm[node.start + i];
            let x = h2.tree.points.get(orig);
            basis.eval_all(&x, &mut vals);
            let row = i * k;
            h2.u.leaf_mut(j)[row..row + k].copy_from_slice(&vals);
            h2.v.leaf_mut(j)[row..row + k].copy_from_slice(&vals);
        }
    }

    // Transfers: E_c[alpha_child, alpha_parent] = L^{parent}_{alpha_p}(y^{child}_{alpha_c}).
    for l in 1..=depth {
        for j in 0..(1usize << l) {
            let parent_bbox = h2.tree.node(l - 1, j / 2).bbox;
            let parent_basis = ChebBasis::new(&parent_bbox, cfg.cheb_grid);
            let child_grid = &grids[crate::clustering::level_offset(l) + j];
            let mut vals = vec![0.0; k];
            {
                let e = h2.u.transfer_mut(l, j);
                for (ac, y) in child_grid.iter().enumerate() {
                    parent_basis.eval_all(y, &mut vals);
                    e[ac * k..(ac + 1) * k].copy_from_slice(&vals);
                }
            }
            let eu: Vec<f64> = h2.u.transfer(l, j).to_vec();
            h2.v.transfer_mut(l, j).copy_from_slice(&eu);
        }
    }

    // Coupling blocks: S_ts[alpha, beta] = kernel(y^t_alpha, y^s_beta).
    for l in 0..=depth {
        let pairs = h2.coupling[l].pairs.clone();
        for (p, &(t, s)) in pairs.iter().enumerate() {
            let gt = &grids[crate::clustering::level_offset(l) + t as usize];
            let gs = &grids[crate::clustering::level_offset(l) + s as usize];
            let blk = h2.coupling[l].block_mut(p, k);
            for (a, ya) in gt.iter().enumerate() {
                for (b, yb) in gs.iter().enumerate() {
                    blk[a * k + b] = kernel.eval(ya, yb);
                }
            }
        }
    }

    // Dense leaves: direct kernel evaluation at point pairs (zero padding
    // beyond actual sizes).
    let pairs = h2.dense.pairs.clone();
    let m = h2.dense.m_pad;
    for (p, &(t, s)) in pairs.iter().enumerate() {
        let nt = h2.tree.node(leaf_level, t as usize).clone();
        let ns = h2.tree.node(leaf_level, s as usize).clone();
        let blk = h2.dense.block_mut(p);
        for i in 0..nt.size() {
            let xi = h2.tree.points.get(h2.tree.perm[nt.start + i]);
            for jj in 0..ns.size() {
                let yj = h2.tree.points.get(h2.tree.perm[ns.start + jj]);
                blk[i * m + jj] = kernel.eval(&xi, &yj);
            }
        }
    }
    h2
}

/// Dense kernel matrix in the *permuted* (cluster-tree) ordering — the
/// O(N²) oracle for accuracy measurements and tests.
pub fn dense_kernel_matrix(tree: &ClusterTree, kernel: &dyn Kernel) -> Mat {
    let n = tree.num_points();
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        let xi = tree.points.get(tree.perm[i]);
        for j in 0..n {
            let yj = tree.points.get(tree.perm[j]);
            a.data[i * n + j] = kernel.eval(&xi, &yj);
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::construct::kernels::ExponentialKernel;
    use crate::util::testing::rel_err;

    fn small_2d(n_side: usize, g: usize) -> (H2Matrix, Mat) {
        let points = PointSet::grid_2d(n_side, 1.0);
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: g };
        let h2 = build_h2(points, &kernel, &cfg);
        let dense = dense_kernel_matrix(&h2.tree, &kernel);
        (h2, dense)
    }

    #[test]
    fn h2_approximates_dense() {
        // exp(-r/0.1) has a kink at r=0 and decays fast on the unit box, so
        // moderate g already gives ~1e-3 relative error at this tiny N
        // (the paper reaches 1e-7 with k=64, i.e. g=8, at m=64).
        let (h2, dense) = small_2d(16, 5); // N = 256
        let rec = h2.to_dense_permuted();
        let err = rel_err(&rec.data, &dense.data);
        assert!(err < 1e-2, "rel err {err}");
    }

    #[test]
    fn accuracy_improves_with_g() {
        let errs: Vec<f64> = [3usize, 5]
            .iter()
            .map(|&g| {
                let (h2, dense) = small_2d(16, g);
                rel_err(&h2.to_dense_permuted().data, &dense.data)
            })
            .collect();
        assert!(errs[1] < errs[0] * 0.2, "{errs:?}");
    }

    #[test]
    fn dense_blocks_exact() {
        // Dense leaves must match the kernel exactly (no interpolation).
        let (h2, dense) = small_2d(8, 3); // N = 64
        let n = h2.n();
        let leaf = h2.depth();
        let m = h2.dense.m_pad;
        for (p, &(t, s)) in h2.dense.pairs.iter().enumerate() {
            let nt = h2.tree.node(leaf, t as usize);
            let ns = h2.tree.node(leaf, s as usize);
            let blk = h2.dense.block(p);
            for i in 0..nt.size() {
                for j in 0..ns.size() {
                    let want = dense.data[(nt.start + i) * n + (ns.start + j)];
                    assert!((blk[i * m + j] - want).abs() < 1e-14);
                }
            }
        }
    }

    #[test]
    fn memory_is_subquadratic() {
        // Compression only pays off once N is comfortably above m·k; use a
        // 1024-point problem with a small rank.
        let points = PointSet::grid_2d(32, 1.0); // N = 1024
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
        let h2 = build_h2(points, &kernel, &cfg);
        let n = h2.n();
        assert!(h2.memory_words() < n * n / 4, "H2 memory not compressive");
    }

    #[test]
    fn build_3d() {
        let points = PointSet::grid_3d(6, 1.0); // 216 points
        let kernel = ExponentialKernel { dim: 3, corr_len: 0.2 };
        let cfg = H2Config { leaf_size: 32, eta: 0.95, cheb_grid: 3 };
        let h2 = build_h2(points, &kernel, &cfg);
        let dense = dense_kernel_matrix(&h2.tree, &kernel);
        let err = rel_err(&h2.to_dense_permuted().data, &dense.data);
        assert!(err < 5e-2, "3D rel err {err}");
        assert_eq!(h2.rank(h2.depth()), 27);
    }
}
