//! H^2 matrix assembly: cluster the points, build the admissibility
//! structure, then populate bases/transfers/couplings by Chebyshev
//! interpolation and the dense leaves by direct kernel evaluation (§2.2).

use crate::admissibility::MatrixStructure;
use crate::clustering::ClusterTree;
use crate::config::H2Config;
use crate::construct::chebyshev::{cheb_grid, ChebBasis};
use crate::construct::kernels::Kernel;
use crate::dist::shard::ShardedMatrix;
use crate::dist::{Decomposition, DecompositionError};
use crate::geometry::{PointSet, MAX_DIM};
use crate::linalg::Mat;
use crate::tree::H2Matrix;

/// When this environment variable is set, any attempt to assemble a full
/// (global) H² matrix in this process panics. The socket coordinator sets
/// it for every `h2opus worker` subprocess: workers must construct only
/// their [`ShardedMatrix`] ([`build_branch`]), which is what makes N
/// beyond one process's memory representable. CI runs the socket suites
/// under this guard, so a regression that sneaks a global build into a
/// worker fails loudly instead of silently re-inflating per-rank memory.
pub const FORBID_FULL_MATRIX_ENV: &str = "H2OPUS_FORBID_FULL_MATRIX";

fn assert_full_matrix_allowed() {
    assert!(
        std::env::var_os(FORBID_FULL_MATRIX_ENV).is_none(),
        "{FORBID_FULL_MATRIX_ENV} is set: this process (a distributed worker rank) must \
         construct branch shards (construct::build_branch), never the full H^2 matrix"
    );
}

/// Build an H^2 approximation of the kernel matrix K[i,j] = κ(x_i, x_j)
/// over `points` (square, same row/column point set).
pub fn build_h2(points: PointSet, kernel: &dyn Kernel, cfg: &H2Config) -> H2Matrix {
    let dim = points.dim;
    assert_eq!(dim, kernel.dim(), "kernel/point dimension mismatch");
    // Leaves must be able to hold the rank (m_pad >= k) or downstream
    // orthogonalization/compression would face wide QRs.
    let tree = ClusterTree::build_with_min_leaf(points, cfg.leaf_size, cfg.rank(dim));
    let structure = MatrixStructure::build(&tree, &tree, cfg.eta);
    build_h2_with_structure(tree, &structure, kernel, cfg)
}

/// Assembly given a pre-built cluster tree + structure (used by the
/// distributed constructor, which builds branch structures separately).
pub fn build_h2_with_structure(
    tree: ClusterTree,
    structure: &MatrixStructure,
    kernel: &dyn Kernel,
    cfg: &H2Config,
) -> H2Matrix {
    assert_full_matrix_allowed();
    let dim = tree.points.dim;
    let k = cfg.rank(dim);
    let depth = tree.depth;
    let ranks = vec![k; depth + 1];
    let m_pad = tree.max_leaf_size();
    let mut h2 = H2Matrix::from_structure(tree, structure, &ranks, m_pad);

    // Per-node Chebyshev grids, cached level by level (heap order).
    let grids: Vec<Vec<[f64; MAX_DIM]>> =
        h2.tree.nodes.iter().map(|n| cheb_grid(&n.bbox, cfg.cheb_grid)).collect();

    // Leaf bases: U_t[i, alpha] = L^t_alpha(x_i). U == V numerically.
    let leaf_level = depth;
    for j in 0..h2.u.num_leaves() {
        let node = h2.tree.node(leaf_level, j).clone();
        let basis = ChebBasis::new(&node.bbox, cfg.cheb_grid);
        let mut vals = vec![0.0; k];
        for i in 0..node.size() {
            let orig = h2.tree.perm[node.start + i];
            let x = h2.tree.points.get(orig);
            basis.eval_all(&x, &mut vals);
            let row = i * k;
            h2.u.leaf_mut(j)[row..row + k].copy_from_slice(&vals);
            h2.v.leaf_mut(j)[row..row + k].copy_from_slice(&vals);
        }
    }

    // Transfers: E_c[alpha_child, alpha_parent] = L^{parent}_{alpha_p}(y^{child}_{alpha_c}).
    for l in 1..=depth {
        for j in 0..(1usize << l) {
            let parent_bbox = h2.tree.node(l - 1, j / 2).bbox;
            let parent_basis = ChebBasis::new(&parent_bbox, cfg.cheb_grid);
            let child_grid = &grids[crate::clustering::level_offset(l) + j];
            let mut vals = vec![0.0; k];
            {
                let e = h2.u.transfer_mut(l, j);
                for (ac, y) in child_grid.iter().enumerate() {
                    parent_basis.eval_all(y, &mut vals);
                    e[ac * k..(ac + 1) * k].copy_from_slice(&vals);
                }
            }
            let eu: Vec<f64> = h2.u.transfer(l, j).to_vec();
            h2.v.transfer_mut(l, j).copy_from_slice(&eu);
        }
    }

    // Coupling blocks: S_ts[alpha, beta] = kernel(y^t_alpha, y^s_beta).
    for l in 0..=depth {
        let pairs = h2.coupling[l].pairs.clone();
        for (p, &(t, s)) in pairs.iter().enumerate() {
            let gt = &grids[crate::clustering::level_offset(l) + t as usize];
            let gs = &grids[crate::clustering::level_offset(l) + s as usize];
            let blk = h2.coupling[l].block_mut(p, k);
            for (a, ya) in gt.iter().enumerate() {
                for (b, yb) in gs.iter().enumerate() {
                    blk[a * k + b] = kernel.eval(ya, yb);
                }
            }
        }
    }

    // Dense leaves: direct kernel evaluation at point pairs (zero padding
    // beyond actual sizes).
    let pairs = h2.dense.pairs.clone();
    let m = h2.dense.m_pad;
    for (p, &(t, s)) in pairs.iter().enumerate() {
        let nt = h2.tree.node(leaf_level, t as usize).clone();
        let ns = h2.tree.node(leaf_level, s as usize).clone();
        let blk = h2.dense.block_mut(p);
        for i in 0..nt.size() {
            let xi = h2.tree.points.get(h2.tree.perm[nt.start + i]);
            for jj in 0..ns.size() {
                let yj = h2.tree.points.get(h2.tree.perm[ns.start + jj]);
                blk[i * m + jj] = kernel.eval(&xi, &yj);
            }
        }
    }
    h2
}

/// Materialize only rank `rank`'s shard of the H² matrix (owned branch +
/// replicated top) directly from the kernel — the out-of-core
/// construction path: no global matrix is ever allocated, so a worker
/// process's matrix footprint is O(N/P) + O(P·k²) instead of O(N·k·C_sp).
/// Returns the shard together with the (index-only) global
/// [`MatrixStructure`], which callers need for exchange plans and input
/// layouts. Bitwise identical to slicing the global construction
/// ([`ShardedMatrix::from_global`]) — asserted by `tests/shard.rs`.
pub fn build_branch(
    points: PointSet,
    kernel: &dyn Kernel,
    cfg: &H2Config,
    p: usize,
    rank: usize,
) -> Result<(ShardedMatrix, MatrixStructure), DecompositionError> {
    build_shard(points, kernel, cfg, p, Some(rank))
}

/// The coordinator's shard: the replicated top subtree only (no branch).
pub fn build_top(
    points: PointSet,
    kernel: &dyn Kernel,
    cfg: &H2Config,
    p: usize,
) -> Result<(ShardedMatrix, MatrixStructure), DecompositionError> {
    build_shard(points, kernel, cfg, p, None)
}

/// Shared branch-scoped assembly. Every block is filled by the *same*
/// formula, in the same per-block evaluation order, as
/// [`build_h2_with_structure`] — construction is deterministic, so shard
/// data is bit-identical to the corresponding slice of a global build.
fn build_shard(
    points: PointSet,
    kernel: &dyn Kernel,
    cfg: &H2Config,
    p: usize,
    rank: Option<usize>,
) -> Result<(ShardedMatrix, MatrixStructure), DecompositionError> {
    let dim = points.dim;
    assert_eq!(dim, kernel.dim(), "kernel/point dimension mismatch");
    let k = cfg.rank(dim);
    let tree = ClusterTree::build_with_min_leaf(points, cfg.leaf_size, k);
    let structure = MatrixStructure::build(&tree, &tree, cfg.eta);
    let d = Decomposition::new(p, tree.depth)?;
    let depth = tree.depth;
    let ranks = vec![k; depth + 1];
    let m_pad = tree.max_leaf_size();
    let mut sm = ShardedMatrix::zeros(tree, &structure, &ranks, m_pad, d, rank);
    let c = d.c_level;
    let g = cfg.cheb_grid;
    let mut vals = vec![0.0; k];

    // ---- replicated top: transfers of levels 1..=C (all nodes) and
    // coupling blocks of levels 0..C ----
    for l in 1..=c {
        for j in 0..(1usize << l) {
            let parent_bbox = sm.tree.node(l - 1, j / 2).bbox;
            let parent_basis = ChebBasis::new(&parent_bbox, g);
            let child_grid = cheb_grid(&sm.tree.node(l, j).bbox, g);
            let sz = k * k;
            let e = &mut sm.top_u_transfers[l][j * sz..(j + 1) * sz];
            for (ac, y) in child_grid.iter().enumerate() {
                parent_basis.eval_all(y, &mut vals);
                e[ac * k..(ac + 1) * k].copy_from_slice(&vals);
            }
        }
        let eu = sm.top_u_transfers[l].clone();
        sm.top_v_transfers[l].copy_from_slice(&eu);
    }
    for l in 0..c {
        let pairs = sm.top_coupling[l].pairs.clone();
        for (pi, &(t, s)) in pairs.iter().enumerate() {
            let gt = cheb_grid(&sm.tree.node(l, t as usize).bbox, g);
            let gs = cheb_grid(&sm.tree.node(l, s as usize).bbox, g);
            let blk = sm.top_coupling[l].block_mut(pi, k);
            for (a, ya) in gt.iter().enumerate() {
                for (b, yb) in gs.iter().enumerate() {
                    blk[a * k + b] = kernel.eval(ya, yb);
                }
            }
        }
    }

    let Some(r) = rank else {
        return Ok((sm, structure));
    };

    // ---- owned branch: leaf bases over the owned leaf range ----
    let leaf_range = sm.leaf_range.clone();
    for j in leaf_range.clone() {
        let node = sm.tree.node(depth, j).clone();
        let basis = ChebBasis::new(&node.bbox, g);
        let slot = j - leaf_range.start;
        for i in 0..node.size() {
            let orig = sm.tree.perm[node.start + i];
            let x = sm.tree.points.get(orig);
            basis.eval_all(&x, &mut vals);
            let row = (slot * m_pad + i) * k;
            sm.u_leaf_bases[row..row + k].copy_from_slice(&vals);
            sm.v_leaf_bases[row..row + k].copy_from_slice(&vals);
        }
    }
    // Interlevel transfers of the owned nodes below the C-level.
    for l in (c + 1)..=depth {
        let own = d.own_range(r, l);
        for j in own.clone() {
            let parent_bbox = sm.tree.node(l - 1, j / 2).bbox;
            let parent_basis = ChebBasis::new(&parent_bbox, g);
            let child_grid = cheb_grid(&sm.tree.node(l, j).bbox, g);
            let sz = k * k;
            let local = j - own.start;
            let e = &mut sm.u_transfers[l][local * sz..(local + 1) * sz];
            for (ac, y) in child_grid.iter().enumerate() {
                parent_basis.eval_all(y, &mut vals);
                e[ac * k..(ac + 1) * k].copy_from_slice(&vals);
            }
        }
        let eu = sm.u_transfers[l].clone();
        sm.v_transfers[l].copy_from_slice(&eu);
    }
    // Owned coupling rows (a column grid may belong to a remote node —
    // only its bounding box is needed, which the replicated tree has).
    for l in c..=depth {
        let row_start = sm.coupling[l].row_start;
        let pairs = sm.coupling[l].level.pairs.clone();
        for (pi, &(t_loc, s)) in pairs.iter().enumerate() {
            let gt = cheb_grid(&sm.tree.node(l, row_start + t_loc as usize).bbox, g);
            let gs = cheb_grid(&sm.tree.node(l, s as usize).bbox, g);
            let blk = sm.coupling[l].level.block_mut(pi, k);
            for (a, ya) in gt.iter().enumerate() {
                for (b, yb) in gs.iter().enumerate() {
                    blk[a * k + b] = kernel.eval(ya, yb);
                }
            }
        }
    }
    // Owned dense rows.
    let dpairs = sm.dense.blocks.pairs.clone();
    let row_start = sm.dense.row_start;
    for (pi, &(t_loc, s)) in dpairs.iter().enumerate() {
        let nt = sm.tree.node(depth, row_start + t_loc as usize).clone();
        let ns = sm.tree.node(depth, s as usize).clone();
        let blk = sm.dense.blocks.block_mut(pi);
        for i in 0..nt.size() {
            let xi = sm.tree.points.get(sm.tree.perm[nt.start + i]);
            for jj in 0..ns.size() {
                let yj = sm.tree.points.get(sm.tree.perm[ns.start + jj]);
                blk[i * m_pad + jj] = kernel.eval(&xi, &yj);
            }
        }
    }
    Ok((sm, structure))
}

/// Dense kernel matrix in the *permuted* (cluster-tree) ordering — the
/// O(N²) oracle for accuracy measurements and tests.
pub fn dense_kernel_matrix(tree: &ClusterTree, kernel: &dyn Kernel) -> Mat {
    let n = tree.num_points();
    let mut a = Mat::zeros(n, n);
    for i in 0..n {
        let xi = tree.points.get(tree.perm[i]);
        for j in 0..n {
            let yj = tree.points.get(tree.perm[j]);
            a.data[i * n + j] = kernel.eval(&xi, &yj);
        }
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::construct::kernels::ExponentialKernel;
    use crate::util::testing::rel_err;

    fn small_2d(n_side: usize, g: usize) -> (H2Matrix, Mat) {
        let points = PointSet::grid_2d(n_side, 1.0);
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: g };
        let h2 = build_h2(points, &kernel, &cfg);
        let dense = dense_kernel_matrix(&h2.tree, &kernel);
        (h2, dense)
    }

    #[test]
    fn h2_approximates_dense() {
        // exp(-r/0.1) has a kink at r=0 and decays fast on the unit box, so
        // moderate g already gives ~1e-3 relative error at this tiny N
        // (the paper reaches 1e-7 with k=64, i.e. g=8, at m=64).
        let (h2, dense) = small_2d(16, 5); // N = 256
        let rec = h2.to_dense_permuted();
        let err = rel_err(&rec.data, &dense.data);
        assert!(err < 1e-2, "rel err {err}");
    }

    #[test]
    fn accuracy_improves_with_g() {
        let errs: Vec<f64> = [3usize, 5]
            .iter()
            .map(|&g| {
                let (h2, dense) = small_2d(16, g);
                rel_err(&h2.to_dense_permuted().data, &dense.data)
            })
            .collect();
        assert!(errs[1] < errs[0] * 0.2, "{errs:?}");
    }

    #[test]
    fn dense_blocks_exact() {
        // Dense leaves must match the kernel exactly (no interpolation).
        let (h2, dense) = small_2d(8, 3); // N = 64
        let n = h2.n();
        let leaf = h2.depth();
        let m = h2.dense.m_pad;
        for (p, &(t, s)) in h2.dense.pairs.iter().enumerate() {
            let nt = h2.tree.node(leaf, t as usize);
            let ns = h2.tree.node(leaf, s as usize);
            let blk = h2.dense.block(p);
            for i in 0..nt.size() {
                for j in 0..ns.size() {
                    let want = dense.data[(nt.start + i) * n + (ns.start + j)];
                    assert!((blk[i * m + j] - want).abs() < 1e-14);
                }
            }
        }
    }

    #[test]
    fn memory_is_subquadratic() {
        // Compression only pays off once N is comfortably above m·k; use a
        // 1024-point problem with a small rank.
        let points = PointSet::grid_2d(32, 1.0); // N = 1024
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
        let h2 = build_h2(points, &kernel, &cfg);
        let n = h2.n();
        assert!(h2.memory_words() < n * n / 4, "H2 memory not compressive");
    }

    #[test]
    fn build_3d() {
        let points = PointSet::grid_3d(6, 1.0); // 216 points
        let kernel = ExponentialKernel { dim: 3, corr_len: 0.2 };
        let cfg = H2Config { leaf_size: 32, eta: 0.95, cheb_grid: 3 };
        let h2 = build_h2(points, &kernel, &cfg);
        let dense = dense_kernel_matrix(&h2.tree, &kernel);
        let err = rel_err(&h2.to_dense_permuted().data, &dense.data);
        assert!(err < 5e-2, "3D rel err {err}");
        assert_eq!(h2.rank(h2.depth()), 27);
    }
}
