//! Construction of H^2 matrices from a kernel function + admissibility
//! condition using Chebyshev interpolation (§5 intro, §6.1): low-rank
//! blocks are seeded by polynomial interpolation of the kernel on cluster
//! bounding boxes; dense blocks evaluate the kernel directly. The
//! interpolation ranks are deliberately non-optimal — algebraic
//! recompression ([`crate::compression`]) then produces the storage-optimal
//! representation, exactly the workflow the paper's compression experiments
//! exercise (§6.3).

pub mod builder;
pub mod chebyshev;
pub mod kernels;

pub use builder::{
    build_branch, build_h2, build_top, dense_kernel_matrix, FORBID_FULL_MATRIX_ENV,
};
pub use kernels::{ExponentialKernel, Kernel};
