//! Krylov + multigrid solver substrate for the integral fractional
//! diffusion application (§6.4). The paper drives this through PETSc
//! (CG + smoothed-aggregation AMG); here the same roles are filled by an
//! in-tree preconditioned CG and a geometric multigrid V-cycle — the
//! natural equivalent for the regular-grid, 5-point-footprint
//! regularization operator C (see DESIGN.md "Substitutions").

pub mod cg;
pub mod csr;
pub mod multigrid;

pub use cg::{pcg, CgResult, LinOp};
pub use csr::Csr;
pub use multigrid::Multigrid;
