//! Compressed-sparse-row matrices (the distributed sparse substrate for
//! the regularization operator C and the multigrid hierarchy).

/// A square CSR matrix.
#[derive(Clone, Debug)]
pub struct Csr {
    pub n: usize,
    pub row_ptr: Vec<usize>,
    pub cols: Vec<u32>,
    pub vals: Vec<f64>,
}

impl Csr {
    /// Build from triplets (duplicates summed, rows sorted).
    pub fn from_triplets(n: usize, triplets: &mut Vec<(u32, u32, f64)>) -> Self {
        triplets.sort_unstable_by_key(|&(r, c, _)| (r, c));
        let mut row_ptr = vec![0usize; n + 1];
        let mut cols = Vec::with_capacity(triplets.len());
        let mut vals: Vec<f64> = Vec::with_capacity(triplets.len());
        let mut last: Option<(u32, u32)> = None;
        for &(r, c, v) in triplets.iter() {
            if last == Some((r, c)) {
                *vals.last_mut().unwrap() += v;
                continue;
            }
            last = Some((r, c));
            cols.push(c);
            vals.push(v);
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..n {
            row_ptr[i + 1] += row_ptr[i];
        }
        Csr { n, row_ptr, cols, vals }
    }

    /// y = A x.
    pub fn spmv(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.n);
        for i in 0..self.n {
            let mut s = 0.0;
            for idx in self.row_ptr[i]..self.row_ptr[i + 1] {
                s += self.vals[idx] * x[self.cols[idx] as usize];
            }
            y[i] = s;
        }
    }

    /// y += alpha * A x.
    pub fn spmv_acc(&self, alpha: f64, x: &[f64], y: &mut [f64]) {
        for i in 0..self.n {
            let mut s = 0.0;
            for idx in self.row_ptr[i]..self.row_ptr[i + 1] {
                s += self.vals[idx] * x[self.cols[idx] as usize];
            }
            y[i] += alpha * s;
        }
    }

    /// Main diagonal.
    pub fn diagonal(&self) -> Vec<f64> {
        let mut d = vec![0.0; self.n];
        for i in 0..self.n {
            for idx in self.row_ptr[i]..self.row_ptr[i + 1] {
                if self.cols[idx] as usize == i {
                    d[i] = self.vals[idx];
                }
            }
        }
        d
    }

    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Symmetry check (structure + values), O(nnz log nnz). Test helper.
    pub fn is_symmetric(&self, tol: f64) -> bool {
        use std::collections::HashMap;
        let mut map = HashMap::new();
        for i in 0..self.n {
            for idx in self.row_ptr[i]..self.row_ptr[i + 1] {
                map.insert((i as u32, self.cols[idx]), self.vals[idx]);
            }
        }
        map.iter().all(|(&(r, c), &v)| {
            map.get(&(c, r)).map(|&w| (v - w).abs() <= tol).unwrap_or(false)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn laplace_1d(n: usize) -> Csr {
        let mut t = Vec::new();
        for i in 0..n as u32 {
            t.push((i, i, 2.0));
            if i > 0 {
                t.push((i, i - 1, -1.0));
            }
            if (i as usize) < n - 1 {
                t.push((i, i + 1, -1.0));
            }
        }
        Csr::from_triplets(n, &mut t)
    }

    #[test]
    fn spmv_laplacian() {
        let a = laplace_1d(5);
        let x = vec![1.0; 5];
        let mut y = vec![0.0; 5];
        a.spmv(&x, &mut y);
        assert_eq!(y, vec![1.0, 0.0, 0.0, 0.0, 1.0]);
    }

    #[test]
    fn duplicates_summed() {
        let mut t = vec![(0u32, 0u32, 1.0), (0, 0, 2.0), (1, 1, 5.0)];
        let a = Csr::from_triplets(2, &mut t);
        assert_eq!(a.nnz(), 2);
        assert_eq!(a.diagonal(), vec![3.0, 5.0]);
    }

    #[test]
    fn symmetric_check() {
        assert!(laplace_1d(8).is_symmetric(0.0));
        let mut t = vec![(0u32, 1u32, 1.0)];
        assert!(!Csr::from_triplets(2, &mut t).is_symmetric(0.0));
    }

    #[test]
    fn spmv_acc_accumulates() {
        let a = laplace_1d(3);
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![10.0; 3];
        a.spmv_acc(2.0, &x, &mut y);
        // A x = [0, 0, 4]... check: row0: 2*1-2= 0; row1: -1+4-3=0; row2: -2+6=4
        assert_eq!(y, vec![10.0, 10.0, 18.0]);
    }
}
