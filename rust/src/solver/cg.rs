//! Preconditioned conjugate gradients (the Krylov solver of §6.4; the
//! paper uses PETSc's CG with the H^2 matvec as the operator).

/// Abstract SPD linear operator.
pub trait LinOp {
    fn n(&self) -> usize;
    /// y = A x
    fn apply(&mut self, x: &[f64], y: &mut [f64]);
}

impl<F: FnMut(&[f64], &mut [f64])> LinOp for (usize, F) {
    fn n(&self) -> usize {
        self.0
    }
    fn apply(&mut self, x: &[f64], y: &mut [f64]) {
        (self.1)(x, y)
    }
}

/// Outcome of a CG solve.
#[derive(Clone, Debug)]
pub struct CgResult {
    pub iterations: usize,
    pub converged: bool,
    /// ||r_k|| / ||b|| per iteration (index 0 = initial residual).
    pub residuals: Vec<f64>,
}

fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// Solve A x = b with preconditioner M ≈ A⁻¹ (both as operators), to
/// relative residual `rtol` or `max_iter`.
pub fn pcg(
    a: &mut dyn LinOp,
    m_inv: &mut dyn LinOp,
    b: &[f64],
    x: &mut [f64],
    rtol: f64,
    max_iter: usize,
) -> CgResult {
    let n = a.n();
    assert_eq!(b.len(), n);
    assert_eq!(x.len(), n);
    let bnorm = dot(b, b).sqrt().max(f64::MIN_POSITIVE);

    let mut r = vec![0.0; n];
    a.apply(x, &mut r);
    for i in 0..n {
        r[i] = b[i] - r[i];
    }
    let mut z = vec![0.0; n];
    m_inv.apply(&r, &mut z);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut ap = vec![0.0; n];

    let mut residuals = vec![dot(&r, &r).sqrt() / bnorm];
    let mut converged = residuals[0] <= rtol;
    let mut it = 0;
    while !converged && it < max_iter {
        a.apply(&p, &mut ap);
        let alpha = rz / dot(&p, &ap).max(f64::MIN_POSITIVE);
        for i in 0..n {
            x[i] += alpha * p[i];
            r[i] -= alpha * ap[i];
        }
        let rnorm = dot(&r, &r).sqrt() / bnorm;
        residuals.push(rnorm);
        it += 1;
        if rnorm <= rtol {
            converged = true;
            break;
        }
        m_inv.apply(&r, &mut z);
        let rz_new = dot(&r, &z);
        let beta = rz_new / rz;
        rz = rz_new;
        for i in 0..n {
            p[i] = z[i] + beta * p[i];
        }
    }
    CgResult { iterations: it, converged, residuals }
}

/// Identity preconditioner.
pub struct Identity(pub usize);

impl LinOp for Identity {
    fn n(&self) -> usize {
        self.0
    }
    fn apply(&mut self, x: &[f64], y: &mut [f64]) {
        y.copy_from_slice(x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::Csr;
    use crate::util::Prng;

    fn laplace_1d(n: usize) -> Csr {
        let mut t = Vec::new();
        for i in 0..n as u32 {
            t.push((i, i, 2.0));
            if i > 0 {
                t.push((i, i - 1, -1.0));
            }
            if (i as usize) < n - 1 {
                t.push((i, i + 1, -1.0));
            }
        }
        Csr::from_triplets(n, &mut t)
    }

    #[test]
    fn cg_solves_laplacian() {
        let n = 64;
        let a = laplace_1d(n);
        let mut rng = Prng::new(80);
        let x_true = rng.normal_vec(n);
        let mut b = vec![0.0; n];
        a.spmv(&x_true, &mut b);
        let mut x = vec![0.0; n];
        let mut op = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
        let res = pcg(&mut op, &mut Identity(n), &b, &mut x, 1e-10, 1000);
        assert!(res.converged, "{res:?}");
        let err: f64 = x.iter().zip(&x_true).map(|(a, b)| (a - b).abs()).fold(0.0, f64::max);
        assert!(err < 1e-7, "err {err}");
    }

    #[test]
    fn jacobi_preconditioner_reduces_iterations() {
        // scale rows to make Jacobi matter
        let n = 128;
        let base = laplace_1d(n);
        let mut t: Vec<(u32, u32, f64)> = Vec::new();
        for i in 0..n {
            let scale = 1.0 + 100.0 * (i as f64 / n as f64);
            for idx in base.row_ptr[i]..base.row_ptr[i + 1] {
                t.push((i as u32, base.cols[idx], base.vals[idx] * scale));
            }
        }
        // symmetrize: D S where S symmetric is not symmetric; instead use
        // D^1/2 S D^1/2 which is
        let mut t2: Vec<(u32, u32, f64)> = Vec::new();
        let sc = |i: u32| (1.0 + 100.0 * (i as f64 / n as f64)).sqrt();
        for i in 0..n {
            for idx in base.row_ptr[i]..base.row_ptr[i + 1] {
                let j = base.cols[idx];
                t2.push((i as u32, j, base.vals[idx] * sc(i as u32) * sc(j)));
            }
        }
        let a = Csr::from_triplets(n, &mut t2);
        let _ = t;
        let b = vec![1.0; n];
        let diag = a.diagonal();

        let mut x0 = vec![0.0; n];
        let mut op1 = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
        let plain = pcg(&mut op1, &mut Identity(n), &b, &mut x0, 1e-8, 10_000);

        let mut x1 = vec![0.0; n];
        let mut op2 = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
        let mut jac = (n, |v: &[f64], y: &mut [f64]| {
            for i in 0..n {
                y[i] = v[i] / diag[i];
            }
        });
        let pre = pcg(&mut op2, &mut jac, &b, &mut x1, 1e-8, 10_000);
        assert!(pre.converged && plain.converged);
        assert!(pre.iterations <= plain.iterations);
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let n = 16;
        let a = laplace_1d(n);
        let b = vec![0.0; n];
        let mut x = vec![0.0; n];
        let mut op = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
        let res = pcg(&mut op, &mut Identity(n), &b, &mut x, 1e-10, 100);
        assert!(res.converged);
        assert_eq!(res.iterations, 0);
    }

    #[test]
    fn residuals_monotone_ish() {
        let n = 64;
        let a = laplace_1d(n);
        let b = vec![1.0; n];
        let mut x = vec![0.0; n];
        let mut op = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
        let res = pcg(&mut op, &mut Identity(n), &b, &mut x, 1e-10, 1000);
        // final residual far below initial
        assert!(res.residuals.last().unwrap() < &1e-9);
    }
}
