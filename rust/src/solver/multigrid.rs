//! Geometric multigrid V-cycle on cell-centered 2D grids — the
//! preconditioner for the regularization operator C (§6.4; the paper uses
//! PETSc smoothed-aggregation AMG, see DESIGN.md "Substitutions").
//!
//! Coarsening is 2×2 cell agglomeration (restriction = 4-cell average,
//! prolongation = piecewise-constant injection, so P = 4·Rᵀ); the operator
//! hierarchy is supplied by the application (rediscretization, the
//! standard geometric choice). Smoother: damped Jacobi, symmetric pre/post
//! so the V-cycle is SPD and usable inside CG.

use crate::solver::cg::LinOp;
use crate::solver::Csr;

/// One level of the hierarchy.
pub struct MgLevel {
    pub a: Csr,
    pub n_side: usize,
    pub diag_inv: Vec<f64>,
}

/// Geometric multigrid preconditioner.
pub struct Multigrid {
    /// levels[0] = finest.
    pub levels: Vec<MgLevel>,
    /// damped-Jacobi weight
    pub omega: f64,
    /// pre/post smoothing steps
    pub nu: usize,
    /// Coarse-grid-correction damping (1.0 with the bilinear transfers;
    /// kept configurable for experiments).
    pub correction_weight: f64,
    // workspaces per level
    r: Vec<Vec<f64>>,
    x: Vec<Vec<f64>>,
    b: Vec<Vec<f64>>,
    t: Vec<Vec<f64>>,
}

impl Multigrid {
    /// Build from per-level operators (finest first); `n_sides[i]` is the
    /// grid side of level i, halving each level.
    pub fn new(ops: Vec<Csr>, n_sides: Vec<usize>) -> Self {
        assert_eq!(ops.len(), n_sides.len());
        assert!(!ops.is_empty());
        for (i, w) in n_sides.windows(2).enumerate() {
            assert_eq!(w[0], 2 * w[1], "level {i} sides must halve: {:?}", n_sides);
        }
        let levels: Vec<MgLevel> = ops
            .into_iter()
            .zip(&n_sides)
            .map(|(a, &n_side)| {
                assert_eq!(a.n, n_side * n_side);
                let diag_inv = a.diagonal().iter().map(|&d| 1.0 / d).collect();
                MgLevel { a, n_side, diag_inv }
            })
            .collect();
        let sizes: Vec<usize> = levels.iter().map(|l| l.a.n).collect();
        Multigrid {
            levels,
            omega: 0.8,
            nu: 2,
            correction_weight: 1.0,
            r: sizes.iter().map(|&s| vec![0.0; s]).collect(),
            x: sizes.iter().map(|&s| vec![0.0; s]).collect(),
            b: sizes.iter().map(|&s| vec![0.0; s]).collect(),
            t: sizes.iter().map(|&s| vec![0.0; s]).collect(),
        }
    }

    fn smooth(&mut self, lvl: usize, steps: usize) {
        for _ in 0..steps {
            let level = &self.levels[lvl];
            level.a.spmv(&self.x[lvl], &mut self.t[lvl]);
            let (x, t, b) = (&mut self.x[lvl], &self.t[lvl], &self.b[lvl]);
            for i in 0..level.a.n {
                x[i] += self.omega * level.diag_inv[i] * (b[i] - t[i]);
            }
        }
    }

    /// Per-dimension bilinear stencil of a fine cell-center between its
    /// two nearest coarse cell-centers: (base index, neighbor index,
    /// base weight, neighbor weight). Clamped one-sided at boundaries.
    #[inline]
    fn stencil_1d(fi: usize, nc: usize) -> (usize, usize, f64, f64) {
        let base = fi / 2;
        let nb = if fi % 2 == 0 { base.wrapping_sub(1) } else { base + 1 };
        if nb >= nc {
            (base, base, 1.0, 0.0)
        } else {
            (base, nb, 0.75, 0.25)
        }
    }

    /// Restrict fine residual to the coarse rhs: R = ¼·Pᵀ of the bilinear
    /// prolongation (exact transpose so the V-cycle stays symmetric).
    fn restrict(&mut self, lvl: usize) {
        let nf = self.levels[lvl].n_side;
        let nc = self.levels[lvl + 1].n_side;
        let (fine, rest) = self.r.split_at_mut(lvl + 1);
        let _ = rest;
        let fine = &fine[lvl];
        let coarse = &mut self.b[lvl + 1];
        coarse.fill(0.0);
        for fj in 0..nf {
            let (bj, nj, wj, vj) = Self::stencil_1d(fj, nc);
            for fi in 0..nf {
                let (bi, ni, wi, vi) = Self::stencil_1d(fi, nc);
                let r = 0.25 * fine[fj * nf + fi];
                coarse[bj * nc + bi] += wj * wi * r;
                coarse[bj * nc + ni] += wj * vi * r;
                coarse[nj * nc + bi] += vj * wi * r;
                coarse[nj * nc + ni] += vj * vi * r;
            }
        }
    }

    /// Prolongate the coarse correction back (bilinear) and add.
    fn prolongate(&mut self, lvl: usize) {
        let nf = self.levels[lvl].n_side;
        let nc = self.levels[lvl + 1].n_side;
        let (head, tail) = self.x.split_at_mut(lvl + 1);
        let fine = &mut head[lvl];
        let coarse = &tail[0];
        let w = self.correction_weight;
        for fj in 0..nf {
            let (bj, nj, wj, vj) = Self::stencil_1d(fj, nc);
            for fi in 0..nf {
                let (bi, ni, wi, vi) = Self::stencil_1d(fi, nc);
                let v = wj * wi * coarse[bj * nc + bi]
                    + wj * vi * coarse[bj * nc + ni]
                    + vj * wi * coarse[nj * nc + bi]
                    + vj * vi * coarse[nj * nc + ni];
                fine[fj * nf + fi] += w * v;
            }
        }
    }

    fn vcycle(&mut self, lvl: usize) {
        if lvl + 1 == self.levels.len() {
            // coarse solve: many Jacobi sweeps (grids are tiny)
            self.smooth(lvl, 50);
            return;
        }
        self.smooth(lvl, self.nu);
        // r = b - A x
        self.levels[lvl].a.spmv(&self.x[lvl], &mut self.t[lvl]);
        for i in 0..self.levels[lvl].a.n {
            self.r[lvl][i] = self.b[lvl][i] - self.t[lvl][i];
        }
        self.restrict(lvl);
        self.x[lvl + 1].fill(0.0);
        self.vcycle(lvl + 1);
        self.prolongate(lvl);
        self.smooth(lvl, self.nu);
    }

    /// One V-cycle as a preconditioner application: x = M⁻¹ b.
    pub fn apply_vcycle(&mut self, b: &[f64], x: &mut [f64]) {
        self.b[0].copy_from_slice(b);
        self.x[0].fill(0.0);
        self.vcycle(0);
        x.copy_from_slice(&self.x[0]);
    }
}

impl LinOp for Multigrid {
    fn n(&self) -> usize {
        self.levels[0].a.n
    }
    fn apply(&mut self, x: &[f64], y: &mut [f64]) {
        self.apply_vcycle(x, y);
    }
}

/// Build the variable-coefficient 5-point operator
/// (−div(κ∇) + shift·I, Dirichlet-by-truncation) on an n×n cell-centered
/// grid over [lo, hi]², scaled by `scale`. Shared by the fractional app's
/// C matrix and the multigrid hierarchy.
pub fn five_point_operator(
    n: usize,
    lo: f64,
    hi: f64,
    scale: f64,
    shift: f64,
    kappa: &dyn Fn(f64, f64) -> f64,
) -> Csr {
    let h = (hi - lo) / n as f64;
    let pos = |i: usize| lo + (i as f64 + 0.5) * h;
    let idx = |i: usize, j: usize| (j * n + i) as u32;
    let mut t: Vec<(u32, u32, f64)> = Vec::with_capacity(5 * n * n);
    for j in 0..n {
        for i in 0..n {
            let (x, y) = (pos(i), pos(j));
            let kc = kappa(x, y);
            let mut diag = shift;
            let neighbor = |ii: i64, jj: i64, t: &mut Vec<(u32, u32, f64)>| {
                if ii < 0 || jj < 0 || ii >= n as i64 || jj >= n as i64 {
                    // Dirichlet (u = 0 outside): face conductance still
                    // contributes to the diagonal
                    let ke = kc; // one-sided
                    return ke / (h * h);
                }
                let (xn, yn) = (pos(ii as usize), pos(jj as usize));
                let ke = (kc * kappa(xn, yn)).sqrt(); // geometric mean (paper's a(x,y))
                t.push((idx(i, j), idx(ii as usize, jj as usize), -scale * ke / (h * h)));
                ke / (h * h)
            };
            diag += neighbor(i as i64 - 1, j as i64, &mut t);
            diag += neighbor(i as i64 + 1, j as i64, &mut t);
            diag += neighbor(i as i64, j as i64 - 1, &mut t);
            diag += neighbor(i as i64, j as i64 + 1, &mut t);
            t.push((idx(i, j), idx(i, j), scale * diag));
        }
    }
    Csr::from_triplets(n * n, &mut t)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::solver::cg::{pcg, Identity};
    use crate::util::Prng;

    fn hierarchy(n0: usize) -> Multigrid {
        let mut ops = Vec::new();
        let mut sides = Vec::new();
        let mut n = n0;
        while n >= 4 {
            ops.push(five_point_operator(n, -1.0, 1.0, 1.0, 0.0, &|_, _| 1.0));
            sides.push(n);
            n /= 2;
        }
        Multigrid::new(ops, sides)
    }

    #[test]
    fn operator_is_symmetric() {
        let a = five_point_operator(8, -1.0, 1.0, 1.0, 0.0, &|x, y| 1.0 + x * x + y * y);
        assert!(a.is_symmetric(1e-12));
    }

    #[test]
    fn vcycle_reduces_residual() {
        let mut mg = hierarchy(32);
        let n = 32 * 32;
        let mut rng = Prng::new(90);
        let b = rng.normal_vec(n);
        let mut x = vec![0.0; n];
        mg.apply_vcycle(&b, &mut x);
        // residual after one V-cycle must be much smaller than ||b||
        let mut r = vec![0.0; n];
        mg.levels[0].a.spmv(&x, &mut r);
        let rnorm: f64 =
            b.iter().zip(&r).map(|(bi, ri)| (bi - ri) * (bi - ri)).sum::<f64>().sqrt();
        let bnorm: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt();
        assert!(rnorm < 0.6 * bnorm, "V-cycle contraction too weak: {}", rnorm / bnorm);
    }

    #[test]
    fn mg_preconditioned_cg_is_h_independent_ish() {
        // iteration counts should stay nearly flat as the grid refines
        let mut iters = Vec::new();
        for n0 in [16usize, 32, 64] {
            let n = n0 * n0;
            let a = five_point_operator(n0, -1.0, 1.0, 1.0, 0.0, &|_, _| 1.0);
            let mut mg = hierarchy(n0);
            let b = vec![1.0; n];
            let mut x = vec![0.0; n];
            let mut op = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
            let res = pcg(&mut op, &mut mg, &b, &mut x, 1e-8, 200);
            assert!(res.converged);
            iters.push(res.iterations);
        }
        assert!(
            iters[2] <= iters[0] + 6,
            "MG-CG iterations grew with refinement: {iters:?}"
        );
    }

    #[test]
    fn mg_beats_unpreconditioned() {
        let n0 = 64;
        let n = n0 * n0;
        let a = five_point_operator(n0, -1.0, 1.0, 1.0, 0.0, &|_, _| 1.0);
        let b = vec![1.0; n];

        let mut x1 = vec![0.0; n];
        let mut op1 = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
        let plain = pcg(&mut op1, &mut Identity(n), &b, &mut x1, 1e-8, 2000);

        let mut x2 = vec![0.0; n];
        let mut mg = hierarchy(n0);
        let mut op2 = (n, |v: &[f64], y: &mut [f64]| a.spmv(v, y));
        let pre = pcg(&mut op2, &mut mg, &b, &mut x2, 1e-8, 2000);
        assert!(pre.iterations * 3 < plain.iterations, "{} vs {}", pre.iterations, plain.iterations);
    }
}
