//! h2opus launcher: build, multiply, compress and solve from the command
//! line. (Hand-rolled CLI: the offline image carries no clap.)
//!
//! ```text
//! h2opus matvec   [--n-side 32] [--dim 2] [--ranks 4] [--nv 1] [--backend native|xla] [--no-overlap]
//!                 [--threaded] [--transport inproc|socket] [--trace out.json] [--measured-trace out.json]
//! h2opus compress [--n-side 32] [--dim 2] [--ranks 4] [--tau 1e-3] [--backend native|xla] [--threaded]
//! h2opus solve    [--n-side 32] [--ranks 4] [--beta 0.75] [--rtol 1e-6] [--backend native|xla]
//! h2opus accuracy [--n-side 32] [--dim 2] [--g 4]
//! h2opus info     [--n-side 32] [--dim 2]
//! h2opus serve    [--ranks 4] [--max-coalesce 16] [--duration 5] [--selfload R] [--stats-sock PATH]
//! h2opus stats    [--connect PATH] [--raw]        (live snapshot of a running `h2opus serve`)
//! h2opus analyze  <trace.json> | --run   [--json] [--assert-overlap MIN] [--assert-no-regression]
//! h2opus worker   --connect SOCK --rank R --ranks P --nv NV [matrix flags]   (internal: socket-transport rank)
//! ```
//!
//! `--backend-threads T` (or `H2OPUS_BACKEND_THREADS`) sets the parallel
//! native backend's pool width — the per-process batched-kernel thread
//! budget, shared by all rank threads (see the `backend` module docs).
//!
//! `--obs` (or `H2OPUS_OBS=1`) turns on span recording; `matvec
//! --obs-trace out.json` writes the merged cross-process Chrome trace
//! (socket transport: one timeline per worker rank, clock-aligned).

use std::collections::HashMap;

use h2opus::backend::native::NativeBackend;
use h2opus::backend::ComputeBackend;
use h2opus::compression::compress_full;
use h2opus::config::NetworkModel;
use h2opus::dist::hgemv::{dist_hgemv, DistOptions, ExecMode};
use h2opus::dist::transport::{JobKind, MatrixJob};
use h2opus::metrics::Metrics;
use h2opus::runtime::XlaBackend;
use h2opus::util::Prng;

/// Split args into `--name value` / `--bool` flags and bare positionals
/// (e.g. the trace path of `h2opus analyze trace.json`).
fn split_args(args: &[String]) -> (HashMap<String, String>, Vec<String>) {
    let mut flags = HashMap::new();
    let mut positionals = Vec::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(name) = args[i].strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            positionals.push(args[i].clone());
            i += 1;
        }
    }
    (flags, positionals)
}

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    split_args(args).0
}

fn get<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn backend_from(flags: &HashMap<String, String>) -> Box<dyn ComputeBackend> {
    match flags.get("backend").map(String::as_str) {
        Some("xla") => match XlaBackend::from_env() {
            Ok(b) => Box::new(b),
            Err(e) => {
                eprintln!("failed to initialize XLA backend ({e:#}); falling back to native");
                Box::new(NativeBackend)
            }
        },
        _ => Box::new(NativeBackend),
    }
}

/// The deterministic test-matrix job a flag set describes — the same
/// specification the socket transport ships to its worker processes.
fn job_from(flags: &HashMap<String, String>) -> MatrixJob {
    let dim: usize = get(flags, "dim", 2);
    let kind = match flags.get("kernel").map(String::as_str) {
        Some("fractional") => JobKind::Fractional { beta: get(flags, "beta", 0.75) },
        _ => JobKind::Exponential,
    };
    MatrixJob {
        dim,
        n_side: get(flags, "n-side", 32),
        leaf_size: get(flags, "leaf-size", 32),
        eta: get(flags, "eta", if dim == 2 { 0.9 } else { 0.95 }),
        cheb_grid: get(flags, "g", if dim == 2 { 4 } else { 2 }),
        corr_len: get(flags, "corr", if dim == 2 { 0.1 } else { 0.2 }),
        kind,
    }
}

fn build_test_matrix(flags: &HashMap<String, String>) -> h2opus::tree::H2Matrix {
    job_from(flags).build()
}

fn cmd_matvec(flags: &HashMap<String, String>) {
    let ranks: usize = get(flags, "ranks", 4);
    let nv: usize = get(flags, "nv", 1);
    let transport = flags.get("transport").map(String::as_str).unwrap_or("inproc");
    if flags.contains_key("obs-trace") {
        h2opus::obs::set_enabled(true);
    }

    if transport == "socket" {
        cmd_matvec_socket(flags, ranks, nv);
        return;
    }

    let a = build_test_matrix(flags);
    let backend = backend_from(flags);
    let n = a.n();
    let mut rng = Prng::new(1234);
    let x = rng.normal_vec(n * nv);
    let mut y = vec![0.0; n * nv];
    let opts = DistOptions {
        net: NetworkModel::default(),
        overlap: !flags.contains_key("no-overlap"),
        trace: flags.contains_key("trace"),
        measured_trace: flags.contains_key("measured-trace"),
        mode: if flags.contains_key("threaded") { ExecMode::Threaded } else { ExecMode::Virtual },
    };
    let rep = dist_hgemv(&a, backend.as_ref(), ranks, nv, &x, &mut y, &opts);
    let gflops = rep.metrics.flops as f64 / rep.time / 1e9;
    println!("N = {n}, P = {ranks}, nv = {nv}, backend = {}", backend.name());
    println!("virtual time      {:>12.3} ms", rep.time * 1e3);
    if let Some(m) = rep.measured {
        println!("measured time     {:>12.3} ms (threaded executor)", m * 1e3);
    }
    println!("flops             {:>12}", rep.metrics.flops);
    println!("aggregate rate    {:>12.2} Gflop/s ({:.2} Gflop/s/rank)", gflops, gflops / ranks as f64);
    println!("comm volume       {:>12} B", rep.recv_bytes);
    if let (Some(path), Some(json)) = (flags.get("trace"), rep.trace_json) {
        std::fs::write(path, json).expect("writing trace");
        println!("trace written to {path}");
    }
    if let (Some(path), Some(json)) = (flags.get("measured-trace"), rep.measured_trace_json) {
        std::fs::write(path, json).expect("writing measured trace");
        println!("measured trace written to {path}");
    }
    if let Some(path) = flags.get("obs-trace") {
        // In-process run: one part, rank lanes were labeled by the
        // executor, unlabeled (main-thread) spans map to pid = P.
        let (spans, dropped) = h2opus::obs::drain();
        let count = spans.len();
        let part = h2opus::obs::TracePart {
            default_pid: ranks,
            offset_ns: 0,
            spans,
            dropped,
            work: None,
        };
        std::fs::write(path, h2opus::obs::merged_trace_json(&[part]))
            .expect("writing obs trace");
        println!("obs trace written to {path} ({count} spans, {dropped} dropped)");
    }
}

#[cfg(unix)]
fn cmd_matvec_socket(flags: &HashMap<String, String>, ranks: usize, nv: usize) {
    use h2opus::dist::transport::socket::{socket_hgemv, SocketOptions};
    let job = job_from(flags);
    let n = job.n_points();
    let mut rng = Prng::new(1234);
    let x = rng.normal_vec(n * nv);
    let mut y = vec![0.0; n * nv];
    if let Some(path) = flags.get("obs-trace") {
        let tau: f64 = get(flags, "tau", 1e-3);
        let json = traced_socket_session(&job, ranks, nv, &x, &mut y, tau);
        std::fs::write(path, &json).expect("writing obs trace");
        println!("merged trace written to {path} ({} bytes)", json.len());
        return;
    }
    let opts = SocketOptions {
        measured_trace: flags.contains_key("measured-trace"),
        ..SocketOptions::default()
    };
    match socket_hgemv(&job, ranks, nv, &x, &mut y, &opts) {
        Ok(rep) => {
            println!("N = {n}, P = {ranks}, nv = {nv}, transport = socket (worker subprocesses)");
            println!("measured time     {:>12.3} ms", rep.measured * 1e3);
            println!("flops             {:>12}", rep.metrics.flops);
            println!("wire volume       {:>12} B over {} messages", rep.metrics.bytes_sent, rep.metrics.messages);
            println!("peak rank matrix  {:>12} B (sharded storage)", rep.metrics.matrix_bytes);
            for (r, t) in rep.per_rank.iter().enumerate() {
                println!("  rank {r:>2}         {:>12.3} ms", t * 1e3);
            }
            if let (Some(path), Some(json)) = (flags.get("measured-trace"), rep.measured_trace_json)
            {
                std::fs::write(path, json).expect("writing measured trace");
                println!("measured trace written to {path}");
            }
        }
        Err(e) => {
            eprintln!("socket matvec failed: {e}");
            std::process::exit(1);
        }
    }
}

#[cfg(not(unix))]
fn cmd_matvec_socket(_flags: &HashMap<String, String>, _ranks: usize, _nv: usize) {
    eprintln!("the socket transport requires Unix domain sockets");
    std::process::exit(1);
}

/// A product → distributed compression → product sequence over one live
/// socket session, with span recording on in every process; returns the
/// clock-aligned merged trace of all P workers + the coordinator.
#[cfg(unix)]
fn traced_socket_session(
    job: &MatrixJob,
    ranks: usize,
    nv: usize,
    x: &[f64],
    y: &mut [f64],
    tau: f64,
) -> String {
    use h2opus::dist::transport::socket::{SocketOptions, SocketSession};
    h2opus::obs::set_enabled(true);
    let die = |what: &str, e: h2opus::dist::transport::TransportError| -> ! {
        eprintln!("{what} failed: {e}");
        std::process::exit(1)
    };
    let mut session = SocketSession::start(job, ranks, nv, SocketOptions::default())
        .unwrap_or_else(|e| die("starting the worker session", e));
    println!("N = {}, P = {ranks}, nv = {nv}, transport = socket (traced)", session.n());
    for (w, off) in session.clock_offsets_ns().iter().enumerate() {
        println!("  worker {w} clock offset {off:>8} ns");
    }
    let r1 = session.hgemv(x, y).unwrap_or_else(|e| die("product", e));
    println!("product           {:>12.3} ms", r1.measured * 1e3);
    let stats = session.compress(tau).unwrap_or_else(|e| die("compression", e));
    println!("compressed        {:>12} -> {} words ({:.2}x)", stats.pre_words, stats.post_words, stats.ratio());
    let r2 = session.hgemv(x, y).unwrap_or_else(|e| die("compressed product", e));
    println!("product (compressed) {:>9.3} ms", r2.measured * 1e3);
    session.collect_spans().unwrap_or_else(|e| die("span flush", e))
}

/// `h2opus analyze` — the performance referee. Analyzes a merged span
/// trace (a file, or one produced live by `--run`) and/or gates the bench
/// trajectory; any failed `--assert-*` gate exits nonzero.
fn cmd_analyze(args: &[String]) {
    use h2opus::obs::trajectory::{check_regressions, load_rows, trajectory_path, DEFAULT_BAND};
    let (mut flags, mut positionals) = split_args(args);
    // Boolean flags followed by the trace path would swallow it as their
    // value ("--json trace.json"); give such values back as positionals.
    for b in ["json", "run", "assert-no-regression"] {
        if let Some(v) = flags.get(b) {
            if v != "true" {
                positionals.push(v.clone());
                flags.insert(b.to_string(), "true".to_string());
            }
        }
    }
    let gate_only = flags.contains_key("assert-no-regression")
        && !flags.contains_key("run")
        && positionals.is_empty();
    let mut failures = 0usize;

    if !gate_only {
        let json = if flags.contains_key("run") {
            run_traced_for_analysis(&flags)
        } else if let Some(path) = positionals.first() {
            match std::fs::read_to_string(path) {
                Ok(j) => j,
                Err(e) => {
                    eprintln!("reading {path} failed: {e}");
                    std::process::exit(1);
                }
            }
        } else {
            eprintln!(
                "usage: h2opus analyze <trace.json> | --run [matrix flags] \
                 [--json] [--top N] [--out report.json] [--assert-overlap MIN] \
                 [--assert-no-regression [--band B] [--trajectory PATH]]"
            );
            std::process::exit(2);
        };
        let cm = h2opus::dist::hgemv::CostModel::host();
        let analysis = match h2opus::obs::analyze_json(&json, &cm) {
            Ok(a) => a,
            Err(e) => {
                eprintln!("trace analysis failed: {e}");
                std::process::exit(1);
            }
        };
        if flags.contains_key("json") {
            println!("{}", analysis.to_json());
        } else {
            print!("{}", analysis.render_text(get(&flags, "top", 12)));
        }
        if let Some(path) = flags.get("out") {
            std::fs::write(path, analysis.to_json()).expect("writing analyzer report");
            println!("report written to {path}");
        }
        if let Some(min) = flags.get("assert-overlap").and_then(|v| v.parse::<f64>().ok()) {
            let eff = analysis.min_overlap_eff();
            if eff < min {
                eprintln!("overlap gate FAILED: min rank overlap {eff:.3} < required {min:.3}");
                failures += 1;
            } else {
                println!("overlap gate ok: min rank overlap {eff:.3} >= {min:.3}");
            }
        }
    }

    if flags.contains_key("assert-no-regression") {
        let path = flags
            .get("trajectory")
            .map(std::path::PathBuf::from)
            .unwrap_or_else(trajectory_path);
        let rows = match load_rows(&path) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("loading trajectory {} failed: {e}", path.display());
                std::process::exit(1);
            }
        };
        let report = check_regressions(&rows, get(&flags, "band", DEFAULT_BAND));
        print!("{}", report.render_text());
        if report.failures() > 0 {
            failures += 1;
        }
    }

    if failures > 0 {
        std::process::exit(1);
    }
}

/// `analyze --run`: run the traced P-rank socket session (product →
/// compression → product) and hand the merged trace straight to the
/// analyzer, no file round trip.
#[cfg(unix)]
fn run_traced_for_analysis(flags: &HashMap<String, String>) -> String {
    let ranks: usize = get(flags, "ranks", 4);
    let nv: usize = get(flags, "nv", 1);
    let tau: f64 = get(flags, "tau", 1e-3);
    let job = job_from(flags);
    let n = job.n_points();
    let mut rng = Prng::new(1234);
    let x = rng.normal_vec(n * nv);
    let mut y = vec![0.0; n * nv];
    let json = traced_socket_session(&job, ranks, nv, &x, &mut y, tau);
    if let Some(path) = flags.get("save-trace") {
        std::fs::write(path, &json).expect("writing obs trace");
        println!("merged trace written to {path} ({} bytes)", json.len());
    }
    json
}

#[cfg(not(unix))]
fn run_traced_for_analysis(_flags: &HashMap<String, String>) -> String {
    eprintln!("analyze --run requires the socket transport (Unix domain sockets)");
    std::process::exit(1);
}

#[cfg(unix)]
fn cmd_worker(flags: &HashMap<String, String>) {
    let job = job_from(flags);
    let connect = flags.get("connect").map(String::as_str).unwrap_or_else(|| {
        eprintln!("worker: --connect <socket path> is required");
        std::process::exit(2)
    });
    let rank: usize = get(flags, "rank", 0);
    let ranks: usize = get(flags, "ranks", 1);
    let nv: usize = get(flags, "nv", 1);
    if let Err(e) =
        h2opus::dist::transport::socket::run_worker(&job, std::path::Path::new(connect), rank, ranks, nv)
    {
        eprintln!("worker {rank}/{ranks} failed: {e}");
        std::process::exit(1);
    }
}

#[cfg(not(unix))]
fn cmd_worker(_flags: &HashMap<String, String>) {
    eprintln!("the socket transport requires Unix domain sockets");
    std::process::exit(1);
}

fn cmd_compress(flags: &HashMap<String, String>) {
    let mut a = build_test_matrix(flags);
    let backend = backend_from(flags);
    let tau: f64 = get(flags, "tau", 1e-3);
    let ranks: usize = get(flags, "ranks", 4);
    let pre = a.low_rank_memory_words();
    if ranks > 1 {
        let mode =
            if flags.contains_key("threaded") { ExecMode::Threaded } else { ExecMode::Virtual };
        let (c, rep) = h2opus::dist::compress::dist_compress(
            &mut a,
            ranks,
            tau,
            backend.as_ref(),
            NetworkModel::default(),
            mode,
        );
        println!("N = {}, P = {ranks}, tau = {tau:e}", c.n());
        println!("orthogonalization {:>12.3} ms", rep.orthogonalization_time * 1e3);
        println!("compression       {:>12.3} ms", rep.compression_time * 1e3);
        if let Some(m) = rep.measured {
            println!("measured          {:>12.3} ms (threaded executor)", m * 1e3);
        }
        println!("memory            {pre} -> {} words ({:.2}x)", rep.stats.post_words, rep.stats.ratio());
        println!("ranks             {:?} -> {:?}", rep.stats.old_ranks, rep.stats.new_ranks);
    } else {
        let mut mt = Metrics::new();
        let (c, stats) = compress_full(&mut a, tau, backend.as_ref(), &mut mt);
        println!("N = {}, tau = {tau:e}", c.n());
        println!("memory {pre} -> {} words ({:.2}x)", stats.post_words, stats.ratio());
        println!("ranks  {:?} -> {:?}", stats.old_ranks, stats.new_ranks);
    }
}

fn cmd_solve(flags: &HashMap<String, String>) {
    use h2opus::apps::fractional::{setup, solve, FractionalProblem};
    let n_side: usize = get(flags, "n-side", 32);
    let ranks: usize = get(flags, "ranks", 4);
    let rtol: f64 = get(flags, "rtol", 1e-6);
    let transport = flags.get("transport").map(String::as_str).unwrap_or("inproc");
    let backend = backend_from(flags);
    let mut problem = FractionalProblem::paper_defaults(n_side, ranks);
    problem.beta = get(flags, "beta", 0.75);
    println!(
        "fractional diffusion: {n_side}x{n_side} grid, beta = {}, P = {ranks}, transport = {transport}",
        problem.beta
    );
    let mut sys = setup(problem, backend.as_ref());
    println!("setup: K {:.3} s, D {:.3} s, C+MG {:.3} s", sys.setup_k, sys.setup_d, sys.setup_c);
    let sol = if transport == "socket" {
        solve_over_socket(&mut sys, ranks, rtol)
    } else {
        solve(&mut sys, backend.as_ref(), rtol)
    };
    println!(
        "solve: {} iterations, {:.3} s total, {:.3} ms/iteration, converged = {}",
        sol.result.iterations,
        sol.solve_time,
        sol.time_per_iteration * 1e3,
        sol.result.converged
    );
    if let Some(s) = sol.session_product_s {
        println!("session product latency: {:.3} ms/iteration (pipelined submit/wait)", s * 1e3);
    }
}

/// CG over a persistent socket session: the kernel matrix is sharded
/// across P live worker subprocesses that stay up for the whole
/// iteration history (one spawn + shard build, many products).
#[cfg(unix)]
fn solve_over_socket(
    sys: &mut h2opus::apps::fractional::FractionalSystem,
    ranks: usize,
    rtol: f64,
) -> h2opus::apps::fractional::FractionalSolve {
    use h2opus::apps::fractional::solve_with_session;
    use h2opus::dist::transport::socket::{SocketOptions, SocketSession};
    let job = sys.problem.matrix_job();
    let mut session = match SocketSession::start(&job, ranks, 1, SocketOptions::default()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to start the worker session: {e}");
            std::process::exit(1);
        }
    };
    let sol = solve_with_session(sys, &mut session, rtol);
    println!(
        "session: {} worker ranks spawned once, {} distributed products served",
        session.ranks(),
        session.products()
    );
    sol
}

#[cfg(not(unix))]
fn solve_over_socket(
    _sys: &mut h2opus::apps::fractional::FractionalSystem,
    _ranks: usize,
    _rtol: f64,
) -> h2opus::apps::fractional::FractionalSolve {
    eprintln!("the socket transport requires Unix domain sockets");
    std::process::exit(1);
}

/// Run a request-coalescing [`SessionServer`] with a live stats control
/// socket, optionally generating its own client load (`--selfload R`
/// concurrent single-vector requests per round) so `h2opus stats` has
/// something to show.
#[cfg(unix)]
fn cmd_serve(flags: &HashMap<String, String>) {
    use h2opus::dist::transport::server::{ServerOptions, SessionServer, StatsEndpoint};
    use h2opus::dist::transport::socket::SocketOptions;
    let ranks: usize = get(flags, "ranks", 4);
    let duration: f64 = get(flags, "duration", 5.0);
    let selfload: usize = get(flags, "selfload", 4);
    let stats_path =
        flags.get("stats-sock").cloned().unwrap_or_else(|| "/tmp/h2opus-stats.sock".into());
    let sopts = ServerOptions {
        max_coalesce: get(flags, "max-coalesce", 16),
        pipeline_depth: get(flags, "pipeline", 2),
    };
    if flags.contains_key("obs-trace") {
        // Recording must be on before the workers spawn so they inherit it
        // and the final flush covers every process.
        h2opus::obs::set_enabled(true);
    }
    let job = job_from(flags);
    // --supervised: worker crashes are reaped and the crew respawned with
    // in-flight requests replayed, instead of poisoning the server.
    let started = if flags.contains_key("supervised") {
        let sup = h2opus::dist::supervisor::SupervisorOptions {
            max_rebuilds: get(flags, "max-rebuilds", 2),
        };
        SessionServer::start_supervised(&job, ranks, SocketOptions::default(), sopts, sup)
    } else {
        SessionServer::start(&job, ranks, SocketOptions::default(), sopts)
    };
    let server = match started {
        Ok(s) => s,
        Err(e) => {
            eprintln!("failed to start the serving session: {e}");
            std::process::exit(1);
        }
    };
    let endpoint = match StatsEndpoint::bind(std::path::Path::new(&stats_path)) {
        Ok(ep) => ep,
        Err(e) => {
            eprintln!("failed to bind the stats socket: {e}");
            std::process::exit(1);
        }
    };
    let n = server.n();
    println!(
        "serving N = {n} over P = {ranks} worker ranks for {duration:.0} s; \
         stats socket {stats_path} (try: h2opus stats --connect {stats_path})"
    );
    let mut rng = Prng::new(7);
    let t0 = std::time::Instant::now();
    while t0.elapsed().as_secs_f64() < duration {
        if selfload > 0 {
            let mut handles = Vec::with_capacity(selfload);
            let mut dead = None;
            for _ in 0..selfload {
                let x = rng.normal_vec(n);
                match server.submit(&x) {
                    Ok(h) => handles.push(h),
                    Err(e) => {
                        dead = Some(e);
                        break;
                    }
                }
            }
            for h in handles {
                if let Err(e) = h.wait() {
                    dead = Some(e);
                }
            }
            if let Some(e) = dead {
                eprintln!("serving session failed: {e}");
                break;
            }
        } else {
            std::thread::sleep(std::time::Duration::from_millis(50));
        }
        endpoint.poll(&server).expect("polling stats socket");
    }
    println!("{}", server.stats().summary());
    if let Some(path) = flags.get("obs-trace") {
        match server.collect_spans() {
            Ok(json) => {
                std::fs::write(path, &json).expect("writing obs trace");
                println!("merged trace written to {path} ({} bytes)", json.len());
            }
            Err(e) => eprintln!("span flush failed: {e}"),
        }
    }
}

#[cfg(not(unix))]
fn cmd_serve(_flags: &HashMap<String, String>) {
    eprintln!("the session server requires Unix domain sockets");
    std::process::exit(1);
}

/// Fetch one live snapshot from a running `h2opus serve` and pretty-print
/// it (`--raw` dumps the Prometheus-style exposition verbatim).
#[cfg(unix)]
fn cmd_stats(flags: &HashMap<String, String>) {
    use h2opus::dist::transport::server::fetch_stats_within;
    let path =
        flags.get("connect").cloned().unwrap_or_else(|| "/tmp/h2opus-stats.sock".into());
    let timeout = std::time::Duration::from_secs_f64(get(flags, "timeout", 10.0));
    let text = match fetch_stats_within(std::path::Path::new(&path), timeout) {
        Ok(t) => t,
        Err(e) => {
            eprintln!("stats fetch from {path} failed: {e}");
            std::process::exit(1);
        }
    };
    if flags.contains_key("raw") {
        print!("{text}");
        return;
    }
    for line in text.lines() {
        if let Some(summary) = line.strip_prefix("# h2opus ") {
            println!("{summary}");
        }
    }
    println!();
    let rows: Vec<(&str, &str)> = text
        .lines()
        .filter(|l| !l.starts_with('#') && !l.contains("_bucket{"))
        .filter_map(|l| l.split_once(' '))
        .collect();
    let width = rows.iter().map(|(name, _)| name.len()).max().unwrap_or(0);
    for (name, value) in rows {
        println!("  {name:<width$}  {value}");
    }
}

#[cfg(not(unix))]
fn cmd_stats(_flags: &HashMap<String, String>) {
    eprintln!("the session server requires Unix domain sockets");
    std::process::exit(1);
}

fn cmd_accuracy(flags: &HashMap<String, String>) {
    use h2opus::construct::{dense_kernel_matrix, ExponentialKernel};
    let a = build_test_matrix(flags);
    let dim: usize = get(flags, "dim", 2);
    let corr = if dim == 2 { 0.1 } else { 0.2 };
    let kernel = ExponentialKernel { dim, corr_len: corr };
    let n = a.n();
    // paper §6.1: sampled accuracy with random vectors
    let mut rng = Prng::new(99);
    let x = rng.normal_vec(n);
    let dense = dense_kernel_matrix(&a.tree, &kernel);
    let mut y_dense = vec![0.0; n];
    h2opus::linalg::gemm_nn(n, n, 1, &dense.data, &x, &mut y_dense, false);
    let y_h2 = {
        let plan = h2opus::matvec::HgemvPlan::new(&a, 1);
        let mut ws = h2opus::matvec::HgemvWorkspace::new(&a, 1);
        let mut y = vec![0.0; n];
        let mut mt = Metrics::new();
        h2opus::matvec::hgemv(&a, &NativeBackend, &plan, &x, &mut y, &mut ws, &mut mt);
        y
    };
    let err = h2opus::util::testing::rel_err(&y_h2, &y_dense);
    println!("N = {n}, dim = {dim}, rank = {}", a.rank(a.depth()));
    println!("sampled relative accuracy ||Ax - A_H2 x||/||Ax|| = {err:.3e}");
    println!("sparsity constant C_sp = {}", a.sparsity_constant());
    println!("H2 memory {} words (dense would be {})", a.memory_words(), n * n);
}

fn cmd_info(flags: &HashMap<String, String>) {
    let a = build_test_matrix(flags);
    println!("N           {}", a.n());
    println!("depth       {}", a.depth());
    println!("ranks/level {:?}", a.u.ranks);
    println!("C_sp        {}", a.sparsity_constant());
    println!("coupling    {:?}", a.coupling.iter().map(|c| c.num_blocks()).collect::<Vec<_>>());
    println!("dense       {}", a.dense.pairs.len());
    println!("memory      {} words ({:.1}% of dense)", a.memory_words(),
        100.0 * a.memory_words() as f64 / (a.n() as f64 * a.n() as f64));
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args[1.min(args.len())..]);
    // Span recording: H2OPUS_OBS=1 (inherited by worker subprocesses) or
    // the --obs flag. Disabled costs one atomic load per site.
    h2opus::obs::init_from_env();
    if flags.contains_key("obs") {
        h2opus::obs::set_enabled(true);
    }
    // --cost-calibration PATH anchors the virtual-time CostModel to this
    // host (the file model_check.py --fit writes); the env var form
    // H2OPUS_COST_CALIBRATION works for embedders and subprocesses.
    if let Some(path) = flags.get("cost-calibration") {
        std::env::set_var("H2OPUS_COST_CALIBRATION", path);
    }
    // --backend-threads T sizes the batched backend's worker pool (before
    // any batched call freezes the global pool width); the env form makes
    // spawned `h2opus worker` subprocesses inherit the same budget.
    if let Some(t) = flags.get("backend-threads").and_then(|v| v.parse::<usize>().ok()) {
        h2opus::backend::set_backend_threads(t);
        std::env::set_var("H2OPUS_BACKEND_THREADS", t.to_string());
    }
    // Deterministic fault injection: --chaos-seed S derives a FaultPlan
    // per worker rank, --chaos-plan overrides it with an explicit rule
    // string. Set as env so spawned `h2opus worker` ranks inherit it.
    if let Some(seed) = flags.get("chaos-seed") {
        if seed.parse::<u64>().is_err() {
            eprintln!("--chaos-seed: not a u64: {seed:?}");
            std::process::exit(1);
        }
        std::env::set_var("H2OPUS_CHAOS_SEED", seed);
    }
    if let Some(plan) = flags.get("chaos-plan") {
        // Validate eagerly: a typo'd plan must abort the run here, not
        // silently run a chaos test with fault injection disabled.
        if let Err(e) = h2opus::dist::transport::chaos::FaultPlan::parse(plan) {
            eprintln!("--chaos-plan: {e}");
            std::process::exit(1);
        }
        std::env::set_var("H2OPUS_CHAOS_PLAN", plan);
    }
    match cmd {
        "matvec" => cmd_matvec(&flags),
        "compress" => cmd_compress(&flags),
        "solve" => cmd_solve(&flags),
        "accuracy" => cmd_accuracy(&flags),
        "info" => cmd_info(&flags),
        "serve" => cmd_serve(&flags),
        "stats" => cmd_stats(&flags),
        "analyze" => cmd_analyze(&args[1..]),
        "worker" => cmd_worker(&flags),
        _ => {
            println!("h2opus — distributed H^2 matrix operations (paper reproduction)");
            println!("commands: matvec | compress | solve | accuracy | info | serve | stats | analyze | worker");
            println!("common flags: --n-side N --dim 2|3 --ranks P --nv NV --backend native|xla");
            println!("              --backend-threads T (batched-kernel pool width; env H2OPUS_BACKEND_THREADS)");
            println!("              --cost-calibration target/cost_model_calibration.json");
            println!("              --obs (span recording; env H2OPUS_OBS=1)");
            println!("matvec flags: --threaded --transport inproc|socket --trace F --measured-trace F");
            println!("              --obs-trace F (merged cross-process span trace; socket: product + compress + product)");
            println!("              --kernel exp|fractional --beta B");
            println!("solve flags:  --transport inproc|socket (socket = persistent sharded worker session)");
            println!("              --chaos-seed S | --chaos-plan 'kill,src=1,nth=4' (deterministic fault injection)");
            println!("serve flags:  --max-coalesce NV --pipeline D --duration S --selfload R --stats-sock PATH");
            println!("              --supervised --max-rebuilds K (respawn crashed crews, replay in-flight requests)");
            println!("stats flags:  --connect PATH --raw --timeout S");
            println!("analyze:      h2opus analyze <trace.json> | --run [matrix flags] [--save-trace F]");
            println!("              --json --top N --out report.json --assert-overlap MIN");
            println!("              --assert-no-regression --band B --trajectory PATH (bench regression gate)");
        }
    }
}
