//! K-d tree hierarchical clustering of point sets (the paper's T_I).
//!
//! The cluster tree is a *perfect* binary tree: every internal node has two
//! children and all leaves sit at the same depth. Median splits along the
//! longest bounding-box axis keep sibling sizes within one point of each
//! other, so level `l` always has exactly `2^l` nodes — the property that
//! makes per-level flattened storage and fixed-size batching possible
//! (§2.1), and that lets the distributed decomposition split clean branches
//! at the C-level (§2.2).

use crate::geometry::{BBox, PointSet};

/// One node of the cluster tree: a contiguous range [start, end) of the
/// permuted point ordering, plus its bounding box.
#[derive(Clone, Debug)]
pub struct ClusterNode {
    pub start: usize,
    pub end: usize,
    pub bbox: BBox,
}

impl ClusterNode {
    pub fn size(&self) -> usize {
        self.end - self.start
    }
}

/// A perfect binary cluster tree over a point set.
///
/// Nodes are stored in heap order: level `l` occupies indices
/// `[2^l - 1, 2^(l+1) - 1)`, so each level is a contiguous slice; the
/// children of node `i` are `2i+1` and `2i+2`.
#[derive(Clone, Debug)]
pub struct ClusterTree {
    /// The clustered points (owned).
    pub points: PointSet,
    /// `perm[pos]` = original index of the point at permuted position `pos`.
    pub perm: Vec<usize>,
    /// Inverse permutation: `iperm[orig] = pos`.
    pub iperm: Vec<usize>,
    /// Depth of the tree; leaves live at level `depth` (root = level 0).
    pub depth: usize,
    /// Heap-ordered nodes; length `2^(depth+1) - 1`.
    pub nodes: Vec<ClusterNode>,
}

impl ClusterTree {
    /// Build a cluster tree with leaf sizes `<= leaf_size` (and as close to
    /// it as a perfect tree allows).
    pub fn build(points: PointSet, leaf_size: usize) -> Self {
        Self::build_with_min_leaf(points, leaf_size, 1)
    }

    /// Build with leaf sizes in `[min_leaf, leaf_size]` where possible:
    /// the depth is reduced if median splitting would produce leaves
    /// smaller than `min_leaf` (needed when the basis rank k requires
    /// m_pad >= k, e.g. for orthogonalization/compression).
    pub fn build_with_min_leaf(points: PointSet, leaf_size: usize, min_leaf: usize) -> Self {
        assert!(leaf_size >= 1);
        let n = points.len();
        assert!(n >= 1, "cannot cluster an empty point set");
        // Smallest depth such that ceil(n / 2^depth) <= leaf_size...
        let mut depth = 0usize;
        while n.div_ceil(1 << depth) > leaf_size {
            depth += 1;
        }
        // ...then back off while the smallest leaf (floor) would be below
        // min_leaf (balanced splits keep all leaves within 1 of n/2^depth).
        while depth > 0 && (n >> depth) < min_leaf {
            depth -= 1;
        }
        let mut perm: Vec<usize> = (0..n).collect();
        let node_count = (1usize << (depth + 1)) - 1;
        // Temporary ranges; bboxes filled after splitting.
        let mut ranges = vec![(0usize, 0usize); node_count];
        ranges[0] = (0, n);

        // Split level by level: sort the node's index range along the
        // longest bbox axis and cut at the midpoint (left gets the ceil).
        for l in 0..depth {
            for j in 0..(1usize << l) {
                let id = level_offset(l) + j;
                let (start, end) = ranges[id];
                let idx = &mut perm[start..end];
                let bbox = BBox::of(&points, idx);
                let axis = bbox.longest_axis();
                idx.sort_by(|&a, &b| {
                    points.coords[axis][a]
                        .partial_cmp(&points.coords[axis][b])
                        .unwrap()
                });
                let mid = start + (end - start).div_ceil(2);
                ranges[2 * id + 1] = (start, mid);
                ranges[2 * id + 2] = (mid, end);
            }
        }

        let mut nodes = Vec::with_capacity(node_count);
        for (id, &(start, end)) in ranges.iter().enumerate() {
            assert!(end > start, "empty cluster node {id}: leaf_size too small for a perfect tree");
            let bbox = BBox::of(&points, &perm[start..end]);
            nodes.push(ClusterNode { start, end, bbox });
        }
        let mut iperm = vec![0usize; n];
        for (pos, &orig) in perm.iter().enumerate() {
            iperm[orig] = pos;
        }
        ClusterTree { points, perm, iperm, depth, nodes }
    }

    /// Number of levels (= depth + 1).
    pub fn num_levels(&self) -> usize {
        self.depth + 1
    }

    /// Number of nodes at level `l`.
    pub fn nodes_at(&self, l: usize) -> usize {
        1usize << l
    }

    /// The nodes of level `l` as a contiguous slice.
    pub fn level(&self, l: usize) -> &[ClusterNode] {
        let off = level_offset(l);
        &self.nodes[off..off + (1 << l)]
    }

    /// Node `j` of level `l`.
    pub fn node(&self, l: usize, j: usize) -> &ClusterNode {
        &self.nodes[level_offset(l) + j]
    }

    /// Leaf nodes (level `depth`).
    pub fn leaves(&self) -> &[ClusterNode] {
        self.level(self.depth)
    }

    /// Maximum leaf size (the padded leaf dimension m_pad used for batching).
    pub fn max_leaf_size(&self) -> usize {
        self.leaves().iter().map(|n| n.size()).max().unwrap()
    }

    /// Total number of points.
    pub fn num_points(&self) -> usize {
        self.points.len()
    }

    /// Original point indices of node (l, j).
    pub fn node_indices(&self, l: usize, j: usize) -> &[usize] {
        let n = self.node(l, j);
        &self.perm[n.start..n.end]
    }
}

/// First heap index of level `l`.
#[inline]
pub fn level_offset(l: usize) -> usize {
    (1usize << l) - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geometry::PointSet;

    #[test]
    fn perfect_tree_shape() {
        let ps = PointSet::grid_2d(8, 1.0); // 64 points
        let t = ClusterTree::build(ps, 8);
        assert_eq!(t.depth, 3); // 64/8 = 8 leaves
        assert_eq!(t.level(3).len(), 8);
        assert_eq!(t.nodes.len(), 15);
        for leaf in t.leaves() {
            assert_eq!(leaf.size(), 8);
        }
    }

    #[test]
    fn non_power_of_two_sizes_balanced() {
        let mut ps = PointSet::new(2);
        for i in 0..37 {
            ps.push(&[i as f64, (i * 7 % 11) as f64]);
        }
        let t = ClusterTree::build(ps, 5);
        // depth: ceil(37/2^d) <= 5 -> d = 3 (37/8 = 4.6)
        assert_eq!(t.depth, 3);
        let sizes: Vec<usize> = t.leaves().iter().map(|n| n.size()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 37);
        assert!(sizes.iter().all(|&s| (4..=5).contains(&s)), "{sizes:?}");
    }

    #[test]
    fn children_partition_parent() {
        let ps = PointSet::grid_2d(8, 1.0);
        let t = ClusterTree::build(ps, 8);
        for l in 0..t.depth {
            for j in 0..t.nodes_at(l) {
                let p = t.node(l, j);
                let c1 = t.node(l + 1, 2 * j);
                let c2 = t.node(l + 1, 2 * j + 1);
                assert_eq!(p.start, c1.start);
                assert_eq!(c1.end, c2.start);
                assert_eq!(c2.end, p.end);
            }
        }
    }

    #[test]
    fn perm_is_permutation() {
        let ps = PointSet::grid_2d(5, 1.0); // 25 points
        let t = ClusterTree::build(ps, 4);
        let mut seen = vec![false; 25];
        for &p in &t.perm {
            assert!(!seen[p]);
            seen[p] = true;
        }
        for (orig, &pos) in t.iperm.iter().enumerate() {
            assert_eq!(t.perm[pos], orig);
        }
    }

    #[test]
    fn clusters_are_spatially_tight() {
        // After median splits, sibling boxes should not overlap much along
        // the split axis: check the root split separates x or y cleanly.
        let ps = PointSet::grid_2d(16, 1.0);
        let t = ClusterTree::build(ps, 32);
        let c1 = t.node(1, 0);
        let c2 = t.node(1, 1);
        let axis = t.node(0, 0).bbox.longest_axis();
        assert!(c1.bbox.hi[axis] <= c2.bbox.lo[axis] + 1e-12);
    }

    #[test]
    fn single_node_tree() {
        let ps = PointSet::grid_2d(2, 1.0); // 4 points
        let t = ClusterTree::build(ps, 8);
        assert_eq!(t.depth, 0);
        assert_eq!(t.nodes.len(), 1);
        assert_eq!(t.node(0, 0).size(), 4);
    }

    #[test]
    fn max_leaf_size_bound() {
        for n in [10usize, 33, 64, 100] {
            let mut ps = PointSet::new(1);
            for i in 0..n {
                ps.push(&[i as f64]);
            }
            let t = ClusterTree::build(ps, 7);
            assert!(t.max_leaf_size() <= 7);
        }
    }
}
