//! Run configuration for H^2 construction and the distributed runtime.

/// Parameters controlling H^2 construction (§2, §6.1).
#[derive(Clone, Debug)]
pub struct H2Config {
    /// Target leaf (dense block) size m; the paper uses 64, we default to 32
    /// on the CPU testbed.
    pub leaf_size: usize,
    /// Admissibility parameter η (paper: 0.9 in 2D, 0.95 in 3D).
    pub eta: f64,
    /// Chebyshev grid points per dimension g; rank k = g^dim.
    pub cheb_grid: usize,
}

impl H2Config {
    /// Paper-style 2D configuration scaled to the CPU testbed:
    /// m=32, η=0.9, g=4 → k=16.
    pub fn default_2d() -> Self {
        H2Config { leaf_size: 32, eta: 0.9, cheb_grid: 4 }
    }

    /// Paper-style 3D configuration: m=32, η=0.95, g=2 → k=8.
    pub fn default_3d() -> Self {
        H2Config { leaf_size: 32, eta: 0.95, cheb_grid: 2 }
    }

    /// Rank produced by Chebyshev interpolation in `dim` dimensions.
    pub fn rank(&self, dim: usize) -> usize {
        self.cheb_grid.pow(dim as u32)
    }
}

/// α-β network model for the simulated interconnect (see DESIGN.md
/// "Substitutions"). Defaults approximate a per-GPU share of Summit's
/// fat-tree: 5 µs latency, 25 GB/s bandwidth.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Per-message latency α in seconds.
    pub alpha: f64,
    /// Per-byte transfer time β in seconds (1 / bandwidth).
    pub beta: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        NetworkModel { alpha: 5e-6, beta: 1.0 / 25e9 }
    }
}

impl NetworkModel {
    /// Transfer time for a message of `bytes` bytes.
    pub fn time(&self, bytes: usize) -> f64 {
        self.alpha + self.beta * bytes as f64
    }

    /// An instantaneous network (for tests that want pure-compute virtual
    /// time).
    pub fn instant() -> Self {
        NetworkModel { alpha: 0.0, beta: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rank_is_g_pow_dim() {
        let c = H2Config { leaf_size: 32, eta: 0.9, cheb_grid: 4 };
        assert_eq!(c.rank(2), 16);
        assert_eq!(c.rank(3), 64);
    }

    #[test]
    fn network_time_monotone_in_bytes() {
        let n = NetworkModel::default();
        assert!(n.time(1000) < n.time(10_000));
        assert!(n.time(0) >= n.alpha);
    }

    #[test]
    fn instant_network_is_free() {
        assert_eq!(NetworkModel::instant().time(1 << 20), 0.0);
    }
}
