//! Algebraic H^2 matrix compression (§5).
//!
//! Pipeline (exactly the paper's):
//! 1. [`orthogonalize`] — upsweep QR pass making both basis trees
//!    orthonormal (exact; coupling blocks absorb the R factors).
//! 2. [`compress`] —
//!    a. *downsweep* building, per node, the R factor Z of the weight
//!       matrix B (Eqs. 1–4): QR of small stacks of coupling/transfer
//!       blocks, seeded by the parent's Z;
//!    b. *truncation upsweep*: SVD of the reweighed bases (leaf: U·Zᵀ,
//!       inner: stacked projected transfers), keeping singular values above
//!       τ·σ_ref and producing the new nested basis and the projection
//!       maps P = U'ᵀU;
//!    c. *projection*: S' = P_t S P_sᵀ (batched GEMMs).
//!
//! All stages are batched per level, mirroring the paper's use of KBLAS
//! batched QR/SVD and MAGMA batched GEMM.

pub mod orthogonalize;
pub mod truncate;

pub use orthogonalize::{
    absorb_r_level, orth_leaf_level, orth_transfer_level, orthogonalize, orthogonalize_logged,
    orthogonalize_logged_with, tree_is_orthogonal,
};
pub use truncate::{
    compress, compress_full, compress_full_logged, compress_full_logged_with, compress_logged,
    compress_logged_with, project_level, truncate_inner_level, truncate_leaf_level, weight_level,
    CompressionStats, LeafTruncation,
};

/// Per-level wall-time log of the compression pipeline's phases. The
/// distributed scheduler ([`crate::dist::compress`]) replays this log in
/// virtual time: levels at or below the C-level execute concurrently on all
/// ranks (cost / P each), levels above it serialize on the master.
#[derive(Clone, Debug, Default)]
pub struct PhaseLog {
    /// (phase name, tree level, seconds)
    pub entries: Vec<(&'static str, usize, f64)>,
}

impl PhaseLog {
    pub fn push(&mut self, phase: &'static str, level: usize, secs: f64) {
        self.entries.push((phase, level, secs));
    }

    /// Total seconds across phases matching `pred`.
    pub fn total<F: Fn(&str) -> bool>(&self, pred: F) -> f64 {
        self.entries.iter().filter(|(n, _, _)| pred(n)).map(|(_, _, t)| t).sum()
    }
}
