//! Basis-generation downsweep + truncation upsweep + coupling projection
//! (§5.1, §5.2). Precondition: both basis trees orthonormal (run
//! [`super::orthogonalize`] first; [`compress_full`] does both).

use crate::backend::{contiguous_offsets, BatchRef, ComputeBackend, GemmDims};
use super::PhaseLog;
use crate::metrics::Metrics;
use crate::tree::{BasisTree, H2Matrix};
use crate::util::Timer;

/// Outcome of a compression: rank and memory before/after.
#[derive(Clone, Debug)]
pub struct CompressionStats {
    pub old_ranks: Vec<usize>,
    pub new_ranks: Vec<usize>,
    /// Low-rank memory (f64 words) before/after.
    pub pre_words: usize,
    pub post_words: usize,
    /// Reference singular value of the *row (U) tree's* leaf SVDs — the
    /// column tree is truncated against its own reference, which is not
    /// reported here.
    pub sigma_ref: f64,
}

impl CompressionStats {
    /// The paper's Fig. 11 memory-reduction factor.
    pub fn ratio(&self) -> f64 {
        self.pre_words as f64 / self.post_words.max(1) as f64
    }
}

/// Per-level per-node square factors (Z of the weight QR, or projection P).
type LevelBlocks = Vec<Vec<f64>>;

/// The absolute singular-value threshold for truncating against a spectrum
/// whose largest singular value is `sigma_max`: τ·σ_max — except that a
/// level whose spectrum is identically zero (`sigma_max == 0`, e.g. a
/// basis with no coupling anywhere under it) carries no information at
/// all, so *everything* is truncatable: the threshold is +∞ and the rank
/// floor of 1 applies. The former `.max(f64::MIN_POSITIVE)` clamp instead
/// produced a subnormal threshold, which any rounding-noise singular value
/// exceeds — an all-zero level then kept full rank instead of collapsing.
pub fn truncation_threshold(tau: f64, sigma_max: f64) -> f64 {
    if sigma_max <= 0.0 {
        f64::INFINITY
    } else {
        tau * sigma_max
    }
}

/// Largest per-block ε-rank of a batch of singular-value vectors (`k`
/// values per block): the max count of leading values strictly above
/// `abs_tol`. Raw — the caller applies the rank floor (`.max(1)`) and any
/// structural ceiling; in the distributed path the per-branch partial
/// maxima combine by another max at the coordinator before those clamps,
/// so rank decisions are bitwise-identical to serial.
pub fn max_rank_below(s: &[f64], k: usize, abs_tol: f64) -> usize {
    s.chunks_exact(k)
        .map(|sv| sv.iter().take_while(|&&x| x > abs_tol).count())
        .max()
        .unwrap_or(0)
}

/// Downsweep of §5.1: compute, for every node of the row (or column) basis
/// tree, the R factor `Z_t` of the weight matrix B_t, by QR of the stack
/// [Z_parent·Eᵀ ; S blocks of the node's row/column] (Eq. 4).
fn weight_downsweep(
    a: &H2Matrix,
    for_rows: bool,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    log: &mut PhaseLog,
) -> LevelBlocks {
    let depth = a.depth();
    let mut z: LevelBlocks = vec![Vec::new(); depth + 1];
    for l in 0..=depth {
        let timer = Timer::start();
        let z_parent = if l > 0 { Some(z[l - 1].as_slice()) } else { None };
        let r = weight_level(a, for_rows, l, z_parent, backend, metrics);
        z[l] = r;
        log.push("weight_qr", l, timer.elapsed());
    }
    z
}

/// One level of the weight downsweep: per node of level l, the R factor of
/// the stacked weight matrix [Z_parent·Eᵀ ; level-l coupling blocks of the
/// node's block row/column] (Eq. 4). `z_parent` holds the level-(l-1)
/// factors (None at the root).
pub fn weight_level(
    a: &H2Matrix,
    for_rows: bool,
    l: usize,
    z_parent: Option<&[f64]>,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> Vec<f64> {
    let tree = if for_rows { &a.u } else { &a.v };
    let k_l = a.rank(l);
    let nodes = 1usize << l;
    let k_par = if l > 0 { a.rank(l - 1) } else { 0 };
    // Blocks per node in this level's block row/column.
    let cl = &a.coupling[l];
    let owners: Vec<usize> =
        cl.pairs.iter().map(|&(t, s)| if for_rows { t } else { s } as usize).collect();
    let max_b = level_max_blocks(&cl.pairs, for_rows);
    weight_level_core(
        &tree.transfers[l],
        k_l,
        k_par,
        nodes,
        &owners,
        &cl.data,
        for_rows,
        z_parent,
        max_b,
        backend,
        metrics,
    )
}

/// Global max blocks-per-node of one coupling level's block rows (or
/// columns): the stack height every rank must agree on — a branch slice
/// computes it from the replicated index-only structure, never from its
/// local pair subset, so the zero-row padding (and hence the QR output)
/// is bitwise-identical to serial.
pub fn level_max_blocks(pairs: &[(u32, u32)], for_rows: bool) -> usize {
    let mut counts = std::collections::HashMap::new();
    for &(t, s) in pairs {
        *counts.entry(if for_rows { t } else { s }).or_insert(0usize) += 1;
    }
    counts.values().copied().max().unwrap_or(0)
}

/// Tree-agnostic body of [`weight_level`], shared with the branch-sliced
/// distributed path: per node of the `nodes`-wide (sub)level, QR-R of the
/// stack [Z_parent·Eᵀ ; S blocks]. `owners[q]` names the (local) node the
/// q-th k_l×k_l block of `blocks` belongs to, in the serial marshaling
/// order; `max_b` is the *global* per-node block maximum (see
/// [`level_max_blocks`]); `transfers_l` holds the contiguous per-node E
/// blocks (unused when `z_parent` is `None`).
#[allow(clippy::too_many_arguments)]
pub(crate) fn weight_level_core(
    transfers_l: &[f64],
    k_l: usize,
    k_par: usize,
    nodes: usize,
    owners: &[usize],
    blocks: &[f64],
    for_rows: bool,
    z_parent: Option<&[f64]>,
    max_b: usize,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> Vec<f64> {
    let parent_rows = if z_parent.is_some() { k_par } else { 0 };
    let stack_rows = parent_rows + max_b * k_l;
    if stack_rows == 0 {
        // No blocks anywhere at the root level: zero weight.
        return vec![0.0; nodes * k_l * k_l];
    }
    // QR needs rows >= cols: pad with zero rows if needed.
    let stack_rows = stack_rows.max(k_l);
    let mut stack = vec![0.0; nodes * stack_rows * k_l];

    // Parent contribution: Z_par[t/2] · E_tᵀ into the first k_par rows.
    if let Some(zp) = z_parent {
        let a_off: Vec<usize> = (0..nodes).map(|t| (t / 2) * k_par * k_par).collect();
        let b_off = contiguous_offsets(nodes, k_l * k_par);
        let c_off: Vec<usize> = (0..nodes).map(|t| t * stack_rows * k_l).collect();
        backend.batched_gemm(
            GemmDims { nb: nodes, m: k_par, k: k_par, n: k_l, trans_a: false, trans_b: true, accumulate: false },
            BatchRef { data: zp, offsets: &a_off },
            BatchRef { data: transfers_l, offsets: &b_off },
            &mut stack,
            &c_off,
            metrics,
        );
    }

    // Coupling contributions (marshaled copies; S transposed for the
    // row tree — Eq. 4 stacks S_ijᵀ — and direct for the column tree).
    let mut cursor = vec![0usize; nodes];
    for (q, &owner) in owners.iter().enumerate() {
        let row0 = parent_rows + cursor[owner] * k_l;
        cursor[owner] += 1;
        let blk = &blocks[q * k_l * k_l..(q + 1) * k_l * k_l];
        let dst = &mut stack[owner * stack_rows * k_l + row0 * k_l..];
        if for_rows {
            for i in 0..k_l {
                for j in 0..k_l {
                    dst[i * k_l + j] = blk[j * k_l + i];
                }
            }
        } else {
            dst[..k_l * k_l].copy_from_slice(blk);
        }
    }

    let mut r = vec![0.0; nodes * k_l * k_l];
    backend.batched_qr_r(nodes, stack_rows, k_l, &stack, &mut r, metrics);
    r
}

/// Result of truncating one basis tree.
struct TruncatedTree {
    basis: BasisTree,
    /// Projection maps P_t = U'ᵀU per level (k'_l × k_l per node).
    p: LevelBlocks,
    new_ranks: Vec<usize>,
    /// Reference singular value of the leaf SVDs.
    sigma_ref: f64,
}

/// Truncation upsweep of §5.2: SVD the reweighed bases level by level,
/// keep singular values > τ·σ_ref, build the new nested basis and P maps.
fn truncate_tree(
    a: &H2Matrix,
    for_rows: bool,
    z: &LevelBlocks,
    tau: f64,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    log: &mut PhaseLog,
) -> TruncatedTree {
    let depth = a.depth();
    let tree = if for_rows { &a.u } else { &a.v };
    let m_pad = tree.leaf_dim;
    let leaf_sizes = tree.leaf_sizes.clone();

    let leaf = truncate_leaf_level(a, for_rows, &z[depth], tau, backend, metrics, log);
    let mut new_ranks = vec![0usize; depth + 1];
    new_ranks[depth] = leaf.k_new;
    let mut p: LevelBlocks = vec![Vec::new(); depth + 1];
    p[depth] = leaf.p_leaf;

    // --- Inner levels (children l -> parents l-1). ---
    let mut new_transfers: Vec<Vec<f64>> = vec![Vec::new(); depth + 1];
    for l in (1..=depth).rev() {
        let timer = Timer::start();
        let (etr, pp, k_new_p) = truncate_inner_level(
            a,
            for_rows,
            l,
            &z[l - 1],
            new_ranks[l],
            &p[l],
            leaf.abs_tol,
            backend,
            metrics,
        );
        new_ranks[l - 1] = k_new_p;
        new_transfers[l] = etr;
        p[l - 1] = pp;
        log.push("trunc_svd", l - 1, timer.elapsed());
    }

    // Assemble the new basis tree.
    let mut basis = BasisTree::zeros(depth, new_ranks.clone(), m_pad, leaf_sizes);
    basis.leaf_bases = leaf.new_leaf_bases;
    for l in 1..=depth {
        basis.transfers[l] = std::mem::take(&mut new_transfers[l]);
    }
    TruncatedTree { basis, p, new_ranks, sigma_ref: leaf.sigma_ref }
}

/// Outcome of the leaf stage of the truncation upsweep.
pub struct LeafTruncation {
    /// New leaf bases (m_pad × k_new per node).
    pub new_leaf_bases: Vec<f64>,
    /// Leaf projection maps P = U'ᵀU (k_new × k_old per node).
    pub p_leaf: Vec<f64>,
    /// New (uniform) leaf rank.
    pub k_new: usize,
    /// Absolute singular-value threshold τ·σ_ref used for every level.
    pub abs_tol: f64,
    /// Reference singular value σ_ref (largest leaf singular value).
    pub sigma_ref: f64,
}

/// Leaf stage of the truncation upsweep: M_t = U_t·Z_tᵀ, batched SVD, rank
/// selection against τ·σ_ref, new leaf bases and leaf P maps.
#[allow(clippy::too_many_arguments)]
pub fn truncate_leaf_level(
    a: &H2Matrix,
    for_rows: bool,
    z_leaf: &[f64],
    tau: f64,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    log: &mut PhaseLog,
) -> LeafTruncation {
    let timer = Timer::start();
    let depth = a.depth();
    let tree = if for_rows { &a.u } else { &a.v };
    let (u_svd, s_svd) = truncate_leaf_svd(tree, z_leaf, backend, metrics);
    let sigma_ref = s_svd.iter().cloned().fold(0.0_f64, f64::max);
    let abs_tol = truncation_threshold(tau, sigma_ref);
    let k_new = max_rank_below(&s_svd, tree.ranks[depth], abs_tol).max(1);
    log.push("trunc_svd", depth, timer.elapsed());
    let timer = Timer::start();
    let (new_leaf_bases, p_leaf) = truncate_leaf_finish(tree, &u_svd, k_new, backend, metrics);
    log.push("trunc_p", depth, timer.elapsed());
    LeafTruncation { new_leaf_bases, p_leaf, k_new, abs_tol, sigma_ref }
}

/// SVD half of the leaf stage, tree-scoped so a rank's branch (a
/// [`BasisTree`] over its local leaves) runs it unmodified: M_t = U_t·Z_tᵀ
/// then batched SVD. Returns `(u_svd, s_svd)`; rank selection happens on
/// the full spectrum (serial) or via the coordinator's max-reduction over
/// per-branch partials (distributed).
pub fn truncate_leaf_svd(
    tree: &BasisTree,
    z_leaf: &[f64],
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> (Vec<f64>, Vec<f64>) {
    let m_pad = tree.leaf_dim;
    let leaves = tree.num_leaves();
    let k_leaf = tree.ranks[tree.depth];

    let mut m_buf = vec![0.0; leaves * m_pad * k_leaf];
    {
        let a_off = contiguous_offsets(leaves, m_pad * k_leaf);
        let z_off = contiguous_offsets(leaves, k_leaf * k_leaf);
        backend.batched_gemm(
            GemmDims { nb: leaves, m: m_pad, k: k_leaf, n: k_leaf, trans_a: false, trans_b: true, accumulate: false },
            BatchRef { data: &tree.leaf_bases, offsets: &a_off },
            BatchRef { data: z_leaf, offsets: &z_off },
            &mut m_buf,
            &a_off,
            metrics,
        );
    }
    let mut u_svd = vec![0.0; leaves * m_pad * k_leaf];
    let mut s_svd = vec![0.0; leaves * k_leaf];
    let mut v_svd = vec![0.0; leaves * k_leaf * k_leaf];
    backend.batched_svd(leaves, m_pad, k_leaf, &m_buf, &mut u_svd, &mut s_svd, &mut v_svd, metrics);
    (u_svd, s_svd)
}

/// Basis-building half of the leaf stage, with the (globally agreed) new
/// rank decided: new leaf bases (first k' columns of each SVD U) and the
/// leaf projection maps P = U'ᵀU. Tree-scoped like [`truncate_leaf_svd`].
pub fn truncate_leaf_finish(
    tree: &BasisTree,
    u_svd: &[f64],
    k_new: usize,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> (Vec<f64>, Vec<f64>) {
    let m_pad = tree.leaf_dim;
    let leaves = tree.num_leaves();
    let k_leaf = tree.ranks[tree.depth];

    let mut new_leaf_bases = vec![0.0; leaves * m_pad * k_new];
    for j in 0..leaves {
        for i in 0..m_pad {
            for c in 0..k_new {
                new_leaf_bases[j * m_pad * k_new + i * k_new + c] =
                    u_svd[j * m_pad * k_leaf + i * k_leaf + c];
            }
        }
    }
    let mut p_leaf = vec![0.0; leaves * k_new * k_leaf];
    {
        let a_off = contiguous_offsets(leaves, m_pad * k_new);
        let b_off = contiguous_offsets(leaves, m_pad * k_leaf);
        let c_off = contiguous_offsets(leaves, k_new * k_leaf);
        backend.batched_gemm(
            GemmDims { nb: leaves, m: k_new, k: m_pad, n: k_leaf, trans_a: true, trans_b: false, accumulate: false },
            BatchRef { data: &new_leaf_bases, offsets: &a_off },
            BatchRef { data: &tree.leaf_bases, offsets: &b_off },
            &mut p_leaf,
            &c_off,
            metrics,
        );
    }
    (new_leaf_bases, p_leaf)
}

/// One inner level of the truncation upsweep (children l -> parents l-1):
/// tmp1 = E_c·Z_pᵀ, tmp2 = P_c·tmp1, SVD of the stacked sibling pair, new
/// transfers E' from the left-factor halves, and the parents' projection
/// maps P_p = Σ_c E'_cᵀ(P_c·E_c). Returns (new transfers at level l,
/// parent P maps, new parent rank).
#[allow(clippy::too_many_arguments)]
pub fn truncate_inner_level(
    a: &H2Matrix,
    for_rows: bool,
    l: usize,
    z_parent: &[f64],
    k_new_c: usize,
    p_c: &[f64],
    abs_tol: f64,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> (Vec<f64>, Vec<f64>, usize) {
    let tree = if for_rows { &a.u } else { &a.v };
    let k_par = tree.ranks[l - 1];
    let (us, ss, stack_rows) =
        truncate_inner_svd(tree, l, z_parent, k_new_c, p_c, backend, metrics);
    let k_new_p = max_rank_below(&ss, k_par, abs_tol)
        .max(1)
        .min(2 * k_new_c); // cannot exceed the stack's actual row count
    let (etr, pp) = truncate_inner_finish(
        tree, l, &us, stack_rows, k_new_c, k_new_p, p_c, backend, metrics,
    );
    (etr, pp, k_new_p)
}

/// SVD half of one inner truncation level (children `l` -> parents `l-1`
/// *within `tree`*): tmp1 = E_c·Z_pᵀ, tmp2 = P_c·tmp1 stacked per sibling
/// pair, batched SVD. Returns `(us, ss, stack_rows)`; the new parent rank
/// is decided on the full `ss` (serial) or by the coordinator's
/// max-reduction over per-branch partials (distributed), then
/// [`truncate_inner_finish`] completes the level.
pub fn truncate_inner_svd(
    tree: &BasisTree,
    l: usize,
    z_parent: &[f64],
    k_new_c: usize,
    p_c: &[f64],
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> (Vec<f64>, Vec<f64>, usize) {
    let k_l = tree.ranks[l];
    let k_par = tree.ranks[l - 1];
    let nodes_c = 1usize << l;
    let nodes_p = 1usize << (l - 1);

    // tmp1_c = E_c · Z_parᵀ  (k_l × k_par)
    let mut tmp1 = vec![0.0; nodes_c * k_l * k_par];
    let e_off = contiguous_offsets(nodes_c, k_l * k_par);
    let zoff: Vec<usize> = (0..nodes_c).map(|c| (c / 2) * k_par * k_par).collect();
    backend.batched_gemm(
        GemmDims { nb: nodes_c, m: k_l, k: k_par, n: k_par, trans_a: false, trans_b: true, accumulate: false },
        BatchRef { data: &tree.transfers[l], offsets: &e_off },
        BatchRef { data: z_parent, offsets: &zoff },
        &mut tmp1,
        &e_off,
        metrics,
    );
    // tmp2_c = P_c · tmp1_c  (k'_l × k_par), written into SVD stacks.
    let stack_rows = (2 * k_new_c).max(k_par); // zero row padding for wide stacks
    let mut stack = vec![0.0; nodes_p * stack_rows * k_par];
    let p_off = contiguous_offsets(nodes_c, k_new_c * k_l);
    let stack_off: Vec<usize> = (0..nodes_c)
        .map(|c| (c / 2) * stack_rows * k_par + (c % 2) * k_new_c * k_par)
        .collect();
    backend.batched_gemm(
        GemmDims { nb: nodes_c, m: k_new_c, k: k_l, n: k_par, trans_a: false, trans_b: false, accumulate: false },
        BatchRef { data: p_c, offsets: &p_off },
        BatchRef { data: &tmp1, offsets: &e_off },
        &mut stack,
        &stack_off,
        metrics,
    );

    let mut us = vec![0.0; nodes_p * stack_rows * k_par];
    let mut ss = vec![0.0; nodes_p * k_par];
    let mut vs = vec![0.0; nodes_p * k_par * k_par];
    backend.batched_svd(nodes_p, stack_rows, k_par, &stack, &mut us, &mut ss, &mut vs, metrics);
    (us, ss, stack_rows)
}

/// Basis-building half of one inner truncation level, with the (globally
/// agreed) new parent rank decided: new transfers E'_c from the left
/// factor halves and the parents' projection maps
/// P_p = Σ_c E'_cᵀ(P_c·E_c). Returns `(etr, pp)`.
#[allow(clippy::too_many_arguments)]
pub fn truncate_inner_finish(
    tree: &BasisTree,
    l: usize,
    us: &[f64],
    stack_rows: usize,
    k_new_c: usize,
    k_new_p: usize,
    p_c: &[f64],
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> (Vec<f64>, Vec<f64>) {
    let k_l = tree.ranks[l];
    let k_par = tree.ranks[l - 1];
    let nodes_c = 1usize << l;
    let nodes_p = 1usize << (l - 1);
    let e_off = contiguous_offsets(nodes_c, k_l * k_par);
    let p_off = contiguous_offsets(nodes_c, k_new_c * k_l);

    // New transfers E'_c: rows of the left factor halves.
    let mut etr = vec![0.0; nodes_c * k_new_c * k_new_p];
    for c in 0..nodes_c {
        let base = (c / 2) * stack_rows * k_par + (c % 2) * k_new_c * k_par;
        for i in 0..k_new_c {
            for q in 0..k_new_p {
                etr[c * k_new_c * k_new_p + i * k_new_p + q] = us[base + i * k_par + q];
            }
        }
    }

    // P_p = Σ_c E'_cᵀ · (P_c · E_c)
    let mut pce = vec![0.0; nodes_c * k_new_c * k_par];
    backend.batched_gemm(
        GemmDims { nb: nodes_c, m: k_new_c, k: k_l, n: k_par, trans_a: false, trans_b: false, accumulate: false },
        BatchRef { data: p_c, offsets: &p_off },
        BatchRef { data: &tree.transfers[l], offsets: &e_off },
        &mut pce,
        &contiguous_offsets(nodes_c, k_new_c * k_par),
        metrics,
    );
    // Sibling pair accumulation as two *parity* batches (even children,
    // then odd children), like the upsweep's `LevelTransferPlan::parity`:
    // within each call every parent P block appears once, so the §3.2
    // conflict-free-offsets contract holds and the batch may be executed
    // in parallel. Each parent still accumulates its even child before its
    // odd child — the per-block in-place order of the former single-batch
    // form — so results are bit-identical to it.
    let mut pp = vec![0.0; nodes_p * k_new_p * k_par];
    let pce_off = contiguous_offsets(nodes_c, k_new_c * k_par);
    for parity in 0..2 {
        let ep_off: Vec<usize> =
            (0..nodes_p).map(|i| (2 * i + parity) * k_new_c * k_new_p).collect();
        let pce_par: Vec<usize> = (0..nodes_p).map(|i| pce_off[2 * i + parity]).collect();
        let pp_off: Vec<usize> = (0..nodes_p).map(|i| i * k_new_p * k_par).collect();
        backend.batched_gemm(
            GemmDims { nb: nodes_p, m: k_new_p, k: k_new_c, n: k_par, trans_a: true, trans_b: false, accumulate: true },
            BatchRef { data: &etr, offsets: &ep_off },
            BatchRef { data: &pce, offsets: &pce_par },
            &mut pp,
            &pp_off,
            metrics,
        );
    }
    (etr, pp)
}

/// Compress `a` (orthogonal bases required) to relative accuracy τ.
/// Returns the compressed matrix and stats; `a` is unchanged.
pub fn compress(
    a: &H2Matrix,
    tau: f64,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> (H2Matrix, CompressionStats) {
    compress_logged(a, tau, backend, metrics, &mut PhaseLog::default())
}

/// [`compress`] with per-level phase timing.
pub fn compress_logged(
    a: &H2Matrix,
    tau: f64,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    log: &mut PhaseLog,
) -> (H2Matrix, CompressionStats) {
    compress_logged_with(a, tau, backend, metrics, log, false)
}

/// [`compress_logged`] with optional row/column-tree task parallelism:
/// when `parallel`, the row-tree side (weight downsweep + truncation
/// upsweep of U) runs on its own OS thread while the column-tree side (V)
/// runs on the caller's — both sides only *read* `a` and build private
/// factors, so this is `Send`-safe and every floating-point result is
/// identical to the serial path. The coupling projection (which needs both
/// sides' P maps) stays serial.
pub fn compress_logged_with(
    a: &H2Matrix,
    tau: f64,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    log: &mut PhaseLog,
    parallel: bool,
) -> (H2Matrix, CompressionStats) {
    let depth = a.depth();
    let (tu, tv) = if parallel {
        let mut mt_u = Metrics::new();
        let mut log_u = PhaseLog::default();
        let mut mt_v = Metrics::new();
        let mut log_v = PhaseLog::default();
        // Both sides run on persistent pool threads (no spawn cost per
        // product — dist::pool), U first, V second; results return in job
        // order.
        let (tu, tv) = {
            let (mtu, lgu) = (&mut mt_u, &mut log_u);
            let (mtv, lgv) = (&mut mt_v, &mut log_v);
            let jobs: Vec<Box<dyn FnOnce() -> TruncatedTree + Send + '_>> = vec![
                Box::new(move || {
                    let z_u = weight_downsweep(a, true, backend, mtu, lgu);
                    truncate_tree(a, true, &z_u, tau, backend, mtu, lgu)
                }),
                Box::new(move || {
                    let z_v = weight_downsweep(a, false, backend, mtv, lgv);
                    truncate_tree(a, false, &z_v, tau, backend, mtv, lgv)
                }),
            ];
            let mut results = crate::dist::pool::RankPool::global().scoped(jobs);
            let tv = results.pop().expect("column-tree truncation result");
            let tu = results.pop().expect("row-tree truncation result");
            (tu, tv)
        };
        metrics.merge(&mt_u);
        metrics.merge(&mt_v);
        log.entries.extend(log_u.entries);
        log.entries.extend(log_v.entries);
        (tu, tv)
    } else {
        let z_u = weight_downsweep(a, true, backend, metrics, log);
        let z_v = weight_downsweep(a, false, backend, metrics, log);
        let tu = truncate_tree(a, true, &z_u, tau, backend, metrics, log);
        let tv = truncate_tree(a, false, &z_v, tau, backend, metrics, log);
        (tu, tv)
    };

    // Project couplings: S' = P^U_t · S · (P^V_s)ᵀ.
    let mut coupling = Vec::with_capacity(a.coupling.len());
    for l in 0..a.coupling.len() {
        let timer = Timer::start();
        let ncl = project_level(
            a,
            l,
            &tu.p[l],
            tu.new_ranks[l],
            &tv.p[l],
            tv.new_ranks[l],
            backend,
            metrics,
        );
        coupling.push(ncl);
        log.push("project", l, timer.elapsed());
    }

    // Unify U/V ranks per level (pad the narrower basis with zero columns).
    let new_ranks: Vec<usize> =
        (0..=depth).map(|l| tu.new_ranks[l].max(tv.new_ranks[l])).collect();
    let u = pad_basis(&tu.basis, &new_ranks);
    let v = pad_basis(&tv.basis, &new_ranks);

    let result = H2Matrix { tree: a.tree.clone(), u, v, coupling, dense: a.dense.clone() };
    let stats = CompressionStats {
        old_ranks: a.u.ranks.clone(),
        new_ranks,
        pre_words: a.low_rank_memory_words(),
        post_words: result.low_rank_memory_words(),
        sigma_ref: tu.sigma_ref,
    };
    (result, stats)
}

/// Project one coupling level onto the truncated bases:
/// S' = P^U_t · S · (P^V_s)ᵀ. `pu`/`pv` are the level-l projection maps of
/// the row/column trees with `ku`/`kv` rows per node; the new level uses
/// the unified rank max(ku, kv) (zero-padding the narrower map), as the
/// fixed-shape batch design requires.
#[allow(clippy::too_many_arguments)]
pub fn project_level(
    a: &H2Matrix,
    l: usize,
    pu: &[f64],
    ku: usize,
    pv: &[f64],
    kv: usize,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> crate::tree::CouplingLevel {
    let cl = &a.coupling[l];
    let k = a.rank(l);
    let k_new = ku.max(kv);
    let nb = cl.num_blocks();
    let mut ncl = crate::tree::CouplingLevel::from_pairs(cl.pairs.clone(), 1 << l, k_new);
    if nb > 0 {
        let pu = pad_p(pu, 1 << l, ku, k_new, k);
        let pv = pad_p(pv, 1 << l, kv, k_new, k);
        let t_off: Vec<usize> = cl.pairs.iter().map(|&(t, _)| t as usize * k_new * k).collect();
        let s_off: Vec<usize> = cl.pairs.iter().map(|&(_, s)| s as usize * k_new * k).collect();
        project_level_core(
            nb,
            k,
            k_new,
            &pu,
            &t_off,
            &cl.data,
            &pv,
            &s_off,
            &mut ncl.data,
            backend,
            metrics,
        );
    }
    ncl
}

/// Batched body of [`project_level`], shared with the branch-sliced
/// distributed path: out_q = P^U[t_off_q] · S_q · (P^V[s_off_q])ᵀ for the
/// `nb` k×k blocks of `old_data`, with both P maps already padded to the
/// unified `k_new` rows. The offset vectors address per-pair blocks inside
/// `pu`/`pv` — global node offsets in serial, compact owned+halo maps in a
/// branch slice.
#[allow(clippy::too_many_arguments)]
pub(crate) fn project_level_core(
    nb: usize,
    k: usize,
    k_new: usize,
    pu: &[f64],
    t_off: &[usize],
    old_data: &[f64],
    pv: &[f64],
    s_off: &[usize],
    out: &mut [f64],
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) {
    let blk_off = contiguous_offsets(nb, k * k);
    let mut tmp = vec![0.0; nb * k_new * k];
    backend.batched_gemm(
        GemmDims { nb, m: k_new, k, n: k, trans_a: false, trans_b: false, accumulate: false },
        BatchRef { data: pu, offsets: t_off },
        BatchRef { data: old_data, offsets: &blk_off },
        &mut tmp,
        &contiguous_offsets(nb, k_new * k),
        metrics,
    );
    backend.batched_gemm(
        GemmDims { nb, m: k_new, k, n: k_new, trans_a: false, trans_b: true, accumulate: false },
        BatchRef { data: &tmp, offsets: &contiguous_offsets(nb, k_new * k) },
        BatchRef { data: pv, offsets: s_off },
        out,
        &contiguous_offsets(nb, k_new * k_new),
        metrics,
    );
}

/// Orthogonalize + compress in one call (the full §6.3 pipeline). Returns
/// the compressed matrix and stats; `a` is left orthogonalized.
pub fn compress_full(
    a: &mut H2Matrix,
    tau: f64,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> (H2Matrix, CompressionStats) {
    super::orthogonalize(a, backend, metrics);
    compress(a, tau, backend, metrics)
}

/// [`compress_full`] with per-level phase timing for both stages.
pub fn compress_full_logged(
    a: &mut H2Matrix,
    tau: f64,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    log: &mut PhaseLog,
) -> (H2Matrix, CompressionStats) {
    compress_full_logged_with(a, tau, backend, metrics, log, false)
}

/// [`compress_full_logged`] with the row/column-tree task parallelism of
/// [`orthogonalize_logged_with`](super::orthogonalize::orthogonalize_logged_with)
/// and [`compress_logged_with`] when `parallel`. Bitwise-identical results
/// in both modes.
pub fn compress_full_logged_with(
    a: &mut H2Matrix,
    tau: f64,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    log: &mut PhaseLog,
    parallel: bool,
) -> (H2Matrix, CompressionStats) {
    super::orthogonalize::orthogonalize_logged_with(a, backend, metrics, log, parallel);
    compress_logged_with(a, tau, backend, metrics, log, parallel)
}

/// Zero-pad per-node P maps from k_old_rows rows to k_new rows.
pub(crate) fn pad_p(p: &[f64], nodes: usize, k_rows: usize, k_new: usize, k_cols: usize) -> Vec<f64> {
    if k_rows == k_new {
        return p.to_vec();
    }
    let mut out = vec![0.0; nodes * k_new * k_cols];
    for j in 0..nodes {
        for i in 0..k_rows {
            out[j * k_new * k_cols + i * k_cols..j * k_new * k_cols + (i + 1) * k_cols]
                .copy_from_slice(&p[j * k_rows * k_cols + i * k_cols..j * k_rows * k_cols + (i + 1) * k_cols]);
        }
    }
    out
}

/// Zero-pad a basis tree's per-level ranks up to `ranks` (columns of leaf
/// bases, rows+cols of transfers).
pub(crate) fn pad_basis(tree: &BasisTree, ranks: &[usize]) -> BasisTree {
    if tree.ranks == ranks {
        return tree.clone();
    }
    let depth = tree.depth;
    let mut out = BasisTree::zeros(depth, ranks.to_vec(), tree.leaf_dim, tree.leaf_sizes.clone());
    // leaves: copy first old-k columns
    let (ko, kn) = (tree.ranks[depth], ranks[depth]);
    for j in 0..tree.num_leaves() {
        for i in 0..tree.leaf_dim {
            for c in 0..ko {
                out.leaf_bases[j * tree.leaf_dim * kn + i * kn + c] =
                    tree.leaf_bases[j * tree.leaf_dim * ko + i * ko + c];
            }
        }
    }
    for l in 1..=depth {
        let (ro, co) = (tree.ranks[l], tree.ranks[l - 1]);
        let (rn, cn) = (ranks[l], ranks[l - 1]);
        for j in 0..(1usize << l) {
            for i in 0..ro {
                for c in 0..co {
                    out.transfers[l][j * rn * cn + i * cn + c] =
                        tree.transfers[l][j * ro * co + i * co + c];
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::compression::orthogonalize::tree_is_orthogonal;
    use crate::config::H2Config;
    use crate::construct::{build_h2, dense_kernel_matrix, ExponentialKernel};
    use crate::geometry::PointSet;
    use crate::matvec::{hgemv, HgemvPlan, HgemvWorkspace};
    use crate::util::testing::rel_err;
    use crate::util::Prng;

    fn sample_h2(g: usize) -> H2Matrix {
        let points = PointSet::grid_2d(16, 1.0); // N = 256
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: g };
        build_h2(points, &kernel, &cfg)
    }

    fn matvec_of(a: &H2Matrix, x: &[f64]) -> Vec<f64> {
        let plan = HgemvPlan::new(a, 1);
        let mut ws = HgemvWorkspace::new(a, 1);
        let mut y = vec![0.0; a.n()];
        let mut mt = Metrics::new();
        hgemv(a, &NativeBackend, &plan, x, &mut y, &mut ws, &mut mt);
        y
    }

    #[test]
    fn compression_preserves_matvec_to_tau() {
        let mut a = sample_h2(4); // k = 16 = m
        let mut mt = Metrics::new();
        let mut rng = Prng::new(60);
        let x = rng.normal_vec(a.n());
        let y_ref = matvec_of(&a, &x);
        for tau in [1e-3, 1e-6] {
            let mut b = a.clone();
            let (c, stats) = compress_full(&mut b, tau, &NativeBackend, &mut mt);
            let y = matvec_of(&c, &x);
            let err = rel_err(&y, &y_ref);
            // truncation error accumulates over ~depth levels
            let budget = tau * 100.0;
            assert!(err < budget, "tau={tau}: err={err} ratio={}", stats.ratio());
        }
        let _ = &mut a;
    }

    #[test]
    fn compression_reduces_memory() {
        let mut a = sample_h2(4);
        let mut mt = Metrics::new();
        let (c, stats) = compress_full(&mut a, 1e-3, &NativeBackend, &mut mt);
        assert!(stats.post_words < stats.pre_words, "{stats:?}");
        assert!(stats.ratio() > 1.3, "ratio {}", stats.ratio());
        for l in 0..=c.depth() {
            assert!(c.rank(l) <= a.rank(l));
        }
    }

    #[test]
    fn compressed_basis_is_orthogonal() {
        let mut a = sample_h2(4);
        let mut mt = Metrics::new();
        let (c, _) = compress_full(&mut a, 1e-4, &NativeBackend, &mut mt);
        assert!(tree_is_orthogonal(&c.u, 1e-8));
        assert!(tree_is_orthogonal(&c.v, 1e-8));
    }

    #[test]
    fn tighter_tau_keeps_more_rank() {
        let mut a1 = sample_h2(4);
        let mut a2 = a1.clone();
        let mut mt = Metrics::new();
        let (_, loose) = compress_full(&mut a1, 1e-2, &NativeBackend, &mut mt);
        let (_, tight) = compress_full(&mut a2, 1e-8, &NativeBackend, &mut mt);
        assert!(
            loose.post_words <= tight.post_words,
            "loose {} > tight {}",
            loose.post_words,
            tight.post_words
        );
    }

    #[test]
    fn compress_approximates_kernel_matrix() {
        // End-to-end §6.3 workflow: Chebyshev build -> orthogonalize ->
        // compress -> compare against the dense kernel matrix.
        // g=5 -> k=25 requires leaf_size >= 25.
        let points = PointSet::grid_2d(16, 1.0);
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 32, eta: 0.9, cheb_grid: 5 };
        let mut a = build_h2(points, &kernel, &cfg);
        let dense = dense_kernel_matrix(&a.tree, &ExponentialKernel { dim: 2, corr_len: 0.1 });
        let mut mt = Metrics::new();
        let (c, _) = compress_full(&mut a, 1e-6, &NativeBackend, &mut mt);
        let err = rel_err(&c.to_dense_permuted().data, &dense.data);
        // construction error (g=5) dominates the 1e-6 truncation
        assert!(err < 1e-2, "err {err}");
    }

    #[test]
    fn zero_spectrum_threshold_is_explicit() {
        // An all-zero level must truncate everything (threshold +inf), not
        // compare against a subnormal tau * MIN_POSITIVE that any rounding
        // noise clears.
        assert!(truncation_threshold(1e-6, 0.0).is_infinite());
        assert!(truncation_threshold(1e-6, -0.0).is_infinite());
        assert_eq!(truncation_threshold(1e-6, 2.0), 2e-6);
        assert_eq!(max_rank_below(&[3.0, 2.0, 0.0, 1.0], 2, f64::INFINITY), 0);
        assert_eq!(max_rank_below(&[3.0, 2.0, 0.0, 1.0], 2, 0.5), 2);
    }

    #[test]
    fn all_zero_coupling_collapses_to_minimum_rank() {
        // Zero out every coupling block: the weight downsweep then sees a
        // zero spectrum (sigma_ref = 0) on both trees, and the regression
        // is that compression collapses to the rank floor of 1 per level
        // instead of retaining full rank against a subnormal threshold.
        let mut a = sample_h2(4);
        for cl in &mut a.coupling {
            for v in &mut cl.data {
                *v = 0.0;
            }
        }
        let mut mt = Metrics::new();
        let (c, stats) = compress_full(&mut a, 1e-6, &NativeBackend, &mut mt);
        assert_eq!(stats.sigma_ref, 0.0);
        for l in 0..=c.depth() {
            assert_eq!(c.rank(l), 1, "level {l} kept rank {}", c.rank(l));
        }
        assert!(stats.post_words < stats.pre_words);
    }

    #[test]
    fn dense_blocks_untouched() {
        let mut a = sample_h2(4);
        let before = a.dense.data.clone();
        let mut mt = Metrics::new();
        let (c, _) = compress_full(&mut a, 1e-3, &NativeBackend, &mut mt);
        assert_eq!(c.dense.data, before);
    }
}
