//! Basis orthogonalization (§5.2 end): an upsweep QR pass through each
//! basis tree. Leaf bases are QR-factorized; at inner levels the stacked
//! child products [R_c1·E_c1; R_c2·E_c2] are QR-factorized, their Q halves
//! become the new transfer matrices and R propagates up. Coupling blocks
//! absorb the R factors (S ← R_t^U · S · R_s^Vᵀ), so the matrix is
//! unchanged to machine precision.

use super::PhaseLog;
use crate::backend::{contiguous_offsets, BatchRef, ComputeBackend, GemmDims};
use crate::metrics::Metrics;
use crate::tree::{BasisTree, H2Matrix};
use crate::util::Timer;

/// R factors produced per level: `r[l]` holds 2^l blocks of k_l × k_l.
pub type LevelR = Vec<Vec<f64>>;

/// Orthogonalize one basis tree in place; returns the per-level R factors.
pub fn orthogonalize_tree(
    tree: &mut BasisTree,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> LevelR {
    orthogonalize_tree_logged(tree, backend, metrics, &mut PhaseLog::default())
}

/// [`orthogonalize_tree`] with per-level phase timing.
pub fn orthogonalize_tree_logged(
    tree: &mut BasisTree,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    log: &mut PhaseLog,
) -> LevelR {
    let depth = tree.depth;
    let mut r: LevelR = vec![Vec::new(); depth + 1];

    let t = Timer::start();
    r[depth] = orth_leaf_level(tree, backend, metrics);
    log.push("orth_leaf_qr", depth, t.elapsed());

    // Inner levels, children l+1 -> parents l.
    for l in (0..depth).rev() {
        let t = Timer::start();
        r[l] = orth_transfer_level(tree, backend, metrics, l, &r[l + 1]);
        log.push("orth_stack", l, t.elapsed());
    }
    r
}

/// Leaf stage of the orthogonalization upsweep: batched QR of the leaf
/// bases; leaves become their Q factors, the R factors are returned.
pub fn orth_leaf_level(
    tree: &mut BasisTree,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) -> Vec<f64> {
    let depth = tree.depth;
    let k_leaf = tree.ranks[depth];
    let m_pad = tree.leaf_dim;
    assert!(
        m_pad >= k_leaf,
        "orthogonalization requires leaf_size >= rank (got m_pad={m_pad} < k={k_leaf})"
    );
    let leaves = tree.num_leaves();
    let mut q = vec![0.0; leaves * m_pad * k_leaf];
    let mut r_leaf = vec![0.0; leaves * k_leaf * k_leaf];
    backend.batched_qr(leaves, m_pad, k_leaf, &tree.leaf_bases, &mut q, &mut r_leaf, metrics);
    tree.leaf_bases.copy_from_slice(&q);
    r_leaf
}

/// One inner level of the orthogonalization upsweep (children l+1 ->
/// parents l): QR of the stacked [R_c1·E_c1; R_c2·E_c2] pairs. The level-l+1
/// transfers become the Q halves; the parents' R factors are returned.
pub fn orth_transfer_level(
    tree: &mut BasisTree,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    l: usize,
    r_child: &[f64],
) -> Vec<f64> {
    let k_c = tree.ranks[l + 1];
    let k_l = tree.ranks[l];
    assert!(2 * k_c >= k_l, "stacked transfer QR needs 2*k_child >= k_parent");
    let nb_parent = 1usize << l;
    let nb_child = 1usize << (l + 1);
    // stack[i] = [R_{2i} E_{2i}; R_{2i+1} E_{2i+1}]  (2k_c × k_l)
    let mut stack = vec![0.0; nb_parent * 2 * k_c * k_l];
    let a_off = contiguous_offsets(nb_child, k_c * k_c);
    let b_off = contiguous_offsets(nb_child, k_c * k_l);
    let c_off: Vec<usize> =
        (0..nb_child).map(|c| (c / 2) * 2 * k_c * k_l + (c % 2) * k_c * k_l).collect();
    backend.batched_gemm(
        GemmDims { nb: nb_child, m: k_c, k: k_c, n: k_l, trans_a: false, trans_b: false, accumulate: false },
        BatchRef { data: r_child, offsets: &a_off },
        BatchRef { data: &tree.transfers[l + 1], offsets: &b_off },
        &mut stack,
        &c_off,
        metrics,
    );
    let mut qs = vec![0.0; nb_parent * 2 * k_c * k_l];
    let mut rs = vec![0.0; nb_parent * k_l * k_l];
    backend.batched_qr(nb_parent, 2 * k_c, k_l, &stack, &mut qs, &mut rs, metrics);
    // New transfers = Q halves.
    for c in 0..nb_child {
        let src = (c / 2) * 2 * k_c * k_l + (c % 2) * k_c * k_l;
        tree.transfers[l + 1][c * k_c * k_l..(c + 1) * k_c * k_l]
            .copy_from_slice(&qs[src..src + k_c * k_l]);
    }
    rs
}

/// Orthogonalize both bases of `a` and absorb the R factors into the
/// coupling blocks. The represented matrix is unchanged.
pub fn orthogonalize(a: &mut H2Matrix, backend: &dyn ComputeBackend, metrics: &mut Metrics) {
    orthogonalize_logged(a, backend, metrics, &mut PhaseLog::default())
}

/// [`orthogonalize`] with per-level phase timing.
pub fn orthogonalize_logged(
    a: &mut H2Matrix,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    log: &mut PhaseLog,
) {
    orthogonalize_logged_with(a, backend, metrics, log, false)
}

/// [`orthogonalize_logged`] with optional row/column-tree task
/// parallelism: when `parallel`, the U- and V-tree QR upsweeps run on two
/// OS threads — they mutate disjoint state (`a.u` vs `a.v`), so this is
/// `Send`-safe by construction and every floating-point result, metric
/// total and log entry order is identical to the serial path. The R
/// absorption into the coupling blocks stays serial (it needs both trees).
pub fn orthogonalize_logged_with(
    a: &mut H2Matrix,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    log: &mut PhaseLog,
    parallel: bool,
) {
    let (r_u, r_v) = if parallel {
        let (u_tree, v_tree) = (&mut a.u, &mut a.v);
        let mut mt_u = Metrics::new();
        let mut log_u = PhaseLog::default();
        let mut mt_v = Metrics::new();
        let mut log_v = PhaseLog::default();
        // Both trees orthogonalize on persistent pool threads (no spawn
        // cost per product — dist::pool); results return in job order.
        let (r_u, r_v) = {
            let (mtu, lgu) = (&mut mt_u, &mut log_u);
            let (mtv, lgv) = (&mut mt_v, &mut log_v);
            let jobs: Vec<Box<dyn FnOnce() -> LevelR + Send + '_>> = vec![
                Box::new(move || orthogonalize_tree_logged(u_tree, backend, mtu, lgu)),
                Box::new(move || orthogonalize_tree_logged(v_tree, backend, mtv, lgv)),
            ];
            let mut results = crate::dist::pool::RankPool::global().scoped(jobs);
            let r_v = results.pop().expect("V-tree R factors");
            let r_u = results.pop().expect("U-tree R factors");
            (r_u, r_v)
        };
        metrics.merge(&mt_u);
        metrics.merge(&mt_v);
        log.entries.extend(log_u.entries);
        log.entries.extend(log_v.entries);
        (r_u, r_v)
    } else {
        let r_u = orthogonalize_tree_logged(&mut a.u, backend, metrics, log);
        let r_v = orthogonalize_tree_logged(&mut a.v, backend, metrics, log);
        (r_u, r_v)
    };

    // S_ts <- R^U_t · S_ts · (R^V_s)^T, level by level.
    for l in 0..a.coupling.len() {
        let t = Timer::start();
        if a.coupling[l].num_blocks() == 0 {
            continue;
        }
        absorb_r_level(a, backend, metrics, l, &r_u[l], &r_v[l]);
        log.push("orth_project", l, t.elapsed());
    }
}

/// Absorb the level-l R factors into the level-l coupling blocks:
/// S_ts <- R^U_t · S_ts · (R^V_s)ᵀ.
pub fn absorb_r_level(
    a: &mut H2Matrix,
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
    l: usize,
    r_u: &[f64],
    r_v: &[f64],
) {
    let nb = a.coupling[l].num_blocks();
    if nb == 0 {
        return;
    }
    let k = a.rank(l);
    let pairs = a.coupling[l].pairs.clone();
    let t_off: Vec<usize> = pairs.iter().map(|&(t, _)| t as usize * k * k).collect();
    let s_off: Vec<usize> = pairs.iter().map(|&(_, s)| s as usize * k * k).collect();
    absorb_level_core(&mut a.coupling[l].data, nb, k, r_u, &t_off, r_v, &s_off, backend, metrics);
}

/// Batched body of [`absorb_r_level`], shared with the branch-sliced
/// distributed path: data_q <- R^U[t_off_q] · data_q · (R^V[s_off_q])ᵀ for
/// the `nb` k×k blocks of `data`. The offset vectors address per-pair R
/// blocks inside `r_u`/`r_v` — global node offsets in serial, compact
/// owned+halo maps in a branch slice.
#[allow(clippy::too_many_arguments)]
pub(crate) fn absorb_level_core(
    data: &mut [f64],
    nb: usize,
    k: usize,
    r_u: &[f64],
    t_off: &[usize],
    r_v: &[f64],
    s_off: &[usize],
    backend: &dyn ComputeBackend,
    metrics: &mut Metrics,
) {
    let blk_off = contiguous_offsets(nb, k * k);
    let mut tmp = vec![0.0; nb * k * k];
    backend.batched_gemm(
        GemmDims { nb, m: k, k, n: k, trans_a: false, trans_b: false, accumulate: false },
        BatchRef { data: r_u, offsets: t_off },
        BatchRef { data: &*data, offsets: &blk_off },
        &mut tmp,
        &blk_off,
        metrics,
    );
    backend.batched_gemm(
        GemmDims { nb, m: k, k, n: k, trans_a: false, trans_b: true, accumulate: false },
        BatchRef { data: &tmp, offsets: &blk_off },
        BatchRef { data: r_v, offsets: s_off },
        data,
        &blk_off,
        metrics,
    );
}

/// Test helper: check every explicit basis of the tree has orthonormal
/// columns (leaf level and all inner levels), to tolerance `tol`.
/// All-zero columns are accepted: rank unification after compression pads
/// the narrower of U/V with zero columns (see `truncate::pad_basis`).
pub fn tree_is_orthogonal(tree: &BasisTree, tol: f64) -> bool {
    for l in (0..=tree.depth).rev() {
        let k = tree.ranks[l];
        for j in 0..(1usize << l) {
            let basis = tree.explicit_basis(l, j);
            for p in 0..k {
                for q in 0..k {
                    let dot: f64 = basis.iter().map(|row| row[p] * row[q]).sum();
                    let want = if p == q { 1.0 } else { 0.0 };
                    let zero_col = p == q && dot.abs() <= tol; // padded column
                    if (dot - want).abs() > tol && !zero_col {
                        return false;
                    }
                }
            }
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::config::H2Config;
    use crate::construct::{build_h2, ExponentialKernel};
    use crate::geometry::PointSet;
    use crate::matvec::{hgemv, HgemvPlan, HgemvWorkspace};
    use crate::util::testing::rel_err;
    use crate::util::Prng;

    fn sample_h2() -> H2Matrix {
        let points = PointSet::grid_2d(16, 1.0); // N = 256
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 }; // k=9 <= m=16
        build_h2(points, &kernel, &cfg)
    }

    fn matvec_of(a: &H2Matrix, x: &[f64]) -> Vec<f64> {
        let plan = HgemvPlan::new(a, 1);
        let mut ws = HgemvWorkspace::new(a, 1);
        let mut y = vec![0.0; a.n()];
        let mut mt = Metrics::new();
        hgemv(a, &NativeBackend, &plan, x, &mut y, &mut ws, &mut mt);
        y
    }

    #[test]
    fn bases_become_orthonormal() {
        let mut a = sample_h2();
        assert!(!tree_is_orthogonal(&a.u, 1e-8), "Chebyshev basis should not start orthogonal");
        let mut mt = Metrics::new();
        orthogonalize(&mut a, &NativeBackend, &mut mt);
        assert!(tree_is_orthogonal(&a.u, 1e-8));
        assert!(tree_is_orthogonal(&a.v, 1e-8));
    }

    #[test]
    fn matvec_invariant_under_orthogonalization() {
        let mut a = sample_h2();
        let n = a.n();
        let mut rng = Prng::new(50);
        let x = rng.normal_vec(n);
        let y_before = matvec_of(&a, &x);
        let mut mt = Metrics::new();
        orthogonalize(&mut a, &NativeBackend, &mut mt);
        let y_after = matvec_of(&a, &x);
        let err = rel_err(&y_after, &y_before);
        assert!(err < 1e-11, "orthogonalization changed the matrix: {err}");
    }

    #[test]
    fn orthogonalization_idempotent_in_effect() {
        // A second orthogonalization must keep the matrix unchanged and the
        // bases orthonormal (R factors ≈ identity up to signs).
        let mut a = sample_h2();
        let mut mt = Metrics::new();
        orthogonalize(&mut a, &NativeBackend, &mut mt);
        let mut rng = Prng::new(51);
        let x = rng.normal_vec(a.n());
        let y1 = matvec_of(&a, &x);
        orthogonalize(&mut a, &NativeBackend, &mut mt);
        let y2 = matvec_of(&a, &x);
        assert!(rel_err(&y2, &y1) < 1e-11);
        assert!(tree_is_orthogonal(&a.u, 1e-8));
    }

    #[test]
    fn memory_unchanged_by_orthogonalization() {
        let mut a = sample_h2();
        let before = a.memory_words();
        let mut mt = Metrics::new();
        orthogonalize(&mut a, &NativeBackend, &mut mt);
        assert_eq!(a.memory_words(), before);
    }
}
