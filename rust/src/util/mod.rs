//! Small self-contained utilities: PRNG, timers, chrome-trace emission and a
//! mini property-testing harness (the offline build image has no
//! `rand`/`criterion`/`proptest`; see DESIGN.md "Substitutions").

pub mod prng;
pub mod testing;
pub mod timer;
pub mod trace;

pub use prng::Prng;
pub use timer::Timer;
