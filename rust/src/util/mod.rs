//! Small self-contained utilities: PRNG, timers, chrome-trace emission, a
//! mini property-testing harness (the offline build image has no
//! `rand`/`criterion`/`proptest`; see DESIGN.md "Substitutions") and the
//! persistent data-parallel worker pool behind the batched native backend
//! ([`parallel`]).

pub mod parallel;
pub mod prng;
pub mod testing;
pub mod timer;
pub mod trace;

pub use prng::Prng;
pub use timer::Timer;
