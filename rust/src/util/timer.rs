//! Wall-clock timing helpers and the paper's measurement protocol:
//! "every point in every plot has been generated as the average of 10 runs
//! after discarding the fastest and slowest timings" (§6.1).
//!
//! Timers read [`crate::obs::span::now_ns`] — the same process-local
//! monotonic epoch spans are stamped against — so timer-based phase
//! reports and recorded traces share one clock domain and a timer start
//! can be placed on a merged timeline directly.

use crate::obs::span::now_ns;

/// Simple wall-clock timer on the span epoch.
pub struct Timer {
    start_ns: u64,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start_ns: now_ns() }
    }

    /// Elapsed seconds since construction.
    pub fn elapsed(&self) -> f64 {
        now_ns().saturating_sub(self.start_ns) as f64 * 1e-9
    }

    /// Construction stamp in span-epoch nanoseconds — directly comparable
    /// to `Span::start_ns` of spans recorded in this process.
    pub fn start_ns(&self) -> u64 {
        self.start_ns
    }
}

/// Run `f` once for warmup, then `runs` times; return the trimmed mean of
/// the measured times (drop the single fastest and single slowest run), in
/// seconds. This is the paper's §6.1 protocol.
pub fn trimmed_mean_time<F: FnMut()>(runs: usize, mut f: F) -> f64 {
    assert!(runs >= 3, "need >=3 runs to trim");
    f(); // warmup
    let mut times = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Timer::start();
        f();
        times.push(t.elapsed());
    }
    trimmed_mean(&times)
}

/// Trimmed mean of a set of samples: drop min and max, average the rest.
pub fn trimmed_mean(samples: &[f64]) -> f64 {
    assert!(samples.len() >= 3);
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let inner = &s[1..s.len() - 1];
    inner.iter().sum::<f64>() / inner.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trimmed_mean_drops_extremes() {
        let samples = [100.0, 1.0, 2.0, 3.0, 0.0];
        assert!((trimmed_mean(&samples) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed();
        let b = t.elapsed();
        assert!(b >= a);
        assert!(a >= 0.0);
    }

    #[test]
    fn timer_shares_the_span_epoch() {
        let t = Timer::start();
        let stamp = now_ns();
        assert!(t.start_ns() <= stamp, "timer start must be on the span clock");
    }

    #[test]
    fn trimmed_mean_time_runs() {
        let mut count = 0;
        let t = trimmed_mean_time(3, || count += 1);
        assert_eq!(count, 4); // warmup + 3
        assert!(t >= 0.0);
    }
}
