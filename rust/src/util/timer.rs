//! Wall-clock timing helpers and the paper's measurement protocol:
//! "every point in every plot has been generated as the average of 10 runs
//! after discarding the fastest and slowest timings" (§6.1).

use std::time::Instant;

/// Simple wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Timer { start: Instant::now() }
    }

    /// Elapsed seconds since construction.
    pub fn elapsed(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Run `f` once for warmup, then `runs` times; return the trimmed mean of
/// the measured times (drop the single fastest and single slowest run), in
/// seconds. This is the paper's §6.1 protocol.
pub fn trimmed_mean_time<F: FnMut()>(runs: usize, mut f: F) -> f64 {
    assert!(runs >= 3, "need >=3 runs to trim");
    f(); // warmup
    let mut times = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t = Timer::start();
        f();
        times.push(t.elapsed());
    }
    trimmed_mean(&times)
}

/// Trimmed mean of a set of samples: drop min and max, average the rest.
pub fn trimmed_mean(samples: &[f64]) -> f64 {
    assert!(samples.len() >= 3);
    let mut s = samples.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let inner = &s[1..s.len() - 1];
    inner.iter().sum::<f64>() / inner.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trimmed_mean_drops_extremes() {
        let samples = [100.0, 1.0, 2.0, 3.0, 0.0];
        assert!((trimmed_mean(&samples) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn timer_monotone() {
        let t = Timer::start();
        let a = t.elapsed();
        let b = t.elapsed();
        assert!(b >= a);
    }

    #[test]
    fn trimmed_mean_time_runs() {
        let mut count = 0;
        let t = trimmed_mean_time(3, || count += 1);
        assert_eq!(count, 4); // warmup + 3
        assert!(t >= 0.0);
    }
}
