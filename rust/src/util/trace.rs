//! Chrome-trace (about:tracing / Perfetto) event emission, used to
//! regenerate the paper's Fig. 8 execution timeline: per-rank streams with
//! compute kernels, transfer phases and MPI gaps in *virtual time*.

use std::fmt::Write as _;

/// Escape a string for inclusion inside a JSON string literal: quotes,
/// backslashes, and all control characters (U+0000..U+001F must be escaped
/// per RFC 8259 — a raw tab or newline in an event name would otherwise
/// produce invalid JSON that Perfetto rejects).
pub fn escape_json(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out
}

/// One complete ("X") trace event.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    /// Event name, e.g. "upsweep L3" or "MPI exchange".
    pub name: String,
    /// Category: "compute", "comm", "transfer", "lowprio".
    pub cat: String,
    /// Process id: we map rank -> pid.
    pub pid: usize,
    /// Thread id: we map stream (0 main, 1 comm, 2 low-priority) -> tid.
    pub tid: usize,
    /// Start, microseconds (virtual time).
    pub ts_us: f64,
    /// Duration, microseconds.
    pub dur_us: f64,
}

/// Collects events and serializes them to the Chrome trace JSON format.
/// (Hand-rolled writer: no serde in the offline image.)
#[derive(Default, Debug)]
pub struct TraceCollector {
    pub events: Vec<TraceEvent>,
}

impl TraceCollector {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, cat: &str, pid: usize, tid: usize, ts_s: f64, dur_s: f64) {
        self.events.push(TraceEvent {
            name: name.to_string(),
            cat: cat.to_string(),
            pid,
            tid,
            ts_us: ts_s * 1e6,
            dur_us: dur_s * 1e6,
        });
    }

    /// Serialize to Chrome trace JSON (array-of-events form).
    pub fn to_json(&self) -> String {
        let mut out = String::from("[\n");
        for (i, e) in self.events.iter().enumerate() {
            let comma = if i + 1 == self.events.len() { "" } else { "," };
            writeln!(
                out,
                "  {{\"name\": \"{}\", \"cat\": \"{}\", \"ph\": \"X\", \"pid\": {}, \"tid\": {}, \"ts\": {:.3}, \"dur\": {:.3}}}{}",
                escape_json(&e.name),
                escape_json(&e.cat),
                e.pid,
                e.tid,
                e.ts_us,
                e.dur_us,
                comma
            )
            .unwrap();
        }
        out.push(']');
        out
    }

    /// Render an ASCII timeline (one row per (pid,tid)), for quick terminal
    /// inspection of overlap behaviour; `width` columns cover [0, t_max].
    pub fn ascii_timeline(&self, width: usize) -> String {
        if self.events.is_empty() {
            return String::new();
        }
        let t_max = self
            .events
            .iter()
            .map(|e| e.ts_us + e.dur_us)
            .fold(0.0_f64, f64::max);
        let mut keys: Vec<(usize, usize)> = self.events.iter().map(|e| (e.pid, e.tid)).collect();
        keys.sort_unstable();
        keys.dedup();
        let mut out = String::new();
        for (pid, tid) in keys {
            let mut row = vec![' '; width];
            for e in self.events.iter().filter(|e| e.pid == pid && e.tid == tid) {
                let a = ((e.ts_us / t_max) * width as f64) as usize;
                let b = (((e.ts_us + e.dur_us) / t_max) * width as f64).ceil() as usize;
                let ch = match e.cat.as_str() {
                    "compute" => '#',
                    "comm" => '~',
                    "transfer" => '=',
                    "lowprio" => '.',
                    _ => '?',
                };
                for c in row.iter_mut().take(b.min(width)).skip(a.min(width)) {
                    *c = ch;
                }
            }
            writeln!(out, "r{pid}/s{tid} |{}|", row.iter().collect::<String>()).unwrap();
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrip_shape() {
        let mut t = TraceCollector::new();
        t.add("gemm", "compute", 0, 0, 0.0, 1e-3);
        t.add("mpi", "comm", 0, 1, 1e-3, 2e-3);
        let j = t.to_json();
        assert!(j.starts_with('['));
        assert!(j.ends_with(']'));
        assert!(j.contains("\"ph\": \"X\""));
        assert!(j.contains("\"dur\": 1000.000"));
    }

    #[test]
    fn json_escapes_hostile_names_and_cats() {
        use crate::util::testing::parse_json;
        let mut t = TraceCollector::new();
        // Quotes, backslashes, and control characters in BOTH name and cat:
        // cat was previously emitted raw, so a tab or quote there produced
        // invalid JSON.
        t.add("up\"sweep\\L3\nnext\ttab", "com\"m\u{1}", 0, 0, 0.0, 1e-3);
        t.add("plain", "compute", 1, 2, 1e-3, 1e-3);
        let parsed = parse_json(&t.to_json()).expect("emitted trace must be strict JSON");
        let events = parsed.as_arr().expect("top level is an array");
        assert_eq!(events.len(), 2);
        // Escapes decode back to the original strings.
        assert_eq!(
            events[0].get("name").unwrap().as_str(),
            Some("up\"sweep\\L3\nnext\ttab")
        );
        assert_eq!(events[0].get("cat").unwrap().as_str(), Some("com\"m\u{1}"));
        assert_eq!(events[1].get("pid").unwrap().as_f64(), Some(1.0));
        assert_eq!(events[1].get("ts").unwrap().as_f64(), Some(1000.0));
    }

    #[test]
    fn escape_json_covers_control_range() {
        assert_eq!(escape_json("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(escape_json("\n\r\t"), "\\n\\r\\t");
        assert_eq!(escape_json("\u{0}\u{1f}"), "\\u0000\\u001f");
        assert_eq!(escape_json("héllo — ok"), "héllo — ok");
    }

    #[test]
    fn ascii_has_rows_per_stream() {
        let mut t = TraceCollector::new();
        t.add("a", "compute", 0, 0, 0.0, 1.0);
        t.add("b", "comm", 1, 0, 0.5, 0.5);
        let a = t.ascii_timeline(40);
        assert_eq!(a.lines().count(), 2);
        assert!(a.contains('#'));
        assert!(a.contains('~'));
    }

    #[test]
    fn empty_timeline_is_empty() {
        let t = TraceCollector::new();
        assert!(t.ascii_timeline(10).is_empty());
    }
}
