//! Deterministic xoshiro256** PRNG. Used everywhere randomness is needed
//! (accuracy sampling, synthetic vectors, property tests) so every run and
//! every test is reproducible from a seed.

/// xoshiro256** by Blackman & Vigna (public domain reference implementation).
#[derive(Clone, Debug)]
pub struct Prng {
    s: [u64; 4],
}

impl Prng {
    /// Seed via splitmix64 so any u64 (including 0) gives a good state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Prng { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        // 53 high bits -> double in [0,1)
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Vector of iid uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.range(lo, hi)).collect()
    }

    /// Vector of iid standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Prng::new(42);
        let mut b = Prng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Prng::new(1);
        let mut b = Prng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut p = Prng::new(7);
        for _ in 0..10_000 {
            let u = p.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut p = Prng::new(9);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| p.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut p = Prng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| p.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut p = Prng::new(13);
        for _ in 0..1000 {
            assert!(p.below(17) < 17);
        }
    }
}
