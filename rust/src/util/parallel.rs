//! Persistent data-parallel worker pool for the batched backends.
//!
//! The paper's single-GPU rates come from running each marshaled batch of
//! small dense blocks on thousands of GPU threads at once (MAGMA/KBLAS);
//! the CPU-side equivalent is a pool of OS threads splitting each batch's
//! *blocks* between them. This pool differs from [`crate::dist::pool::RankPool`]
//! (long-lived rank bodies, one job per thread, jobs boxed per batch) in
//! three ways dictated by the GEMM hot path:
//!
//! - **allocation-free dispatch**: [`ParallelPool::run`] publishes a
//!   borrowed `&dyn Fn` chunk closure through a mutex-guarded job slot and
//!   wakes the parked workers with a condvar — no per-call boxing, no
//!   channel sends. The batched-GEMM acceptance bar is *zero* allocations
//!   per dispatched call.
//! - **dynamic chunking**: workers (and the calling thread, which
//!   participates) claim chunks of block indices from an atomic counter,
//!   so a batch whose blocks vary in cost still balances.
//! - **contended calls degrade, not deadlock**: `run` takes a dispatch
//!   try-lock; a second caller (e.g. another rank thread of the threaded
//!   executor mid-product) finds the pool busy and simply executes its
//!   batch inline on its own thread. Nested parallelism (P rank threads ×
//!   pool width) therefore never oversubscribes beyond `P + width`
//!   threads, and the pool can never deadlock on itself — the thread
//!   budget policy documented in [`crate::backend`].
//!
//! # Safety model
//!
//! `run` erases the chunk closure's lifetime to park it in the shared job
//! slot (the same transmute contract as `RankPool::scoped`): it does not
//! return — not even by unwinding — until every worker has retired from
//! the epoch, so the borrow can never dangle. Worker panics are caught
//! (the worker survives for the next batch) and re-raised on the caller
//! after the batch completes.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::thread::JoinHandle;

/// Wide pointer to the caller's chunk closure, lifetime-erased so it can
/// sit in the shared job slot. Only dereferenced between job publication
/// and the epoch's completion; `run` blocks (even on panic paths) until
/// every worker has retired, so the pointee always outlives its use.
#[derive(Clone, Copy)]
struct TaskRef(*const (dyn Fn(usize, usize) + Sync));

// SAFETY: the pointee is `Sync` (shared-callable from any thread) and the
// epoch protocol above keeps it alive for as long as any worker can
// dereference it.
unsafe impl Send for TaskRef {}

struct JobSlot {
    /// Bumped once per dispatched batch; a worker runs one chunk loop per
    /// observed epoch.
    epoch: u64,
    /// The published chunk closure (`None` outside a dispatch).
    task: Option<TaskRef>,
    /// Number of block items in the current batch.
    n_items: usize,
    /// Chunk granularity of the current batch.
    chunk: usize,
    /// Workers still inside the current epoch's chunk loop.
    active: usize,
    /// Set when a worker chunk panicked (re-raised by the caller).
    panicked: bool,
    /// Pool is being dropped; workers exit.
    shutdown: bool,
}

struct Shared {
    slot: Mutex<JobSlot>,
    /// Wakes parked workers when a batch is published (or on shutdown).
    start: Condvar,
    /// Wakes the dispatching caller when the last worker retires.
    done: Condvar,
    /// Next unclaimed block index of the current batch.
    next: AtomicUsize,
}

/// A persistent pool of parked worker threads executing batches of
/// independent blocks. See the module docs for the dispatch protocol.
pub struct ParallelPool {
    shared: Arc<Shared>,
    /// Dispatch width: spawned workers + the calling thread.
    width: usize,
    /// At most one batch dispatch at a time; contenders run inline.
    dispatch: Mutex<()>,
    handles: Vec<JoinHandle<()>>,
}

impl ParallelPool {
    /// A pool of total width `threads` (the calling thread participates,
    /// so `threads - 1` workers are spawned; width 0 or 1 spawns none and
    /// [`run`](ParallelPool::run) executes inline).
    pub fn new(threads: usize) -> ParallelPool {
        let width = threads.max(1);
        let shared = Arc::new(Shared {
            slot: Mutex::new(JobSlot {
                epoch: 0,
                task: None,
                n_items: 0,
                chunk: 1,
                active: 0,
                panicked: false,
                shutdown: false,
            }),
            start: Condvar::new(),
            done: Condvar::new(),
            next: AtomicUsize::new(0),
        });
        let handles = (1..width)
            .map(|i| {
                let shared = Arc::clone(&shared);
                std::thread::Builder::new()
                    .name(format!("h2opus-par-{i}"))
                    .spawn(move || worker_loop(&shared))
                    .expect("spawning parallel pool worker")
            })
            .collect();
        ParallelPool { shared, width, dispatch: Mutex::new(()), handles }
    }

    /// The process-wide pool used by the batched native backend, sized by
    /// [`crate::backend::backend_threads`] at first use (set the budget —
    /// env var or [`crate::backend::set_backend_threads`] — before the
    /// first batched call).
    pub fn global() -> &'static ParallelPool {
        static GLOBAL: OnceLock<ParallelPool> = OnceLock::new();
        GLOBAL.get_or_init(|| ParallelPool::new(crate::backend::backend_threads()))
    }

    /// Total dispatch width (workers + caller).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Execute `f(lo, hi)` over a partition of `0..n_items`, splitting the
    /// chunks across the pool width (the calling thread participates) and
    /// returning once every chunk has completed.
    ///
    /// Every index in `0..n_items` is passed to exactly one invocation, so
    /// per-item work runs exactly once regardless of width — callers rely
    /// on this for bitwise parity with the serial loop. If another batch
    /// is already dispatched on this pool (a concurrent rank thread), the
    /// whole batch runs inline on the calling thread instead of blocking.
    ///
    /// Panics in `f` (on any thread) are re-raised here after the batch
    /// has fully completed; the pool survives for the next batch.
    pub fn run(&self, n_items: usize, f: &(dyn Fn(usize, usize) + Sync)) {
        if n_items == 0 {
            return;
        }
        if self.width <= 1 || self.handles.is_empty() {
            f(0, n_items);
            return;
        }
        // One dispatch at a time. A contended (or poisoned — a previous
        // caller panicked while dispatching) lock falls back to inline
        // execution: correctness never depends on winning the pool.
        let Ok(guard) = self.dispatch.try_lock() else {
            f(0, n_items);
            return;
        };
        // ~4 chunks per thread balances uneven block costs without
        // starving the atomic counter.
        let chunk = (n_items / (self.width * 4)).max(1);
        let workers = self.handles.len();
        {
            let mut slot = self.shared.slot.lock().expect("pool slot");
            debug_assert!(slot.task.is_none() && slot.active == 0);
            self.shared.next.store(0, Ordering::Relaxed);
            // SAFETY: see `TaskRef` — this call waits for `active == 0`
            // below before returning or unwinding, so the erased borrow
            // outlives every dereference.
            let erased = unsafe {
                std::mem::transmute::<
                    &(dyn Fn(usize, usize) + Sync),
                    *const (dyn Fn(usize, usize) + Sync),
                >(f)
            };
            slot.task = Some(TaskRef(erased));
            slot.n_items = n_items;
            slot.chunk = chunk;
            slot.active = workers;
            slot.epoch += 1;
            self.shared.start.notify_all();
        }
        // The caller participates in the chunk loop. Catch its panic so
        // the wait below always happens — unwinding past it would dangle
        // the published borrow.
        let caller = catch_unwind(AssertUnwindSafe(|| {
            chunk_loop(&self.shared.next, n_items, chunk, f);
        }));
        let worker_panicked = {
            let mut slot = self.shared.slot.lock().expect("pool slot");
            while slot.active > 0 {
                slot = self.shared.done.wait(slot).expect("pool slot");
            }
            slot.task = None;
            std::mem::replace(&mut slot.panicked, false)
        };
        drop(guard);
        if let Err(payload) = caller {
            resume_unwind(payload);
        }
        if worker_panicked {
            panic!("h2opus parallel pool: a worker chunk panicked (see stderr)");
        }
    }
}

impl Drop for ParallelPool {
    fn drop(&mut self) {
        {
            let mut slot = self.shared.slot.lock().expect("pool slot");
            slot.shutdown = true;
            self.shared.start.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Claim and execute chunks until the batch's index space is exhausted.
fn chunk_loop(next: &AtomicUsize, n_items: usize, chunk: usize, f: &(dyn Fn(usize, usize) + Sync)) {
    loop {
        let lo = next.fetch_add(chunk, Ordering::Relaxed);
        if lo >= n_items {
            return;
        }
        f(lo, (lo + chunk).min(n_items));
    }
}

fn worker_loop(shared: &Shared) {
    let mut last_epoch = 0u64;
    loop {
        let (task, n_items, chunk) = {
            let mut slot = shared.slot.lock().expect("pool slot");
            loop {
                if slot.shutdown {
                    return;
                }
                if slot.epoch != last_epoch {
                    break;
                }
                slot = shared.start.wait(slot).expect("pool slot");
            }
            last_epoch = slot.epoch;
            (slot.task.expect("published task"), slot.n_items, slot.chunk)
        };
        // SAFETY: the dispatching caller cannot pass its `active == 0`
        // wait until this worker decrements below, so the pointee is
        // alive for the whole chunk loop.
        let f = unsafe { &*task.0 };
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            chunk_loop(&shared.next, n_items, chunk, f);
        }));
        let mut slot = shared.slot.lock().expect("pool slot");
        if outcome.is_err() {
            slot.panicked = true;
        }
        slot.active -= 1;
        if slot.active == 0 {
            shared.done.notify_all();
        }
    }
}

/// A shared output buffer written at caller-guaranteed pairwise-disjoint
/// ranges from multiple threads.
///
/// # The conflict-free-offsets contract
///
/// The batched backends may hand distinct `[off, off + len)` windows of
/// one `&mut [f64]` to different pool threads. That is sound if and only
/// if the windows outstanding at any one time are pairwise disjoint — in
/// this codebase, the §3.2 *conflict-free batch ordering* guarantees
/// exactly that: within one batched call, every output offset is distinct
/// and blocks have one fixed size, so the windows cannot overlap (the
/// batched-GEMM entry points `debug_assert` this). Bounds are always
/// checked; disjointness is the caller's contract.
pub struct DisjointOut {
    ptr: *mut f64,
    len: usize,
}

// SAFETY: access is raw-pointer based; the disjointness contract above
// makes concurrent use race-free, and visibility of the writes is
// established by the pool's slot mutex (workers retire under it before
// the dispatching caller returns).
unsafe impl Send for DisjointOut {}
unsafe impl Sync for DisjointOut {}

impl DisjointOut {
    pub fn new(data: &mut [f64]) -> DisjointOut {
        DisjointOut { ptr: data.as_mut_ptr(), len: data.len() }
    }

    /// The window `[off, off + len)` of the underlying buffer.
    ///
    /// # Safety
    ///
    /// The caller must guarantee no other live slice of this buffer —
    /// from this or any other thread — overlaps the window (the
    /// conflict-free-offsets contract above). Out-of-bounds windows
    /// panic.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice_mut(&self, off: usize, len: usize) -> &mut [f64] {
        assert!(
            off.checked_add(len).is_some_and(|end| end <= self.len),
            "disjoint output window [{off}, {off}+{len}) out of bounds (len {})",
            self.len
        );
        std::slice::from_raw_parts_mut(self.ptr.add(off), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_covers_every_index_exactly_once() {
        let pool = ParallelPool::new(4);
        for &n in &[1usize, 2, 3, 16, 257, 1024] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            pool.run(n, &|lo, hi| {
                for i in lo..hi {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} of {n}");
            }
        }
    }

    #[test]
    fn width_one_runs_inline() {
        let pool = ParallelPool::new(1);
        assert_eq!(pool.width(), 1);
        let caller = std::thread::current().id();
        pool.run(8, &|_, _| assert_eq!(std::thread::current().id(), caller));
    }

    #[test]
    fn zero_items_is_a_noop() {
        let pool = ParallelPool::new(3);
        pool.run(0, &|_, _| panic!("must not be called"));
    }

    #[test]
    fn pool_is_reusable_across_batches() {
        let pool = ParallelPool::new(3);
        for round in 0..50 {
            let sum = AtomicU64::new(0);
            pool.run(100, &|lo, hi| {
                let part: u64 = (lo..hi).map(|i| i as u64).sum();
                sum.fetch_add(part, Ordering::Relaxed);
            });
            assert_eq!(sum.load(Ordering::Relaxed), 4950, "round {round}");
        }
    }

    #[test]
    fn worker_panic_propagates_and_pool_survives() {
        let pool = ParallelPool::new(4);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            pool.run(64, &|lo, _| {
                if lo == 0 {
                    panic!("chunk failed");
                }
            });
        }));
        assert!(result.is_err(), "chunk panic must reach the caller");
        let sum = AtomicU64::new(0);
        pool.run(10, &|lo, hi| {
            sum.fetch_add((hi - lo) as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 10, "pool must survive a panicked batch");
    }

    #[test]
    fn contended_dispatch_falls_back_inline() {
        // Many threads hammer one pool; every batch must still cover its
        // index space exactly once (winners use the pool, losers inline).
        let pool = ParallelPool::new(2);
        std::thread::scope(|s| {
            for _ in 0..4 {
                s.spawn(|| {
                    for _ in 0..50 {
                        let sum = AtomicU64::new(0);
                        pool.run(64, &|lo, hi| {
                            sum.fetch_add((hi - lo) as u64, Ordering::Relaxed);
                        });
                        assert_eq!(sum.load(Ordering::Relaxed), 64);
                    }
                });
            }
        });
    }

    #[test]
    fn disjoint_out_bounds_checked() {
        let mut data = vec![0.0; 8];
        let out = DisjointOut::new(&mut data);
        let s = unsafe { out.slice_mut(4, 4) };
        s.fill(1.0);
        assert!(std::panic::catch_unwind(AssertUnwindSafe(|| unsafe {
            out.slice_mut(6, 4);
        }))
        .is_err());
        assert_eq!(data[3], 0.0);
        assert_eq!(data[4], 1.0);
    }
}
