//! Mini property-testing harness (the offline image has no `proptest`).
//!
//! [`check`] runs a property over `cases` randomly generated inputs drawn
//! from a caller-provided generator; on failure it reports the seed and the
//! case index so the exact failing input can be re-generated
//! deterministically (`Prng::new(seed)` + case index replay).

use super::prng::Prng;

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

/// Run `prop` on `cases` inputs produced by `gen` from a seeded PRNG.
/// Panics with seed + case index on the first failure.
pub fn check<T, G, P>(name: &str, seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Prng) -> T,
    P: FnMut(&T) -> CaseResult,
    T: std::fmt::Debug,
{
    let mut rng = Prng::new(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {i} (seed {seed}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Assert two slices are elementwise close in a mixed absolute/relative
/// sense: |a-b| <= atol + rtol*max(|a|,|b|).
pub fn assert_allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * x.abs().max(y.abs());
        assert!(
            (x - y).abs() <= tol,
            "{ctx}: element {i} differs: {x} vs {y} (|diff|={}, tol={tol})",
            (x - y).abs()
        );
    }
}

/// Relative l2 error ||a-b|| / ||b|| (0 if both are zero).
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
    let den: f64 = b.iter().map(|y| y * y).sum::<f64>().sqrt();
    if den == 0.0 {
        if num == 0.0 { 0.0 } else { f64::INFINITY }
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check("trivial", 1, 50, |r| r.uniform(), |&u| {
            if (0.0..1.0).contains(&u) { Ok(()) } else { Err(format!("out of range: {u}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 2, 10, |r| r.uniform(), |&u| {
            if u < 0.5 { Ok(()) } else { Err("too big".into()) }
        });
    }

    #[test]
    fn rel_err_zero_for_equal() {
        assert_eq!(rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn rel_err_scale() {
        let e = rel_err(&[1.1], &[1.0]);
        assert!((e - 0.1).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn allclose_detects_mismatch() {
        assert_allclose(&[1.0], &[2.0], 1e-6, 1e-9, "t");
    }
}
