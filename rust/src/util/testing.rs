//! Mini property-testing harness (the offline image has no `proptest`).
//!
//! [`check`] runs a property over `cases` randomly generated inputs drawn
//! from a caller-provided generator; on failure it reports the seed and the
//! case index so the exact failing input can be re-generated
//! deterministically (`Prng::new(seed)` + case index replay).

use super::prng::Prng;

/// Outcome of one property case.
pub type CaseResult = Result<(), String>;

/// Run `prop` on `cases` inputs produced by `gen` from a seeded PRNG.
/// Panics with seed + case index on the first failure.
pub fn check<T, G, P>(name: &str, seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    G: FnMut(&mut Prng) -> T,
    P: FnMut(&T) -> CaseResult,
    T: std::fmt::Debug,
{
    let mut rng = Prng::new(seed);
    for i in 0..cases {
        let input = gen(&mut rng);
        if let Err(msg) = prop(&input) {
            panic!(
                "property '{name}' failed at case {i} (seed {seed}):\n  input: {input:?}\n  {msg}"
            );
        }
    }
}

/// Assert two slices are elementwise close in a mixed absolute/relative
/// sense: |a-b| <= atol + rtol*max(|a|,|b|).
pub fn assert_allclose(a: &[f64], b: &[f64], rtol: f64, atol: f64, ctx: &str) {
    assert_eq!(a.len(), b.len(), "{ctx}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
        let tol = atol + rtol * x.abs().max(y.abs());
        assert!(
            (x - y).abs() <= tol,
            "{ctx}: element {i} differs: {x} vs {y} (|diff|={}, tol={tol})",
            (x - y).abs()
        );
    }
}

/// Relative l2 error ||a-b|| / ||b|| (0 if both are zero).
pub fn rel_err(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    let num: f64 = a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>().sqrt();
    let den: f64 = b.iter().map(|y| y * y).sum::<f64>().sqrt();
    if den == 0.0 {
        if num == 0.0 { 0.0 } else { f64::INFINITY }
    } else {
        num / den
    }
}

/// A parsed JSON value (strict RFC 8259 subset used by trace/obs tests —
/// the offline image has no `serde_json`, and the point of these tests is
/// that our hand-rolled writers emit JSON a *strict* parser accepts).
#[derive(Clone, Debug, PartialEq)]
pub enum JsonValue {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<JsonValue>),
    Obj(Vec<(String, JsonValue)>),
}

impl JsonValue {
    /// Member lookup on an object (None for non-objects / missing keys).
    pub fn get(&self, key: &str) -> Option<&JsonValue> {
        match self {
            JsonValue::Obj(members) => {
                members.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            JsonValue::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            JsonValue::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[JsonValue]> {
        match self {
            JsonValue::Arr(items) => Some(items),
            _ => None,
        }
    }
}

/// Parse a complete JSON document, rejecting trailing garbage, trailing
/// commas, unescaped control characters inside strings, and bare NaN/Inf.
pub fn parse_json(text: &str) -> Result<JsonValue, String> {
    let bytes = text.as_bytes();
    let mut pos = 0;
    let value = parse_value(bytes, &mut pos)?;
    skip_ws(bytes, &mut pos);
    if pos != bytes.len() {
        return Err(format!("trailing garbage at byte {pos}"));
    }
    Ok(value)
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn expect(b: &[u8], pos: &mut usize, c: u8) -> Result<(), String> {
    if *pos < b.len() && b[*pos] == c {
        *pos += 1;
        Ok(())
    } else {
        Err(format!("expected '{}' at byte {}", c as char, *pos))
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    skip_ws(b, pos);
    match b.get(*pos) {
        None => Err("unexpected end of input".into()),
        Some(b'{') => parse_object(b, pos),
        Some(b'[') => parse_array(b, pos),
        Some(b'"') => Ok(JsonValue::Str(parse_string(b, pos)?)),
        Some(b't') => parse_literal(b, pos, "true", JsonValue::Bool(true)),
        Some(b'f') => parse_literal(b, pos, "false", JsonValue::Bool(false)),
        Some(b'n') => parse_literal(b, pos, "null", JsonValue::Null),
        Some(_) => parse_number(b, pos),
    }
}

fn parse_literal(
    b: &[u8],
    pos: &mut usize,
    lit: &str,
    v: JsonValue,
) -> Result<JsonValue, String> {
    if b[*pos..].starts_with(lit.as_bytes()) {
        *pos += lit.len();
        Ok(v)
    } else {
        Err(format!("invalid literal at byte {}", *pos))
    }
}

fn parse_object(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    expect(b, pos, b'{')?;
    let mut members = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(JsonValue::Obj(members));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        expect(b, pos, b':')?;
        let val = parse_value(b, pos)?;
        members.push((key, val));
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(JsonValue::Obj(members));
            }
            _ => return Err(format!("expected ',' or '}}' at byte {}", *pos)),
        }
    }
}

fn parse_array(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    expect(b, pos, b'[')?;
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(JsonValue::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(JsonValue::Arr(items));
            }
            _ => return Err(format!("expected ',' or ']' at byte {}", *pos)),
        }
    }
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String, String> {
    expect(b, pos, b'"')?;
    let mut out = String::new();
    loop {
        match b.get(*pos) {
            None => return Err("unterminated string".into()),
            Some(&c) if c < 0x20 => {
                return Err(format!("unescaped control character 0x{c:02x} at byte {}", *pos));
            }
            Some(b'"') => {
                *pos += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hex = b
                            .get(*pos + 1..*pos + 5)
                            .ok_or_else(|| "truncated \\u escape".to_string())?;
                        let hex = std::str::from_utf8(hex)
                            .map_err(|_| "non-ascii \\u escape".to_string())?;
                        let code = u32::from_str_radix(hex, 16)
                            .map_err(|_| format!("bad \\u escape '{hex}'"))?;
                        // Surrogates never appear in our writers' output
                        // (escape_json only emits \u00XX) — reject them.
                        let c = char::from_u32(code)
                            .ok_or_else(|| format!("surrogate \\u{hex}"))?;
                        out.push(c);
                        *pos += 4;
                    }
                    other => return Err(format!("bad escape {other:?}")),
                }
                *pos += 1;
            }
            Some(_) => {
                // Consume one UTF-8 scalar (input is a &str, so boundaries
                // are valid by construction).
                let rest = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| "invalid utf-8".to_string())?;
                let c = rest.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_number(b: &[u8], pos: &mut usize) -> Result<JsonValue, String> {
    let start = *pos;
    if b.get(*pos) == Some(&b'-') {
        *pos += 1;
    }
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos]).map_err(|_| "bad number".to_string())?;
    if s.is_empty() || s == "-" {
        return Err(format!("expected a value at byte {start}"));
    }
    let x: f64 = s.parse().map_err(|_| format!("bad number '{s}'"))?;
    if !x.is_finite() {
        return Err(format!("non-finite number '{s}'"));
    }
    Ok(JsonValue::Num(x))
}

/// Parse a Prometheus text exposition strictly: returns `(name, value)`
/// samples in document order, where `name` keeps its label block
/// verbatim (e.g. `x_bucket{le="+Inf"}`). Comment lines (`#`) and blank
/// lines are skipped. Rejects malformed metric names, unbalanced label
/// blocks, and any value token that is not a plain decimal float or one
/// of the canonical `+Inf` / `-Inf` / `NaN` tokens — Rust's permissive
/// `f64::from_str` (which accepts `inf`, `+infinity`, …) is deliberately
/// not the arbiter here, because real scrapers are stricter.
pub fn parse_prometheus_text(text: &str) -> Result<Vec<(String, f64)>, String> {
    let mut samples = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let err = |what: &str| format!("line {}: {what}: '{line}'", lineno + 1);
        // Split "name{labels} value" at the last space outside any label
        // block (label values never contain spaces in our writers, but
        // the split must still not land inside the braces).
        let split = line
            .char_indices()
            .filter(|&(i, c)| {
                c == ' ' && line[..i].matches('{').count() == line[..i].matches('}').count()
            })
            .map(|(i, _)| i)
            .next_back()
            .ok_or_else(|| err("no value separator"))?;
        let (name, value) = (&line[..split], line[split + 1..].trim());
        validate_prom_name(name).map_err(|e| err(&e))?;
        samples.push((name.to_string(), parse_prom_number(value).map_err(|e| err(&e))?));
    }
    Ok(samples)
}

fn validate_prom_name(name: &str) -> Result<(), String> {
    let (base, labels) = match name.split_once('{') {
        Some((b, rest)) => {
            let labels =
                rest.strip_suffix('}').ok_or("label block not closed".to_string())?;
            (b, Some(labels))
        }
        None => {
            if name.contains('}') {
                return Err("stray '}' in metric name".into());
            }
            (name, None)
        }
    };
    if base.is_empty()
        || !base.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        || !base.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
    {
        return Err(format!("invalid metric name '{base}'"));
    }
    if let Some(labels) = labels {
        for pair in labels.split(',') {
            let (k, v) = pair.split_once('=').ok_or(format!("label '{pair}' missing '='"))?;
            if k.is_empty() || !k.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
                return Err(format!("invalid label name '{k}'"));
            }
            if !(v.len() >= 2 && v.starts_with('"') && v.ends_with('"')) {
                return Err(format!("label value {v} not quoted"));
            }
        }
    }
    Ok(())
}

/// Parse one exposition value token: canonical non-finite tokens or a
/// strict decimal float (`sign? digits (. digits)? ([eE] sign? digits)?`).
pub fn parse_prom_number(s: &str) -> Result<f64, String> {
    match s {
        "+Inf" => return Ok(f64::INFINITY),
        "-Inf" => return Ok(f64::NEG_INFINITY),
        "NaN" => return Ok(f64::NAN),
        _ => {}
    }
    let b = s.as_bytes();
    let mut i = 0;
    let bad = || format!("invalid value token '{s}'");
    if matches!(b.first(), Some(b'+') | Some(b'-')) {
        i += 1;
    }
    let int_start = i;
    while i < b.len() && b[i].is_ascii_digit() {
        i += 1;
    }
    if i == int_start {
        return Err(bad());
    }
    if i < b.len() && b[i] == b'.' {
        i += 1;
        let frac_start = i;
        while i < b.len() && b[i].is_ascii_digit() {
            i += 1;
        }
        if i == frac_start {
            return Err(bad());
        }
    }
    if i < b.len() && (b[i] == b'e' || b[i] == b'E') {
        i += 1;
        if i < b.len() && (b[i] == b'+' || b[i] == b'-') {
            i += 1;
        }
        let exp_start = i;
        while i < b.len() && b[i].is_ascii_digit() {
            i += 1;
        }
        if i == exp_start {
            return Err(bad());
        }
    }
    if i != b.len() {
        return Err(bad());
    }
    s.parse().map_err(|_| bad())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check("trivial", 1, 50, |r| r.uniform(), |&u| {
            if (0.0..1.0).contains(&u) { Ok(()) } else { Err(format!("out of range: {u}")) }
        });
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn check_reports_failure() {
        check("fails", 2, 10, |r| r.uniform(), |&u| {
            if u < 0.5 { Ok(()) } else { Err("too big".into()) }
        });
    }

    #[test]
    fn rel_err_zero_for_equal() {
        assert_eq!(rel_err(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
    }

    #[test]
    fn rel_err_scale() {
        let e = rel_err(&[1.1], &[1.0]);
        assert!((e - 0.1).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn allclose_detects_mismatch() {
        assert_allclose(&[1.0], &[2.0], 1e-6, 1e-9, "t");
    }

    #[test]
    fn json_parses_nested_document() {
        let v = parse_json(
            r#"{"a": [1, -2.5e3, "x\n\"y\\z"], "b": {"c": true, "d": null}, "e": false}"#,
        )
        .unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[1].as_f64(), Some(-2500.0));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].as_str(), Some("x\n\"y\\z"));
        assert_eq!(v.get("b").unwrap().get("c"), Some(&JsonValue::Bool(true)));
        assert_eq!(v.get("b").unwrap().get("d"), Some(&JsonValue::Null));
        assert_eq!(v.get("e"), Some(&JsonValue::Bool(false)));
    }

    #[test]
    fn json_unicode_escapes_decode() {
        let v = parse_json(r#""tab:\u0009 bell:\u0007 snowman:\u2603""#).unwrap();
        assert_eq!(v.as_str(), Some("tab:\t bell:\u{7} snowman:\u{2603}"));
    }

    #[test]
    fn prometheus_parser_accepts_canonical_tokens() {
        let text = "# TYPE x gauge\nx +Inf\ny -Inf\nz NaN\nw 12.5\nv 1e-3\nu{rank=\"3\"} 7\n";
        let samples = parse_prometheus_text(text).unwrap();
        assert_eq!(samples[0].0, "x");
        assert_eq!(samples[0].1, f64::INFINITY);
        assert_eq!(samples[1].1, f64::NEG_INFINITY);
        assert!(samples[2].1.is_nan());
        assert_eq!(samples[3].1, 12.5);
        assert_eq!(samples[4].1, 1e-3);
        assert_eq!(samples[5], ("u{rank=\"3\"}".to_string(), 7.0));
    }

    #[test]
    fn prometheus_parser_rejects_rust_float_spellings() {
        // Rust's f64::from_str would accept all of these; scrapers don't.
        for bad in ["x inf", "x -inf", "x infinity", "x nan", "x Inf", "x 1.", "x .5", "x 1e"] {
            assert!(parse_prometheus_text(bad).is_err(), "accepted '{bad}'");
        }
        assert!(parse_prometheus_text("x{le=\"0.5\" 1").is_err(), "unclosed label block");
        assert!(parse_prometheus_text("x{le=0.5} 1").is_err(), "unquoted label value");
        assert!(parse_prometheus_text("9bad 1").is_err(), "invalid name");
        assert!(parse_prometheus_text("noseparator").is_err());
    }

    #[test]
    fn json_rejects_malformed_input() {
        assert!(parse_json("[1, 2,]").is_err(), "trailing comma");
        assert!(parse_json("[1] garbage").is_err(), "trailing garbage");
        assert!(parse_json("\"raw \u{1} control\"").is_err(), "unescaped control char");
        assert!(parse_json("{\"a\": }").is_err(), "missing value");
        assert!(parse_json("NaN").is_err(), "bare NaN");
        assert!(parse_json("").is_err(), "empty input");
        assert!(parse_json("\"open").is_err(), "unterminated string");
    }
}
