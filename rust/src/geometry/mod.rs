//! Point sets, bounding boxes and the regular-grid generators used by the
//! paper's test problems (§6.1: 2D/3D grids with exponential kernels; §6.4:
//! the fractional-diffusion grid over Ω ∪ Ω₀).

/// Maximum spatial dimension supported (the paper evaluates 2D and 3D).
pub const MAX_DIM: usize = 3;

/// A set of points in `dim`-dimensional space, stored as a structure of
/// arrays: coordinate `d` of point `i` is `coords[d][i]`.
#[derive(Clone, Debug)]
pub struct PointSet {
    pub dim: usize,
    pub coords: Vec<Vec<f64>>,
}

impl PointSet {
    pub fn new(dim: usize) -> Self {
        assert!((1..=MAX_DIM).contains(&dim));
        PointSet { dim, coords: vec![Vec::new(); dim] }
    }

    pub fn len(&self) -> usize {
        self.coords[0].len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn push(&mut self, p: &[f64]) {
        assert_eq!(p.len(), self.dim);
        for (d, &v) in p.iter().enumerate() {
            self.coords[d].push(v);
        }
    }

    /// Coordinates of point `i` (up to MAX_DIM, zero-extended).
    #[inline]
    pub fn get(&self, i: usize) -> [f64; MAX_DIM] {
        let mut p = [0.0; MAX_DIM];
        for d in 0..self.dim {
            p[d] = self.coords[d][i];
        }
        p
    }

    /// Squared Euclidean distance between points i and j.
    #[inline]
    pub fn dist2(&self, i: usize, j: usize) -> f64 {
        let mut s = 0.0;
        for d in 0..self.dim {
            let diff = self.coords[d][i] - self.coords[d][j];
            s += diff * diff;
        }
        s
    }

    /// Regular 2D grid of `n x n` points filling [0, a]².
    /// This is the paper's 2D spatial-statistics point set (§6.1).
    pub fn grid_2d(n: usize, a: f64) -> Self {
        let mut ps = PointSet::new(2);
        let h = if n > 1 { a / (n - 1) as f64 } else { 0.0 };
        for j in 0..n {
            for i in 0..n {
                ps.push(&[i as f64 * h, j as f64 * h]);
            }
        }
        ps
    }

    /// Regular 3D grid of `n x n x n` points filling [0, a]³ (§6.1, 3D
    /// Gaussian-process set).
    pub fn grid_3d(n: usize, a: f64) -> Self {
        let mut ps = PointSet::new(3);
        let h = if n > 1 { a / (n - 1) as f64 } else { 0.0 };
        for k in 0..n {
            for j in 0..n {
                for i in 0..n {
                    ps.push(&[i as f64 * h, j as f64 * h, k as f64 * h]);
                }
            }
        }
        ps
    }

    /// Cell-centered 2D grid: n×n cell midpoints over [lo, hi]² — the
    /// §6.4 fractional-diffusion discretization (used both by
    /// `apps::fractional` and by the distributed solver session's
    /// [`crate::dist::transport::MatrixJob`], which must agree bitwise).
    pub fn cell_grid_2d(n: usize, lo: f64, hi: f64) -> Self {
        let h = (hi - lo) / n as f64;
        let mut ps = PointSet::new(2);
        for j in 0..n {
            for i in 0..n {
                ps.push(&[lo + (i as f64 + 0.5) * h, lo + (j as f64 + 0.5) * h]);
            }
        }
        ps
    }

    /// 2D grid of points with spacing `h` covering the box
    /// [lo, hi]² (inclusive of both ends when (hi-lo)/h is integral).
    /// Used for the fractional-diffusion domains Ω and Ω ∪ Ω₀ (§6.4).
    pub fn grid_2d_box(lo: f64, hi: f64, h: f64) -> Self {
        let n = ((hi - lo) / h).round() as usize + 1;
        let mut ps = PointSet::new(2);
        for j in 0..n {
            for i in 0..n {
                ps.push(&[lo + i as f64 * h, lo + j as f64 * h]);
            }
        }
        ps
    }
}

/// Axis-aligned bounding box.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BBox {
    pub dim: usize,
    pub lo: [f64; MAX_DIM],
    pub hi: [f64; MAX_DIM],
}

impl BBox {
    /// Bounding box of a subset of points given by `idx`.
    pub fn of(points: &PointSet, idx: &[usize]) -> Self {
        assert!(!idx.is_empty());
        let mut lo = [f64::INFINITY; MAX_DIM];
        let mut hi = [f64::NEG_INFINITY; MAX_DIM];
        for d in 0..points.dim {
            for &i in idx {
                let v = points.coords[d][i];
                lo[d] = lo[d].min(v);
                hi[d] = hi[d].max(v);
            }
        }
        for d in points.dim..MAX_DIM {
            lo[d] = 0.0;
            hi[d] = 0.0;
        }
        BBox { dim: points.dim, lo, hi }
    }

    /// Center of the box.
    pub fn center(&self) -> [f64; MAX_DIM] {
        let mut c = [0.0; MAX_DIM];
        for d in 0..self.dim {
            c[d] = 0.5 * (self.lo[d] + self.hi[d]);
        }
        c
    }

    /// Length of the box diagonal (the paper's D_t).
    pub fn diameter(&self) -> f64 {
        let mut s = 0.0;
        for d in 0..self.dim {
            let e = self.hi[d] - self.lo[d];
            s += e * e;
        }
        s.sqrt()
    }

    /// Euclidean distance between the centers of two boxes (the paper's
    /// ||C_t - C_s||).
    pub fn center_dist(&self, other: &BBox) -> f64 {
        let (a, b) = (self.center(), other.center());
        let mut s = 0.0;
        for d in 0..self.dim.max(other.dim) {
            let diff = a[d] - b[d];
            s += diff * diff;
        }
        s.sqrt()
    }

    /// Extent along dimension d.
    pub fn extent(&self, d: usize) -> f64 {
        self.hi[d] - self.lo[d]
    }

    /// Dimension with the largest extent (k-d tree split axis).
    pub fn longest_axis(&self) -> usize {
        (0..self.dim)
            .max_by(|&a, &b| self.extent(a).partial_cmp(&self.extent(b)).unwrap())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_2d_count_and_extent() {
        let g = PointSet::grid_2d(4, 3.0);
        assert_eq!(g.len(), 16);
        let idx: Vec<usize> = (0..16).collect();
        let bb = BBox::of(&g, &idx);
        assert_eq!(bb.lo[0], 0.0);
        assert_eq!(bb.hi[0], 3.0);
        assert_eq!(bb.hi[1], 3.0);
    }

    #[test]
    fn grid_3d_count() {
        let g = PointSet::grid_3d(3, 1.0);
        assert_eq!(g.len(), 27);
        assert_eq!(g.dim, 3);
    }

    #[test]
    fn grid_2d_box_spacing() {
        let g = PointSet::grid_2d_box(-1.0, 1.0, 0.5);
        assert_eq!(g.len(), 25); // 5x5
        assert_eq!(g.coords[0][0], -1.0);
    }

    #[test]
    fn dist2_symmetric() {
        let g = PointSet::grid_2d(3, 1.0);
        assert_eq!(g.dist2(0, 5), g.dist2(5, 0));
        assert_eq!(g.dist2(2, 2), 0.0);
    }

    #[test]
    fn bbox_diameter_unit_square() {
        let g = PointSet::grid_2d(2, 1.0);
        let bb = BBox::of(&g, &[0, 1, 2, 3]);
        assert!((bb.diameter() - 2f64.sqrt()).abs() < 1e-14);
        assert_eq!(bb.center()[0], 0.5);
    }

    #[test]
    fn bbox_center_dist() {
        let g = PointSet::grid_2d(2, 1.0);
        let left = BBox::of(&g, &[0, 2]); // x = 0 column
        let right = BBox::of(&g, &[1, 3]); // x = 1 column
        assert!((left.center_dist(&right) - 1.0).abs() < 1e-14);
    }

    #[test]
    fn longest_axis_picks_max_extent() {
        let mut ps = PointSet::new(2);
        ps.push(&[0.0, 0.0]);
        ps.push(&[10.0, 1.0]);
        let bb = BBox::of(&ps, &[0, 1]);
        assert_eq!(bb.longest_axis(), 0);
    }
}
