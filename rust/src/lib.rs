//! # h2opus-rs
//!
//! A Rust + JAX/Pallas reproduction of **H2Opus**, the distributed-memory
//! multi-GPU package for hierarchical (`H^2`) matrix operations
//! (Zampini, Boukaram, Turkiyyah, Knio, Keyes — 2021).
//!
//! `H^2` matrices are O(N) representations of the dense matrices arising
//! from non-local operators (kernel covariance matrices, integral
//! equations, fractional diffusion). This crate implements:
//!
//! - construction of `H^2` matrices from a kernel + geometric admissibility
//!   condition via Chebyshev interpolation ([`construct`]),
//! - matrix-(multi)vector multiplication, `HGEMV` ([`matvec`]),
//! - algebraic recompression to a target accuracy ([`compression`]),
//! - a distributed-memory runtime over simulated MPI ranks in virtual time,
//!   with the §4.1 communication-volume optimization
//!   ([`dist::ExchangePlan`]) and §4.2 communication/computation overlap
//!   ([`dist::hgemv`], [`dist::compress`]) — see the [`dist`] module docs
//!   for a runnable example,
//! - per-rank *sharded* matrix storage for out-of-core N
//!   ([`dist::shard`]): real worker processes construct only their branch
//!   of the matrix and serve bitwise serial-identical products over a
//!   persistent socket session ([`dist::transport::socket`]),
//! - batched dense linear-algebra backends: a pure-Rust reference and an
//!   AOT-compiled JAX/Pallas path executed through PJRT ([`backend`],
//!   [`runtime`]),
//! - an end-to-end application: a 2D variable-diffusivity integral
//!   fractional diffusion solver with CG + multigrid preconditioning
//!   ([`apps`], [`solver`]).
//!
//! The layering mirrors the paper: tree-structured data is *marshaled* per
//! level into large batches of small fixed-size dense operations, which are
//! then executed by a batched backend (the paper used MAGMA/KBLAS on V100
//! GPUs; here a Pallas batched-GEMM kernel AOT-lowered to HLO, plus pure-jnp
//! batched QR/SVD, executed by the PJRT CPU client — and a native Rust
//! backend used as oracle and baseline).
//!
//! See `DESIGN.md` (repo root) for the full system inventory, the
//! "Substitutions" table describing how the paper's stack (MPI, MAGMA,
//! PETSc/AMG) maps onto this offline build, and the E1–E9 experiment
//! index; the qualitative shapes of the paper's Figs. 8–12 are asserted in
//! `rust/tests/distributed.rs`, and the figure-style reporters live in
//! `rust/benches/`.

pub mod admissibility;
pub mod apps;
pub mod backend;
pub mod clustering;
pub mod compression;
pub mod config;
pub mod construct;
pub mod dist;
pub mod geometry;
pub mod linalg;
pub mod matvec;
pub mod metrics;
pub mod obs;
pub mod runtime;
pub mod solver;
pub mod tree;
pub mod util;

pub use config::H2Config;
