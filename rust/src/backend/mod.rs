//! Batched dense-linear-algebra backends.
//!
//! The paper's single-GPU performance comes from marshaling tree levels
//! into batches of small fixed-size dense operations executed by MAGMA
//! (GEMM) and KBLAS (QR/SVD). Here the same role is played by a
//! [`ComputeBackend`] trait with two implementations:
//!
//! - [`native::NativeBackend`] — pure Rust; the correctness oracle and the
//!   performance baseline,
//! - [`crate::runtime::XlaBackend`] — AOT-compiled JAX/Pallas HLO artifacts
//!   executed through the PJRT CPU client, mirroring the paper's
//!   batched-GPU-kernel architecture.
//!
//! The batched-GEMM entry point takes *offset arrays* instead of contiguous
//! buffers: this is exactly the paper's marshaling output (Alg. 3) — a
//! gather of per-block pointers into the flattened tree storage with no
//! data movement. The conflict-free batch ordering of §3.2 guarantees
//! output offsets are distinct within a call — and since every block of a
//! call has one fixed output size, distinct offsets mean pairwise-disjoint
//! output windows. That disjointness is the documented safety contract the
//! parallel native dispatch builds on: blocks of one batch may execute on
//! different pool threads writing through
//! [`crate::util::parallel::DisjointOut`] with no further synchronization,
//! and per-block results are bitwise identical to the serial loop because
//! each block runs the very same scalar kernel on the same inputs.
//!
//! # Thread budget
//!
//! The parallel dispatch width is a process-wide budget read from
//! `H2OPUS_BACKEND_THREADS` (or set programmatically with
//! [`set_backend_threads`], or via the CLI's `--backend-threads`): the
//! global [`crate::util::parallel::ParallelPool`] is sized to it at first
//! use. The default is 1 — the exact serial loop. Composition with the
//! threaded distributed executor's per-rank OS threads is
//! first-come-first-served: the P rank threads *share* the one pool (a
//! rank that finds it busy executes its batch inline), so total
//! oversubscription is bounded by `P + budget` threads and nesting can
//! never deadlock.

pub mod native;

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::metrics::Metrics;

/// The process-wide batched-backend thread budget (resolved once): the
/// value set by [`set_backend_threads`] if any, else
/// `H2OPUS_BACKEND_THREADS`, else 1 (serial).
static BACKEND_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Current backend thread budget (≥ 1). First call resolves and caches it.
pub fn backend_threads() -> usize {
    match BACKEND_THREADS.load(Ordering::Relaxed) {
        0 => {
            let t = std::env::var("H2OPUS_BACKEND_THREADS")
                .ok()
                .and_then(|v| v.parse::<usize>().ok())
                .filter(|&t| t >= 1)
                .unwrap_or(1);
            // Install the env default only if nothing was set meanwhile: a
            // concurrent `set_backend_threads` must win over the lazy
            // resolution, not be clobbered by it.
            match BACKEND_THREADS.compare_exchange(0, t, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => t,
                Err(current) => current,
            }
        }
        t => t,
    }
}

/// Override the backend thread budget (values < 1 clamp to 1). Must run
/// before the first batched call to take effect on the global pool, whose
/// width freezes when it is first used ([`crate::util::parallel::ParallelPool::global`]);
/// the CLI calls this at startup from `--backend-threads`.
pub fn set_backend_threads(threads: usize) {
    BACKEND_THREADS.store(threads.max(1), Ordering::Relaxed);
}

/// Dimensions of one batched GEMM: nb blocks of op(A)·B with
/// op(A): m × k, B: k × n, C: m × n.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct GemmDims {
    pub nb: usize,
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// When true, A blocks are stored k × m and used transposed.
    pub trans_a: bool,
    /// When true, B blocks are stored n × k and used transposed.
    pub trans_b: bool,
    /// When true, C += op(A)·op(B); otherwise C = op(A)·op(B).
    pub accumulate: bool,
}

/// A batched-GEMM argument: flat storage plus one offset per block.
pub struct BatchRef<'a> {
    pub data: &'a [f64],
    pub offsets: &'a [usize],
}

/// Batched dense linear algebra over f64.
///
/// `Sync` is a supertrait: the threaded distributed executor
/// ([`crate::dist::threaded`]) shares one backend immutably across its
/// per-rank OS threads, so every implementation must be safe to call
/// concurrently through `&self` (interior mutability must be locked, as
/// in `runtime::XlaBackend`).
pub trait ComputeBackend: Sync {
    fn name(&self) -> &str;

    /// Batched GEMM over gathered offsets:
    /// `C[c_off[i]..] (=|+=) op(A[a_off[i]..]) · op(B[b_off[i]..])`.
    fn batched_gemm(
        &self,
        dims: GemmDims,
        a: BatchRef<'_>,
        b: BatchRef<'_>,
        c_data: &mut [f64],
        c_offsets: &[usize],
        metrics: &mut Metrics,
    );

    /// Batched thin QR of nb contiguous (rows × cols) blocks (rows >= cols):
    /// writes Q (nb × rows × cols) and R (nb × cols × cols).
    fn batched_qr(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        q: &mut [f64],
        r: &mut [f64],
        metrics: &mut Metrics,
    );

    /// Batched R-only QR (the compression downsweep never needs Q).
    fn batched_qr_r(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        r: &mut [f64],
        metrics: &mut Metrics,
    );

    /// Batched thin SVD of nb contiguous (rows × cols) blocks (rows >= cols):
    /// writes U (nb × rows × cols), singular values (nb × cols, descending)
    /// and V (nb × cols × cols).
    fn batched_svd(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        u: &mut [f64],
        s: &mut [f64],
        v: &mut [f64],
        metrics: &mut Metrics,
    );
}

/// Convenience: contiguous offsets 0, stride, 2·stride, ...
pub fn contiguous_offsets(nb: usize, stride: usize) -> Vec<usize> {
    (0..nb).map(|i| i * stride).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_offsets_stride() {
        assert_eq!(contiguous_offsets(3, 10), vec![0, 10, 20]);
        assert!(contiguous_offsets(0, 5).is_empty());
    }

    #[test]
    fn backend_threads_resolves_to_at_least_one() {
        // Whatever the environment says (including unset or garbage), the
        // resolved budget is a usable width.
        assert!(backend_threads() >= 1);
    }
}
