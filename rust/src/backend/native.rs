//! Pure-Rust batched backend: loops over [`crate::linalg`] kernels.
//! Serves as the correctness oracle for the XLA backend and the baseline
//! for the batched-performance microbenchmarks (E9).

use super::{BatchRef, ComputeBackend, GemmDims};
use crate::linalg::{gemm_nn, gemm_nt, gemm_tn, householder_qr, jacobi_svd, qr_r_only};
use crate::metrics::Metrics;

/// The native (pure Rust) compute backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn batched_gemm(
        &self,
        dims: GemmDims,
        a: BatchRef<'_>,
        b: BatchRef<'_>,
        c_data: &mut [f64],
        c_offsets: &[usize],
        metrics: &mut Metrics,
    ) {
        let GemmDims { nb, m, k, n, trans_a, trans_b, accumulate } = dims;
        assert_eq!(a.offsets.len(), nb);
        assert_eq!(b.offsets.len(), nb);
        assert_eq!(c_offsets.len(), nb);
        let (a_sz, b_sz, c_sz) = (m * k, k * n, m * n);
        for i in 0..nb {
            let ab = &a.data[a.offsets[i]..a.offsets[i] + a_sz];
            let bb = &b.data[b.offsets[i]..b.offsets[i] + b_sz];
            let cb = &mut c_data[c_offsets[i]..c_offsets[i] + c_sz];
            match (trans_a, trans_b) {
                (false, false) => gemm_nn(m, k, n, ab, bb, cb, accumulate),
                (true, false) => gemm_tn(m, k, n, ab, bb, cb, accumulate),
                (false, true) => gemm_nt(m, k, n, ab, bb, cb, accumulate),
                (true, true) => {
                    // Not used by any phase; compose via a temporary.
                    let mut tmp = vec![0.0; m * k];
                    // tmp = A^T stored m x k
                    for r in 0..m {
                        for c in 0..k {
                            tmp[r * k + c] = ab[c * m + r];
                        }
                    }
                    gemm_nt(m, k, n, &tmp, bb, cb, accumulate);
                }
            }
        }
        metrics.gemm(nb, m, k, n);
    }

    fn batched_qr(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        q: &mut [f64],
        r: &mut [f64],
        metrics: &mut Metrics,
    ) {
        let (a_sz, r_sz) = (rows * cols, cols * cols);
        for i in 0..nb {
            let (qi, ri) = householder_qr(rows, cols, &a[i * a_sz..(i + 1) * a_sz]);
            q[i * a_sz..(i + 1) * a_sz].copy_from_slice(&qi);
            r[i * r_sz..(i + 1) * r_sz].copy_from_slice(&ri);
        }
        metrics.qr(nb, rows, cols);
    }

    fn batched_qr_r(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        r: &mut [f64],
        metrics: &mut Metrics,
    ) {
        let (a_sz, r_sz) = (rows * cols, cols * cols);
        for i in 0..nb {
            let ri = qr_r_only(rows, cols, &a[i * a_sz..(i + 1) * a_sz]);
            r[i * r_sz..(i + 1) * r_sz].copy_from_slice(&ri);
        }
        metrics.qr(nb, rows, cols);
    }

    fn batched_svd(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        u: &mut [f64],
        s: &mut [f64],
        v: &mut [f64],
        metrics: &mut Metrics,
    ) {
        let (a_sz, v_sz) = (rows * cols, cols * cols);
        for i in 0..nb {
            let (ui, si, vi) = jacobi_svd(rows, cols, &a[i * a_sz..(i + 1) * a_sz]);
            u[i * a_sz..(i + 1) * a_sz].copy_from_slice(&ui);
            s[i * cols..(i + 1) * cols].copy_from_slice(&si);
            v[i * v_sz..(i + 1) * v_sz].copy_from_slice(&vi);
        }
        metrics.svd(nb, rows, cols);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::contiguous_offsets;
    use crate::util::testing::assert_allclose;
    use crate::util::Prng;

    #[test]
    fn batched_gemm_matches_singles() {
        let mut rng = Prng::new(30);
        let (nb, m, k, n) = (5, 3, 4, 2);
        let a = rng.normal_vec(nb * m * k);
        let b = rng.normal_vec(nb * k * n);
        let mut c = vec![0.0; nb * m * n];
        let be = NativeBackend;
        let mut mt = Metrics::new();
        be.batched_gemm(
            GemmDims { nb, m, k, n, trans_a: false, trans_b: false, accumulate: false },
            BatchRef { data: &a, offsets: &contiguous_offsets(nb, m * k) },
            BatchRef { data: &b, offsets: &contiguous_offsets(nb, k * n) },
            &mut c,
            &contiguous_offsets(nb, m * n),
            &mut mt,
        );
        for i in 0..nb {
            let mut want = vec![0.0; m * n];
            crate::linalg::gemm_nn(m, k, n, &a[i * m * k..], &b[i * k * n..], &mut want, false);
            assert_allclose(&c[i * m * n..(i + 1) * m * n], &want, 1e-14, 0.0, "block");
        }
        assert_eq!(mt.flops, 2 * (nb * m * k * n) as u64);
    }

    #[test]
    fn gathered_offsets_scatter_correctly() {
        // C offsets deliberately out of order / strided.
        let be = NativeBackend;
        let mut mt = Metrics::new();
        let a = vec![1.0, 2.0]; // two 1x1 blocks
        let b = vec![10.0, 20.0];
        let mut c = vec![0.0; 10];
        be.batched_gemm(
            GemmDims { nb: 2, m: 1, k: 1, n: 1, trans_a: false, trans_b: false, accumulate: true },
            BatchRef { data: &a, offsets: &[0, 1] },
            BatchRef { data: &b, offsets: &[0, 1] },
            &mut c,
            &[7, 3],
            &mut mt,
        );
        assert_eq!(c[7], 10.0);
        assert_eq!(c[3], 40.0);
    }

    #[test]
    fn trans_variants() {
        let mut rng = Prng::new(31);
        let (m, k, n) = (3, 5, 2);
        let at = rng.normal_vec(k * m);
        let b = rng.normal_vec(k * n);
        let be = NativeBackend;
        let mut mt = Metrics::new();
        let mut c1 = vec![0.0; m * n];
        be.batched_gemm(
            GemmDims { nb: 1, m, k, n, trans_a: true, trans_b: false, accumulate: false },
            BatchRef { data: &at, offsets: &[0] },
            BatchRef { data: &b, offsets: &[0] },
            &mut c1,
            &[0],
            &mut mt,
        );
        let mut want = vec![0.0; m * n];
        crate::linalg::gemm_tn(m, k, n, &at, &b, &mut want, false);
        assert_allclose(&c1, &want, 1e-14, 0.0, "tn");
    }

    #[test]
    fn batched_qr_and_svd_roundtrip() {
        let mut rng = Prng::new(32);
        let (nb, rows, cols) = (4, 8, 3);
        let a = rng.normal_vec(nb * rows * cols);
        let be = NativeBackend;
        let mut mt = Metrics::new();
        let mut q = vec![0.0; nb * rows * cols];
        let mut r = vec![0.0; nb * cols * cols];
        be.batched_qr(nb, rows, cols, &a, &mut q, &mut r, &mut mt);
        for i in 0..nb {
            let mut qr = vec![0.0; rows * cols];
            crate::linalg::gemm_nn(
                rows,
                cols,
                cols,
                &q[i * rows * cols..],
                &r[i * cols * cols..],
                &mut qr,
                false,
            );
            assert_allclose(&qr, &a[i * rows * cols..(i + 1) * rows * cols], 1e-10, 1e-10, "qr");
        }
        let mut u = vec![0.0; nb * rows * cols];
        let mut s = vec![0.0; nb * cols];
        let mut v = vec![0.0; nb * cols * cols];
        be.batched_svd(nb, rows, cols, &a, &mut u, &mut s, &mut v, &mut mt);
        for i in 0..nb {
            // descending singular values
            let si = &s[i * cols..(i + 1) * cols];
            for w in si.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        }
    }
}
