//! Pure-Rust batched backend: register-blocked [`crate::linalg`] kernels
//! dispatched over the persistent worker pool
//! ([`crate::util::parallel::ParallelPool`]).
//!
//! Serves as the correctness oracle for the XLA backend and the
//! performance baseline for the batched microbenchmarks (E9). The role the
//! paper fills with MAGMA/KBLAS batched GPU kernels — execute a marshaled
//! batch of small dense blocks at hardware speed — is played here by
//! splitting the batch's blocks across pool threads. Safety rests on the
//! §3.2 conflict-free-offsets contract (see [`crate::backend`] module
//! docs); *per-block results are bitwise identical to the serial loop*
//! because every block runs the same scalar kernel on the same inputs,
//! whichever thread claims it, and blocks write disjoint outputs. The
//! serial loop is recovered exactly at width 1 (`H2OPUS_BACKEND_THREADS`
//! unset or 1).

use super::{BatchRef, ComputeBackend, GemmDims};
use crate::linalg::{
    gemm_nn, gemm_nt, gemm_tn, gemm_tt, householder_qr, jacobi_svd, qr_r_only,
};
use crate::metrics::Metrics;
use crate::obs;
use crate::obs::names as obs_names;
use crate::util::parallel::{DisjointOut, ParallelPool};

/// The native (pure Rust) compute backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

/// Minimum estimated flops in a batch before the pool dispatch pays for
/// itself (a condvar wake + join is ~a few µs; below this the serial loop
/// wins). Results are identical either way — this is purely a scheduling
/// threshold.
const PAR_MIN_FLOPS: usize = 65_536;

/// One block of a batched GEMM: op(A)·op(B) on the shared microkernels.
#[inline]
fn gemm_block(
    m: usize,
    k: usize,
    n: usize,
    trans_a: bool,
    trans_b: bool,
    accumulate: bool,
    ab: &[f64],
    bb: &[f64],
    cb: &mut [f64],
) {
    match (trans_a, trans_b) {
        (false, false) => gemm_nn(m, k, n, ab, bb, cb, accumulate),
        (true, false) => gemm_tn(m, k, n, ab, bb, cb, accumulate),
        (false, true) => gemm_nt(m, k, n, ab, bb, cb, accumulate),
        // Not used by any marshaled phase; direct kernel (the old path
        // composed this through a per-call Aᵀ temporary — the parallel
        // dispatch is allocation-free, so the kernel must be too).
        (true, true) => gemm_tt(m, k, n, ab, bb, cb, accumulate),
    }
}

/// Debug-build verification of the §3.2 contract the parallel dispatch
/// relies on: output offsets of one call must be pairwise disjoint at
/// block size `len`.
#[cfg(debug_assertions)]
fn debug_check_disjoint(offsets: &[usize], len: usize) {
    let mut sorted = offsets.to_vec();
    sorted.sort_unstable();
    for w in sorted.windows(2) {
        assert!(
            w[0] + len <= w[1],
            "batched output offsets overlap: [{}, {}+{len}) and [{}, {}+{len}) — \
             the conflict-free batch contract is violated",
            w[0],
            w[0],
            w[1],
            w[1]
        );
    }
}

#[cfg(not(debug_assertions))]
fn debug_check_disjoint(_offsets: &[usize], _len: usize) {}

impl NativeBackend {
    /// [`ComputeBackend::batched_gemm`] over an explicit pool (the trait
    /// method uses the process-global one). Exposed so tests and benches
    /// can pin the dispatch width without touching process state.
    #[allow(clippy::too_many_arguments)]
    pub fn batched_gemm_on(
        &self,
        pool: &ParallelPool,
        dims: GemmDims,
        a: BatchRef<'_>,
        b: BatchRef<'_>,
        c_data: &mut [f64],
        c_offsets: &[usize],
        metrics: &mut Metrics,
    ) {
        let GemmDims { nb, m, k, n, trans_a, trans_b, accumulate } = dims;
        assert_eq!(a.offsets.len(), nb);
        assert_eq!(b.offsets.len(), nb);
        assert_eq!(c_offsets.len(), nb);
        let (a_sz, b_sz, c_sz) = (m * k, k * n, m * n);
        debug_check_disjoint(c_offsets, c_sz);
        let out = DisjointOut::new(c_data);
        let run_blocks = |lo: usize, hi: usize| {
            for i in lo..hi {
                let ab = &a.data[a.offsets[i]..a.offsets[i] + a_sz];
                let bb = &b.data[b.offsets[i]..b.offsets[i] + b_sz];
                // SAFETY: §3.2 conflict-free batches — every c offset of
                // this call is distinct and blocks share one size, so the
                // windows are pairwise disjoint (debug-asserted above) and
                // each is claimed by exactly one chunk.
                let cb = unsafe { out.slice_mut(c_offsets[i], c_sz) };
                gemm_block(m, k, n, trans_a, trans_b, accumulate, ab, bb, cb);
            }
        };
        if nb >= 2 && pool.width() > 1 && 2 * nb * m * k * n >= PAR_MIN_FLOPS {
            pool.run(nb, &run_blocks);
        } else {
            run_blocks(0, nb);
        }
        metrics.gemm(nb, m, k, n);
    }

    /// [`ComputeBackend::batched_qr`] over an explicit pool.
    #[allow(clippy::too_many_arguments)]
    pub fn batched_qr_on(
        &self,
        pool: &ParallelPool,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        q: &mut [f64],
        r: &mut [f64],
        metrics: &mut Metrics,
    ) {
        let (a_sz, r_sz) = (rows * cols, cols * cols);
        let q_out = DisjointOut::new(q);
        let r_out = DisjointOut::new(r);
        let run_blocks = |lo: usize, hi: usize| {
            for i in lo..hi {
                let (qi, ri) = householder_qr(rows, cols, &a[i * a_sz..(i + 1) * a_sz]);
                // SAFETY: block i's output windows are contiguous
                // i-indexed stripes — disjoint by construction.
                unsafe { q_out.slice_mut(i * a_sz, a_sz) }.copy_from_slice(&qi);
                unsafe { r_out.slice_mut(i * r_sz, r_sz) }.copy_from_slice(&ri);
            }
        };
        if nb >= 2 && pool.width() > 1 && 2 * nb * rows * cols * cols >= PAR_MIN_FLOPS {
            pool.run(nb, &run_blocks);
        } else {
            run_blocks(0, nb);
        }
        metrics.qr(nb, rows, cols);
    }

    /// [`ComputeBackend::batched_qr_r`] over an explicit pool.
    #[allow(clippy::too_many_arguments)]
    pub fn batched_qr_r_on(
        &self,
        pool: &ParallelPool,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        r: &mut [f64],
        metrics: &mut Metrics,
    ) {
        let (a_sz, r_sz) = (rows * cols, cols * cols);
        let r_out = DisjointOut::new(r);
        let run_blocks = |lo: usize, hi: usize| {
            for i in lo..hi {
                let ri = qr_r_only(rows, cols, &a[i * a_sz..(i + 1) * a_sz]);
                // SAFETY: contiguous i-indexed stripes — disjoint.
                unsafe { r_out.slice_mut(i * r_sz, r_sz) }.copy_from_slice(&ri);
            }
        };
        if nb >= 2 && pool.width() > 1 && 2 * nb * rows * cols * cols >= PAR_MIN_FLOPS {
            pool.run(nb, &run_blocks);
        } else {
            run_blocks(0, nb);
        }
        metrics.qr(nb, rows, cols);
    }

    /// [`ComputeBackend::batched_svd`] over an explicit pool.
    #[allow(clippy::too_many_arguments)]
    pub fn batched_svd_on(
        &self,
        pool: &ParallelPool,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        u: &mut [f64],
        s: &mut [f64],
        v: &mut [f64],
        metrics: &mut Metrics,
    ) {
        let (a_sz, v_sz) = (rows * cols, cols * cols);
        let u_out = DisjointOut::new(u);
        let s_out = DisjointOut::new(s);
        let v_out = DisjointOut::new(v);
        let run_blocks = |lo: usize, hi: usize| {
            for i in lo..hi {
                let (ui, si, vi) = jacobi_svd(rows, cols, &a[i * a_sz..(i + 1) * a_sz]);
                // SAFETY: contiguous i-indexed stripes — disjoint.
                unsafe { u_out.slice_mut(i * a_sz, a_sz) }.copy_from_slice(&ui);
                unsafe { s_out.slice_mut(i * cols, cols) }.copy_from_slice(&si);
                unsafe { v_out.slice_mut(i * v_sz, v_sz) }.copy_from_slice(&vi);
            }
        };
        // Jacobi sweeps cost well over the nominal 14·m·n² estimate, so
        // parallelize eagerly.
        if nb >= 2 && pool.width() > 1 && 14 * nb * rows * cols * cols >= PAR_MIN_FLOPS {
            pool.run(nb, &run_blocks);
        } else {
            run_blocks(0, nb);
        }
        metrics.svd(nb, rows, cols);
    }
}

impl ComputeBackend for NativeBackend {
    fn name(&self) -> &str {
        "native"
    }

    fn batched_gemm(
        &self,
        dims: GemmDims,
        a: BatchRef<'_>,
        b: BatchRef<'_>,
        c_data: &mut [f64],
        c_offsets: &[usize],
        metrics: &mut Metrics,
    ) {
        let _s = obs::span_arg(obs_names::BATCH_GEMM, dims.nb as u64);
        self.batched_gemm_on(ParallelPool::global(), dims, a, b, c_data, c_offsets, metrics)
    }

    fn batched_qr(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        q: &mut [f64],
        r: &mut [f64],
        metrics: &mut Metrics,
    ) {
        let _s = obs::span_arg(obs_names::BATCH_QR, nb as u64);
        self.batched_qr_on(ParallelPool::global(), nb, rows, cols, a, q, r, metrics)
    }

    fn batched_qr_r(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        r: &mut [f64],
        metrics: &mut Metrics,
    ) {
        let _s = obs::span_arg(obs_names::BATCH_QR, nb as u64);
        self.batched_qr_r_on(ParallelPool::global(), nb, rows, cols, a, r, metrics)
    }

    fn batched_svd(
        &self,
        nb: usize,
        rows: usize,
        cols: usize,
        a: &[f64],
        u: &mut [f64],
        s: &mut [f64],
        v: &mut [f64],
        metrics: &mut Metrics,
    ) {
        let _s = obs::span_arg(obs_names::BATCH_SVD, nb as u64);
        self.batched_svd_on(ParallelPool::global(), nb, rows, cols, a, u, s, v, metrics)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::contiguous_offsets;
    use crate::util::testing::assert_allclose;
    use crate::util::Prng;

    #[test]
    fn batched_gemm_matches_singles() {
        let mut rng = Prng::new(30);
        let (nb, m, k, n) = (5, 3, 4, 2);
        let a = rng.normal_vec(nb * m * k);
        let b = rng.normal_vec(nb * k * n);
        let mut c = vec![0.0; nb * m * n];
        let be = NativeBackend;
        let mut mt = Metrics::new();
        be.batched_gemm(
            GemmDims { nb, m, k, n, trans_a: false, trans_b: false, accumulate: false },
            BatchRef { data: &a, offsets: &contiguous_offsets(nb, m * k) },
            BatchRef { data: &b, offsets: &contiguous_offsets(nb, k * n) },
            &mut c,
            &contiguous_offsets(nb, m * n),
            &mut mt,
        );
        for i in 0..nb {
            let mut want = vec![0.0; m * n];
            crate::linalg::gemm_nn(m, k, n, &a[i * m * k..], &b[i * k * n..], &mut want, false);
            assert_allclose(&c[i * m * n..(i + 1) * m * n], &want, 1e-14, 0.0, "block");
        }
        assert_eq!(mt.flops, 2 * (nb * m * k * n) as u64);
    }

    #[test]
    fn gathered_offsets_scatter_correctly() {
        // C offsets deliberately out of order / strided.
        let be = NativeBackend;
        let mut mt = Metrics::new();
        let a = vec![1.0, 2.0]; // two 1x1 blocks
        let b = vec![10.0, 20.0];
        let mut c = vec![0.0; 10];
        be.batched_gemm(
            GemmDims { nb: 2, m: 1, k: 1, n: 1, trans_a: false, trans_b: false, accumulate: true },
            BatchRef { data: &a, offsets: &[0, 1] },
            BatchRef { data: &b, offsets: &[0, 1] },
            &mut c,
            &[7, 3],
            &mut mt,
        );
        assert_eq!(c[7], 10.0);
        assert_eq!(c[3], 40.0);
    }

    #[test]
    fn trans_variants() {
        let mut rng = Prng::new(31);
        let (m, k, n) = (3, 5, 2);
        let at = rng.normal_vec(k * m);
        let b = rng.normal_vec(k * n);
        let be = NativeBackend;
        let mut mt = Metrics::new();
        let mut c1 = vec![0.0; m * n];
        be.batched_gemm(
            GemmDims { nb: 1, m, k, n, trans_a: true, trans_b: false, accumulate: false },
            BatchRef { data: &at, offsets: &[0] },
            BatchRef { data: &b, offsets: &[0] },
            &mut c1,
            &[0],
            &mut mt,
        );
        let mut want = vec![0.0; m * n];
        crate::linalg::gemm_tn(m, k, n, &at, &b, &mut want, false);
        assert_allclose(&c1, &want, 1e-14, 0.0, "tn");
    }

    #[test]
    fn double_transpose_variant_is_allocation_free_kernel() {
        let mut rng = Prng::new(33);
        let (m, k, n) = (4, 3, 5);
        let at = rng.normal_vec(k * m); // A stored k x m
        let bt = rng.normal_vec(n * k); // B stored n x k
        let be = NativeBackend;
        let mut mt = Metrics::new();
        let mut c = vec![0.0; m * n];
        be.batched_gemm(
            GemmDims { nb: 1, m, k, n, trans_a: true, trans_b: true, accumulate: false },
            BatchRef { data: &at, offsets: &[0] },
            BatchRef { data: &bt, offsets: &[0] },
            &mut c,
            &[0],
            &mut mt,
        );
        let mut want = vec![0.0; m * n];
        crate::linalg::gemm_tt(m, k, n, &at, &bt, &mut want, false);
        assert_allclose(&c, &want, 1e-14, 0.0, "tt");
    }

    #[test]
    fn batched_qr_and_svd_roundtrip() {
        let mut rng = Prng::new(32);
        let (nb, rows, cols) = (4, 8, 3);
        let a = rng.normal_vec(nb * rows * cols);
        let be = NativeBackend;
        let mut mt = Metrics::new();
        let mut q = vec![0.0; nb * rows * cols];
        let mut r = vec![0.0; nb * cols * cols];
        be.batched_qr(nb, rows, cols, &a, &mut q, &mut r, &mut mt);
        for i in 0..nb {
            let mut qr = vec![0.0; rows * cols];
            crate::linalg::gemm_nn(
                rows,
                cols,
                cols,
                &q[i * rows * cols..],
                &r[i * cols * cols..],
                &mut qr,
                false,
            );
            assert_allclose(&qr, &a[i * rows * cols..(i + 1) * rows * cols], 1e-10, 1e-10, "qr");
        }
        let mut u = vec![0.0; nb * rows * cols];
        let mut s = vec![0.0; nb * cols];
        let mut v = vec![0.0; nb * cols * cols];
        be.batched_svd(nb, rows, cols, &a, &mut u, &mut s, &mut v, &mut mt);
        for i in 0..nb {
            // descending singular values
            let si = &s[i * cols..(i + 1) * cols];
            for w in si.windows(2) {
                assert!(w[0] >= w[1] - 1e-12);
            }
        }
    }

    #[test]
    fn parallel_pool_dispatch_is_bitwise_serial() {
        // A batch big enough to clear PAR_MIN_FLOPS, run on an explicit
        // 4-wide pool vs the serial loop: outputs must match bit for bit.
        let mut rng = Prng::new(34);
        let (nb, m, k, n) = (64, 8, 8, 8);
        let a = rng.normal_vec(nb * m * k);
        let b = rng.normal_vec(nb * k * n);
        let dims = GemmDims { nb, m, k, n, trans_a: false, trans_b: false, accumulate: false };
        let ao = contiguous_offsets(nb, m * k);
        let bo = contiguous_offsets(nb, k * n);
        let co = contiguous_offsets(nb, m * n);
        let be = NativeBackend;
        let pool4 = ParallelPool::new(4);
        let pool1 = ParallelPool::new(1);
        let mut c_par = vec![0.0; nb * m * n];
        let mut c_ser = vec![0.0; nb * m * n];
        let mut mt = Metrics::new();
        be.batched_gemm_on(
            &pool4,
            dims,
            BatchRef { data: &a, offsets: &ao },
            BatchRef { data: &b, offsets: &bo },
            &mut c_par,
            &co,
            &mut mt,
        );
        be.batched_gemm_on(
            &pool1,
            dims,
            BatchRef { data: &a, offsets: &ao },
            BatchRef { data: &b, offsets: &bo },
            &mut c_ser,
            &co,
            &mut mt,
        );
        assert_eq!(c_par, c_ser, "parallel dispatch must be bitwise-identical to serial");
    }
}
