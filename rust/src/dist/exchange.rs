//! Communication-volume-optimized exchange plans (§4.1).
//!
//! During the distributed HGEMV upsweep each rank computes the x̂
//! coefficients of its own branch; the per-level tree multiplication then
//! needs, for every coupling block (t, s) whose row t it owns, the column
//! coefficients x̂_s — which live on owner(s). A naive implementation
//! allgathers every level's coefficients; the optimized plan precomputes,
//! per (level, destination rank, source rank), exactly the set of column
//! nodes some owned block references, and ships only those. The per-rank
//! byte counters feed `Metrics::bytes_sent`/`messages` and the Fig. 8
//! comm streams.

use std::collections::{BTreeMap, BTreeSet};

use crate::admissibility::MatrixStructure;
use crate::dist::Decomposition;
use crate::tree::H2Matrix;

/// The exchange sets of one tree level.
#[derive(Clone, Debug, Default)]
pub struct LevelExchange {
    /// `recv[rank]` = (source rank, column nodes to receive), sorted by
    /// source; node lists sorted and deduplicated.
    pub recv: Vec<Vec<(usize, Vec<u32>)>>,
    /// `send[rank]` = (destination rank, column nodes to send) — the
    /// transpose of `recv`.
    pub send: Vec<Vec<(usize, Vec<u32>)>>,
}

/// Per-level send/recv sets of basis coefficients for one decomposition.
#[derive(Clone, Debug)]
pub struct ExchangePlan {
    pub decomp: Decomposition,
    /// `levels[l]` for l in 0..=depth; levels above the C-level are empty
    /// (the top subtree is handled by the master gather/scatter).
    pub levels: Vec<LevelExchange>,
}

impl ExchangePlan {
    /// Precompute the exchange sets of `a` under decomposition `d`.
    pub fn build(a: &H2Matrix, d: Decomposition) -> Self {
        assert_eq!(d.depth, a.depth(), "decomposition built for a different tree");
        let levels: Vec<&[(u32, u32)]> = a.coupling.iter().map(|cl| cl.pairs.as_slice()).collect();
        Self::from_level_pairs(&levels, d)
    }

    /// Precompute the exchange sets from the index-only
    /// [`MatrixStructure`] — what a sharded worker process has (it never
    /// assembles the global matrix, but the structure is O(N) index data
    /// every rank derives from the replicated cluster tree).
    pub fn build_from_structure(s: &MatrixStructure, d: Decomposition) -> Self {
        let levels: Vec<&[(u32, u32)]> = s.coupling.iter().map(|v| v.as_slice()).collect();
        Self::from_level_pairs(&levels, d)
    }

    fn from_level_pairs(pairs_by_level: &[&[(u32, u32)]], d: Decomposition) -> Self {
        assert_eq!(
            pairs_by_level.len(),
            d.depth + 1,
            "decomposition built for a different tree"
        );
        let mut levels = Vec::with_capacity(d.depth + 1);
        for (l, level_pairs) in pairs_by_level.iter().enumerate() {
            let mut need: Vec<BTreeMap<usize, BTreeSet<u32>>> = vec![BTreeMap::new(); d.p];
            if l >= d.c_level {
                for &(t, s) in level_pairs.iter() {
                    let pt = d.owner(l, t as usize);
                    let ps = d.owner(l, s as usize);
                    if pt != ps {
                        need[pt].entry(ps).or_default().insert(s);
                    }
                }
            }
            let recv: Vec<Vec<(usize, Vec<u32>)>> = need
                .iter()
                .map(|m| {
                    m.iter().map(|(&src, nodes)| (src, nodes.iter().copied().collect())).collect()
                })
                .collect();
            let mut send_map: Vec<BTreeMap<usize, Vec<u32>>> = vec![BTreeMap::new(); d.p];
            for (dst, lists) in recv.iter().enumerate() {
                for (src, nodes) in lists {
                    send_map[*src].insert(dst, nodes.clone());
                }
            }
            let send = send_map.into_iter().map(|m| m.into_iter().collect()).collect();
            levels.push(LevelExchange { recv, send });
        }
        ExchangePlan { decomp: d, levels }
    }

    /// Optimized bytes received by `rank` for one `nv`-vector product:
    /// only the column nodes its coupling rows reference, f64 coefficients
    /// of k_l values per node per vector.
    pub fn bytes_into(&self, a: &H2Matrix, rank: usize, nv: usize) -> usize {
        let mut total = 0;
        for l in self.decomp.c_level..=a.depth() {
            // x̂ coefficients live in the V (column) tree.
            let k = a.v.ranks[l];
            for (_, nodes) in &self.levels[l].recv[rank] {
                total += nodes.len() * k * nv * 8;
            }
        }
        total
    }

    /// Naive allgather bytes received by `rank`: every other rank's
    /// complete coefficient set at every distributed level.
    pub fn naive_bytes_into(&self, a: &H2Matrix, rank: usize, nv: usize) -> usize {
        debug_assert!(rank < self.decomp.p);
        let c = self.decomp.c_level;
        let mut total = 0;
        for l in c..=a.depth() {
            let others = (1usize << l) - (1usize << (l - c));
            total += others * a.v.ranks[l] * nv * 8;
        }
        total
    }

    /// Number of point-to-point messages `rank` receives in one exchange.
    pub fn messages_into(&self, rank: usize) -> usize {
        self.levels.iter().map(|le| le.recv[rank].len()).sum()
    }

    /// The merged, sorted set of remote column nodes `rank` receives at
    /// `level` — the x̂ halo of its branch-local workspace
    /// ([`crate::dist::branch::BranchWorkspace`]). Receive sets are
    /// disjoint across sources (every node has exactly one owner), so the
    /// concatenation is duplicate-free.
    pub fn halo_nodes(&self, level: usize, rank: usize) -> Vec<u32> {
        let mut nodes: Vec<u32> = self.levels[level].recv[rank]
            .iter()
            .flat_map(|(_, ns)| ns.iter().copied())
            .collect();
        nodes.sort_unstable();
        nodes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::admissibility::MatrixStructure;
    use crate::clustering::ClusterTree;
    use crate::geometry::PointSet;
    use crate::tree::H2Matrix;

    /// A hand-built depth-2 tree over 16 1D-ish points: 4 leaves of 4
    /// points, rank 2 at every level, with a synthetic coupling structure.
    fn hand_tree() -> H2Matrix {
        let mut ps = PointSet::new(1);
        for i in 0..16 {
            ps.push(&[i as f64]);
        }
        let tree = ClusterTree::build(ps, 4);
        assert_eq!(tree.depth, 2);
        let structure = MatrixStructure {
            // level 2: the two middle leaves talk across the branch cut,
            // and the outer leaves talk to each other.
            coupling: vec![Vec::new(), Vec::new(), vec![(0, 3), (1, 2), (2, 1), (3, 0)]],
            dense: vec![(0, 0), (0, 1), (1, 0), (1, 1), (2, 2), (2, 3), (3, 2), (3, 3)],
        };
        H2Matrix::from_structure(tree, &structure, &[2, 2, 2], 4)
    }

    #[test]
    fn bytes_match_hand_count() {
        let a = hand_tree();
        let d = Decomposition::new(2, 2).unwrap();
        let plan = ExchangePlan::build(&a, d);
        // Rank 0 owns leaves {0, 1}; its rows reference columns {3, 2} on
        // rank 1: 2 nodes x k=2 x 8 bytes = 32 bytes, one message.
        assert_eq!(plan.bytes_into(&a, 0, 1), 32);
        assert_eq!(plan.bytes_into(&a, 1, 1), 32);
        assert_eq!(plan.messages_into(0), 1);
        // nv scales linearly.
        assert_eq!(plan.bytes_into(&a, 0, 4), 128);
        // Naive allgather: level 1 one foreign node + level 2 two foreign
        // nodes, k=2 -> (1 + 2) * 2 * 8 = 48 bytes.
        assert_eq!(plan.naive_bytes_into(&a, 0, 1), 48);
    }

    #[test]
    fn recv_and_send_are_transposes() {
        let a = hand_tree();
        let plan = ExchangePlan::build(&a, Decomposition::new(2, 2).unwrap());
        for le in &plan.levels {
            for (dst, lists) in le.recv.iter().enumerate() {
                for (src, nodes) in lists {
                    let sent = le.send[*src]
                        .iter()
                        .find(|(d2, _)| *d2 == dst)
                        .map(|(_, n)| n.clone())
                        .unwrap_or_default();
                    assert_eq!(&sent, nodes);
                }
            }
        }
    }

    #[test]
    fn optimized_never_exceeds_naive() {
        let points = PointSet::grid_2d(16, 1.0);
        let kernel = crate::construct::ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = crate::config::H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
        let a = crate::construct::build_h2(points, &kernel, &cfg);
        for p in [2usize, 4] {
            if a.depth() < p.trailing_zeros() as usize {
                continue;
            }
            let plan = ExchangePlan::build(&a, Decomposition::new(p, a.depth()).unwrap());
            for r in 0..p {
                assert!(plan.bytes_into(&a, r, 3) <= plan.naive_bytes_into(&a, r, 3));
            }
        }
    }

    #[test]
    fn halo_nodes_are_sorted_disjoint_union_of_recv_sets() {
        let a = hand_tree();
        let plan = ExchangePlan::build(&a, Decomposition::new(2, 2).unwrap());
        for rank in 0..2 {
            for l in 1..=2 {
                let halo = plan.halo_nodes(l, rank);
                let mut expect: Vec<u32> = plan.levels[l].recv[rank]
                    .iter()
                    .flat_map(|(_, ns)| ns.iter().copied())
                    .collect();
                expect.sort_unstable();
                expect.dedup();
                assert_eq!(halo, expect, "rank {rank} level {l}");
                // Halo nodes are never owned by the receiver.
                for &n in &halo {
                    assert_ne!(plan.decomp.owner(l, n as usize), rank);
                }
            }
        }
    }

    #[test]
    fn single_rank_plan_is_empty() {
        let a = hand_tree();
        let plan = ExchangePlan::build(&a, Decomposition::new(1, 2).unwrap());
        assert_eq!(plan.bytes_into(&a, 0, 1), 0);
        assert_eq!(plan.naive_bytes_into(&a, 0, 1), 0);
        assert_eq!(plan.messages_into(0), 0);
    }
}
