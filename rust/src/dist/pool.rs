//! Persistent rank thread pool.
//!
//! The PR-2 executor spawned its rank threads with `std::thread::scope`
//! *per product*, so a chained workload (CG with the H² operator: one
//! HGEMV per iteration) paid thread spawn/join latency every iteration.
//! This pool keeps the rank threads parked between products and replays
//! the scoped-execution contract on top of them: [`RankPool::scoped`]
//! blocks until every submitted job has completed, so jobs may borrow
//! from the caller's stack exactly as `thread::scope` allows.
//!
//! One global pool serves the process ([`RankPool::global`]); it grows to
//! the largest rank count ever requested and never shrinks. `scoped`
//! holds the pool lock for the duration of a batch, so concurrent
//! distributed products serialize on the pool (matching the one-
//! interconnect-per-process reality) — jobs themselves never touch the
//! pool, so this cannot deadlock.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Mutex, OnceLock};

/// A type-erased job as stored by the worker channels.
type Job = Box<dyn FnOnce() + Send + 'static>;

struct Worker {
    /// (job, job index, completion signal). The worker drops the job —
    /// and with it every borrow it captured — *before* signalling, so a
    /// completed batch holds no references into the caller's stack.
    tx: Sender<(Job, usize, Sender<usize>)>,
}

/// A grow-only pool of parked rank threads with scoped (borrow-friendly)
/// batch execution.
pub struct RankPool {
    workers: Mutex<Vec<Worker>>,
}

static GLOBAL: OnceLock<RankPool> = OnceLock::new();

impl Default for RankPool {
    fn default() -> Self {
        RankPool::new()
    }
}

impl RankPool {
    /// An empty pool (grows on first use). Prefer [`RankPool::global`];
    /// private pools exist for tests and embedders that want isolation.
    pub fn new() -> RankPool {
        RankPool { workers: Mutex::new(Vec::new()) }
    }

    /// The process-wide pool.
    pub fn global() -> &'static RankPool {
        GLOBAL.get_or_init(RankPool::new)
    }

    /// Current number of parked worker threads (observability/tests).
    pub fn size(&self) -> usize {
        self.workers.lock().expect("pool lock").len()
    }

    /// Run every job on its own pool thread (job i on worker i) and block
    /// until all have finished; results come back in job order. Panics in
    /// a job are caught on the worker — the worker survives for the next
    /// product — and re-raised here after the whole batch has completed.
    ///
    /// Jobs may borrow non-`'static` data: the borrow cannot outlive this
    /// call, which only returns once every job has run to completion (the
    /// same guarantee `std::thread::scope` gives). If a worker dies
    /// without completing its job the process aborts — continuing would
    /// leave a live borrow with no owner to wait on.
    pub fn scoped<'scope, R: Send + 'scope>(
        &self,
        jobs: Vec<Box<dyn FnOnce() -> R + Send + 'scope>>,
    ) -> Vec<R> {
        let n = jobs.len();
        if n == 0 {
            return Vec::new();
        }
        let results: Vec<Mutex<Option<std::thread::Result<R>>>> =
            (0..n).map(|_| Mutex::new(None)).collect();
        let (done_tx, done_rx) = channel::<usize>();

        {
            // Grow to the requested width (never shrink); hold the lock
            // for the whole batch.
            let mut workers = self.workers.lock().expect("pool lock");
            while workers.len() < n {
                workers.push(spawn_worker(workers.len()));
            }
            for (i, job) in jobs.into_iter().enumerate() {
                let slot = &results[i];
                // The wrapper catches panics itself, so the worker thread
                // survives and `f()` never unwinds across the channel loop.
                let wrapped: Box<dyn FnOnce() + Send + '_> = Box::new(move || {
                    let outcome = catch_unwind(AssertUnwindSafe(job));
                    *slot.lock().expect("result slot") = Some(outcome);
                });
                // SAFETY: the loop below blocks until every job has
                // signalled completion (aborting the process if a worker
                // dies first), and a worker signals only *after* dropping
                // the job — so every borrow captured by `wrapped` strictly
                // outlives its use; the closure never escapes this call's
                // dynamic extent. The transmute only erases the lifetime
                // so the job fits the worker channel's `'static` item
                // type.
                let wrapped: Job = unsafe { std::mem::transmute(wrapped) };
                if workers[i].tx.send((wrapped, i, done_tx.clone())).is_err() {
                    // The worker thread is gone and the job it should have
                    // run was dropped unexecuted — its `done` signal will
                    // never come; waiting would hang and returning would
                    // dangle the remaining in-flight borrows.
                    eprintln!("h2opus rank pool: worker {i} died; aborting");
                    std::process::abort();
                }
            }
            drop(done_tx);
            let mut completed = 0usize;
            while completed < n {
                match done_rx.recv() {
                    Ok(_) => completed += 1,
                    Err(_) => {
                        eprintln!("h2opus rank pool: worker died mid-batch; aborting");
                        std::process::abort();
                    }
                }
            }
            // `workers` (the lock guard) drops here, after the batch.
        }

        results
            .into_iter()
            .map(|slot| {
                let outcome = slot
                    .into_inner()
                    .expect("result slot lock")
                    .expect("every job completed before the batch returned");
                match outcome {
                    Ok(r) => r,
                    Err(payload) => resume_unwind(payload),
                }
            })
            .collect()
    }
}

fn spawn_worker(idx: usize) -> Worker {
    let (tx, rx) = channel::<(Job, usize, Sender<usize>)>();
    std::thread::Builder::new()
        .name(format!("h2opus-rank-{idx}"))
        .spawn(move || {
            while let Ok((job, i, done)) = rx.recv() {
                job();
                // The job (and every borrow it captured) is dropped before
                // the completion signal — see `RankPool::scoped`'s SAFETY.
                let _ = done.send(i);
            }
        })
        .expect("spawning pool worker thread");
    Worker { tx }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn scoped_returns_results_in_job_order() {
        let pool = RankPool::global();
        let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> =
            (0..6).map(|i| Box::new(move || i * 10) as Box<dyn FnOnce() -> usize + Send>).collect();
        let out = pool.scoped(jobs);
        assert_eq!(out, vec![0, 10, 20, 30, 40, 50]);
    }

    #[test]
    fn scoped_jobs_may_borrow_the_stack() {
        let data = vec![1.0f64; 128];
        let sum = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
            .map(|_| {
                let data = &data;
                let sum = &sum;
                Box::new(move || {
                    sum.fetch_add(data.len(), Ordering::Relaxed);
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        RankPool::global().scoped(jobs);
        assert_eq!(sum.load(Ordering::Relaxed), 4 * 128);
    }

    #[test]
    fn pool_threads_are_reused_across_batches() {
        // A private pool: the global one is shared with concurrently
        // running tests, so its size is not observable race-free.
        let pool = RankPool::new();
        let jobs = |n: usize| -> Vec<Box<dyn FnOnce() -> usize + Send>> {
            (0..n).map(|i| Box::new(move || i) as Box<dyn FnOnce() -> usize + Send>).collect()
        };
        pool.scoped(jobs(3));
        assert_eq!(pool.size(), 3);
        pool.scoped(jobs(3));
        assert_eq!(pool.size(), 3, "second batch must reuse parked threads");
        pool.scoped(jobs(5));
        assert_eq!(pool.size(), 5, "pool must grow on demand");
    }

    #[test]
    fn job_panic_propagates_after_batch() {
        let result = std::panic::catch_unwind(|| {
            let jobs: Vec<Box<dyn FnOnce() -> usize + Send>> = vec![
                Box::new(|| 1),
                Box::new(|| panic!("rank job failed")),
            ];
            RankPool::global().scoped(jobs);
        });
        assert!(result.is_err(), "panic in a job must reach the caller");
        // The pool survives the panic.
        let out = RankPool::global()
            .scoped(vec![Box::new(|| 7usize) as Box<dyn FnOnce() -> usize + Send>]);
        assert_eq!(out, vec![7]);
    }
}
