//! The distributed-memory runtime layer (§2.2, §4): simulated MPI over an
//! α-β network model, in *virtual time*.
//!
//! The paper distributes an H² matrix over P processes by assigning each
//! one a branch of the tree below the C-level (log₂P) plus a replicated
//! top subtree. This layer reproduces that architecture with virtual
//! ranks on one address space:
//!
//! - [`Decomposition`] — branch ownership: rank r owns the contiguous node
//!   range `[r·2^(l-C), (r+1)·2^(l-C))` at every level l ≥ C;
//! - [`ExchangePlan`] (also reachable as `dist::plan`) — the §4.1
//!   communication-volume optimization: per (level, rank, source) sets of
//!   basis-coefficient nodes actually referenced by owned coupling rows,
//!   with [`ExchangePlan::bytes_into`] / [`ExchangePlan::naive_bytes_into`]
//!   accounting against the naive allgather;
//! - [`hgemv`] — the distributed matrix-(multi)vector product: executes
//!   the exact serial phase functions of [`crate::matvec`] sliced per
//!   branch (bitwise-identical results), and prices the schedule with an
//!   analytic compute cost model plus the network model, overlapping
//!   communication with diagonal-block compute (§4.2) and emitting Fig. 8
//!   style compute/comm/lowprio traces;
//! - [`compress`] — distributed algebraic recompression: the serial
//!   per-level compression phases replayed in virtual time (levels at or
//!   below the C-level run concurrently at cost/P, levels above serialize
//!   on the master);
//! - [`threaded`] — the *real* executor: [`ExecMode::Threaded`] runs each
//!   rank's branch slice on its own pooled OS thread ([`pool`]),
//!   exchanging level-C coefficients through a pluggable [`transport`]
//!   driven by the same [`ExchangePlan`], bitwise identical to the serial
//!   product, and reports measured wall-clock (optionally a measured
//!   Chrome trace) alongside the virtual time;
//! - [`branch`] — branch-local marshaling plans and O(N/P) workspaces
//!   (own nodes + level-C halo), so per-rank memory shrinks with P as the
//!   paper's distributed format promises;
//! - [`shard`] — per-rank *matrix storage*: a [`ShardedMatrix`] holds only
//!   the owned basis-subtree slice, owned coupling/dense rows and the
//!   replicated top subtree, with local↔global translation tables; worker
//!   processes build shards directly from the kernel
//!   ([`crate::construct::build_branch`]) and never allocate the global
//!   matrix — the out-of-core-N frontier;
//! - [`transport`] — the interconnects: in-process channels
//!   ([`transport::inproc`]), real worker *subprocesses* over Unix domain
//!   sockets ([`transport::socket`] — `h2opus worker` ranks with true
//!   per-process O(N/P) memory), and a recording wrapper
//!   ([`transport::recording`]) stamping per-message `Instant`s for the
//!   measured traces;
//! - [`supervisor`] — crash recovery over the socket transport: a
//!   [`SessionSupervisor`] reaps a poisoned crew, respawns it from the
//!   recorded [`transport::MatrixJob`], re-compresses to the recorded τ
//!   and replays in-flight products exactly once, bounded by a rebuild
//!   budget ([`transport::chaos`] provides the deterministic fault
//!   injection that exercises this path).
//!
//! # Example
//!
//! ```
//! use h2opus::backend::native::NativeBackend;
//! use h2opus::config::H2Config;
//! use h2opus::construct::{build_h2, ExponentialKernel};
//! use h2opus::dist::hgemv::{dist_hgemv, DistOptions};
//! use h2opus::geometry::PointSet;
//!
//! let a = build_h2(
//!     PointSet::grid_2d(16, 1.0), // N = 256
//!     &ExponentialKernel { dim: 2, corr_len: 0.1 },
//!     &H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 },
//! );
//! let n = a.n();
//! let x = vec![1.0; n];
//! let mut y = vec![0.0; n];
//! // P = 4 virtual ranks, one right-hand side.
//! let rep = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &DistOptions::default());
//! assert!(rep.time > 0.0);
//! assert!(rep.metrics.bytes_sent > 0); // §4.1 comm volume is accounted
//!
//! // The §4.1 plan itself:
//! let d = h2opus::dist::Decomposition::new(4, a.depth()).unwrap();
//! let plan = h2opus::dist::ExchangePlan::build(&a, d);
//! for r in 0..4 {
//!     assert!(plan.bytes_into(&a, r, 1) <= plan.naive_bytes_into(&a, r, 1));
//! }
//! ```

pub mod branch;
pub mod compress;
pub mod decomposition;
pub mod exchange;
pub mod hgemv;
pub mod pool;
pub mod shard;
#[cfg(unix)]
pub mod supervisor;
pub mod threaded;
pub mod transport;

/// Legacy path: the exchange plan has historically been imported through
/// `dist::plan` (e.g. by the property tests).
pub use self::exchange as plan;

pub use self::branch::{BranchIo, BranchPlan, BranchWorkspace};
pub use self::compress::{
    compress_branch, compress_sharded, compress_top, dist_compress, DistCompressReport,
};
pub use self::decomposition::{Decomposition, DecompositionError};
pub use self::exchange::{ExchangePlan, LevelExchange};
pub use self::hgemv::{dist_hgemv, CostModel, DistHgemv, DistOptions, DistReport};
pub use self::pool::RankPool;
pub use self::shard::ShardedMatrix;
#[cfg(unix)]
pub use self::supervisor::{RecoveryStats, SessionSupervisor, SupervisorOptions};
pub use self::threaded::ExecMode;
