//! Branch decomposition of the H² tree over P virtual ranks (§2.2).
//!
//! With P a power of two and C = log₂P the *C-level*, rank r owns the
//! branch rooted at node r of level C: at every level l ≥ C it owns the
//! contiguous node range `[r·2^(l-C), (r+1)·2^(l-C))`. The subtree above
//! the C-level (levels 0..C) is replicated conceptually but *processed* on
//! the master rank 0, as low-priority work overlapped with the branches'
//! local phases (§4.2).

use std::fmt;
use std::ops::Range;

/// Why a (P, depth) pair cannot be decomposed into branches.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DecompositionError {
    /// P is zero or not a power of two, so the tree's sibling pairs cannot
    /// be split into equal branches.
    NotPowerOfTwo { p: usize },
    /// log₂P exceeds the tree depth: a rank must own at least one complete
    /// branch (one level-C node).
    TooShallow { p: usize, c_level: usize, depth: usize },
}

impl fmt::Display for DecompositionError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            DecompositionError::NotPowerOfTwo { p } => write!(
                f,
                "rank count must be a nonzero power of two (each rank owns one complete \
                 level-C branch of the binary cluster tree), got P = {p}"
            ),
            DecompositionError::TooShallow { p, c_level, depth } => write!(
                f,
                "P = {p} ranks require a cluster tree of depth >= {c_level} (the C-level \
                 log2 P) so every rank owns a complete branch, got depth {depth}"
            ),
        }
    }
}

impl std::error::Error for DecompositionError {}

/// Assignment of tree branches to P virtual ranks at the split level.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Decomposition {
    /// Number of virtual ranks (power of two).
    pub p: usize,
    /// Depth of the decomposed tree (leaves at this level).
    pub depth: usize,
    /// The split level C = log₂P; each rank owns one level-C node's branch.
    pub c_level: usize,
}

impl Decomposition {
    /// Decompose a depth-`depth` tree over `p` ranks.
    ///
    /// Errors unless `p` is a power of two with log₂p ≤ depth (a rank must
    /// own at least one complete branch); the error message names the
    /// offending parameter.
    pub fn new(p: usize, depth: usize) -> Result<Self, DecompositionError> {
        if p == 0 || !p.is_power_of_two() {
            return Err(DecompositionError::NotPowerOfTwo { p });
        }
        let c_level = p.trailing_zeros() as usize;
        if c_level > depth {
            return Err(DecompositionError::TooShallow { p, c_level, depth });
        }
        Ok(Decomposition { p, depth, c_level })
    }

    /// Owning rank of node `j` at level `l`. Nodes above the C-level belong
    /// to the master's replicated top subtree and report rank 0.
    pub fn owner(&self, l: usize, j: usize) -> usize {
        debug_assert!(l <= self.depth && j < (1 << l));
        if l < self.c_level {
            0
        } else {
            j >> (l - self.c_level)
        }
    }

    /// The contiguous node range rank `rank` owns at level `l` (requires
    /// l ≥ C: above the C-level no rank owns nodes).
    pub fn own_range(&self, rank: usize, l: usize) -> Range<usize> {
        debug_assert!(rank < self.p);
        assert!(l >= self.c_level, "level {l} is above the C-level {}", self.c_level);
        let width = 1usize << (l - self.c_level);
        rank * width..(rank + 1) * width
    }

    /// Leaves per rank.
    pub fn leaves_per_rank(&self) -> usize {
        1usize << (self.depth - self.c_level)
    }

    /// Nodes of level l one rank's branch owns: 2^(l-C) (requires l ≥ C).
    pub fn branch_width(&self, l: usize) -> usize {
        assert!(l >= self.c_level, "level {l} is above the C-level {}", self.c_level);
        1usize << (l - self.c_level)
    }

    /// Branch-local index of node `j` at level l within its owner's
    /// contiguous range — the rebasing the branch-local marshaling plans
    /// ([`crate::dist::branch::BranchPlan`]) apply to own-node offsets.
    pub fn local_index(&self, rank: usize, l: usize, j: usize) -> usize {
        let own = self.own_range(rank, l);
        debug_assert!(own.contains(&j), "node {j} at level {l} is not owned by rank {rank}");
        j - own.start
    }

    pub fn num_ranks(&self) -> usize {
        self.p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ownership_partitions_every_level() {
        // Every node at or below the C-level is owned exactly once, and
        // own_range agrees with owner.
        for p in [1usize, 2, 4, 8] {
            let d = Decomposition::new(p, 5).unwrap();
            for l in d.c_level..=d.depth {
                let mut owned = vec![0usize; 1 << l];
                for r in 0..p {
                    for j in d.own_range(r, l) {
                        owned[j] += 1;
                        assert_eq!(d.owner(l, j), r, "P={p} l={l} j={j}");
                    }
                }
                assert!(owned.iter().all(|&c| c == 1), "P={p} level {l}: {owned:?}");
            }
        }
    }

    #[test]
    fn top_subtree_reports_master() {
        let d = Decomposition::new(8, 6).unwrap();
        assert_eq!(d.c_level, 3);
        for l in 0..3 {
            for j in 0..(1 << l) {
                assert_eq!(d.owner(l, j), 0);
            }
        }
    }

    #[test]
    fn branch_width_and_local_index_agree_with_own_range() {
        let d = Decomposition::new(4, 5).unwrap();
        for l in d.c_level..=d.depth {
            for r in 0..4 {
                let own = d.own_range(r, l);
                assert_eq!(own.len(), d.branch_width(l));
                for (i, j) in own.enumerate() {
                    assert_eq!(d.local_index(r, l, j), i);
                }
            }
        }
    }

    #[test]
    fn single_rank_owns_everything() {
        let d = Decomposition::new(1, 4).unwrap();
        assert_eq!(d.c_level, 0);
        assert_eq!(d.leaves_per_rank(), 16);
        assert_eq!(d.own_range(0, 4), 0..16);
        assert_eq!(d.owner(2, 3), 0);
    }

    #[test]
    fn rejects_non_power_of_two_with_descriptive_message() {
        for p in [0usize, 3, 6, 12] {
            let err = Decomposition::new(p, 5).unwrap_err();
            assert_eq!(err, DecompositionError::NotPowerOfTwo { p });
            let msg = err.to_string();
            assert!(msg.contains("power of two"), "message must name the constraint: {msg}");
            assert!(msg.contains(&format!("P = {p}")), "message must name the value: {msg}");
        }
        // Powers of two are accepted.
        assert!(Decomposition::new(4, 5).is_ok());
    }

    #[test]
    fn rejects_too_shallow_tree_with_descriptive_message() {
        let err = Decomposition::new(8, 2).unwrap_err();
        assert_eq!(err, DecompositionError::TooShallow { p: 8, c_level: 3, depth: 2 });
        let msg = err.to_string();
        assert!(msg.contains("depth >= 3"), "message must give the required depth: {msg}");
        assert!(msg.contains("got depth 2"), "message must give the actual depth: {msg}");
        // The boundary case P = 2^depth is a valid one-leaf-per-rank split.
        assert!(Decomposition::new(4, 2).is_ok());
    }
}
