//! `dist::shard` — per-rank H² matrix storage for out-of-core N.
//!
//! Until this module existed, every rank of the distributed executor —
//! including each `h2opus worker` subprocess — constructed and held the
//! *entire* [`H2Matrix`], so the largest representable problem was bounded
//! by one process's memory. A [`ShardedMatrix`] holds, per rank, only
//! what the paper's §3 distribution assigns it:
//!
//! - its own **basis-subtree slice**: U/V leaf bases of the owned leaf
//!   range and U/V interlevel transfers of the owned nodes at every level
//!   below the C-level,
//! - the **coupling rows** (levels l ≥ C) and **dense leaf rows** whose
//!   row cluster lies in the branch,
//! - the **replicated top subtree**: the full transfers of levels 1..=C
//!   (which include the rank's own level-C boundary transfer) and the
//!   full coupling blocks of levels 0..C — O(P·k²), shared by every rank
//!   exactly as in the paper and in Börm's distributed H² layout,
//! - **translation tables** mapping local node/pair indices back to the
//!   global tree: coupling/dense pairs store `(local row, global col)`
//!   next to [`ShardCoupling::row_start`], and the leaf range rebases
//!   leaf slots.
//!
//! The cluster tree itself (points + permutation + node ranges) is O(N)
//! *index* data — orders of magnitude below the O(N·k·C_sp) matrix data —
//! and stays replicated so a rank can slice inputs/outputs and evaluate
//! admissibility-derived layouts locally.
//!
//! Two constructions produce bit-identical shards:
//!
//! - [`ShardedMatrix::from_global`] slices an assembled [`H2Matrix`]
//!   (used by the in-process threaded executor, which shares one address
//!   space), and
//! - [`crate::construct::build_branch`] materializes a shard *directly*
//!   from the kernel without ever allocating the global matrix (used by
//!   `h2opus worker` processes — the out-of-core path). Worker processes
//!   additionally run under the `H2OPUS_FORBID_FULL_MATRIX` guard, which
//!   makes any full-matrix construction a hard failure.
//!
//! Local coupling structure reuses [`CouplingLevel`] with rows rebased to
//! the branch: the per-row conflict-free batches of a shard are then
//! *exactly* the owned-row prefilter of the global batches, in the same
//! serial order — which is what keeps sharded HGEMV bitwise identical to
//! the serial product (asserted by `tests/shard.rs`).

use std::ops::Range;

use crate::admissibility::MatrixStructure;
use crate::clustering::ClusterTree;
use crate::dist::Decomposition;
use crate::tree::{CouplingLevel, DenseBlocks, H2Matrix};

/// One level of owned coupling rows: a [`CouplingLevel`] whose pairs are
/// `(local row, global col)` — local row `t` is global row
/// `row_start + t`. The CSR/batch structure over local rows coincides
/// with the owned-row prefilter of the global level's batches.
#[derive(Clone, Debug, Default)]
pub struct ShardCoupling {
    /// Global node index of local block row 0.
    pub row_start: usize,
    /// Local-row coupling level (pairs `(t_local, s_global)`).
    pub level: CouplingLevel,
}

impl ShardCoupling {
    /// Global (row, col) node pair of local pair `p`.
    pub fn global_pair(&self, p: usize) -> (usize, usize) {
        let (t, s) = self.level.pairs[p];
        (self.row_start + t as usize, s as usize)
    }
}

/// Owned dense leaf rows: a [`DenseBlocks`] whose pairs are
/// `(local leaf row, global leaf col)`.
#[derive(Clone, Debug, Default)]
pub struct ShardDense {
    /// Global leaf index of local block row 0.
    pub row_start: usize,
    /// Local-row dense blocks (pairs `(t_local, s_global)`).
    pub blocks: DenseBlocks,
}

impl ShardDense {
    /// Global (row, col) leaf pair of local pair `p`.
    pub fn global_pair(&self, p: usize) -> (usize, usize) {
        let (t, s) = self.blocks.pairs[p];
        (self.row_start + t as usize, s as usize)
    }
}

/// One rank's slice of an H² matrix (see module docs): the owned branch,
/// the replicated top subtree and the local↔global translation tables.
#[derive(Clone, Debug)]
pub struct ShardedMatrix {
    /// The full cluster tree (points, permutation, node ranges): O(N)
    /// index data, replicated on every rank.
    pub tree: ClusterTree,
    /// The decomposition this shard was cut under.
    pub decomp: Decomposition,
    /// The owning branch rank, or `None` for a top-only shard (what the
    /// socket coordinator holds: replicated top, no branch).
    pub rank: Option<usize>,
    /// Per-level U basis ranks (identical to the global tree's).
    pub u_ranks: Vec<usize>,
    /// Per-level V basis ranks.
    pub v_ranks: Vec<usize>,
    /// Padded leaf dimension m_pad.
    pub leaf_dim: usize,

    // ---- replicated top subtree (levels above the C-level) ----
    /// Full coupling levels 0..C in the global layout (empty when C = 0).
    pub top_coupling: Vec<CouplingLevel>,
    /// `top_u_transfers[l]` for l in 1..=C: the *full* level (all 2^l
    /// nodes, global layout). Index 0 is empty. Level C carries every
    /// rank's boundary transfer, so a branch rank finds its own at offset
    /// `rank · k_C · k_{C-1}`.
    pub top_u_transfers: Vec<Vec<f64>>,
    pub top_v_transfers: Vec<Vec<f64>>,

    // ---- owned branch (empty for a top-only shard) ----
    /// Globally indexed owned leaf range.
    pub leaf_range: Range<usize>,
    /// Actual row counts of the owned leaves.
    pub leaf_sizes: Vec<usize>,
    /// Owned U leaf bases: local slot j at `[j·m_pad·k ..]`.
    pub u_leaf_bases: Vec<f64>,
    pub v_leaf_bases: Vec<f64>,
    /// `u_transfers[l]` for l in C+1..=depth: owned nodes only, local
    /// layout (local node j at `[j·k_l·k_{l-1} ..]`). Lower levels empty —
    /// the level-C boundary transfer lives in the replicated top.
    pub u_transfers: Vec<Vec<f64>>,
    pub v_transfers: Vec<Vec<f64>>,
    /// `coupling[l]` for l in C..=depth: owned coupling rows. Lower
    /// levels empty (they live in `top_coupling`).
    pub coupling: Vec<ShardCoupling>,
    /// Owned dense leaf rows.
    pub dense: ShardDense,
}

/// The `(t_local, s_global)` pair list of the owned contiguous row range
/// of a globally sorted `(t, s)` list — the shard's serial-order slice.
pub(crate) fn owned_pairs(pairs: &[(u32, u32)], rows: &Range<usize>) -> Vec<(u32, u32)> {
    let lo = pairs.partition_point(|&(t, _)| (t as usize) < rows.start);
    let hi = pairs.partition_point(|&(t, _)| (t as usize) < rows.end);
    pairs[lo..hi].iter().map(|&(t, s)| (t - rows.start as u32, s)).collect()
}

impl ShardedMatrix {
    /// A zero-data shard with the full structural layout (top + branch
    /// when `rank` is given): what [`crate::construct::build_branch`]
    /// fills numerically, block by block, without a global matrix.
    pub fn zeros(
        tree: ClusterTree,
        structure: &MatrixStructure,
        ranks: &[usize],
        m_pad: usize,
        d: Decomposition,
        rank: Option<usize>,
    ) -> Self {
        let depth = tree.depth;
        assert_eq!(d.depth, depth, "decomposition built for a different tree");
        assert_eq!(structure.coupling.len(), depth + 1);
        assert_eq!(ranks.len(), depth + 1);
        let c = d.c_level;

        // Replicated top.
        let mut top_u_transfers = vec![Vec::new()];
        for l in 1..=c {
            top_u_transfers.push(vec![0.0; (1usize << l) * ranks[l] * ranks[l - 1]]);
        }
        let top_v_transfers = top_u_transfers.clone();
        let top_coupling: Vec<CouplingLevel> = (0..c)
            .map(|l| CouplingLevel::from_pairs(structure.coupling[l].clone(), 1 << l, ranks[l]))
            .collect();

        // Owned branch.
        let mut leaf_range = 0..0;
        let mut leaf_sizes = Vec::new();
        let mut u_leaf_bases = Vec::new();
        let mut v_leaf_bases = Vec::new();
        let mut u_transfers = vec![Vec::new(); depth + 1];
        let mut v_transfers = vec![Vec::new(); depth + 1];
        let mut coupling = vec![ShardCoupling::default(); depth + 1];
        let mut dense = ShardDense::default();
        if let Some(r) = rank {
            assert!(r < d.p, "rank {r} out of range for P = {}", d.p);
            leaf_range = d.own_range(r, depth);
            leaf_sizes =
                tree.leaves()[leaf_range.clone()].iter().map(|n| n.size()).collect();
            let k_leaf = ranks[depth];
            u_leaf_bases = vec![0.0; leaf_range.len() * m_pad * k_leaf];
            v_leaf_bases = u_leaf_bases.clone();
            for l in (c + 1)..=depth {
                let words = d.branch_width(l) * ranks[l] * ranks[l - 1];
                u_transfers[l] = vec![0.0; words];
                v_transfers[l] = vec![0.0; words];
            }
            for l in c..=depth {
                let rows = d.own_range(r, l);
                let pairs = owned_pairs(&structure.coupling[l], &rows);
                coupling[l] = ShardCoupling {
                    row_start: rows.start,
                    level: CouplingLevel::from_pairs(pairs, rows.len(), ranks[l]),
                };
            }
            let dpairs = owned_pairs(&structure.dense, &leaf_range);
            dense = ShardDense {
                row_start: leaf_range.start,
                blocks: DenseBlocks::from_pairs(dpairs, leaf_range.len(), m_pad),
            };
        }

        ShardedMatrix {
            tree,
            decomp: d,
            rank,
            u_ranks: ranks.to_vec(),
            v_ranks: ranks.to_vec(),
            leaf_dim: m_pad,
            top_coupling,
            top_u_transfers,
            top_v_transfers,
            leaf_range,
            leaf_sizes,
            u_leaf_bases,
            v_leaf_bases,
            u_transfers,
            v_transfers,
            coupling,
            dense,
        }
    }

    /// Slice `rank`'s shard out of an assembled global matrix. Bitwise
    /// identical to the directly constructed shard
    /// ([`crate::construct::build_branch`]) — asserted by `tests/shard.rs`.
    pub fn from_global(a: &H2Matrix, d: Decomposition, rank: usize) -> Self {
        let mut sm = Self::top_from_global(a, d);
        assert!(rank < d.p, "rank {rank} out of range for P = {}", d.p);
        sm.rank = Some(rank);
        let depth = d.depth;
        let c = d.c_level;
        let m_pad = a.u.leaf_dim;

        let leaf_range = d.own_range(rank, depth);
        sm.leaf_sizes = a.u.leaf_sizes[leaf_range.clone()].to_vec();
        let ku = a.u.ranks[depth];
        let kv = a.v.ranks[depth];
        sm.u_leaf_bases =
            a.u.leaf_bases[leaf_range.start * m_pad * ku..leaf_range.end * m_pad * ku].to_vec();
        sm.v_leaf_bases =
            a.v.leaf_bases[leaf_range.start * m_pad * kv..leaf_range.end * m_pad * kv].to_vec();
        for l in (c + 1)..=depth {
            let own = d.own_range(rank, l);
            let su = a.u.ranks[l] * a.u.ranks[l - 1];
            let sv = a.v.ranks[l] * a.v.ranks[l - 1];
            sm.u_transfers[l] = a.u.transfers[l][own.start * su..own.end * su].to_vec();
            sm.v_transfers[l] = a.v.transfers[l][own.start * sv..own.end * sv].to_vec();
        }
        for l in c..=depth {
            let rows = d.own_range(rank, l);
            let k = a.rank(l);
            let cl = &a.coupling[l];
            let lo = cl.row_ptr[rows.start];
            let hi = cl.row_ptr[rows.end];
            let pairs: Vec<(u32, u32)> =
                cl.pairs[lo..hi].iter().map(|&(t, s)| (t - rows.start as u32, s)).collect();
            let mut level = CouplingLevel::from_pairs(pairs, rows.len(), k);
            level.data.copy_from_slice(&cl.data[lo * k * k..hi * k * k]);
            sm.coupling[l] = ShardCoupling { row_start: rows.start, level };
        }
        let db = &a.dense;
        let lo = db.row_ptr[leaf_range.start];
        let hi = db.row_ptr[leaf_range.end];
        let pairs: Vec<(u32, u32)> =
            db.pairs[lo..hi].iter().map(|&(t, s)| (t - leaf_range.start as u32, s)).collect();
        let mut blocks = DenseBlocks::from_pairs(pairs, leaf_range.len(), m_pad);
        blocks
            .data
            .copy_from_slice(&db.data[lo * m_pad * m_pad..hi * m_pad * m_pad]);
        sm.dense = ShardDense { row_start: leaf_range.start, blocks };
        sm.leaf_range = leaf_range;
        sm
    }

    /// The replicated-top-only shard of a global matrix (what the socket
    /// coordinator holds: O(P·k²) matrix data plus the O(N) tree).
    pub fn top_from_global(a: &H2Matrix, d: Decomposition) -> Self {
        assert_eq!(d.depth, a.depth(), "decomposition built for a different tree");
        let depth = d.depth;
        let c = d.c_level;
        let mut top_u_transfers = vec![Vec::new()];
        let mut top_v_transfers = vec![Vec::new()];
        for l in 1..=c {
            top_u_transfers.push(a.u.transfers[l].clone());
            top_v_transfers.push(a.v.transfers[l].clone());
        }
        ShardedMatrix {
            tree: a.tree.clone(),
            decomp: d,
            rank: None,
            u_ranks: a.u.ranks.clone(),
            v_ranks: a.v.ranks.clone(),
            leaf_dim: a.u.leaf_dim,
            top_coupling: a.coupling[..c].to_vec(),
            top_u_transfers,
            top_v_transfers,
            leaf_range: 0..0,
            leaf_sizes: Vec::new(),
            u_leaf_bases: Vec::new(),
            v_leaf_bases: Vec::new(),
            u_transfers: vec![Vec::new(); depth + 1],
            v_transfers: vec![Vec::new(); depth + 1],
            coupling: vec![ShardCoupling::default(); depth + 1],
            dense: ShardDense::default(),
        }
    }

    pub fn depth(&self) -> usize {
        self.tree.depth
    }

    /// Matrix dimension N.
    pub fn n(&self) -> usize {
        self.tree.num_points()
    }

    pub fn c_level(&self) -> usize {
        self.decomp.c_level
    }

    /// The owning branch rank; panics on a top-only shard.
    pub fn branch_rank(&self) -> usize {
        self.rank.expect("top-only shard has no branch rank")
    }

    // ---- local <-> global translation -------------------------------

    /// Local slot of the globally indexed owned leaf `j`.
    pub fn local_leaf(&self, j: usize) -> usize {
        debug_assert!(self.leaf_range.contains(&j), "leaf {j} is not owned by this shard");
        j - self.leaf_range.start
    }

    /// Global leaf index of local slot `slot`.
    pub fn global_leaf(&self, slot: usize) -> usize {
        debug_assert!(slot < self.leaf_range.len());
        self.leaf_range.start + slot
    }

    /// Local node index of the globally indexed owned node `j` at level
    /// `l ≥ C`.
    pub fn local_node(&self, l: usize, j: usize) -> usize {
        self.decomp.local_index(self.branch_rank(), l, j)
    }

    /// Global node index of local node `local` at level `l ≥ C`.
    pub fn global_node(&self, l: usize, local: usize) -> usize {
        let own = self.decomp.own_range(self.branch_rank(), l);
        debug_assert!(local < own.len());
        own.start + local
    }

    // ---- storage accounting -----------------------------------------

    /// f64 words of the owned branch (bases with *actual* leaf sizes,
    /// transfers below the C-level, owned coupling blocks, dense rows at
    /// actual sizes) — the per-rank 1/P share. Uses the same conventions
    /// as [`H2Matrix::memory_words`], so the shards of one matrix sum to
    /// exactly its serial footprint (plus one replicated top per rank).
    pub fn branch_words(&self) -> usize {
        let depth = self.depth();
        let ku = self.u_ranks[depth];
        let kv = self.v_ranks[depth];
        let mut words: usize = self.leaf_sizes.iter().map(|&s| s * (ku + kv)).sum();
        for l in (self.c_level() + 1)..=depth {
            words += self.u_transfers[l].len() + self.v_transfers[l].len();
        }
        for (l, sc) in self.coupling.iter().enumerate() {
            words += sc.level.num_blocks() * self.u_ranks[l] * self.u_ranks[l];
        }
        for &(t, s) in &self.dense.blocks.pairs {
            words += self.leaf_sizes[t as usize] * self.tree.node(depth, s as usize).size();
        }
        words
    }

    /// f64 words of the replicated top subtree (identical on every rank).
    pub fn replication_words(&self) -> usize {
        let mut words: usize = self
            .top_u_transfers
            .iter()
            .zip(&self.top_v_transfers)
            .map(|(u, v)| u.len() + v.len())
            .sum();
        for (l, cl) in self.top_coupling.iter().enumerate() {
            words += cl.num_blocks() * self.u_ranks[l] * self.u_ranks[l];
        }
        words
    }

    /// Total matrix bytes this shard stores — the quantity
    /// [`crate::metrics::Metrics::matrix_bytes`] reports and the
    /// out-of-core memory regression test bounds by
    /// `serial/P + replication/imbalance slack`.
    pub fn matrix_bytes(&self) -> usize {
        (self.branch_words() + self.replication_words()) * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::H2Config;
    use crate::construct::{build_h2, ExponentialKernel};
    use crate::geometry::PointSet;

    fn sample() -> H2Matrix {
        let points = PointSet::grid_2d(16, 1.0); // N = 256
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
        build_h2(points, &kernel, &cfg)
    }

    #[test]
    fn shards_partition_the_global_matrix() {
        let a = sample();
        let serial = a.memory_words();
        for p in [1usize, 2, 4, 8] {
            let d = Decomposition::new(p, a.depth()).unwrap();
            let shards: Vec<ShardedMatrix> =
                (0..p).map(|r| ShardedMatrix::from_global(&a, d, r)).collect();
            // Every owned structure element appears exactly once; the
            // replicated top is identical on every rank.
            let branch_total: usize = shards.iter().map(|s| s.branch_words()).sum();
            let rep = shards[0].replication_words();
            for s in &shards {
                assert_eq!(s.replication_words(), rep);
            }
            assert_eq!(branch_total + rep, serial, "P={p}: shards do not partition the matrix");
            // Coupling blocks partition per level.
            for (l, cl) in a.coupling.iter().enumerate() {
                let c = d.c_level;
                let owned: usize = if l >= c {
                    shards.iter().map(|s| s.coupling[l].level.num_blocks()).sum()
                } else {
                    shards[0].top_coupling[l].num_blocks()
                };
                assert_eq!(owned, cl.num_blocks(), "P={p} level {l}");
            }
            let dense_total: usize = shards.iter().map(|s| s.dense.blocks.pairs.len()).sum();
            assert_eq!(dense_total, a.dense.pairs.len());
        }
    }

    #[test]
    fn from_global_slices_match_the_source() {
        let a = sample();
        let d = Decomposition::new(4, a.depth()).unwrap();
        let depth = a.depth();
        for r in 0..4 {
            let sm = ShardedMatrix::from_global(&a, d, r);
            assert_eq!(sm.branch_rank(), r);
            // Leaf bases: local slot j == global leaf leaf_range.start + j.
            let k = a.rank(depth);
            let m = a.u.leaf_dim;
            for slot in 0..sm.leaf_range.len() {
                let g = sm.global_leaf(slot);
                assert_eq!(sm.local_leaf(g), slot);
                assert_eq!(
                    &sm.u_leaf_bases[slot * m * k..(slot + 1) * m * k],
                    a.u.leaf(g),
                    "rank {r} leaf {g}"
                );
            }
            // Coupling rows carry the global data in serial order.
            for l in d.c_level..=depth {
                let sc = &sm.coupling[l];
                for p in 0..sc.level.num_blocks() {
                    let (gt, gs) = sc.global_pair(p);
                    // find the global pair index
                    let gp = a.coupling[l]
                        .pairs
                        .iter()
                        .position(|&(t, s)| (t as usize, s as usize) == (gt, gs))
                        .expect("pair exists globally");
                    assert_eq!(
                        sc.level.block(p, a.rank(l)),
                        a.coupling[l].block(gp, a.rank(l)),
                        "rank {r} level {l} pair {p}"
                    );
                    assert_eq!(d.owner(l, gt), r, "shard holds a foreign row");
                }
            }
            // Dense rows.
            for p in 0..sm.dense.blocks.pairs.len() {
                let (gt, gs) = sm.dense.global_pair(p);
                let gp = a
                    .dense
                    .pairs
                    .iter()
                    .position(|&(t, s)| (t as usize, s as usize) == (gt, gs))
                    .expect("dense pair exists globally");
                assert_eq!(sm.dense.blocks.block(p), a.dense.block(gp));
            }
            // The boundary transfer sits in the replicated top at the
            // rank's offset.
            let c = d.c_level;
            let sz = a.rank(c) * a.rank(c - 1);
            assert_eq!(
                &sm.top_u_transfers[c][r * sz..(r + 1) * sz],
                a.u.transfer(c, r)
            );
        }
    }

    #[test]
    fn shard_batches_equal_prefiltered_global_batches() {
        // The local conflict-free batches must be the owned-row prefilter
        // of the global batches, in the same order — the bitwise-identity
        // precondition of the sharded HGEMV.
        let a = sample();
        let d = Decomposition::new(4, a.depth()).unwrap();
        for r in 0..4 {
            let sm = ShardedMatrix::from_global(&a, d, r);
            for l in d.c_level..=a.depth() {
                let rows = d.own_range(r, l);
                let sc = &sm.coupling[l];
                let global_filtered: Vec<Vec<(usize, usize)>> = a.coupling[l]
                    .batches
                    .iter()
                    .map(|b| {
                        b.iter()
                            .map(|&pi| a.coupling[l].pairs[pi as usize])
                            .filter(|&(t, _)| rows.contains(&(t as usize)))
                            .map(|(t, s)| (t as usize, s as usize))
                            .collect()
                    })
                    .filter(|b: &Vec<_>| !b.is_empty())
                    .collect();
                let local: Vec<Vec<(usize, usize)>> = sc
                    .level
                    .batches
                    .iter()
                    .map(|b| {
                        b.iter().map(|&pi| sc.global_pair(pi as usize)).collect::<Vec<_>>()
                    })
                    .filter(|b: &Vec<_>| !b.is_empty())
                    .collect();
                assert_eq!(local, global_filtered, "rank {r} level {l}");
            }
        }
    }

    #[test]
    fn top_only_shard_is_small_and_branchless() {
        let a = sample();
        let d = Decomposition::new(8, a.depth()).unwrap();
        let sm = ShardedMatrix::top_from_global(&a, d);
        assert!(sm.rank.is_none());
        assert_eq!(sm.branch_words(), 0);
        assert!(sm.replication_words() > 0);
        assert!(
            sm.matrix_bytes() < a.memory_words() * 8 / 4,
            "top-only shard ({} B) must be far below the serial matrix ({} B)",
            sm.matrix_bytes(),
            a.memory_words() * 8
        );
        assert_eq!(sm.top_coupling.len(), 3);
        assert_eq!(sm.top_u_transfers.len(), 4);
    }

    #[test]
    fn single_rank_shard_is_the_whole_matrix() {
        let a = sample();
        let d = Decomposition::new(1, a.depth()).unwrap();
        let sm = ShardedMatrix::from_global(&a, d, 0);
        assert_eq!(sm.replication_words(), 0);
        assert_eq!(sm.branch_words(), a.memory_words());
        assert_eq!(sm.leaf_range, 0..1 << a.depth());
    }
}
