//! The real distributed HGEMV executor, generic over the transport, with
//! per-rank *sharded* matrix storage.
//!
//! Where [`crate::dist::hgemv`] *simulates* the paper's §4 runtime (one
//! loop over virtual ranks, speedups priced by the analytic
//! [`crate::dist::hgemv::CostModel`]), this module actually executes it:
//! every rank runs its branch slice of the phase functions over a
//! branch-local O(N/P) workspace reading from its own
//! [`crate::dist::ShardedMatrix`] ([`crate::dist::branch`]), exchanging
//! level-C basis coefficients through a pluggable
//! [`crate::dist::transport::Endpoint`] driven by the same
//! [`crate::dist::ExchangePlan`] that prices the virtual schedule.
//!
//! [`run_branch`] / [`run_top_master`] are the transport-generic rank
//! bodies; [`run_threaded`] instantiates them over the in-process
//! transport ([`crate::dist::transport::inproc`]) with one pooled OS
//! thread per rank ([`crate::dist::pool::RankPool`]), slicing one shard
//! per rank out of the caller's matrix; the socket transport
//! ([`crate::dist::transport::socket`]) instantiates the *same* bodies in
//! real worker subprocesses whose shards are built branch-scoped from the
//! kernel — no process of a socket session ever allocates the global
//! matrix.
//!
//! # Execution plan (per rank r)
//!
//! 1. gather its own + dense-halo input rows (O(N/P));
//! 2. upsweep its branch with *pipelined sends*: each level's x̂ exchange
//!    set ships as soon as that level's upsweep transfer finishes (leaf
//!    level first), not after the whole branch upsweep — deepening the
//!    §4.2 comm/compute overlap at large P; the level-C block then
//!    gathers to the master;
//! 3. run its dense/diagonal blocks — which need no remote coefficients —
//!    while the exchange is in flight;
//! 4. receive its exchange set tag-matched (out-of-order safe via
//!    [`crate::dist::transport::Mailbox`]) into the workspace halo,
//!    multiply its coupling rows level by level, merge the master's
//!    level-(C-1) ŷ parent and apply its own C-level boundary transfer;
//! 5. downsweep its branch and scatter its disjoint slice of the output
//!    (directly, or as an `Output` message on process transports).
//!
//! The master gathers the level-C x̂, processes the replicated top subtree
//! of its (top-only) shard over a top-only workspace (O(P), not O(N) —
//! [`crate::matvec::HgemvWorkspace::top_only_dims`]) and scatters each
//! rank's ŷ parent.
//!
//! # Bitwise-identity argument
//!
//! Each rank executes the *same* per-block GEMMs over the *same* branch
//! slices in the *same* per-destination order as the serial sweep (the
//! shard's conflict-free batches are the owned-row prefilter of the
//! global batches without reordering), on bitwise-identical inputs
//! (messages are pure copies; shard data is a pure copy or a
//! deterministic re-evaluation of the same formulas). The only cross-rank
//! accumulation — the C-level boundary — is applied by the *receiving*
//! rank on top of its own coupling sums, reproducing the serial in-place
//! order. Hence `y` is bitwise identical to the serial product for every
//! P, on every transport (asserted by `tests/transport.rs` and
//! `tests/shard.rs`).
//!
//! Every rank also stamps an `Instant` around each phase, and the
//! in-process endpoints are wrapped in
//! [`crate::dist::transport::recording::Recording`] — so a *measured*
//! Chrome trace ([`crate::dist::hgemv::DistOptions::measured_trace`]) can
//! be emitted next to the virtual-schedule trace.
//!
//! # Composing with the parallel backend (thread budget)
//!
//! With `H2OPUS_BACKEND_THREADS > 1` every rank's batched calls go to the
//! parallel native backend, whose pool is *process-global and shared*:
//! the first rank to dispatch a batch parallelizes it across the budget;
//! ranks finding the pool busy run their batch inline (exactly the serial
//! loop). Total thread pressure is therefore bounded by `P + budget`, the
//! executor needs no per-rank budget split, and — because per-block
//! results are bitwise-independent of who executes them — the bitwise
//! identity argument below is untouched by the backend's parallelism.
//! (Socket-transport worker *processes* each own their pool; the budget
//! env var is inherited, so `P × budget` cores are used across the
//! session — set it to `cores / P` to share a machine evenly.)

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::time::Instant;

use crate::backend::{BatchRef, ComputeBackend, GemmDims};
use crate::dist::branch::{
    branch_dense_multiply, branch_downsweep_boundary, branch_downsweep_leaf,
    branch_downsweep_transfer, branch_tree_multiply, branch_upsweep_leaf,
    branch_upsweep_transfer, fill_branch_input, unpad_branch_output, BranchPlan, BranchWorkspace,
};
use crate::dist::hgemv::DistHgemv;
use crate::dist::pool::RankPool;
use crate::dist::shard::ShardedMatrix;
use crate::dist::transport::recording::{CommEvent, Recording};
use crate::dist::transport::{inproc, Endpoint, Mailbox, Message, MsgKind, TransportError};
use crate::dist::ExchangePlan;
use crate::matvec::plan::{BatchOffsets, LevelMultPlan, LevelTransferPlan};
use crate::matvec::HgemvWorkspace;
use crate::metrics::Metrics;
use crate::obs;
use crate::obs::names as obs_names;
use crate::tree::H2Matrix;
use crate::util::trace::TraceCollector;

/// How the distributed operations execute their numerical work.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Single-threaded replay of the per-branch phases, priced in virtual
    /// time by the analytic cost model (the simulator).
    #[default]
    Virtual,
    /// One pooled OS thread per virtual rank over the in-process
    /// transport, sharded matrix storage + branch-local O(N/P)
    /// workspaces; reports measured wall-clock alongside the virtual
    /// schedule. (Real OS-*process* ranks are reached through
    /// [`crate::dist::transport::socket::socket_hgemv`], which reuses the
    /// same rank bodies over branch-constructed shards.)
    Threaded,
}

/// Phase ids of the measured per-rank trace. Indexes [`PHASES`].
pub(crate) const PH_INPUT: usize = 0;
pub(crate) const PH_UPSWEEP: usize = 1;
pub(crate) const PH_SEND: usize = 2;
pub(crate) const PH_DENSE: usize = 3;
pub(crate) const PH_RECV: usize = 4;
pub(crate) const PH_MULT: usize = 5;
pub(crate) const PH_BOUNDARY: usize = 6;
pub(crate) const PH_DOWNSWEEP: usize = 7;
pub(crate) const PH_OUTPUT: usize = 8;
pub(crate) const PH_GATHER: usize = 9;
pub(crate) const PH_TOP: usize = 10;
pub(crate) const PH_SCATTER: usize = 11;

/// (name, chrome-trace category) of every phase id.
pub(crate) const PHASES: &[(&str, &str)] = &[
    ("input gather", "compute"),
    ("upsweep", "compute"),
    ("xhat send", "comm"),
    ("dense + diagonal mult", "compute"),
    ("xhat recv", "comm"),
    ("coupling mult", "compute"),
    ("boundary merge", "compute"),
    ("downsweep", "compute"),
    ("output scatter", "compute"),
    ("xhat gather", "comm"),
    ("top subtree", "lowprio"),
    ("yhat scatter", "comm"),
];

/// Observability name of each phase id (same order as [`PHASES`]), so the
/// span runtime sees the identical phase structure on every transport —
/// `run_branch`/`run_top_master` are shared by the in-process executor and
/// the socket worker processes.
pub(crate) const PH_OBS: [obs_names::NameId; 12] = [
    obs_names::INPUT_GATHER,
    obs_names::UPSWEEP,
    obs_names::XHAT_SEND,
    obs_names::DENSE_MULT,
    obs_names::XHAT_RECV,
    obs_names::COUPLING_MULT,
    obs_names::BOUNDARY_MERGE,
    obs_names::DOWNSWEEP,
    obs_names::OUTPUT_SCATTER,
    obs_names::TOP_GATHER,
    obs_names::TOP_SUBTREE,
    obs_names::YHAT_SCATTER,
];

/// Measured phase spans of one rank: (phase id, start s, duration s),
/// relative to the product's shared origin instant.
#[derive(Clone, Debug, Default)]
pub(crate) struct RankTrace {
    pub events: Vec<(usize, f64, f64)>,
}

impl RankTrace {
    fn push(&mut self, phase: usize, start: f64, end: f64) {
        self.events.push((phase, start, end - start));
        // Mirror the phase into the span runtime (reconstructing the start
        // from the just-measured duration keeps this a single clock read).
        // The boundary phase is excluded: `run_branch` splits it into
        // wait/merge spans itself, so the blocking receive is never
        // conflated with the post-receive compute.
        if phase != PH_BOUNDARY && obs::enabled() {
            let dur_ns = ((end - start) * 1e9) as u64;
            obs::record(PH_OBS[phase], 0, obs::now_ns().saturating_sub(dur_ns), dur_ns);
        }
    }
}

/// Where a rank's output rows go.
pub(crate) enum YSink<'a> {
    /// Write into this disjoint slice of the shared output, whose first
    /// row is the given base row (in-process transport).
    Slice(&'a mut [f64], usize),
    /// Ship them to the master as an `Output` message tagged with this
    /// wire product id (process ranks; see
    /// `transport::socket`'s pipelined framing — the in-process
    /// transport never constructs this variant).
    Send(u32),
}

/// What the threaded execution hands back to the virtual-time scheduler.
pub(crate) struct ThreadedOutcome {
    /// Wall-clock seconds of the parallel section (dispatch to join).
    pub measured: f64,
    /// Per-rank wall-clock completion offsets.
    pub per_rank: Vec<f64>,
    /// Executed-work counters plus actual channel traffic, merged in rank
    /// order (master last).
    pub metrics: Metrics,
    /// Measured Chrome trace (per-phase spans + recorded messages), when
    /// requested.
    pub trace_json: Option<String>,
}

/// Ship level `l`'s send sets (pipelined: called as soon as that level's
/// x̂ is final).
fn send_level_xhat<E: Endpoint>(
    sm: &ShardedMatrix,
    bp: &BranchPlan,
    bw: &BranchWorkspace,
    ep: &mut E,
    metrics: &mut Metrics,
    l: usize,
) -> Result<(), TransportError> {
    let nv = bp.nv;
    let k = sm.v_ranks[l];
    for (dst, offs) in &bp.sends[l] {
        let mut data = Vec::with_capacity(offs.len() * k * nv);
        for &o in offs {
            data.extend_from_slice(&bw.xhat[l][o..o + k * nv]);
        }
        metrics.send(data.len() * 8);
        ep.send(*dst, Message::new(MsgKind::Xhat, l, bp.rank, data))?;
    }
    Ok(())
}

/// One branch rank's slice of the product (steps 1–5 of the module docs),
/// generic over the transport endpoint, reading only the rank's shard.
#[allow(clippy::too_many_arguments)]
pub(crate) fn run_branch<E: Endpoint>(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    ex: &ExchangePlan,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    ep: &mut E,
    mb: &mut Mailbox,
    x: Option<&[f64]>,
    y_out: YSink<'_>,
    t0: Instant,
) -> Result<(Metrics, RankTrace), TransportError> {
    let d = ex.decomp;
    let (p, c, depth) = (d.p, d.c_level, d.depth);
    let nv = bp.nv;
    let r = bp.rank;
    let mut metrics = Metrics::new();
    let mut trace = RankTrace::default();
    let now = |t0: &Instant| t0.elapsed().as_secs_f64();

    // 1. Branch-local input (the in-process path gathers from the shared
    // vector; process ranks received it as their Input message already).
    if let Some(x) = x {
        let t = now(&t0);
        fill_branch_input(sm, bp, x, &mut bw.x_pad);
        trace.push(PH_INPUT, t, now(&t0));
    }

    // 2. Branch upsweep with pipelined sends: a level's exchange set ships
    // the moment that level's x̂ is final.
    let t = now(&t0);
    branch_upsweep_leaf(sm, backend, bp, bw, &mut metrics);
    trace.push(PH_UPSWEEP, t, now(&t0));
    let t = now(&t0);
    send_level_xhat(sm, bp, bw, ep, &mut metrics, depth)?;
    trace.push(PH_SEND, t, now(&t0));
    for l in ((c + 1)..=depth).rev() {
        let t = now(&t0);
        branch_upsweep_transfer(sm, backend, bp, bw, &mut metrics, l);
        trace.push(PH_UPSWEEP, t, now(&t0));
        let t = now(&t0);
        send_level_xhat(sm, bp, bw, ep, &mut metrics, l - 1)?;
        trace.push(PH_SEND, t, now(&t0));
    }
    if c > 0 {
        // Level-C gather to the master (own node is local slot 0).
        let t = now(&t0);
        let k_c = sm.v_ranks[c];
        let data = bw.xhat[c][0..k_c * nv].to_vec();
        metrics.send(data.len() * 8);
        ep.send(p, Message::new(MsgKind::Gather, c, r, data))?;
        trace.push(PH_SEND, t, now(&t0));
    }

    // 3. Dense/diagonal blocks need no remote coefficients: execute them
    // while the exchange is in flight (§4.2's overlap, for real).
    let t = now(&t0);
    branch_dense_multiply(sm, backend, bp, bw, &mut metrics);
    trace.push(PH_DENSE, t, now(&t0));

    // 4. Receive the exchange set into the workspace halo, tag-matched
    // (the master's scatter or a fast peer may overtake — the mailbox
    // stashes whatever arrives early).
    let expected = ex.messages_into(r);
    let t = now(&t0);
    for _ in 0..expected {
        let msg = mb.recv_kind(ep, MsgKind::Xhat)?;
        let l = msg.tag.level as usize;
        let src = msg.tag.src as usize;
        let k = sm.v_ranks[l];
        let offs = bp.recv_scatter[l]
            .iter()
            .find(|(s, _)| *s == src)
            .map(|(_, offs)| offs)
            .ok_or_else(|| {
                TransportError::Protocol(format!(
                    "rank {r}: xhat message from {src} at level {l} is outside the exchange plan"
                ))
            })?;
        if msg.data.len() != offs.len() * k * nv {
            return Err(TransportError::Protocol(format!(
                "rank {r}: xhat payload from {src} at level {l} has {} values, plan promises {}",
                msg.data.len(),
                offs.len() * k * nv
            )));
        }
        for (i, &o) in offs.iter().enumerate() {
            bw.xhat[l][o..o + k * nv].copy_from_slice(&msg.data[i * k * nv..(i + 1) * k * nv]);
        }
    }
    trace.push(PH_RECV, t, now(&t0));

    // Coupling rows, level by level in serial order.
    let t = now(&t0);
    for l in c..=depth {
        branch_tree_multiply(sm, backend, bp, bw, &mut metrics, l);
    }
    trace.push(PH_MULT, t, now(&t0));

    // C-level boundary: merge the master's ŷ parent, then apply this
    // rank's boundary transfer on top of its own coupling sums — the same
    // in-place accumulation the serial downsweep performs.
    if c > 0 {
        let t = now(&t0);
        let wait = obs::span(obs_names::BOUNDARY_WAIT);
        let msg = mb.recv_kind(ep, MsgKind::Parent)?;
        drop(wait);
        if msg.data.len() != bw.parent.len() {
            return Err(TransportError::Protocol(format!(
                "rank {r}: parent payload has {} values, expected {}",
                msg.data.len(),
                bw.parent.len()
            )));
        }
        // The merge span opens only after the parent message is in hand,
        // so in a merged trace it is *caused by* the master's ŷ scatter —
        // the happens-before edge `tests/obs.rs` checks.
        let merge = obs::span(obs_names::BOUNDARY_MERGE);
        bw.parent.copy_from_slice(&msg.data);
        branch_downsweep_boundary(sm, backend, bp, bw, &mut metrics);
        drop(merge);
        trace.push(PH_BOUNDARY, t, now(&t0));
    }

    // 5. Branch downsweep and the disjoint output scatter.
    let t = now(&t0);
    for l in (c + 1)..=depth {
        branch_downsweep_transfer(sm, backend, bp, bw, &mut metrics, l);
    }
    branch_downsweep_leaf(sm, backend, bp, bw, &mut metrics);
    trace.push(PH_DOWNSWEEP, t, now(&t0));

    let t = now(&t0);
    match y_out {
        YSink::Slice(chunk, base_row) => {
            unpad_branch_output(sm, bp, &bw.y_pad, chunk, base_row);
        }
        YSink::Send(product) => {
            let base_row = sm.tree.node(depth, bp.leaf_range.start).start;
            let end_row = if bp.leaf_range.end == (1usize << depth) {
                sm.n()
            } else {
                sm.tree.node(depth, bp.leaf_range.end).start
            };
            let mut rows = vec![0.0; (end_row - base_row) * nv];
            unpad_branch_output(sm, bp, &bw.y_pad, &mut rows, base_row);
            metrics.send(rows.len() * 8);
            ep.send(p, Message::new(MsgKind::Output, product as usize, r, rows))?;
        }
    }
    trace.push(PH_OUTPUT, t, now(&t0));

    Ok((metrics, trace))
}

// ---- replicated-top plan + phase functions (master side) ---------------
//
// These replicate, GEMM for GEMM, what the serial whole-level phase calls
// (`upsweep_transfer_level` / `tree_multiply_level` /
// `downsweep_transfer_level` over the full node range) execute for levels
// at or above the C-level — but read the shard's replicated top buffers,
// so the master needs a `ShardedMatrix`, never the full matrix. Offsets
// are identical to the serial plan's (full levels are stored in the
// global layout), hence bitwise-identical results.

/// Precomputed marshaling offsets of the replicated top: built once per
/// product (in-process) or once per *session* (socket), so the per-level
/// phase calls below stay allocation-free like every other hot path.
pub(crate) struct TopPlan {
    /// `up[l]` for l in 1..=C (index 0 unused): the full level's two
    /// parity batches, shared by up- and downsweep like the serial plan.
    up: Vec<LevelTransferPlan>,
    /// `mult[l]` for l in 0..C: the full level's conflict-free batches.
    mult: Vec<LevelMultPlan>,
}

impl TopPlan {
    pub(crate) fn build(sm: &ShardedMatrix, nv: usize) -> TopPlan {
        let c = sm.c_level();
        let mut up = vec![LevelTransferPlan::default()];
        for l in 1..=c {
            let (k_l, k_par) = (sm.v_ranks[l], sm.v_ranks[l - 1]);
            let nb = 1usize << (l - 1);
            let mut plan = LevelTransferPlan::default();
            for parity in 0..2 {
                let po = &mut plan.parity[parity];
                po.nb = nb;
                for i in 0..nb {
                    let child = 2 * i + parity;
                    po.transfer_off.push(child * k_l * k_par);
                    po.child_off.push(child * k_l * nv);
                    po.parent_off.push(i * k_par * nv);
                }
            }
            up.push(plan);
        }
        let mut mult = Vec::with_capacity(c);
        for (l, cl) in sm.top_coupling.iter().enumerate() {
            let k = sm.u_ranks[l];
            let mut lp = LevelMultPlan::default();
            for batch in &cl.batches {
                let mut bo = BatchOffsets { nb: batch.len(), ..Default::default() };
                for &pi in batch {
                    let (t, s) = cl.pairs[pi as usize];
                    bo.block_off.push(pi as usize * k * k);
                    bo.src_off.push(s as usize * k * nv);
                    bo.dst_off.push(t as usize * k * nv);
                }
                lp.batches.push(bo);
            }
            mult.push(lp);
        }
        TopPlan { up, mult }
    }
}

fn top_upsweep_transfer(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    tp: &TopPlan,
    ws: &mut HgemvWorkspace,
    metrics: &mut Metrics,
    l: usize,
) {
    let nv = ws.nv;
    let (k_l, k_par) = (sm.v_ranks[l], sm.v_ranks[l - 1]);
    let (lo, hi) = ws.xhat.levels.split_at_mut(l);
    let parent = &mut lo[l - 1];
    let child = &hi[0];
    for parity in 0..2 {
        let po = &tp.up[l].parity[parity];
        backend.batched_gemm(
            GemmDims {
                nb: po.nb,
                m: k_par,
                k: k_l,
                n: nv,
                trans_a: true,
                trans_b: false,
                accumulate: true,
            },
            BatchRef { data: &sm.top_v_transfers[l], offsets: &po.transfer_off },
            BatchRef { data: child, offsets: &po.child_off },
            parent,
            &po.parent_off,
            metrics,
        );
    }
}

fn top_tree_multiply(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    tp: &TopPlan,
    ws: &mut HgemvWorkspace,
    metrics: &mut Metrics,
    l: usize,
) {
    let nv = ws.nv;
    let k = sm.u_ranks[l];
    for bo in &tp.mult[l].batches {
        backend.batched_gemm(
            GemmDims {
                nb: bo.nb,
                m: k,
                k,
                n: nv,
                trans_a: false,
                trans_b: false,
                accumulate: true,
            },
            BatchRef { data: &sm.top_coupling[l].data, offsets: &bo.block_off },
            BatchRef { data: &ws.xhat.levels[l], offsets: &bo.src_off },
            &mut ws.yhat.levels[l],
            &bo.dst_off,
            metrics,
        );
    }
}

fn top_downsweep_transfer(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    tp: &TopPlan,
    ws: &mut HgemvWorkspace,
    metrics: &mut Metrics,
    l: usize,
) {
    let nv = ws.nv;
    let (k_l, k_par) = (sm.u_ranks[l], sm.u_ranks[l - 1]);
    let (lo, hi) = ws.yhat.levels.split_at_mut(l);
    let parent = &lo[l - 1];
    let child = &mut hi[0];
    for parity in 0..2 {
        let po = &tp.up[l].parity[parity];
        backend.batched_gemm(
            GemmDims {
                nb: po.nb,
                m: k_l,
                k: k_par,
                n: nv,
                trans_a: false,
                trans_b: false,
                accumulate: true,
            },
            BatchRef { data: &sm.top_u_transfers[l], offsets: &po.transfer_off },
            BatchRef { data: parent, offsets: &po.parent_off },
            child,
            &po.child_off,
            metrics,
        );
    }
}

/// The master's side: level-C gather, replicated top subtree over a
/// top-only workspace reading a top-only shard, ŷ parent scatter. Generic
/// over the transport.
pub(crate) fn run_top_master<E: Endpoint>(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    tp: &TopPlan,
    ws: &mut HgemvWorkspace,
    ep: &mut E,
    mb: &mut Mailbox,
    t0: Instant,
) -> Result<(Metrics, RankTrace), TransportError> {
    let d = sm.decomp;
    let (p, c) = (d.p, d.c_level);
    debug_assert!(c > 0, "the master only exists when the top subtree does");
    let nv = ws.nv;
    let mut metrics = Metrics::new();
    let mut trace = RankTrace::default();
    let now = |t0: &Instant| t0.elapsed().as_secs_f64();

    // Gather the level-C x̂ block of every branch rank.
    let t = now(&t0);
    let k_c = sm.v_ranks[c];
    for _ in 0..p {
        let msg = mb.recv_kind(ep, MsgKind::Gather)?;
        let src = msg.tag.src as usize;
        if src >= p || msg.data.len() != k_c * nv {
            return Err(TransportError::Protocol(format!(
                "master: malformed gather from {src} ({} values, expected {})",
                msg.data.len(),
                k_c * nv
            )));
        }
        ws.xhat.levels[c][src * k_c * nv..(src + 1) * k_c * nv].copy_from_slice(&msg.data);
    }
    trace.push(PH_GATHER, t, now(&t0));

    // Replicated top subtree (the Fig. 8 low-priority stream).
    let t = now(&t0);
    for l in (1..=c).rev() {
        top_upsweep_transfer(sm, backend, tp, ws, &mut metrics, l);
    }
    for l in 0..c {
        top_tree_multiply(sm, backend, tp, ws, &mut metrics, l);
    }
    for l in 1..c {
        top_downsweep_transfer(sm, backend, tp, ws, &mut metrics, l);
    }
    trace.push(PH_TOP, t, now(&t0));

    // Scatter each rank's level-(C-1) ŷ parent; the rank applies the
    // C-level transfer itself (its node only), so the boundary node's
    // accumulation order matches the serial sweep bitwise.
    let t = now(&t0);
    let k_par = sm.u_ranks[c - 1];
    for r in 0..p {
        let par = r >> 1;
        let data = ws.yhat.levels[c - 1][par * k_par * nv..(par + 1) * k_par * nv].to_vec();
        metrics.send(data.len() * 8);
        ep.send(r, Message::new(MsgKind::Parent, 0, p, data))?;
    }
    trace.push(PH_SCATTER, t, now(&t0));

    Ok((metrics, trace))
}

/// Break every peer out of its blocking receive after this endpoint's
/// rank body failed: a `Shutdown` broadcast turns into
/// [`TransportError::Closed`] inside their [`Mailbox`] waits, so one
/// failing rank surfaces as an error at every other instead of a hang.
pub(crate) fn abort_peers<E: Endpoint>(ep: &mut E, n_eps: usize, src: usize) {
    for dst in 0..n_eps {
        if dst != src {
            let _ = ep.send(dst, Message::new(MsgKind::Shutdown, 0, src, Vec::new()));
        }
    }
}

/// Render the measured Chrome trace from per-rank phase spans plus the
/// recorded message traffic (pid = rank, the master at pid = P).
#[allow(clippy::type_complexity)]
pub(crate) fn measured_trace_json(parts: &[(usize, RankTrace, Vec<CommEvent>)]) -> String {
    let mut tc = TraceCollector::new();
    for (pid, tr, comm) in parts {
        for &(ph, start, dur) in &tr.events {
            let (name, cat) = PHASES[ph];
            let tid = match cat {
                "compute" => 0,
                "comm" => 1,
                _ => 2,
            };
            tc.add(name, cat, *pid, tid, start, dur);
        }
        for e in comm {
            tc.add(&e.label(), "comm", *pid, 1, e.start, e.dur);
        }
    }
    tc.to_json()
}

/// Execute `y = A·x` on pooled OS threads over the in-process transport.
/// Each rank thread reads only its [`ShardedMatrix`] (sliced out of the
/// caller's matrix once, outside the timed region). `x`/`y` are N × nv in
/// the permuted ordering, exactly as in the virtual path; the result is
/// bitwise identical to the serial [`crate::matvec::hgemv`].
pub(crate) fn run_threaded(
    op: &DistHgemv,
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    x: &[f64],
    y: &mut [f64],
    want_trace: bool,
) -> ThreadedOutcome {
    let d = op.decomp;
    let (p, c, depth) = (d.p, d.c_level, d.depth);
    let nv = op.plan.nv;
    let has_master = c > 0;

    // Shards, branch plans and O(N/P) workspaces, allocated outside the
    // timed region: the measurement is of execution, not one-time setup
    // (the virtual path likewise reuses its workspace across products).
    let shards: Vec<ShardedMatrix> =
        (0..p).map(|r| ShardedMatrix::from_global(a, d, r)).collect();
    let sm_top = has_master.then(|| ShardedMatrix::top_from_global(a, d));
    let top_plan = sm_top.as_ref().map(|sm| TopPlan::build(sm, nv));
    let bps: Vec<BranchPlan> =
        shards.iter().map(|sm| BranchPlan::build(sm, &op.exchange, nv)).collect();
    let mut bws: Vec<BranchWorkspace> =
        shards.iter().zip(&bps).map(|(sm, bp)| BranchWorkspace::new(sm, bp)).collect();
    let mut top_ws = sm_top
        .as_ref()
        .map(|sm| HgemvWorkspace::top_only_dims(depth, &sm.u_ranks, &sm.v_ranks, nv, c));

    // Disjoint per-rank output chunks: branch leaf ranges are contiguous
    // point ranges in the permuted ordering, so `y` splits cleanly.
    let lpr = d.leaves_per_rank();
    let leaves = 1usize << depth;
    let row_of =
        |leaf: usize| if leaf == leaves { a.n() } else { a.tree.node(depth, leaf).start };
    let mut y_chunks: Vec<(&mut [f64], usize)> = Vec::with_capacity(p);
    {
        let mut rest: &mut [f64] = y;
        let mut row = 0usize;
        for r in 0..p {
            let end = row_of((r + 1) * lpr);
            let (head, tail) = rest.split_at_mut((end - row) * nv);
            y_chunks.push((head, row));
            rest = tail;
            row = end;
        }
        debug_assert!(rest.is_empty(), "leaf ranges must cover the output");
    }

    let n_eps = p + usize::from(has_master);
    let eps = inproc::mesh(n_eps);

    let t0 = Instant::now();
    type RankOut = (Metrics, RankTrace, Vec<CommEvent>, f64);
    let mut jobs: Vec<Box<dyn FnOnce() -> Result<RankOut, TransportError> + Send + '_>> =
        Vec::with_capacity(n_eps);
    {
        let mut ep_it = eps.into_iter();
        let mut y_it = y_chunks.into_iter();
        let ex = &op.exchange;
        for ((sm, bp), bw) in shards.iter().zip(bps.iter()).zip(bws.iter_mut()) {
            let ep = ep_it.next().expect("one endpoint per rank");
            let (chunk, base_row) = y_it.next().expect("one output chunk per rank");
            jobs.push(Box::new(move || {
                // Recording stamps cost two Instant calls per message —
                // only pay them when the trace was actually requested.
                let mut rec = if want_trace {
                    Recording::new(ep, t0)
                } else {
                    Recording::passthrough(ep, t0)
                };
                let mut mb = Mailbox::new();
                let r_id = bp.rank;
                // Label the pool thread with its logical rank for this job
                // so merged traces attribute its spans (including backend
                // batches it launches) to the rank, not the thread.
                obs::set_lane(r_id as u32);
                let attempt = catch_unwind(AssertUnwindSafe(|| {
                    run_branch(
                        sm,
                        backend,
                        ex,
                        bp,
                        bw,
                        &mut rec,
                        &mut mb,
                        Some(x),
                        YSink::Slice(chunk, base_row),
                        t0,
                    )
                }));
                // On any failure, wake the peers before reporting it —
                // otherwise they block forever on this rank's messages.
                let out = match attempt {
                    Ok(out) => out,
                    Err(payload) => {
                        abort_peers(&mut rec, n_eps, r_id);
                        resume_unwind(payload);
                    }
                };
                if out.is_err() {
                    abort_peers(&mut rec, n_eps, r_id);
                }
                obs::set_lane(obs::LANE_UNSET);
                let (mut metrics, tr) = out?;
                metrics.matrix_bytes = sm.matrix_bytes() as u64;
                Ok((metrics, tr, rec.into_events(), t0.elapsed().as_secs_f64()))
            }));
        }
        if let (Some(tw), Some(smt), Some(tp)) =
            (top_ws.as_mut(), sm_top.as_ref(), top_plan.as_ref())
        {
            let ep = ep_it.next().expect("master endpoint");
            jobs.push(Box::new(move || {
                let mut rec = if want_trace {
                    Recording::new(ep, t0)
                } else {
                    Recording::passthrough(ep, t0)
                };
                let mut mb = Mailbox::new();
                obs::set_lane(p as u32);
                let attempt = catch_unwind(AssertUnwindSafe(|| {
                    run_top_master(smt, backend, tp, tw, &mut rec, &mut mb, t0)
                }));
                let out = match attempt {
                    Ok(out) => out,
                    Err(payload) => {
                        abort_peers(&mut rec, n_eps, p);
                        resume_unwind(payload);
                    }
                };
                if out.is_err() {
                    abort_peers(&mut rec, n_eps, p);
                }
                obs::set_lane(obs::LANE_UNSET);
                let (mut metrics, tr) = out?;
                metrics.matrix_bytes = smt.matrix_bytes() as u64;
                Ok((metrics, tr, rec.into_events(), t0.elapsed().as_secs_f64()))
            }));
        }
    }
    let results = RankPool::global().scoped(jobs);
    let measured = t0.elapsed().as_secs_f64();

    let results: Vec<RankOut> = results
        .into_iter()
        .map(|r| r.unwrap_or_else(|e| panic!("threaded executor rank failed: {e}")))
        .collect();
    let metrics = Metrics::merge_all(results.iter().map(|(m, _, _, _)| m));
    let per_rank: Vec<f64> = results.iter().take(p).map(|&(_, _, _, t)| t).collect();
    let trace_json = want_trace.then(|| {
        let parts: Vec<(usize, RankTrace, Vec<CommEvent>)> = results
            .into_iter()
            .enumerate()
            .map(|(i, (_, tr, comm, _))| (i, tr, comm))
            .collect();
        measured_trace_json(&parts)
    });

    ThreadedOutcome { measured, per_rank, metrics, trace_json }
}
