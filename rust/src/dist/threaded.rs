//! Real thread-parallel distributed HGEMV executor.
//!
//! Where [`crate::dist::hgemv`] *simulates* the paper's §4 runtime (one
//! loop over virtual ranks, speedups priced by the analytic
//! [`crate::dist::hgemv::CostModel`]), this module actually executes it:
//! every virtual rank runs its branch slice of the level/range-scoped
//! phase functions of [`crate::matvec`] on its own OS thread, and the
//! level-C basis-coefficient exchanges travel through typed in-process
//! channels driven by the same [`crate::dist::ExchangePlan`] that prices
//! the virtual schedule. The wall-clock this measures is what the
//! CostModel only estimates — `DistReport::measured` vs `DistReport::time`
//! is the model-vs-reality cross-check (see `python/tests/model_check.py`).
//!
//! # Execution plan (per product)
//!
//! With P ranks and C = log₂P, P branch threads plus (when C > 0) one
//! master thread are spawned. Each branch rank r:
//!
//! 1. upsweeps its own leaf range and transfer levels down to the C-level
//!    (all state private to its branch),
//! 2. sends the x̂ node blocks other ranks' coupling rows reference
//!    ([`crate::dist::ExchangePlan::build`]'s send sets) and its level-C x̂ block to the
//!    master (the gather),
//! 3. runs its dense/diagonal blocks — which need no remote data — while
//!    the exchange is in flight (§4.2's overlap, for real),
//! 4. receives its exchange set, multiplies its coupling rows level by
//!    level, merges the master's level-(C-1) ŷ parent and applies its own
//!    parity transfer across the C-level boundary,
//! 5. downsweeps its branch and scatters its disjoint slice of the output.
//!
//! The master thread gathers the level-C x̂, processes the replicated top
//! subtree (upsweep above C, top coupling levels, downsweep above C) — the
//! low-priority stream of Fig. 8 — and scatters each rank's ŷ parent.
//!
//! # Thread-safety / bitwise-identity argument
//!
//! - Every thread owns a private [`HgemvWorkspace`]; the matrix, plans and
//!   input vector are shared immutably (`ComputeBackend: Sync` makes the
//!   backend shareable too). No mutable state is shared: remote
//!   coefficients arrive as owned `Vec<f64>` messages, and the output is
//!   pre-split into per-rank disjoint `&mut` chunks (branch leaf ranges
//!   are contiguous in the permuted ordering).
//! - Each rank executes the *same* phase functions over the *same* branch
//!   slices in the *same* per-destination order as the serial sweep, on
//!   bitwise-identical inputs (messages are pure copies). The only
//!   cross-thread accumulation — the C-level downsweep transfer — is
//!   applied by the *receiving* rank on top of its own coupling sums via
//!   [`crate::matvec::downsweep_transfer_parity`], reproducing the serial
//!   in-place accumulation order exactly. Hence `y` is bitwise identical
//!   to the serial product for every P.
//! - Per-rank [`Metrics`] are merged after join in rank order
//!   ([`Metrics::merge_all`]), so the counters are race-free and
//!   deterministic.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Instant;

use crate::backend::ComputeBackend;
use crate::dist::hgemv::DistHgemv;
use crate::matvec::{
    dense_multiply_range, downsweep_leaf_range, downsweep_transfer_level,
    downsweep_transfer_parity, pad_leaf_input, tree_multiply_level, unpad_leaf_range,
    upsweep_leaf_range, upsweep_transfer_level, HgemvWorkspace,
};
use crate::metrics::Metrics;
use crate::tree::H2Matrix;

/// How the distributed operations execute their numerical work.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ExecMode {
    /// Single-threaded replay of the per-branch phases, priced in virtual
    /// time by the analytic cost model (the simulator).
    #[default]
    Virtual,
    /// One OS thread per virtual rank exchanging level-C coefficients
    /// through typed channels; reports measured wall-clock alongside the
    /// virtual schedule.
    Threaded,
}

/// The typed messages of the in-process interconnect.
enum Msg {
    /// Plan-driven x̂ exchange: the node blocks of `level` that `src` owns
    /// and the receiver's coupling rows reference, concatenated in the
    /// plan's (sorted) node order.
    Xhat { level: usize, src: usize, data: Vec<f64> },
    /// A rank's level-C x̂ block, gathered to the master.
    Gather { src: usize, data: Vec<f64> },
    /// The master's level-(C-1) ŷ block for the receiving rank's parent.
    Parent { data: Vec<f64> },
}

/// What the threaded execution hands back to the virtual-time scheduler.
pub(crate) struct ThreadedOutcome {
    /// Wall-clock seconds of the parallel section (spawn to join).
    pub measured: f64,
    /// Per-rank wall-clock completion offsets.
    pub per_rank: Vec<f64>,
    /// Executed-work counters plus actual channel traffic, merged in rank
    /// order (master last).
    pub metrics: Metrics,
}

/// One thread's private context.
struct Seat<'s> {
    idx: usize,
    ws: &'s mut HgemvWorkspace,
    rx: Receiver<Msg>,
    tx: Vec<Sender<Msg>>,
    /// Branch ranks carry their disjoint output chunk and its base row.
    y: Option<(&'s mut [f64], usize)>,
}

/// Execute `y = A·x` across real OS threads. `x`/`y` are N × nv in the
/// permuted ordering, exactly as in the virtual path; the result is
/// bitwise identical to the serial [`crate::matvec::hgemv`].
pub(crate) fn run_threaded(
    op: &DistHgemv,
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    x: &[f64],
    y: &mut [f64],
) -> ThreadedOutcome {
    let d = op.decomp;
    let (p, c, depth) = (d.p, d.c_level, d.depth);
    let nv = op.plan.nv;
    let has_master = c > 0;
    let n_threads = p + usize::from(has_master);

    // One channel endpoint per thread: ranks 0..P, master at index P.
    let mut txs: Vec<Sender<Msg>> = Vec::with_capacity(n_threads);
    let mut rxs: Vec<Receiver<Msg>> = Vec::with_capacity(n_threads);
    for _ in 0..n_threads {
        let (tx, rx) = channel();
        txs.push(tx);
        rxs.push(rx);
    }

    // Disjoint per-rank output chunks: branch leaf ranges are contiguous
    // point ranges in the permuted ordering, so `y` splits cleanly.
    let lpr = d.leaves_per_rank();
    let leaves = 1usize << depth;
    let row_of =
        |leaf: usize| if leaf == leaves { a.n() } else { a.tree.node(depth, leaf).start };
    let mut y_chunks: Vec<(&mut [f64], usize)> = Vec::with_capacity(p);
    {
        let mut rest: &mut [f64] = y;
        let mut row = 0usize;
        for r in 0..p {
            let end = row_of((r + 1) * lpr);
            let (head, tail) = rest.split_at_mut((end - row) * nv);
            y_chunks.push((head, row));
            rest = tail;
            row = end;
        }
        debug_assert!(rest.is_empty(), "leaf ranges must cover the output");
    }

    // Workspaces are allocated outside the timed region: the measurement
    // is of execution, not of one-time buffer setup (the virtual path
    // likewise reuses workspaces across products). The threads below rely
    // on these being freshly zeroed — they skip the serial prologue's
    // redundant clears. (Branch-local, reusable workspaces are a ROADMAP
    // open item; plan offsets are absolute, so slicing needs plan work.)
    let mut workspaces: Vec<HgemvWorkspace> =
        (0..n_threads).map(|_| HgemvWorkspace::new(a, nv)).collect();

    let mut seats: Vec<Seat<'_>> = Vec::with_capacity(n_threads);
    {
        let mut y_it = y_chunks.into_iter();
        let mut rx_it = rxs.into_iter();
        for (idx, ws) in workspaces.iter_mut().enumerate() {
            let rx = rx_it.next().expect("one receiver per seat");
            let y = if idx < p { y_it.next() } else { None };
            seats.push(Seat { idx, ws, rx, tx: txs.clone(), y });
        }
    }
    drop(txs);

    let t0 = Instant::now();
    let results: Vec<(Metrics, f64)> = std::thread::scope(|scope| {
        let handles: Vec<_> = seats
            .into_iter()
            .map(|seat| {
                scope.spawn(move || {
                    if seat.idx < p {
                        run_rank(op, a, backend, x, t0, seat)
                    } else {
                        run_master(op, a, backend, t0, seat)
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("executor thread panicked")).collect()
    });
    let measured = t0.elapsed().as_secs_f64();

    let metrics = Metrics::merge_all(results.iter().map(|(m, _)| m));
    let per_rank: Vec<f64> = results.iter().take(p).map(|&(_, t)| t).collect();
    ThreadedOutcome { measured, per_rank, metrics }
}

/// One branch rank's slice of the product (steps 1–5 of the module docs).
fn run_rank(
    op: &DistHgemv,
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    x: &[f64],
    t0: Instant,
    seat: Seat<'_>,
) -> (Metrics, f64) {
    let d = op.decomp;
    let (p, c, depth) = (d.p, d.c_level, d.depth);
    let plan = &op.plan;
    let nv = plan.nv;
    let r = seat.idx;
    let ws = seat.ws;
    let mut metrics = Metrics::new();

    // Local branch upsweep (private state only). The full x_pad gather is
    // needed (dense rows read cross-branch source leaves), but the
    // coefficient trees and y_pad of this freshly allocated workspace are
    // already zero — the serial prologue's clears would be redundant
    // O(N·nv) passes on every rank.
    pad_leaf_input(a, x, &mut ws.x_pad, nv);
    upsweep_leaf_range(a, backend, plan, ws, &mut metrics, d.own_range(r, depth));
    for l in ((c + 1)..=depth).rev() {
        upsweep_transfer_level(a, backend, plan, ws, &mut metrics, l, d.own_range(r, l - 1));
    }

    // Plan-driven x̂ sends, then the level-C gather to the master.
    for l in c..=depth {
        let k = a.v.ranks[l];
        for (dst, nodes) in &op.exchange.levels[l].send[r] {
            let mut data = Vec::with_capacity(nodes.len() * k * nv);
            for &s in nodes {
                let s = s as usize;
                data.extend_from_slice(&ws.xhat.levels[l][s * k * nv..(s + 1) * k * nv]);
            }
            metrics.send(data.len() * 8);
            seat.tx[*dst].send(Msg::Xhat { level: l, src: r, data }).expect("xhat send");
        }
    }
    if c > 0 {
        let k_c = a.v.ranks[c];
        let data = ws.xhat.levels[c][r * k_c * nv..(r + 1) * k_c * nv].to_vec();
        metrics.send(data.len() * 8);
        seat.tx[p].send(Msg::Gather { src: r, data }).expect("gather send");
    }

    // Dense/diagonal blocks need no remote data: execute them while the
    // exchange is in flight. (They write y_pad, disjoint from the ŷ tree,
    // so reordering them before the coupling phase keeps every memory
    // location's accumulation order — and hence the result — bitwise equal
    // to the serial sweep.)
    dense_multiply_range(a, backend, plan, ws, &mut metrics, d.own_range(r, depth));

    // Receive the exchange set (the master's scatter may arrive early —
    // stash it; channel order across senders is not load-bearing).
    let expected = op.exchange.messages_into(r);
    let mut received = 0usize;
    let mut parent: Option<Vec<f64>> = None;
    while received < expected {
        match seat.rx.recv().expect("exchange recv") {
            Msg::Xhat { level, src, data } => {
                scatter_xhat(op, a, ws, r, level, src, &data);
                received += 1;
            }
            Msg::Parent { data } => parent = Some(data),
            Msg::Gather { .. } => unreachable!("gather messages address the master"),
        }
    }

    // Coupling rows, level by level in serial order.
    for l in c..=depth {
        tree_multiply_level(a, backend, plan, ws, &mut metrics, l, d.own_range(r, l));
    }

    // C-level boundary: copy the master's ŷ parent into the private tree,
    // then apply this rank's parity transfer on top of its own coupling
    // sums — the same in-place accumulation the serial downsweep performs.
    if c > 0 {
        let data = parent.unwrap_or_else(|| loop {
            match seat.rx.recv().expect("parent recv") {
                Msg::Parent { data } => break data,
                _ => unreachable!("only the master's scatter is outstanding"),
            }
        });
        let k_par = a.u.ranks[c - 1];
        let par = r >> 1;
        ws.yhat.levels[c - 1][par * k_par * nv..(par + 1) * k_par * nv].copy_from_slice(&data);
        downsweep_transfer_parity(a, backend, plan, ws, &mut metrics, c, par..par + 1, r & 1);
    }

    // Branch downsweep and disjoint output scatter.
    for l in (c + 1)..=depth {
        downsweep_transfer_level(a, backend, plan, ws, &mut metrics, l, d.own_range(r, l - 1));
    }
    downsweep_leaf_range(a, backend, plan, ws, &mut metrics, d.own_range(r, depth));
    let (y_chunk, base_row) = seat.y.expect("rank seat carries an output chunk");
    unpad_leaf_range(a, &ws.y_pad, y_chunk, nv, d.own_range(r, depth), base_row);

    (metrics, t0.elapsed().as_secs_f64())
}

/// The master thread: level-C gather, replicated top subtree, ŷ scatter.
fn run_master(
    op: &DistHgemv,
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    t0: Instant,
    seat: Seat<'_>,
) -> (Metrics, f64) {
    let d = op.decomp;
    let (p, c) = (d.p, d.c_level);
    debug_assert!(c > 0, "the master thread only exists when the top subtree does");
    let plan = &op.plan;
    let nv = plan.nv;
    // The master's workspace is freshly allocated (zeroed) by
    // `run_threaded`; only the gathered level-C blocks are written below.
    let ws = seat.ws;
    let mut metrics = Metrics::new();

    // Gather the level-C x̂ block of every branch rank.
    let k_c = a.v.ranks[c];
    let mut received = 0usize;
    while received < p {
        match seat.rx.recv().expect("gather recv") {
            Msg::Gather { src, data } => {
                ws.xhat.levels[c][src * k_c * nv..(src + 1) * k_c * nv].copy_from_slice(&data);
                received += 1;
            }
            _ => unreachable!("branch ranks only send gathers to the master"),
        }
    }

    // Replicated top subtree (the Fig. 8 low-priority stream): upsweep
    // above the C-level, top coupling levels, downsweep above the C-level.
    for l in (1..=c).rev() {
        upsweep_transfer_level(a, backend, plan, ws, &mut metrics, l, 0..1usize << (l - 1));
    }
    for l in 0..c {
        tree_multiply_level(a, backend, plan, ws, &mut metrics, l, 0..1usize << l);
    }
    for l in 1..c {
        downsweep_transfer_level(a, backend, plan, ws, &mut metrics, l, 0..1usize << (l - 1));
    }

    // Scatter each rank's level-(C-1) ŷ parent. The rank applies the
    // C-level transfer itself (its parity only), so the boundary node's
    // accumulation order matches the serial sweep bitwise.
    let k_par = a.u.ranks[c - 1];
    for r in 0..p {
        let par = r >> 1;
        let data = ws.yhat.levels[c - 1][par * k_par * nv..(par + 1) * k_par * nv].to_vec();
        metrics.send(data.len() * 8);
        seat.tx[r].send(Msg::Parent { data }).expect("parent send");
    }

    (metrics, t0.elapsed().as_secs_f64())
}

/// Place a received exchange payload into the private x̂ tree at the node
/// positions the plan promised (sorted node order, pure copy).
fn scatter_xhat(
    op: &DistHgemv,
    a: &H2Matrix,
    ws: &mut HgemvWorkspace,
    r: usize,
    level: usize,
    src: usize,
    data: &[f64],
) {
    let k = a.v.ranks[level];
    let nv = ws.nv;
    let nodes = op.exchange.levels[level].recv[r]
        .iter()
        .find(|(s, _)| *s == src)
        .map(|(_, nodes)| nodes)
        .expect("message from a source outside the exchange plan");
    debug_assert_eq!(data.len(), nodes.len() * k * nv, "payload must match the plan");
    for (i, &node) in nodes.iter().enumerate() {
        let node = node as usize;
        ws.xhat.levels[level][node * k * nv..(node + 1) * k * nv]
            .copy_from_slice(&data[i * k * nv..(i + 1) * k * nv]);
    }
}
