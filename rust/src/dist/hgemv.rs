//! Distributed HGEMV over simulated MPI ranks in virtual time (§4).
//!
//! Numerically, [`dist_hgemv`] executes the *same* level/range-scoped phase
//! functions as the serial [`crate::matvec::hgemv`], sliced per branch —
//! so its output is bitwise identical to the serial product for every P.
//! What is distributed is the *schedule*: each virtual rank's phase costs
//! are priced by an analytic [`CostModel`] (batched-kernel launch latency,
//! flop rate, memory bandwidth), the coefficient exchanges of the
//! [`ExchangePlan`] are priced by the α-β [`NetworkModel`], and the
//! timeline composes them per §4.2:
//!
//! - local branch upsweep on every rank,
//! - x̂ exchange, overlapped (when [`DistOptions::overlap`]) with the
//!   dense/diagonal block multiplication that needs no remote data,
//! - top-subtree work serialized on the master as a low-priority stream,
//! - branch downsweep after the master's ŷ scatter arrives.
//!
//! With `trace`, the three Fig. 8 streams (compute / comm / lowprio) are
//! emitted through [`TraceCollector`] as Chrome-trace JSON.
//!
//! With [`DistOptions::mode`] set to [`ExecMode::Threaded`] the same
//! branch slices execute concurrently on real OS threads (see
//! [`crate::dist::threaded`]): the report then carries measured wall-clock
//! ([`DistReport::measured`]) alongside the virtual `time`, so the
//! CostModel constants can be cross-checked against reality.

use std::ops::Range;

use crate::backend::ComputeBackend;
use crate::config::NetworkModel;
use crate::dist::threaded::run_threaded;
pub use crate::dist::threaded::ExecMode;
use crate::dist::{Decomposition, ExchangePlan};
use crate::matvec::{
    dense_multiply_range, downsweep_leaf_range, downsweep_transfer_level, hgemv_prologue,
    tree_multiply_level, unpad_leaf_output, upsweep_leaf_range, upsweep_transfer_level, HgemvPlan,
    HgemvWorkspace,
};
use crate::metrics::Metrics;
use crate::tree::H2Matrix;
use crate::util::trace::TraceCollector;

/// Options of one distributed product.
#[derive(Clone, Copy, Debug)]
pub struct DistOptions {
    /// The simulated interconnect.
    pub net: NetworkModel,
    /// Overlap the coefficient exchange with local (diagonal) compute.
    pub overlap: bool,
    /// Collect a Chrome-trace timeline of the *virtual* schedule
    /// ([`DistReport::trace_json`]).
    pub trace: bool,
    /// In [`ExecMode::Threaded`], also collect a *measured* Chrome trace
    /// from per-phase `Instant` stamps inside the rank workers and the
    /// recording transport's per-message stamps
    /// ([`DistReport::measured_trace_json`]).
    pub measured_trace: bool,
    /// Execute on real OS threads ([`ExecMode::Threaded`]) or replay the
    /// virtual-time simulation ([`ExecMode::Virtual`], the default).
    pub mode: ExecMode,
}

impl Default for DistOptions {
    fn default() -> Self {
        DistOptions {
            net: NetworkModel::default(),
            overlap: true,
            trace: false,
            measured_trace: false,
            mode: ExecMode::Virtual,
        }
    }
}

/// Analytic per-kernel cost model for virtual compute time: a batched
/// launch pays a fixed latency, the flops run at a sustained rate, and
/// every operand/result word crosses the memory bus once. The constants
/// approximate a per-GPU share of the paper's V100 node on *small-block*
/// batched kernels (launch-bound at nv = 1 — which is exactly the paper's
/// arithmetic-intensity argument for multi-vector products, Fig. 9).
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Batched-kernel launch latency (s).
    pub t_launch: f64,
    /// Seconds per flop (1 / sustained rate).
    pub flop_time: f64,
    /// Seconds per byte of operand/result traffic.
    pub byte_time: f64,
}

impl Default for CostModel {
    fn default() -> Self {
        CostModel { t_launch: 1.5e-6, flop_time: 4.0e-10, byte_time: 4.0e-11 }
    }
}

impl CostModel {
    /// Virtual time of one batched GEMM of nb (m × k)·(k × n) blocks.
    pub fn gemm(&self, nb: usize, m: usize, k: usize, n: usize) -> f64 {
        if nb == 0 {
            return 0.0;
        }
        let flops = 2.0 * (nb * m * k * n) as f64;
        let words = (nb * (m * k + k * n + m * n)) as f64;
        self.t_launch + flops * self.flop_time + 8.0 * words * self.byte_time
    }

    /// Virtual time of moving `bytes` over the interconnect as one
    /// message (launch latency plus the bandwidth term).
    pub fn xfer(&self, bytes: usize) -> f64 {
        self.t_launch + bytes as f64 * self.byte_time
    }

    /// Price a product pipeline over the resident socket session (the
    /// E10 serving path): each product ships O(N/P) `Input` frames
    /// (`ship_s`), computes on the workers (`compute_s`) and pays the
    /// coordinator's top share plus the `Output` gather (`gather_s`).
    /// Sequential dispatch pays the full sum per product; the pipelined
    /// path overlaps shipping/gathering of adjacent products with worker
    /// compute, so each steady-state step costs the *larger* of the
    /// worker stage and the coordinator stage. Returns `(t_sequential,
    /// t_pipelined)` for `products` products — the gap between the two
    /// is the overlap the pipeline is predicted to hide, which
    /// `model_check.py` cross-checks against the measured E10 rows.
    pub fn pipeline(
        &self,
        products: usize,
        ship_s: f64,
        compute_s: f64,
        gather_s: f64,
    ) -> (f64, f64) {
        if products == 0 {
            return (0.0, 0.0);
        }
        let b = products as f64;
        let seq = b * (ship_s + compute_s + gather_s);
        let steady = compute_s.max(ship_s + gather_s);
        let pipe = ship_s + b * steady + gather_s;
        (seq, pipe.min(seq))
    }

    /// The model the schedule prices with on *this* host: the calibration
    /// file named by the `H2OPUS_COST_CALIBRATION` environment variable
    /// (written by `python/tests/model_check.py --fit` from measured E1/E2
    /// bench rows; the CLI's `--cost-calibration` flag sets the variable),
    /// falling back to the V100-share defaults. Cached after first load.
    pub fn host() -> CostModel {
        static CACHE: std::sync::OnceLock<CostModel> = std::sync::OnceLock::new();
        *CACHE.get_or_init(|| {
            match std::env::var_os("H2OPUS_COST_CALIBRATION") {
                Some(path) => {
                    let path = std::path::PathBuf::from(path);
                    let text = std::fs::read_to_string(&path).ok();
                    match text.as_deref().and_then(CostModel::from_json) {
                        Some(m) => {
                            // Honesty check: a flop_time fitted against a
                            // multithreaded batched backend is not a
                            // single-thread rate. The fit records the pool
                            // width it saw; warn when this process runs a
                            // different one.
                            let fitted = text
                                .as_deref()
                                .and_then(|t| json_number(t, "backend_threads"))
                                .map(|v| v as usize);
                            let current = crate::backend::backend_threads();
                            if let Some(fitted) = fitted {
                                if fitted != current {
                                    eprintln!(
                                        "h2opus: CostModel calibration {} was fit with \
                                         backend_threads={fitted}, but this process uses \
                                         {current} — virtual times may be skewed (refit with \
                                         model_check.py --fit)",
                                        path.display()
                                    );
                                }
                            }
                            m
                        }
                        None => {
                            eprintln!(
                                "h2opus: could not load CostModel calibration from {} — \
                                 using V100-share defaults",
                                path.display()
                            );
                            CostModel::default()
                        }
                    }
                }
                None => CostModel::default(),
            }
        })
    }

    /// Parse a `cost_model_calibration.json` file (the `--fit` output).
    pub fn from_calibration_file(path: &std::path::Path) -> Option<CostModel> {
        let text = std::fs::read_to_string(path).ok()?;
        CostModel::from_json(&text)
    }

    /// Parse the three constants out of the calibration JSON. Hand-rolled
    /// key scan (the offline image vendors no serde): takes the *first*
    /// occurrence of each key, which in the fit's payload is the
    /// calibrated top-level value (the nested `"defaults"` object comes
    /// after). Returns `None` unless all three parse to finite positive
    /// numbers.
    pub fn from_json(text: &str) -> Option<CostModel> {
        let t_launch = json_number(text, "t_launch")?;
        let flop_time = json_number(text, "flop_time")?;
        let byte_time = json_number(text, "byte_time")?;
        let ok = |v: f64| v.is_finite() && v > 0.0;
        if ok(t_launch) && ok(flop_time) && ok(byte_time) {
            Some(CostModel { t_launch, flop_time, byte_time })
        } else {
            None
        }
    }
}

/// First numeric value following `"key":` in a JSON text.
fn json_number(text: &str, key: &str) -> Option<f64> {
    let pat = format!("\"{key}\"");
    let i = text.find(&pat)?;
    let rest = text[i + pat.len()..].trim_start().strip_prefix(':')?.trim_start();
    let end = rest
        .find(|ch: char| !(ch.is_ascii_digit() || matches!(ch, '+' | '-' | '.' | 'e' | 'E')))
        .unwrap_or(rest.len());
    rest[..end].parse().ok()
}

/// Outcome of one distributed product.
#[derive(Clone, Debug)]
pub struct DistReport {
    /// Virtual time of the product (max over ranks).
    pub time: f64,
    /// Per-rank virtual completion times.
    pub per_rank: Vec<f64>,
    /// Executed-work counters plus the comm volume/messages: modeled in
    /// [`ExecMode::Virtual`], actual channel traffic in
    /// [`ExecMode::Threaded`].
    pub metrics: Metrics,
    /// Total bytes received across ranks (exchange + gather/scatter), as
    /// priced by the virtual model in both modes.
    pub recv_bytes: usize,
    /// Chrome-trace JSON of the Fig. 8 streams (when `opts.trace`).
    pub trace_json: Option<String>,
    /// Measured wall-clock seconds of the parallel section
    /// ([`ExecMode::Threaded`] only) — the reality the virtual `time`
    /// models.
    pub measured: Option<f64>,
    /// Per-rank measured completion offsets ([`ExecMode::Threaded`] only).
    pub measured_per_rank: Option<Vec<f64>>,
    /// Chrome-trace JSON of the *measured* execution: per-phase spans
    /// stamped inside the rank workers plus the recording transport's
    /// per-message events ([`ExecMode::Threaded`] with
    /// [`DistOptions::measured_trace`]).
    pub measured_trace_json: Option<String>,
}

/// A reusable distributed-HGEMV operator: decomposition, marshaling plan
/// and exchange plan built once for a given (matrix, P, nv).
#[derive(Clone, Debug)]
pub struct DistHgemv {
    pub decomp: Decomposition,
    pub plan: HgemvPlan,
    pub exchange: ExchangePlan,
}

impl DistHgemv {
    pub fn new(a: &H2Matrix, p: usize, nv: usize) -> Self {
        let decomp = Decomposition::new(p, a.depth()).unwrap_or_else(|e| panic!("{e}"));
        let plan = HgemvPlan::new(a, nv);
        let exchange = ExchangePlan::build(a, decomp);
        DistHgemv { decomp, plan, exchange }
    }

    /// y = A·x across the virtual ranks. `x`/`y` are N × nv in the permuted
    /// ordering, as in [`crate::matvec::hgemv`]; `ws` must match `nv` (in
    /// [`ExecMode::Threaded`] each rank thread uses its own workspace and
    /// `ws` is left untouched).
    pub fn run(
        &self,
        a: &H2Matrix,
        backend: &dyn ComputeBackend,
        x: &[f64],
        y: &mut [f64],
        ws: &mut HgemvWorkspace,
        opts: &DistOptions,
    ) -> DistReport {
        let nv = self.plan.nv;
        assert_eq!(ws.nv, nv, "workspace built for different nv");
        let n = a.n();
        assert_eq!(x.len(), n * nv);
        assert_eq!(y.len(), n * nv);
        let d = self.decomp;
        assert_eq!(d.depth, a.depth(), "decomposition built for a different tree");
        let (p, c, depth) = (d.p, d.c_level, d.depth);
        let plan = &self.plan;
        let mut metrics = Metrics::new();
        let mut measured = None;
        let mut measured_per_rank = None;
        let mut measured_trace_json = None;

        match opts.mode {
            ExecMode::Threaded => {
                // ---- real execution: one pooled OS thread per rank over
                // the in-process transport, branch-local workspaces ----
                let out = run_threaded(self, a, backend, x, y, opts.measured_trace);
                metrics = out.metrics;
                measured = Some(out.measured);
                measured_per_rank = Some(out.per_rank);
                measured_trace_json = out.trace_json;
            }
            ExecMode::Virtual => {
                // ---- numerical execution: the serial phases, sliced per
                // branch on one thread ----
                hgemv_prologue(a, x, ws);
                // Branch upsweeps: leaves, then transfer levels whose
                // parents the ranks own (l-1 >= C).
                for r in 0..p {
                    upsweep_leaf_range(a, backend, plan, ws, &mut metrics, d.own_range(r, depth));
                }
                for l in ((c + 1)..=depth).rev() {
                    for r in 0..p {
                        upsweep_transfer_level(
                            a,
                            backend,
                            plan,
                            ws,
                            &mut metrics,
                            l,
                            d.own_range(r, l - 1),
                        );
                    }
                }
                // Top-subtree upsweep (master).
                for l in (1..=c).rev() {
                    upsweep_transfer_level(a, backend, plan, ws, &mut metrics, l, 0..1usize << (l - 1));
                }
                // Coupling: top levels on the master, distributed levels per rank.
                for l in 0..c {
                    tree_multiply_level(a, backend, plan, ws, &mut metrics, l, 0..1usize << l);
                }
                for l in c..=depth {
                    for r in 0..p {
                        tree_multiply_level(a, backend, plan, ws, &mut metrics, l, d.own_range(r, l));
                    }
                }
                for r in 0..p {
                    dense_multiply_range(a, backend, plan, ws, &mut metrics, d.own_range(r, depth));
                }
                // Top-subtree downsweep, then branch downsweeps.
                for l in 1..=c {
                    downsweep_transfer_level(a, backend, plan, ws, &mut metrics, l, 0..1usize << (l - 1));
                }
                for l in (c + 1)..=depth {
                    for r in 0..p {
                        downsweep_transfer_level(
                            a,
                            backend,
                            plan,
                            ws,
                            &mut metrics,
                            l,
                            d.own_range(r, l - 1),
                        );
                    }
                }
                for r in 0..p {
                    downsweep_leaf_range(a, backend, plan, ws, &mut metrics, d.own_range(r, depth));
                }
                unpad_leaf_output(a, &ws.y_pad, y, nv);
            }
        }

        // Padding waste of the batched execution: leaf vector padding (in
        // and out) plus the zero-padded dense blocks.
        metrics.pad_waste += padding_waste(a, nv);

        // ---- virtual-time schedule (in Threaded mode the actual channel
        // traffic is already in `metrics`; the schedule only prices) ----
        let account_comm = opts.mode == ExecMode::Virtual;
        let mut rep = self.schedule(a, nv, opts, &mut metrics, account_comm);
        rep.measured = measured;
        rep.measured_per_rank = measured_per_rank;
        rep.measured_trace_json = measured_trace_json;
        rep
    }

    /// Price the executed product in virtual time (see module docs). When
    /// `account_comm`, fills the comm counters of `metrics` with the
    /// modeled exchange/gather/scatter volumes (the threaded executor has
    /// already counted its real channel traffic); always moves `metrics`
    /// into the report.
    fn schedule(
        &self,
        a: &H2Matrix,
        nv: usize,
        opts: &DistOptions,
        metrics: &mut Metrics,
        account_comm: bool,
    ) -> DistReport {
        let model = CostModel::host();
        let net = &opts.net;
        let d = self.decomp;
        let (p, c, depth) = (d.p, d.c_level, d.depth);
        let m_pad = a.u.leaf_dim;
        let lpr = d.leaves_per_rank();

        // Per-rank upsweep compute (branches are same-shaped: one cost).
        let mut up_cost = model.gemm(lpr, a.rank(depth), m_pad, nv);
        for l in (c + 1)..=depth {
            let (k_l, k_par) = (a.rank(l), a.rank(l - 1));
            // two parity batches of the rank's 2^(l-1-C) parents
            up_cost += 2.0 * model.gemm(1usize << (l - 1 - c), k_par, k_l, nv);
        }
        let c_up: Vec<f64> = vec![up_cost; p];

        // Per-rank coupling (split into local/remote sources) and dense.
        let mut c_mul_local = vec![0.0; p];
        let mut c_mul_remote = vec![0.0; p];
        let mut c_dense = vec![0.0; p];
        for r in 0..p {
            for l in c..=depth {
                let k = a.rank(l);
                let rows = d.own_range(r, l);
                let (mut total, mut remote) = (0usize, 0usize);
                let mut lvl_cost = 0.0;
                for batch in &a.coupling[l].batches {
                    let nb = count_rows(&a.coupling[l].pairs, batch, &rows);
                    if nb > 0 {
                        lvl_cost += model.gemm(nb, k, k, nv);
                        total += nb;
                        remote += batch
                            .iter()
                            .filter(|&&pi| {
                                let (t, s) = a.coupling[l].pairs[pi as usize];
                                rows.contains(&(t as usize)) && d.owner(l, s as usize) != r
                            })
                            .count();
                    }
                }
                if total > 0 {
                    let f = remote as f64 / total as f64;
                    c_mul_local[r] += lvl_cost * (1.0 - f);
                    c_mul_remote[r] += lvl_cost * f;
                }
            }
            let rows = d.own_range(r, depth);
            for batch in &a.dense.batches {
                let nb = count_rows(&a.dense.pairs, batch, &rows);
                if nb > 0 {
                    c_dense[r] += model.gemm(nb, m_pad, m_pad, nv);
                }
            }
        }

        // Per-rank downsweep compute.
        let c_down: Vec<f64> = (0..p)
            .map(|_| {
                let mut t = 0.0;
                for l in (c + 1)..=depth {
                    let (k_l, k_par) = (a.rank(l), a.rank(l - 1));
                    t += 2.0 * model.gemm(1usize << (l - 1 - c), k_l, k_par, nv);
                }
                t + model.gemm(lpr, m_pad, a.rank(depth), nv)
            })
            .collect();

        // Exchange comm per rank (§4.1 volumes; one message per source per
        // level), wired into the metrics counters.
        let mut x_comm = vec![0.0; p];
        let mut recv_bytes = 0usize;
        for r in 0..p {
            for l in c..=depth {
                // x̂ is a V-tree quantity: price the bytes the threaded
                // executor actually ships (U and V ranks can differ).
                let k = a.v.ranks[l];
                for (_, nodes) in &self.exchange.levels[l].recv[r] {
                    let bytes = nodes.len() * k * nv * 8;
                    x_comm[r] += net.time(bytes);
                    if account_comm {
                        metrics.send(bytes);
                    }
                    recv_bytes += bytes;
                }
            }
        }

        // Top subtree: master gathers the level-C x̂, runs the replicated
        // top (low priority), scatters the level-C ŷ.
        let mut c_top = 0.0;
        for l in 1..=c {
            let (k_l, k_par) = (a.rank(l), a.rank(l - 1));
            c_top += 2.0 * model.gemm(1usize << (l - 1), k_par, k_l, nv); // up
            c_top += 2.0 * model.gemm(1usize << (l - 1), k_l, k_par, nv); // down
        }
        for l in 0..c {
            let k = a.rank(l);
            for batch in &a.coupling[l].batches {
                if !batch.is_empty() {
                    c_top += model.gemm(batch.len(), k, k, nv);
                }
            }
        }
        let t_up_max = c_up.iter().cloned().fold(0.0_f64, f64::max);
        let msg_bytes = a.rank(c) * nv * 8;
        let msg = net.time(msg_bytes);
        let t_master = if c > 0 {
            for _ in 1..p {
                if account_comm {
                    metrics.send(msg_bytes); // gather
                    metrics.send(msg_bytes); // scatter
                }
                recv_bytes += 2 * msg_bytes;
            }
            t_up_max + (p - 1) as f64 * msg + c_top
        } else {
            0.0
        };

        // Compose the per-rank timelines.
        let mut trace = opts.trace.then(TraceCollector::new);
        let mut per_rank = vec![0.0; p];
        for r in 0..p {
            let local = c_dense[r] + c_mul_local[r];
            let t1 = c_up[r];
            let t2 = if opts.overlap {
                t1 + x_comm[r].max(local) + c_mul_remote[r]
            } else {
                t1 + x_comm[r] + local + c_mul_remote[r]
            };
            let t3 = if c > 0 { t2.max(t_master + r as f64 * msg) } else { t2 };
            per_rank[r] = t3 + c_down[r];
            if let Some(tc) = trace.as_mut() {
                tc.add("upsweep", "compute", r, 0, 0.0, t1);
                if x_comm[r] > 0.0 {
                    tc.add("xhat exchange", "comm", r, 1, t1, x_comm[r]);
                }
                let local_start = if opts.overlap { t1 } else { t1 + x_comm[r] };
                if local > 0.0 {
                    tc.add("dense + diagonal mult", "compute", r, 0, local_start, local);
                }
                if c_mul_remote[r] > 0.0 {
                    tc.add("off-rank mult", "compute", r, 0, t2 - c_mul_remote[r], c_mul_remote[r]);
                }
                tc.add("downsweep", "compute", r, 0, t3, c_down[r]);
            }
        }
        if let Some(tc) = trace.as_mut() {
            if c > 0 {
                let gather = (p - 1) as f64 * msg;
                tc.add("xhat gather", "comm", 0, 1, t_up_max, gather);
                tc.add("top subtree", "lowprio", 0, 2, t_up_max + gather, c_top);
                for r in 1..p {
                    tc.add("yhat scatter", "comm", r, 1, t_master + (r - 1) as f64 * msg, msg);
                }
            }
        }

        let time = per_rank.iter().cloned().fold(0.0_f64, f64::max);
        DistReport {
            time,
            per_rank,
            metrics: std::mem::take(metrics),
            recv_bytes,
            trace_json: trace.map(|tc| tc.to_json()),
            measured: None,
            measured_per_rank: None,
            measured_trace_json: None,
        }
    }
}

/// Count the entries of a conflict-free batch whose block row lies in `rows`.
fn count_rows(pairs: &[(u32, u32)], batch: &[u32], rows: &Range<usize>) -> usize {
    batch.iter().filter(|&&pi| rows.contains(&(pairs[pi as usize].0 as usize))).count()
}

/// Zero-padding waste of one product: leaf vector padding for x and y plus
/// the padded rows/cols of the dense blocks.
fn padding_waste(a: &H2Matrix, nv: usize) -> u64 {
    let m_pad = a.u.leaf_dim;
    let leaf_pad: usize =
        a.u.leaf_sizes.iter().map(|&sz| (m_pad - sz) * nv).sum::<usize>() * 2;
    let leaf = a.depth();
    let dense_pad: usize = a
        .dense
        .pairs
        .iter()
        .map(|&(t, s)| {
            let rows = a.tree.node(leaf, t as usize).size();
            let cols = a.tree.node(leaf, s as usize).size();
            m_pad * m_pad - rows * cols
        })
        .sum();
    (leaf_pad + dense_pad) as u64
}

/// One-shot distributed product: builds the plans, runs, reports.
pub fn dist_hgemv(
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    p: usize,
    nv: usize,
    x: &[f64],
    y: &mut [f64],
    opts: &DistOptions,
) -> DistReport {
    let op = DistHgemv::new(a, p, nv);
    let mut ws = HgemvWorkspace::new(a, nv);
    op.run(a, backend, x, y, &mut ws, opts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::NativeBackend;
    use crate::config::H2Config;
    use crate::construct::{build_h2, ExponentialKernel};
    use crate::geometry::PointSet;
    use crate::matvec::hgemv;
    use crate::util::Prng;

    fn sample(n_side: usize) -> H2Matrix {
        let points = PointSet::grid_2d(n_side, 1.0);
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
        build_h2(points, &kernel, &cfg)
    }

    #[test]
    fn bitwise_equal_to_serial_for_all_p() {
        // The distributed path runs the same phase functions over branch
        // slices: outputs must be *identical*, not merely close.
        let a = sample(16); // N = 256
        let n = a.n();
        let mut rng = Prng::new(700);
        for nv in [1usize, 3] {
            let x = rng.normal_vec(n * nv);
            let plan = HgemvPlan::new(&a, nv);
            let mut ws = HgemvWorkspace::new(&a, nv);
            let mut metrics = Metrics::new();
            let mut y_serial = vec![0.0; n * nv];
            hgemv(&a, &NativeBackend, &plan, &x, &mut y_serial, &mut ws, &mut metrics);
            for p in [1usize, 2, 4] {
                let mut y_dist = vec![0.0; n * nv];
                dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y_dist, &DistOptions::default());
                assert_eq!(y_dist, y_serial, "P={p} nv={nv} not bitwise equal");
            }
        }
    }

    #[test]
    fn flops_match_serial_and_comm_counters_live() {
        let a = sample(16);
        let n = a.n();
        let x = vec![1.0; n];
        let mut y = vec![0.0; n];
        let rep = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &DistOptions::default());
        assert_eq!(rep.metrics.flops, crate::matvec::hgemv_flops(&a, 1));
        assert!(rep.metrics.bytes_sent > 0, "exchange must be accounted");
        assert!(rep.metrics.messages > 0);
        assert_eq!(rep.per_rank.len(), 4);
        assert!(rep.time > 0.0);
    }

    #[test]
    fn padding_waste_accounted_on_irregular_leaves() {
        // 17x17 grid -> 289 points over 32 leaves of 9-10 points: both the
        // leaf vectors and the dense blocks carry zero padding.
        let a = sample(17);
        let n = a.n();
        let x = vec![1.0; n];
        let mut y = vec![0.0; n];
        let rep = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &DistOptions::default());
        assert!(rep.metrics.pad_waste > 0, "padding must be accounted");
    }

    #[test]
    fn more_ranks_is_faster_on_this_problem() {
        let a = sample(32); // N = 1024
        let n = a.n();
        let x = vec![0.5; n];
        let mut y = vec![0.0; n];
        let t1 = dist_hgemv(&a, &NativeBackend, 1, 1, &x, &mut y, &DistOptions::default()).time;
        let t4 = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &DistOptions::default()).time;
        assert!(t4 < t1, "P=4 {t4} !< P=1 {t1}");
    }

    #[test]
    fn threaded_bitwise_equal_to_serial_for_all_p() {
        // The real executor runs the same phase functions per branch
        // thread: outputs must be *identical* to the serial product.
        let a = sample(16); // N = 256, depth 4
        let n = a.n();
        let mut rng = Prng::new(701);
        for nv in [1usize, 3] {
            let x = rng.normal_vec(n * nv);
            let plan = HgemvPlan::new(&a, nv);
            let mut ws = HgemvWorkspace::new(&a, nv);
            let mut metrics = Metrics::new();
            let mut y_serial = vec![0.0; n * nv];
            hgemv(&a, &NativeBackend, &plan, &x, &mut y_serial, &mut ws, &mut metrics);
            let opts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
            for p in [1usize, 2, 4, 8] {
                let mut y_thr = vec![0.0; n * nv];
                let rep = dist_hgemv(&a, &NativeBackend, p, nv, &x, &mut y_thr, &opts);
                assert_eq!(y_thr, y_serial, "P={p} nv={nv} not bitwise equal");
                assert!(rep.measured.unwrap() > 0.0);
            }
        }
    }

    #[test]
    fn threaded_counters_match_model_and_channels_live() {
        let a = sample(16);
        let n = a.n();
        let x = vec![1.0; n];
        let mut y = vec![0.0; n];
        let opts = DistOptions { mode: ExecMode::Threaded, ..DistOptions::default() };
        let rep = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &opts);
        // Same GEMMs as the serial sweep, just on different threads.
        assert_eq!(rep.metrics.flops, crate::matvec::hgemv_flops(&a, 1));
        // Real channel traffic: the plan exchanges plus gather + scatter.
        assert!(rep.metrics.bytes_sent > 0, "channel traffic must be counted");
        assert!(rep.metrics.messages > 0);
        assert_eq!(rep.measured_per_rank.as_ref().unwrap().len(), 4);
        // The virtual schedule is still priced alongside.
        assert!(rep.time > 0.0);
    }

    #[test]
    fn cost_model_parses_calibration_json() {
        // The --fit payload shape: calibrated values first, defaults in a
        // nested object afterwards (first-occurrence scan must pick the
        // calibrated ones).
        let json = r#"{
  "t_launch": 2.5e-06,
  "flop_time": 1.25e-10,
  "byte_time": 3.0e-11,
  "rel_rms_residual": 0.21,
  "rows_used": 12,
  "defaults": {"t_launch": 1.5e-06, "flop_time": 4.0e-10, "byte_time": 4.0e-11}
}"#;
        let m = CostModel::from_json(json).expect("parse");
        assert_eq!(m.t_launch, 2.5e-6);
        assert_eq!(m.flop_time, 1.25e-10);
        assert_eq!(m.byte_time, 3.0e-11);
        // Malformed / non-positive constants are rejected, not defaulted.
        assert!(CostModel::from_json("{}").is_none());
        assert!(CostModel::from_json(
            r#"{"t_launch": -1.0, "flop_time": 1e-10, "byte_time": 1e-11}"#
        )
        .is_none());
        assert!(CostModel::from_json(
            r#"{"t_launch": "nope", "flop_time": 1e-10, "byte_time": 1e-11}"#
        )
        .is_none());
    }

    #[test]
    fn cost_model_loads_calibration_file() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("h2opus-calib-test-{}.json", std::process::id()));
        std::fs::write(&path, r#"{"t_launch": 1e-6, "flop_time": 2e-10, "byte_time": 5e-11}"#)
            .expect("write calibration");
        let m = CostModel::from_calibration_file(&path).expect("load");
        assert_eq!(m.flop_time, 2e-10);
        let _ = std::fs::remove_file(&path);
        assert!(CostModel::from_calibration_file(std::path::Path::new(
            "/nonexistent/h2opus-calibration.json"
        ))
        .is_none());
    }

    #[test]
    fn report_is_deterministic() {
        let a = sample(16);
        let n = a.n();
        let x = vec![1.0; n];
        let mut y = vec![0.0; n];
        let opts = DistOptions::default();
        let r1 = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &opts);
        let r2 = dist_hgemv(&a, &NativeBackend, 4, 1, &x, &mut y, &opts);
        assert_eq!(r1.time, r2.time);
        assert_eq!(r1.recv_bytes, r2.recv_bytes);
    }
}
