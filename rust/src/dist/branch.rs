//! Branch-local marshaling plans and O(N/P) workspaces.
//!
//! The PR-2 threaded executor still allocated a *full-size*
//! [`crate::matvec::HgemvWorkspace`] per rank (the serial plan's offsets
//! are absolute), so P ranks cost P× the serial memory — the opposite of
//! the paper's distributed-memory claim. This module slices both the
//! workspace and the marshaling plan per branch:
//!
//! - [`BranchWorkspace`] holds, for one rank, only its branch's nodes at
//!   every level l ≥ C plus a *halo*: the remote x̂ nodes its coupling rows
//!   reference (exactly the [`crate::dist::ExchangePlan`] receive sets)
//!   and the remote leaves its dense rows read. Totalling O(N/P) plus the
//!   level-C boundary, vs the serial workspace's O(N).
//! - [`BranchPlan`] rebases every marshaling offset to that layout: own
//!   nodes map to `global − first_owned`, halo nodes translate through a
//!   sorted per-level table (binary search at plan build, pure offset
//!   arithmetic in the hot path). Matrix data (bases, transfers, coupling
//!   and dense blocks) stays globally indexed — in-process ranks share it
//!   immutably, and socket worker processes rebuild it deterministically.
//!
//! The branch phase functions below feed the *same* per-block GEMMs to the
//! backend in the *same* per-destination order as the serial sweep
//! (prefiltered batch entries keep their serial relative order), so the
//! distributed product stays bitwise identical to [`crate::matvec::hgemv`]
//! for every P — now with per-rank memory that actually shrinks as P
//! grows (asserted by `tests/transport.rs`'s memory regression test).

use std::ops::Range;

use crate::backend::{BatchRef, ComputeBackend, GemmDims};
use crate::dist::ExchangePlan;
use crate::matvec::plan::{BatchOffsets, LevelMultPlan, LevelTransferPlan};
use crate::metrics::Metrics;
use crate::tree::H2Matrix;

/// The branch-sliced marshaling plan of one rank: every coefficient offset
/// is local to that rank's [`BranchWorkspace`]; matrix-data offsets stay
/// global.
#[derive(Clone, Debug)]
pub struct BranchPlan {
    pub rank: usize,
    pub nv: usize,
    pub c_level: usize,
    pub depth: usize,
    /// Globally indexed leaf range this rank owns.
    pub leaf_range: Range<usize>,
    /// Per level l: sorted remote x̂ nodes referenced by owned coupling
    /// rows (the exchange plan's receive sets, merged across sources).
    /// Empty above the C-level.
    pub xhat_halo: Vec<Vec<u32>>,
    /// Sorted remote leaves read by owned dense rows.
    pub xpad_halo: Vec<u32>,
    /// Leaf-stage offsets over the own leaves: bases globally indexed,
    /// vector/coefficient offsets local.
    pub leaf_basis_off: Vec<usize>,
    pub leaf_vec_off: Vec<usize>,
    pub leaf_coeff_off: Vec<usize>,
    /// `up[l]` for l in C+1..=depth (lower indices empty): interlevel
    /// transfer parity batches over the own parents of level l-1, shared
    /// by the upsweep and the downsweep exactly like the serial plan.
    pub up: Vec<LevelTransferPlan>,
    /// `mult[l]` for l in C..=depth (lower indices empty): coupling
    /// batches prefiltered to owned rows, src offsets translated through
    /// the halo table.
    pub mult: Vec<LevelMultPlan>,
    /// Dense batches prefiltered to owned rows.
    pub dense: LevelMultPlan,
    /// Offset of this rank's level-C transfer matrix in `u.transfers[C]`
    /// (the C-level boundary downsweep). Zero when C = 0 (unused).
    pub boundary_transfer_off: usize,
    /// `sends[l]` = (destination rank, local x̂ offsets of the plan's send
    /// nodes) — what to ship as soon as level l's upsweep finishes.
    pub sends: Vec<Vec<(usize, Vec<usize>)>>,
    /// `recv_scatter[l]` = (source rank, local x̂ offsets of the plan's
    /// receive nodes) — where an incoming (level, src) payload lands.
    pub recv_scatter: Vec<Vec<(usize, Vec<usize>)>>,
}

impl BranchPlan {
    /// Slice the marshaling plan of `a` for `rank` under the exchange
    /// plan's decomposition.
    pub fn build(a: &H2Matrix, ex: &ExchangePlan, rank: usize, nv: usize) -> Self {
        let d = ex.decomp;
        let (c, depth) = (d.c_level, d.depth);
        let m_pad = a.u.leaf_dim;
        let k_leaf = a.rank(depth);
        let lpr = d.leaves_per_rank();
        let leaf_range = d.own_range(rank, depth);

        // Halo tables (the exchange plan's receive sets, merged per level).
        let mut xhat_halo: Vec<Vec<u32>> = vec![Vec::new(); depth + 1];
        for l in c..=depth {
            xhat_halo[l] = ex.halo_nodes(l, rank);
        }
        let mut xpad_halo: Vec<u32> = a
            .dense
            .pairs
            .iter()
            .filter(|&&(t, s)| {
                leaf_range.contains(&(t as usize)) && !leaf_range.contains(&(s as usize))
            })
            .map(|&(_, s)| s)
            .collect();
        xpad_halo.sort_unstable();
        xpad_halo.dedup();

        // Local node index at level l: own nodes first (rebased through
        // the decomposition), then the sorted halo.
        let xloc = |l: usize, j: usize| -> usize {
            if d.own_range(rank, l).contains(&j) {
                d.local_index(rank, l, j)
            } else {
                d.branch_width(l)
                    + xhat_halo[l]
                        .binary_search(&(j as u32))
                        .expect("remote coupling source must be in the exchange halo")
            }
        };
        let leaf_loc = |j: usize| -> usize {
            if leaf_range.contains(&j) {
                j - leaf_range.start
            } else {
                lpr + xpad_halo
                    .binary_search(&(j as u32))
                    .expect("remote dense source must be in the leaf halo")
            }
        };

        // Leaf stage (own leaves).
        let mut leaf_basis_off = Vec::with_capacity(lpr);
        let mut leaf_vec_off = Vec::with_capacity(lpr);
        let mut leaf_coeff_off = Vec::with_capacity(lpr);
        for j in leaf_range.clone() {
            leaf_basis_off.push(j * m_pad * k_leaf);
            leaf_vec_off.push((j - leaf_range.start) * m_pad * nv);
            leaf_coeff_off.push((j - leaf_range.start) * k_leaf * nv);
        }

        // Interlevel transfers: own parents of level l-1, local child and
        // parent coefficient offsets, global transfer offsets.
        let mut up: Vec<LevelTransferPlan> = vec![LevelTransferPlan::default(); depth + 1];
        for l in (c + 1)..=depth {
            let (k_l, k_par) = (a.rank(l), a.rank(l - 1));
            let parents = d.own_range(rank, l - 1);
            let child_base = d.own_range(rank, l).start;
            let plan = &mut up[l];
            for parity in 0..2 {
                let po = &mut plan.parity[parity];
                po.nb = parents.len();
                for (i, p) in parents.clone().enumerate() {
                    let child = 2 * p + parity;
                    po.transfer_off.push(child * k_l * k_par);
                    po.child_off.push((child - child_base) * k_l * nv);
                    po.parent_off.push(i * k_par * nv);
                }
            }
        }

        // Coupling batches prefiltered to owned rows; serial relative
        // order within each batch is preserved, so per-destination
        // accumulation order matches the whole-level sweep bitwise.
        let mut mult: Vec<LevelMultPlan> = Vec::with_capacity(depth + 1);
        for (l, cl) in a.coupling.iter().enumerate() {
            let mut lp = LevelMultPlan::default();
            if l >= c {
                let k = a.rank(l);
                let rows = d.own_range(rank, l);
                for batch in &cl.batches {
                    let mut bo = BatchOffsets::default();
                    for &pi in batch {
                        let (t, s) = cl.pairs[pi as usize];
                        if rows.contains(&(t as usize)) {
                            bo.block_off.push(pi as usize * k * k);
                            bo.src_off.push(xloc(l, s as usize) * k * nv);
                            bo.dst_off.push((t as usize - rows.start) * k * nv);
                        }
                    }
                    bo.nb = bo.dst_off.len();
                    if bo.nb > 0 {
                        lp.batches.push(bo);
                    }
                }
            }
            mult.push(lp);
        }

        // Dense batches prefiltered to owned rows.
        let mut dense = LevelMultPlan::default();
        for batch in &a.dense.batches {
            let mut bo = BatchOffsets::default();
            for &pi in batch {
                let (t, s) = a.dense.pairs[pi as usize];
                if leaf_range.contains(&(t as usize)) {
                    bo.block_off.push(pi as usize * m_pad * m_pad);
                    bo.src_off.push(leaf_loc(s as usize) * m_pad * nv);
                    bo.dst_off.push((t as usize - leaf_range.start) * m_pad * nv);
                }
            }
            bo.nb = bo.dst_off.len();
            if bo.nb > 0 {
                dense.batches.push(bo);
            }
        }

        // Exchange send/receive sets translated to local x̂ offsets.
        let mut sends: Vec<Vec<(usize, Vec<usize>)>> = vec![Vec::new(); depth + 1];
        let mut recv_scatter: Vec<Vec<(usize, Vec<usize>)>> = vec![Vec::new(); depth + 1];
        for l in c..=depth {
            let k = a.v.ranks[l];
            let own_start = d.own_range(rank, l).start;
            for (dst, nodes) in &ex.levels[l].send[rank] {
                let offs =
                    nodes.iter().map(|&j| (j as usize - own_start) * k * nv).collect::<Vec<_>>();
                sends[l].push((*dst, offs));
            }
            for (src, nodes) in &ex.levels[l].recv[rank] {
                let offs =
                    nodes.iter().map(|&j| xloc(l, j as usize) * k * nv).collect::<Vec<_>>();
                recv_scatter[l].push((*src, offs));
            }
        }

        let boundary_transfer_off =
            if c > 0 { rank * a.rank(c) * a.rank(c - 1) } else { 0 };

        BranchPlan {
            rank,
            nv,
            c_level: c,
            depth,
            leaf_range,
            xhat_halo,
            xpad_halo,
            leaf_basis_off,
            leaf_vec_off,
            leaf_coeff_off,
            up,
            mult,
            dense,
            boundary_transfer_off,
            sends,
            recv_scatter,
        }
    }

    /// Own nodes of level l, rebased to 0 (width of the branch at l).
    pub fn own_width(&self, l: usize) -> usize {
        debug_assert!(l >= self.c_level);
        1usize << (l - self.c_level)
    }

    /// Level-C boundary slack of this branch in bytes: the x̂ halo, the
    /// dense leaf halo and the parent ŷ block — everything a rank stores
    /// beyond its own 1/P share. The memory regression test allows exactly
    /// this on top of `serial/P`.
    pub fn halo_bytes(&self, a: &H2Matrix) -> usize {
        let nv = self.nv;
        let mut words = 0usize;
        for l in self.c_level..=self.depth {
            words += self.xhat_halo[l].len() * a.v.ranks[l] * nv;
        }
        words += self.xpad_halo.len() * a.u.leaf_dim * nv;
        if self.c_level > 0 {
            words += a.u.ranks[self.c_level - 1] * nv;
        }
        words * 8
    }
}

/// One rank's O(N/P) buffers: own branch nodes plus the boundary halo.
#[derive(Clone, Debug)]
pub struct BranchWorkspace {
    pub nv: usize,
    /// x̂ levels C..=depth: own nodes first, then the halo (lower levels
    /// empty — they live on the master).
    pub xhat: Vec<Vec<f64>>,
    /// ŷ levels C..=depth: own nodes only.
    pub yhat: Vec<Vec<f64>>,
    /// The master's level-(C-1) ŷ parent block (empty when C = 0).
    pub parent: Vec<f64>,
    /// Padded input: own leaves first, then the dense halo leaves.
    pub x_pad: Vec<f64>,
    /// Padded output: own leaves only.
    pub y_pad: Vec<f64>,
}

impl BranchWorkspace {
    pub fn new(a: &H2Matrix, bp: &BranchPlan) -> Self {
        let (c, depth, nv) = (bp.c_level, bp.depth, bp.nv);
        let m_pad = a.u.leaf_dim;
        let lpr = bp.leaf_range.len();
        let mut xhat = Vec::with_capacity(depth + 1);
        let mut yhat = Vec::with_capacity(depth + 1);
        for l in 0..=depth {
            if l < c {
                xhat.push(Vec::new());
                yhat.push(Vec::new());
            } else {
                let w = bp.own_width(l);
                xhat.push(vec![0.0; (w + bp.xhat_halo[l].len()) * a.v.ranks[l] * nv]);
                yhat.push(vec![0.0; w * a.u.ranks[l] * nv]);
            }
        }
        let parent = if c > 0 { vec![0.0; a.u.ranks[c - 1] * nv] } else { Vec::new() };
        BranchWorkspace {
            nv,
            xhat,
            yhat,
            parent,
            x_pad: vec![0.0; (lpr + bp.xpad_halo.len()) * m_pad * nv],
            y_pad: vec![0.0; lpr * m_pad * nv],
        }
    }

    /// Zero every buffer. For embedders that keep a workspace alive across
    /// products: the phase functions accumulate (`accumulate: true`), so a
    /// reused workspace must be cleared first. The built-in executors
    /// currently allocate fresh (zeroed) workspaces per product.
    pub fn clear(&mut self) {
        for l in &mut self.xhat {
            l.fill(0.0);
        }
        for l in &mut self.yhat {
            l.fill(0.0);
        }
        self.parent.fill(0.0);
        self.x_pad.fill(0.0);
        self.y_pad.fill(0.0);
    }

    /// Total allocated bytes — the quantity the O(N/P) memory regression
    /// test bounds by `serial/P +` [`BranchPlan::halo_bytes`].
    pub fn memory_bytes(&self) -> usize {
        let words: usize = self.xhat.iter().map(|l| l.len()).sum::<usize>()
            + self.yhat.iter().map(|l| l.len()).sum::<usize>()
            + self.parent.len()
            + self.x_pad.len()
            + self.y_pad.len();
        words * 8
    }
}

/// Gather the branch's padded input (own leaves then halo leaves) from the
/// full permuted input vector. The in-process executor calls this per
/// rank; the socket coordinator calls it to assemble each worker's
/// `Input` message — either way a rank only ever stores these O(N/P)
/// rows.
pub fn fill_branch_input(a: &H2Matrix, bp: &BranchPlan, x: &[f64], x_pad: &mut [f64]) {
    let nv = bp.nv;
    let depth = bp.depth;
    let m_pad = a.u.leaf_dim;
    x_pad.fill(0.0);
    let mut slot = 0usize;
    for j in bp.leaf_range.clone().chain(bp.xpad_halo.iter().map(|&j| j as usize)) {
        let node = a.tree.node(depth, j);
        let rows = node.size();
        let src = &x[node.start * nv..(node.start + rows) * nv];
        x_pad[slot * m_pad * nv..slot * m_pad * nv + rows * nv].copy_from_slice(src);
        slot += 1;
    }
}

/// Scatter the branch's padded output into `y_chunk`, the rank's disjoint
/// slice of the permuted output starting at point row `base_row`.
pub fn unpad_branch_output(
    a: &H2Matrix,
    bp: &BranchPlan,
    y_pad: &[f64],
    y_chunk: &mut [f64],
    base_row: usize,
) {
    let nv = bp.nv;
    let depth = bp.depth;
    let m_pad = a.u.leaf_dim;
    for (slot, j) in bp.leaf_range.clone().enumerate() {
        let node = a.tree.node(depth, j);
        let rows = node.size();
        let src = &y_pad[slot * m_pad * nv..slot * m_pad * nv + rows * nv];
        let r0 = node.start - base_row;
        y_chunk[r0 * nv..(r0 + rows) * nv].copy_from_slice(src);
    }
}

/// Upsweep leaf stage over the own leaves: x̂_j = V_jᵀ x_j (batched,
/// trans_a) — the branch-local counterpart of
/// [`crate::matvec::upsweep_leaf_range`].
pub fn branch_upsweep_leaf(
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
) {
    let nv = bp.nv;
    let depth = bp.depth;
    if bp.leaf_basis_off.is_empty() {
        return;
    }
    backend.batched_gemm(
        GemmDims {
            nb: bp.leaf_basis_off.len(),
            m: a.v.ranks[depth],
            k: a.v.leaf_dim,
            n: nv,
            trans_a: true,
            trans_b: false,
            accumulate: false,
        },
        BatchRef { data: &a.v.leaf_bases, offsets: &bp.leaf_basis_off },
        BatchRef { data: &bw.x_pad, offsets: &bp.leaf_vec_off },
        &mut bw.xhat[depth],
        &bp.leaf_coeff_off,
        metrics,
    );
}

/// One upsweep transfer level (children l → own parents of l-1), two
/// parity batches in serial order.
pub fn branch_upsweep_transfer(
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
    l: usize,
) {
    let nv = bp.nv;
    let (k_l, k_par) = (a.v.ranks[l], a.v.ranks[l - 1]);
    let (lo, hi) = bw.xhat.split_at_mut(l);
    let parent = &mut lo[l - 1];
    let child = &hi[0];
    for parity in 0..2 {
        let po = &bp.up[l].parity[parity];
        if po.nb == 0 {
            continue;
        }
        backend.batched_gemm(
            GemmDims {
                nb: po.nb,
                m: k_par,
                k: k_l,
                n: nv,
                trans_a: true,
                trans_b: false,
                accumulate: true,
            },
            BatchRef { data: &a.v.transfers[l], offsets: &po.transfer_off },
            BatchRef { data: child, offsets: &po.child_off },
            parent,
            &po.parent_off,
            metrics,
        );
    }
}

/// Tree multiplication of level l over the owned rows (prefiltered
/// conflict-free batches, serial accumulation order).
pub fn branch_tree_multiply(
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
    l: usize,
) {
    let nv = bp.nv;
    let k = a.rank(l);
    for bo in &bp.mult[l].batches {
        backend.batched_gemm(
            GemmDims {
                nb: bo.nb,
                m: k,
                k,
                n: nv,
                trans_a: false,
                trans_b: false,
                accumulate: true,
            },
            BatchRef { data: &a.coupling[l].data, offsets: &bo.block_off },
            BatchRef { data: &bw.xhat[l], offsets: &bo.src_off },
            &mut bw.yhat[l],
            &bo.dst_off,
            metrics,
        );
    }
}

/// Dense phase over the owned block rows (needs no remote coefficients —
/// only the x halo, which arrived with the input).
pub fn branch_dense_multiply(
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
) {
    let nv = bp.nv;
    let m_pad = a.dense.m_pad;
    for bo in &bp.dense.batches {
        backend.batched_gemm(
            GemmDims {
                nb: bo.nb,
                m: m_pad,
                k: m_pad,
                n: nv,
                trans_a: false,
                trans_b: false,
                accumulate: true,
            },
            BatchRef { data: &a.dense.data, offsets: &bo.block_off },
            BatchRef { data: &bw.x_pad, offsets: &bo.src_off },
            &mut bw.y_pad,
            &bo.dst_off,
            metrics,
        );
    }
}

/// The C-level boundary downsweep: ŷ_C(own) += E_own · ŷ_{C-1}(parent),
/// applied by the receiving rank on top of its own coupling sums — the
/// same single-child parity GEMM as
/// [`crate::matvec::downsweep_transfer_parity`], so the boundary node's
/// accumulation order matches the serial sweep bitwise.
pub fn branch_downsweep_boundary(
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
) {
    let c = bp.c_level;
    debug_assert!(c > 0, "no boundary without a top subtree");
    let nv = bp.nv;
    let (k_c, k_par) = (a.u.ranks[c], a.u.ranks[c - 1]);
    backend.batched_gemm(
        GemmDims {
            nb: 1,
            m: k_c,
            k: k_par,
            n: nv,
            trans_a: false,
            trans_b: false,
            accumulate: true,
        },
        BatchRef { data: &a.u.transfers[c], offsets: &[bp.boundary_transfer_off] },
        BatchRef { data: &bw.parent, offsets: &[0] },
        &mut bw.yhat[c],
        &[0],
        metrics,
    );
}

/// One downsweep transfer level (own parents of l-1 → children l), two
/// parity batches reusing the upsweep offsets with roles swapped, exactly
/// like the serial plan.
pub fn branch_downsweep_transfer(
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
    l: usize,
) {
    let nv = bp.nv;
    let (k_l, k_par) = (a.u.ranks[l], a.u.ranks[l - 1]);
    let (lo, hi) = bw.yhat.split_at_mut(l);
    let parent = &lo[l - 1];
    let child = &mut hi[0];
    for parity in 0..2 {
        let po = &bp.up[l].parity[parity];
        if po.nb == 0 {
            continue;
        }
        backend.batched_gemm(
            GemmDims {
                nb: po.nb,
                m: k_l,
                k: k_par,
                n: nv,
                trans_a: false,
                trans_b: false,
                accumulate: true,
            },
            BatchRef { data: &a.u.transfers[l], offsets: &po.transfer_off },
            BatchRef { data: parent, offsets: &po.parent_off },
            child,
            &po.child_off,
            metrics,
        );
    }
}

/// Downsweep leaf expansion over the own leaves: y_j += U_j ŷ_j.
pub fn branch_downsweep_leaf(
    a: &H2Matrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
) {
    let nv = bp.nv;
    let depth = bp.depth;
    if bp.leaf_basis_off.is_empty() {
        return;
    }
    backend.batched_gemm(
        GemmDims {
            nb: bp.leaf_basis_off.len(),
            m: a.u.leaf_dim,
            k: a.u.ranks[depth],
            n: nv,
            trans_a: false,
            trans_b: false,
            accumulate: true,
        },
        BatchRef { data: &a.u.leaf_bases, offsets: &bp.leaf_basis_off },
        BatchRef { data: &bw.yhat[depth], offsets: &bp.leaf_coeff_off },
        &mut bw.y_pad,
        &bp.leaf_vec_off,
        metrics,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::H2Config;
    use crate::construct::{build_h2, ExponentialKernel};
    use crate::dist::Decomposition;
    use crate::geometry::PointSet;

    fn sample() -> H2Matrix {
        let points = PointSet::grid_2d(16, 1.0); // N = 256
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
        build_h2(points, &kernel, &cfg)
    }

    #[test]
    fn branch_plans_partition_the_serial_work() {
        let a = sample();
        for p in [1usize, 2, 4, 8] {
            let d = Decomposition::new(p, a.depth()).unwrap();
            let ex = ExchangePlan::build(&a, d);
            let plans: Vec<BranchPlan> =
                (0..p).map(|r| BranchPlan::build(&a, &ex, r, 1)).collect();
            // Every coupling block at a level >= C appears in exactly one
            // rank's prefiltered batches.
            for (l, cl) in a.coupling.iter().enumerate() {
                if l < d.c_level {
                    continue;
                }
                let total: usize = plans
                    .iter()
                    .map(|bp| bp.mult[l].batches.iter().map(|b| b.nb).sum::<usize>())
                    .sum();
                assert_eq!(total, cl.num_blocks(), "level {l} blocks not partitioned");
            }
            let dense_total: usize = plans
                .iter()
                .map(|bp| bp.dense.batches.iter().map(|b| b.nb).sum::<usize>())
                .sum();
            assert_eq!(dense_total, a.dense.pairs.len());
            // Leaves partition.
            let leaves: usize = plans.iter().map(|bp| bp.leaf_range.len()).sum();
            assert_eq!(leaves, 1 << a.depth());
        }
    }

    #[test]
    fn halo_matches_exchange_plan() {
        let a = sample();
        let d = Decomposition::new(4, a.depth()).unwrap();
        let ex = ExchangePlan::build(&a, d);
        for r in 0..4 {
            let bp = BranchPlan::build(&a, &ex, r, 2);
            for l in d.c_level..=a.depth() {
                let plan_nodes: usize =
                    ex.levels[l].recv[r].iter().map(|(_, ns)| ns.len()).sum();
                assert_eq!(bp.xhat_halo[l].len(), plan_nodes, "rank {r} level {l}");
            }
            // Halo bytes are the advertised slack.
            let bw = BranchWorkspace::new(&a, &bp);
            assert!(bp.halo_bytes(&a) < bw.memory_bytes());
        }
    }

    #[test]
    fn workspace_shrinks_with_p() {
        let a = sample();
        let worst_of = |p: usize| {
            let d = Decomposition::new(p, a.depth()).unwrap();
            let ex = ExchangePlan::build(&a, d);
            (0..p)
                .map(|r| {
                    let bp = BranchPlan::build(&a, &ex, r, 1);
                    BranchWorkspace::new(&a, &bp).memory_bytes()
                })
                .max()
                .unwrap()
        };
        // The strict serial/P + slack bound lives in tests/transport.rs;
        // here just pin the qualitative O(N/P) shape.
        let w1 = worst_of(1);
        let w4 = worst_of(4);
        let w8 = worst_of(8);
        assert!(w4 < w1 / 2, "P=4 per-rank workspace {w4} not < half of serial {w1}");
        assert!(w8 <= w4, "P=8 per-rank workspace {w8} > P=4 {w4}");
    }
}
