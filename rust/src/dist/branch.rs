//! Branch-local marshaling plans, O(N/P) workspaces and the branch phase
//! functions of the distributed HGEMV — all reading from a per-rank
//! [`ShardedMatrix`].
//!
//! PR 3 sliced the *workspace* per branch but every rank still indexed a
//! shared (or rebuilt) full matrix: basis, transfer, coupling and dense
//! offsets were global. With [`crate::dist::shard`] the matrix storage
//! itself is per-rank, so this module now speaks entirely in shard-local
//! coordinates:
//!
//! - [`BranchPlan`] rebases every offset to the shard layout: leaf bases
//!   and transfers index the owned-node buffers, coupling/dense block
//!   offsets index the owned-row buffers (the shard's conflict-free
//!   batches *are* the owned-row prefilter of the global batches, in
//!   serial order), and halo x̂ nodes translate through the sorted
//!   per-level tables (binary search at plan build, pure offset
//!   arithmetic in the hot path). The only globally indexed matrix datum
//!   a branch rank touches is its level-C boundary transfer, which lives
//!   in the shard's replicated top at offset `rank·k_C·k_{C-1}`.
//! - [`BranchWorkspace`] holds the rank's O(N/P) coefficient/padded
//!   buffers: own branch nodes plus the level-C halo (exactly the
//!   [`crate::dist::ExchangePlan`] receive sets) and the dense-halo
//!   leaves.
//! - [`BranchIo`] is the structure-only input layout (owned leaf range +
//!   dense halo) that the socket *coordinator* needs to ship each
//!   worker its `Input` block without building any branch plan — or any
//!   matrix data at all.
//!
//! The branch phase functions feed the *same* per-block GEMMs to the
//! backend in the *same* per-destination order as the serial sweep, so
//! the distributed product stays bitwise identical to
//! [`crate::matvec::hgemv`] for every P — with per-rank matrix *and*
//! workspace memory that shrinks as P grows (asserted by
//! `tests/transport.rs` and `tests/shard.rs`).

use std::ops::Range;

use crate::backend::{BatchRef, ComputeBackend, GemmDims};
use crate::clustering::ClusterTree;
use crate::dist::shard::ShardedMatrix;
use crate::dist::{Decomposition, ExchangePlan};
use crate::matvec::plan::{BatchOffsets, LevelMultPlan, LevelTransferPlan};
use crate::metrics::Metrics;

/// The branch-sliced marshaling plan of one rank: every offset — vector,
/// coefficient *and* matrix data — is local to that rank's
/// [`ShardedMatrix`] + [`BranchWorkspace`].
#[derive(Clone, Debug)]
pub struct BranchPlan {
    pub rank: usize,
    pub nv: usize,
    pub c_level: usize,
    pub depth: usize,
    /// Globally indexed leaf range this rank owns.
    pub leaf_range: Range<usize>,
    /// Per level l: sorted remote x̂ nodes referenced by owned coupling
    /// rows (the exchange plan's receive sets, merged across sources).
    /// Empty above the C-level.
    pub xhat_halo: Vec<Vec<u32>>,
    /// Sorted remote leaves read by owned dense rows.
    pub xpad_halo: Vec<u32>,
    /// Leaf-stage offsets over the own leaves, all shard-local.
    pub leaf_basis_off: Vec<usize>,
    pub leaf_vec_off: Vec<usize>,
    pub leaf_coeff_off: Vec<usize>,
    /// `up[l]` for l in C+1..=depth (lower indices empty): interlevel
    /// transfer parity batches over the own parents of level l-1, shared
    /// by the upsweep and the downsweep exactly like the serial plan.
    /// Transfer offsets index the shard's local transfer buffers.
    pub up: Vec<LevelTransferPlan>,
    /// `mult[l]` for l in C..=depth (lower indices empty): the shard's
    /// conflict-free coupling batches, src offsets translated through the
    /// halo table, block offsets local pair indices.
    pub mult: Vec<LevelMultPlan>,
    /// The shard's dense batches.
    pub dense: LevelMultPlan,
    /// Offset of this rank's level-C transfer matrix in the shard's
    /// replicated `top_u_transfers[C]` (the C-level boundary downsweep).
    /// Zero when C = 0 (unused).
    pub boundary_transfer_off: usize,
    /// `sends[l]` = (destination rank, local x̂ offsets of the plan's send
    /// nodes) — what to ship as soon as level l's upsweep finishes.
    pub sends: Vec<Vec<(usize, Vec<usize>)>>,
    /// `recv_scatter[l]` = (source rank, local x̂ offsets of the plan's
    /// receive nodes) — where an incoming (level, src) payload lands.
    pub recv_scatter: Vec<Vec<(usize, Vec<usize>)>>,
}

impl BranchPlan {
    /// Build the marshaling plan of `sm`'s branch under the exchange
    /// plan's decomposition.
    pub fn build(sm: &ShardedMatrix, ex: &ExchangePlan, nv: usize) -> Self {
        let d = ex.decomp;
        assert_eq!(d, sm.decomp, "exchange plan and shard use different decompositions");
        let rank = sm.branch_rank();
        let (c, depth) = (d.c_level, d.depth);
        let m_pad = sm.leaf_dim;
        let k_leaf = sm.v_ranks[depth];
        let lpr = d.leaves_per_rank();
        let leaf_range = sm.leaf_range.clone();

        // Halo tables (the exchange plan's receive sets, merged per level).
        let mut xhat_halo: Vec<Vec<u32>> = vec![Vec::new(); depth + 1];
        for l in c..=depth {
            xhat_halo[l] = ex.halo_nodes(l, rank);
        }
        let mut xpad_halo: Vec<u32> = sm
            .dense
            .blocks
            .pairs
            .iter()
            .filter(|&&(_, s)| !leaf_range.contains(&(s as usize)))
            .map(|&(_, s)| s)
            .collect();
        xpad_halo.sort_unstable();
        xpad_halo.dedup();

        // Local x̂ node index at level l: own nodes first (rebased through
        // the decomposition), then the sorted halo.
        let xloc = |l: usize, j: usize| -> usize {
            if d.own_range(rank, l).contains(&j) {
                d.local_index(rank, l, j)
            } else {
                d.branch_width(l)
                    + xhat_halo[l]
                        .binary_search(&(j as u32))
                        .expect("remote coupling source must be in the exchange halo")
            }
        };
        let leaf_loc = |j: usize| -> usize {
            if leaf_range.contains(&j) {
                j - leaf_range.start
            } else {
                lpr + xpad_halo
                    .binary_search(&(j as u32))
                    .expect("remote dense source must be in the leaf halo")
            }
        };

        // Leaf stage (own leaves, shard-local bases).
        let mut leaf_basis_off = Vec::with_capacity(lpr);
        let mut leaf_vec_off = Vec::with_capacity(lpr);
        let mut leaf_coeff_off = Vec::with_capacity(lpr);
        for slot in 0..leaf_range.len() {
            leaf_basis_off.push(slot * m_pad * k_leaf);
            leaf_vec_off.push(slot * m_pad * nv);
            leaf_coeff_off.push(slot * k_leaf * nv);
        }

        // Interlevel transfers: own parents of level l-1, all offsets
        // local (children of own parents are own nodes).
        let mut up: Vec<LevelTransferPlan> = vec![LevelTransferPlan::default(); depth + 1];
        for l in (c + 1)..=depth {
            let (k_l, k_par) = (sm.u_ranks[l], sm.u_ranks[l - 1]);
            let parents = d.own_range(rank, l - 1);
            let child_base = d.own_range(rank, l).start;
            let plan = &mut up[l];
            for parity in 0..2 {
                let po = &mut plan.parity[parity];
                po.nb = parents.len();
                for (i, p) in parents.clone().enumerate() {
                    let child = 2 * p + parity;
                    po.transfer_off.push((child - child_base) * k_l * k_par);
                    po.child_off.push((child - child_base) * k_l * nv);
                    po.parent_off.push(i * k_par * nv);
                }
            }
        }

        // Coupling batches: the shard's batches *are* the owned-row
        // prefilter of the global conflict-free batches, in serial
        // relative order — so per-destination accumulation order matches
        // the whole-level sweep bitwise.
        let mut mult: Vec<LevelMultPlan> = Vec::with_capacity(depth + 1);
        for l in 0..=depth {
            let mut lp = LevelMultPlan::default();
            if l >= c {
                let k = sm.u_ranks[l];
                let sc = &sm.coupling[l];
                for batch in &sc.level.batches {
                    let mut bo = BatchOffsets::default();
                    for &pi in batch {
                        let (t_loc, s) = sc.level.pairs[pi as usize];
                        bo.block_off.push(pi as usize * k * k);
                        bo.src_off.push(xloc(l, s as usize) * k * nv);
                        bo.dst_off.push(t_loc as usize * k * nv);
                    }
                    bo.nb = bo.dst_off.len();
                    if bo.nb > 0 {
                        lp.batches.push(bo);
                    }
                }
            }
            mult.push(lp);
        }

        // Dense batches (shard-local rows and blocks).
        let mut dense = LevelMultPlan::default();
        for batch in &sm.dense.blocks.batches {
            let mut bo = BatchOffsets::default();
            for &pi in batch {
                let (t_loc, s) = sm.dense.blocks.pairs[pi as usize];
                bo.block_off.push(pi as usize * m_pad * m_pad);
                bo.src_off.push(leaf_loc(s as usize) * m_pad * nv);
                bo.dst_off.push(t_loc as usize * m_pad * nv);
            }
            bo.nb = bo.dst_off.len();
            if bo.nb > 0 {
                dense.batches.push(bo);
            }
        }

        // Exchange send/receive sets translated to local x̂ offsets.
        let mut sends: Vec<Vec<(usize, Vec<usize>)>> = vec![Vec::new(); depth + 1];
        let mut recv_scatter: Vec<Vec<(usize, Vec<usize>)>> = vec![Vec::new(); depth + 1];
        for l in c..=depth {
            let k = sm.v_ranks[l];
            let own_start = d.own_range(rank, l).start;
            for (dst, nodes) in &ex.levels[l].send[rank] {
                let offs =
                    nodes.iter().map(|&j| (j as usize - own_start) * k * nv).collect::<Vec<_>>();
                sends[l].push((*dst, offs));
            }
            for (src, nodes) in &ex.levels[l].recv[rank] {
                let offs =
                    nodes.iter().map(|&j| xloc(l, j as usize) * k * nv).collect::<Vec<_>>();
                recv_scatter[l].push((*src, offs));
            }
        }

        let boundary_transfer_off =
            if c > 0 { rank * sm.u_ranks[c] * sm.u_ranks[c - 1] } else { 0 };

        BranchPlan {
            rank,
            nv,
            c_level: c,
            depth,
            leaf_range,
            xhat_halo,
            xpad_halo,
            leaf_basis_off,
            leaf_vec_off,
            leaf_coeff_off,
            up,
            mult,
            dense,
            boundary_transfer_off,
            sends,
            recv_scatter,
        }
    }

    /// Own nodes of level l, rebased to 0 (width of the branch at l).
    pub fn own_width(&self, l: usize) -> usize {
        debug_assert!(l >= self.c_level);
        1usize << (l - self.c_level)
    }

    /// Level-C boundary slack of this branch in bytes: the x̂ halo, the
    /// dense leaf halo and the parent ŷ block — everything a rank stores
    /// beyond its own 1/P share. The memory regression test allows exactly
    /// this on top of `serial/P`.
    pub fn halo_bytes(&self, sm: &ShardedMatrix) -> usize {
        let nv = self.nv;
        let mut words = 0usize;
        for l in self.c_level..=self.depth {
            words += self.xhat_halo[l].len() * sm.v_ranks[l] * nv;
        }
        words += self.xpad_halo.len() * sm.leaf_dim * nv;
        if self.c_level > 0 {
            words += sm.u_ranks[self.c_level - 1] * nv;
        }
        words * 8
    }
}

/// The structure-only input layout of one rank: its owned leaf range plus
/// the sorted remote leaves its dense rows read. This is everything the
/// socket coordinator needs to assemble a worker's `Input` block (and to
/// size its `Output`), derivable from the [`MatrixStructure`] alone — no
/// matrix data, no branch plan.
///
/// [`MatrixStructure`]: crate::admissibility::MatrixStructure
#[derive(Clone, Debug)]
pub struct BranchIo {
    pub leaf_range: Range<usize>,
    pub xpad_halo: Vec<u32>,
}

impl BranchIo {
    /// Input layout of `rank` given the global dense pair list.
    pub fn build(dense_pairs: &[(u32, u32)], d: &Decomposition, rank: usize) -> Self {
        let leaf_range = d.own_range(rank, d.depth);
        let mut xpad_halo: Vec<u32> = dense_pairs
            .iter()
            .filter(|&&(t, s)| {
                leaf_range.contains(&(t as usize)) && !leaf_range.contains(&(s as usize))
            })
            .map(|&(_, s)| s)
            .collect();
        xpad_halo.sort_unstable();
        xpad_halo.dedup();
        BranchIo { leaf_range, xpad_halo }
    }

    /// f64 length of the rank's padded input block.
    pub fn x_words(&self, m_pad: usize, nv: usize) -> usize {
        (self.leaf_range.len() + self.xpad_halo.len()) * m_pad * nv
    }
}

/// One rank's O(N/P) buffers: own branch nodes plus the boundary halo.
#[derive(Clone, Debug)]
pub struct BranchWorkspace {
    pub nv: usize,
    /// x̂ levels C..=depth: own nodes first, then the halo (lower levels
    /// empty — they live on the master).
    pub xhat: Vec<Vec<f64>>,
    /// ŷ levels C..=depth: own nodes only.
    pub yhat: Vec<Vec<f64>>,
    /// The master's level-(C-1) ŷ parent block (empty when C = 0).
    pub parent: Vec<f64>,
    /// Padded input: own leaves first, then the dense halo leaves.
    pub x_pad: Vec<f64>,
    /// Padded output: own leaves only.
    pub y_pad: Vec<f64>,
}

impl BranchWorkspace {
    pub fn new(sm: &ShardedMatrix, bp: &BranchPlan) -> Self {
        let (c, depth, nv) = (bp.c_level, bp.depth, bp.nv);
        let m_pad = sm.leaf_dim;
        let lpr = bp.leaf_range.len();
        let mut xhat = Vec::with_capacity(depth + 1);
        let mut yhat = Vec::with_capacity(depth + 1);
        for l in 0..=depth {
            if l < c {
                xhat.push(Vec::new());
                yhat.push(Vec::new());
            } else {
                let w = bp.own_width(l);
                xhat.push(vec![0.0; (w + bp.xhat_halo[l].len()) * sm.v_ranks[l] * nv]);
                yhat.push(vec![0.0; w * sm.u_ranks[l] * nv]);
            }
        }
        let parent = if c > 0 { vec![0.0; sm.u_ranks[c - 1] * nv] } else { Vec::new() };
        BranchWorkspace {
            nv,
            xhat,
            yhat,
            parent,
            x_pad: vec![0.0; (lpr + bp.xpad_halo.len()) * m_pad * nv],
            y_pad: vec![0.0; lpr * m_pad * nv],
        }
    }

    /// Zero every buffer. The phase functions accumulate
    /// (`accumulate: true`), so a workspace reused across products — as
    /// the persistent socket worker session does — must be cleared first.
    pub fn clear(&mut self) {
        for l in &mut self.xhat {
            l.fill(0.0);
        }
        for l in &mut self.yhat {
            l.fill(0.0);
        }
        self.parent.fill(0.0);
        self.x_pad.fill(0.0);
        self.y_pad.fill(0.0);
    }

    /// Zero only the buffers `run_branch` actually accumulates into,
    /// skipping those it provably rewrites in full before reading:
    /// `x_pad` (overwritten by the `Input` copy / tail-zeroing
    /// [`fill_branch_input`]), the leaf x̂ level (own slots overwritten by
    /// the accumulate:false leaf upsweep, halo slots by the
    /// `copy_from_slice` x̂ receives) and `parent` (overwritten by the
    /// `Parent` message copy). The upper x̂ levels, ŷ and `y_pad` all
    /// accumulate and must start at zero. Bitwise identical to
    /// [`BranchWorkspace::clear`] for any complete product; the skipped
    /// fills are the two O(N/P·nv) ones.
    pub fn clear_accumulators(&mut self) {
        let depth = self.xhat.len() - 1;
        for l in &mut self.xhat[..depth] {
            l.fill(0.0);
        }
        for l in &mut self.yhat {
            l.fill(0.0);
        }
        self.y_pad.fill(0.0);
    }

    /// Total allocated bytes — the quantity the O(N/P) memory regression
    /// test bounds by `serial/P +` [`BranchPlan::halo_bytes`].
    pub fn memory_bytes(&self) -> usize {
        let words: usize = self.xhat.iter().map(|l| l.len()).sum::<usize>()
            + self.yhat.iter().map(|l| l.len()).sum::<usize>()
            + self.parent.len()
            + self.x_pad.len()
            + self.y_pad.len();
        words * 8
    }
}

/// Gather one rank's padded input (own leaves then halo leaves) from the
/// full permuted input vector into `x_pad`, given only the structure-level
/// layout. The in-process executor calls this per rank; the socket
/// coordinator calls it to assemble each worker's `Input` message —
/// either way a rank only ever stores these O(N/P) rows.
#[allow(clippy::too_many_arguments)]
pub fn fill_input_rows(
    tree: &ClusterTree,
    leaf_range: Range<usize>,
    xpad_halo: &[u32],
    m_pad: usize,
    nv: usize,
    x: &[f64],
    x_pad: &mut [f64],
) {
    let depth = tree.depth;
    // Per-slot tail zeroing instead of a full upfront fill: the copied
    // rows overwrite their prefix anyway, so only the padding rows
    // `rows..m_pad` of each slot need clearing — bitwise identical,
    // and the O(N/P·nv) fill drops off the per-product critical path.
    let mut slot = 0usize;
    for j in leaf_range.chain(xpad_halo.iter().map(|&j| j as usize)) {
        let node = tree.node(depth, j);
        let rows = node.size();
        let src = &x[node.start * nv..(node.start + rows) * nv];
        let dst = &mut x_pad[slot * m_pad * nv..(slot + 1) * m_pad * nv];
        dst[..rows * nv].copy_from_slice(src);
        dst[rows * nv..].fill(0.0);
        slot += 1;
    }
}

/// [`fill_input_rows`] with the layout taken from a [`BranchIo`].
pub fn fill_io_input(
    tree: &ClusterTree,
    io: &BranchIo,
    m_pad: usize,
    nv: usize,
    x: &[f64],
    x_pad: &mut [f64],
) {
    fill_input_rows(tree, io.leaf_range.clone(), &io.xpad_halo, m_pad, nv, x, x_pad);
}

/// [`fill_input_rows`] with the layout taken from a built branch plan.
pub fn fill_branch_input(sm: &ShardedMatrix, bp: &BranchPlan, x: &[f64], x_pad: &mut [f64]) {
    fill_input_rows(&sm.tree, bp.leaf_range.clone(), &bp.xpad_halo, sm.leaf_dim, bp.nv, x, x_pad);
}

/// Scatter the branch's padded output into `y_chunk`, the rank's disjoint
/// slice of the permuted output starting at point row `base_row`.
pub fn unpad_branch_output(
    sm: &ShardedMatrix,
    bp: &BranchPlan,
    y_pad: &[f64],
    y_chunk: &mut [f64],
    base_row: usize,
) {
    let nv = bp.nv;
    let depth = bp.depth;
    let m_pad = sm.leaf_dim;
    for (slot, j) in bp.leaf_range.clone().enumerate() {
        let node = sm.tree.node(depth, j);
        let rows = node.size();
        let src = &y_pad[slot * m_pad * nv..slot * m_pad * nv + rows * nv];
        let r0 = node.start - base_row;
        y_chunk[r0 * nv..(r0 + rows) * nv].copy_from_slice(src);
    }
}

/// Upsweep leaf stage over the own leaves: x̂_j = V_jᵀ x_j (batched,
/// trans_a) — the branch-local counterpart of
/// [`crate::matvec::upsweep_leaf_range`], reading the shard's own bases.
pub fn branch_upsweep_leaf(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
) {
    let nv = bp.nv;
    let depth = bp.depth;
    if bp.leaf_basis_off.is_empty() {
        return;
    }
    backend.batched_gemm(
        GemmDims {
            nb: bp.leaf_basis_off.len(),
            m: sm.v_ranks[depth],
            k: sm.leaf_dim,
            n: nv,
            trans_a: true,
            trans_b: false,
            accumulate: false,
        },
        BatchRef { data: &sm.v_leaf_bases, offsets: &bp.leaf_basis_off },
        BatchRef { data: &bw.x_pad, offsets: &bp.leaf_vec_off },
        &mut bw.xhat[depth],
        &bp.leaf_coeff_off,
        metrics,
    );
}

/// One upsweep transfer level (children l → own parents of l-1), two
/// parity batches in serial order.
pub fn branch_upsweep_transfer(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
    l: usize,
) {
    let nv = bp.nv;
    let (k_l, k_par) = (sm.v_ranks[l], sm.v_ranks[l - 1]);
    let (lo, hi) = bw.xhat.split_at_mut(l);
    let parent = &mut lo[l - 1];
    let child = &hi[0];
    for parity in 0..2 {
        let po = &bp.up[l].parity[parity];
        if po.nb == 0 {
            continue;
        }
        backend.batched_gemm(
            GemmDims {
                nb: po.nb,
                m: k_par,
                k: k_l,
                n: nv,
                trans_a: true,
                trans_b: false,
                accumulate: true,
            },
            BatchRef { data: &sm.v_transfers[l], offsets: &po.transfer_off },
            BatchRef { data: child, offsets: &po.child_off },
            parent,
            &po.parent_off,
            metrics,
        );
    }
}

/// Tree multiplication of level l over the owned rows (the shard's
/// conflict-free batches, serial accumulation order).
pub fn branch_tree_multiply(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
    l: usize,
) {
    let nv = bp.nv;
    let k = sm.u_ranks[l];
    for bo in &bp.mult[l].batches {
        backend.batched_gemm(
            GemmDims {
                nb: bo.nb,
                m: k,
                k,
                n: nv,
                trans_a: false,
                trans_b: false,
                accumulate: true,
            },
            BatchRef { data: &sm.coupling[l].level.data, offsets: &bo.block_off },
            BatchRef { data: &bw.xhat[l], offsets: &bo.src_off },
            &mut bw.yhat[l],
            &bo.dst_off,
            metrics,
        );
    }
}

/// Dense phase over the owned block rows (needs no remote coefficients —
/// only the x halo, which arrived with the input).
pub fn branch_dense_multiply(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
) {
    let nv = bp.nv;
    let m_pad = sm.leaf_dim;
    for bo in &bp.dense.batches {
        backend.batched_gemm(
            GemmDims {
                nb: bo.nb,
                m: m_pad,
                k: m_pad,
                n: nv,
                trans_a: false,
                trans_b: false,
                accumulate: true,
            },
            BatchRef { data: &sm.dense.blocks.data, offsets: &bo.block_off },
            BatchRef { data: &bw.x_pad, offsets: &bo.src_off },
            &mut bw.y_pad,
            &bo.dst_off,
            metrics,
        );
    }
}

/// The C-level boundary downsweep: ŷ_C(own) += E_own · ŷ_{C-1}(parent),
/// applied by the receiving rank on top of its own coupling sums — the
/// same single-child parity GEMM as
/// [`crate::matvec::downsweep_transfer_parity`], so the boundary node's
/// accumulation order matches the serial sweep bitwise. The transfer is
/// read from the shard's replicated top (level C holds all P boundary
/// transfers).
pub fn branch_downsweep_boundary(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
) {
    let c = bp.c_level;
    debug_assert!(c > 0, "no boundary without a top subtree");
    let nv = bp.nv;
    let (k_c, k_par) = (sm.u_ranks[c], sm.u_ranks[c - 1]);
    backend.batched_gemm(
        GemmDims {
            nb: 1,
            m: k_c,
            k: k_par,
            n: nv,
            trans_a: false,
            trans_b: false,
            accumulate: true,
        },
        BatchRef { data: &sm.top_u_transfers[c], offsets: &[bp.boundary_transfer_off] },
        BatchRef { data: &bw.parent, offsets: &[0] },
        &mut bw.yhat[c],
        &[0],
        metrics,
    );
}

/// One downsweep transfer level (own parents of l-1 → children l), two
/// parity batches reusing the upsweep offsets with roles swapped, exactly
/// like the serial plan.
pub fn branch_downsweep_transfer(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
    l: usize,
) {
    let nv = bp.nv;
    let (k_l, k_par) = (sm.u_ranks[l], sm.u_ranks[l - 1]);
    let (lo, hi) = bw.yhat.split_at_mut(l);
    let parent = &lo[l - 1];
    let child = &mut hi[0];
    for parity in 0..2 {
        let po = &bp.up[l].parity[parity];
        if po.nb == 0 {
            continue;
        }
        backend.batched_gemm(
            GemmDims {
                nb: po.nb,
                m: k_l,
                k: k_par,
                n: nv,
                trans_a: false,
                trans_b: false,
                accumulate: true,
            },
            BatchRef { data: &sm.u_transfers[l], offsets: &po.transfer_off },
            BatchRef { data: parent, offsets: &po.parent_off },
            child,
            &po.child_off,
            metrics,
        );
    }
}

/// Downsweep leaf expansion over the own leaves: y_j += U_j ŷ_j.
pub fn branch_downsweep_leaf(
    sm: &ShardedMatrix,
    backend: &dyn ComputeBackend,
    bp: &BranchPlan,
    bw: &mut BranchWorkspace,
    metrics: &mut Metrics,
) {
    let nv = bp.nv;
    let depth = bp.depth;
    if bp.leaf_basis_off.is_empty() {
        return;
    }
    backend.batched_gemm(
        GemmDims {
            nb: bp.leaf_basis_off.len(),
            m: sm.leaf_dim,
            k: sm.u_ranks[depth],
            n: nv,
            trans_a: false,
            trans_b: false,
            accumulate: true,
        },
        BatchRef { data: &sm.u_leaf_bases, offsets: &bp.leaf_basis_off },
        BatchRef { data: &bw.yhat[depth], offsets: &bp.leaf_coeff_off },
        &mut bw.y_pad,
        &bp.leaf_vec_off,
        metrics,
    );
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::H2Config;
    use crate::construct::{build_h2, ExponentialKernel};
    use crate::geometry::PointSet;
    use crate::tree::H2Matrix;

    fn sample() -> H2Matrix {
        let points = PointSet::grid_2d(16, 1.0); // N = 256
        let kernel = ExponentialKernel { dim: 2, corr_len: 0.1 };
        let cfg = H2Config { leaf_size: 16, eta: 0.9, cheb_grid: 3 };
        build_h2(points, &kernel, &cfg)
    }

    #[test]
    fn branch_plans_partition_the_serial_work() {
        let a = sample();
        for p in [1usize, 2, 4, 8] {
            let d = Decomposition::new(p, a.depth()).unwrap();
            let ex = ExchangePlan::build(&a, d);
            let shards: Vec<ShardedMatrix> =
                (0..p).map(|r| ShardedMatrix::from_global(&a, d, r)).collect();
            let plans: Vec<BranchPlan> =
                shards.iter().map(|sm| BranchPlan::build(sm, &ex, 1)).collect();
            // Every coupling block at a level >= C appears in exactly one
            // rank's batches.
            for (l, cl) in a.coupling.iter().enumerate() {
                if l < d.c_level {
                    continue;
                }
                let total: usize = plans
                    .iter()
                    .map(|bp| bp.mult[l].batches.iter().map(|b| b.nb).sum::<usize>())
                    .sum();
                assert_eq!(total, cl.num_blocks(), "level {l} blocks not partitioned");
            }
            let dense_total: usize = plans
                .iter()
                .map(|bp| bp.dense.batches.iter().map(|b| b.nb).sum::<usize>())
                .sum();
            assert_eq!(dense_total, a.dense.pairs.len());
            // Leaves partition.
            let leaves: usize = plans.iter().map(|bp| bp.leaf_range.len()).sum();
            assert_eq!(leaves, 1 << a.depth());
        }
    }

    #[test]
    fn halo_matches_exchange_plan() {
        let a = sample();
        let d = Decomposition::new(4, a.depth()).unwrap();
        let ex = ExchangePlan::build(&a, d);
        for r in 0..4 {
            let sm = ShardedMatrix::from_global(&a, d, r);
            let bp = BranchPlan::build(&sm, &ex, 2);
            for l in d.c_level..=a.depth() {
                let plan_nodes: usize =
                    ex.levels[l].recv[r].iter().map(|(_, ns)| ns.len()).sum();
                assert_eq!(bp.xhat_halo[l].len(), plan_nodes, "rank {r} level {l}");
            }
            // Halo bytes are the advertised slack.
            let bw = BranchWorkspace::new(&sm, &bp);
            assert!(bp.halo_bytes(&sm) < bw.memory_bytes());
        }
    }

    #[test]
    fn branch_io_matches_branch_plan_layout() {
        // The coordinator's structure-only input layout must agree with
        // the worker's shard-derived plan, or Input payloads would be
        // rejected.
        let a = sample();
        let d = Decomposition::new(4, a.depth()).unwrap();
        let ex = ExchangePlan::build(&a, d);
        for r in 0..4 {
            let sm = ShardedMatrix::from_global(&a, d, r);
            let bp = BranchPlan::build(&sm, &ex, 3);
            let io = BranchIo::build(&a.dense.pairs, &d, r);
            assert_eq!(io.leaf_range, bp.leaf_range, "rank {r}");
            assert_eq!(io.xpad_halo, bp.xpad_halo, "rank {r}");
            let bw = BranchWorkspace::new(&sm, &bp);
            assert_eq!(io.x_words(sm.leaf_dim, 3), bw.x_pad.len(), "rank {r}");
        }
    }

    #[test]
    fn workspace_shrinks_with_p() {
        let a = sample();
        let worst_of = |p: usize| {
            let d = Decomposition::new(p, a.depth()).unwrap();
            let ex = ExchangePlan::build(&a, d);
            (0..p)
                .map(|r| {
                    let sm = ShardedMatrix::from_global(&a, d, r);
                    let bp = BranchPlan::build(&sm, &ex, 1);
                    BranchWorkspace::new(&sm, &bp).memory_bytes()
                })
                .max()
                .unwrap()
        };
        // The strict serial/P + slack bound lives in tests/transport.rs;
        // here just pin the qualitative O(N/P) shape.
        let w1 = worst_of(1);
        let w4 = worst_of(4);
        let w8 = worst_of(8);
        assert!(w4 < w1 / 2, "P=4 per-rank workspace {w4} not < half of serial {w1}");
        assert!(w8 <= w4, "P=8 per-rank workspace {w8} > P=4 {w4}");
    }
}
