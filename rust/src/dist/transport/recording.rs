//! A transport wrapper that stamps an [`Instant`] on every send and
//! receive, so measured Chrome traces can draw the *actual* message
//! traffic of a product next to the per-phase compute spans (the
//! virtual-schedule trace only shows the modeled comm).
//!
//! The wrapper is transparent: it implements [`Endpoint`] over any inner
//! endpoint and costs two `Instant::now()` calls per message. Events are
//! recorded relative to an origin instant shared by every endpoint of the
//! product (the executor's `t0`), so per-rank streams line up on one
//! timeline.

use std::time::Instant;

use super::{Endpoint, Message, MsgKind, Tag, TransportError};

/// Direction of a recorded transport operation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommDir {
    Send,
    Recv,
}

/// One stamped transport operation.
#[derive(Clone, Debug)]
pub struct CommEvent {
    pub dir: CommDir,
    pub tag: Tag,
    /// The peer endpoint (destination for sends; source for receives).
    pub peer: usize,
    /// Payload bytes on the wire.
    pub bytes: usize,
    /// Seconds since the shared origin at which the operation began.
    pub start: f64,
    /// Seconds the operation blocked (receives include the wait).
    pub dur: f64,
}

impl CommEvent {
    /// Display name for trace events, e.g. `send xhat L3 -> 2`.
    pub fn label(&self) -> String {
        match self.dir {
            CommDir::Send => {
                format!("send {} L{} -> {}", self.tag.kind.name(), self.tag.level, self.peer)
            }
            CommDir::Recv => {
                format!("recv {} L{} <- {}", self.tag.kind.name(), self.tag.level, self.tag.src)
            }
        }
    }
}

/// The recording wrapper endpoint.
pub struct Recording<E: Endpoint> {
    inner: E,
    origin: Instant,
    enabled: bool,
    events: Vec<CommEvent>,
}

impl<E: Endpoint> Recording<E> {
    /// Wrap `inner`, timestamping relative to `origin`.
    pub fn new(inner: E, origin: Instant) -> Self {
        Recording { inner, origin, enabled: true, events: Vec::new() }
    }

    /// A disabled wrapper: delegates with no stamping (one branch per
    /// operation), so executors can keep a single code path without
    /// paying `Instant` calls inside the measured section when no trace
    /// was requested.
    pub fn passthrough(inner: E, origin: Instant) -> Self {
        Recording { inner, origin, enabled: false, events: Vec::new() }
    }

    /// The recorded operations, in execution order, consuming the wrapper.
    pub fn into_events(self) -> Vec<CommEvent> {
        self.events
    }

    /// The recorded operations so far.
    pub fn events(&self) -> &[CommEvent] {
        &self.events
    }

    /// Unwrap the inner endpoint, discarding the recorder.
    pub fn into_inner(self) -> E {
        self.inner
    }

    fn now(&self) -> f64 {
        self.origin.elapsed().as_secs_f64()
    }
}

impl<E: Endpoint> Endpoint for Recording<E> {
    fn id(&self) -> usize {
        self.inner.id()
    }

    fn send(&mut self, dst: usize, msg: Message) -> Result<(), TransportError> {
        if !self.enabled {
            return self.inner.send(dst, msg);
        }
        let tag = msg.tag;
        let bytes = msg.payload_bytes();
        let start = self.now();
        let out = self.inner.send(dst, msg);
        self.events.push(CommEvent {
            dir: CommDir::Send,
            tag,
            peer: dst,
            bytes,
            start,
            dur: self.now() - start,
        });
        out
    }

    fn recv(&mut self) -> Result<Message, TransportError> {
        if !self.enabled {
            return self.inner.recv();
        }
        let start = self.now();
        let msg = self.inner.recv()?;
        self.events.push(CommEvent {
            dir: CommDir::Recv,
            tag: msg.tag,
            peer: msg.tag.src as usize,
            bytes: msg.payload_bytes(),
            start,
            dur: self.now() - start,
        });
        Ok(msg)
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        if !self.enabled {
            return self.inner.barrier();
        }
        let start = self.now();
        let out = self.inner.barrier();
        self.events.push(CommEvent {
            dir: CommDir::Recv,
            tag: Tag::new(MsgKind::Barrier, 0, self.inner.id()),
            peer: self.inner.id(),
            bytes: 0,
            start,
            dur: self.now() - start,
        });
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dist::transport::inproc;

    #[test]
    fn stamps_sends_and_recvs_in_order() {
        let origin = Instant::now();
        let mut eps = inproc::mesh(2).into_iter();
        let mut a = Recording::new(eps.next().unwrap(), origin);
        let mut b = Recording::new(eps.next().unwrap(), origin);
        a.send(1, Message::new(MsgKind::Xhat, 2, 0, vec![1.0, 2.0, 3.0])).unwrap();
        let m = b.recv().unwrap();
        assert_eq!(m.data.len(), 3);
        let ea = a.into_events();
        assert_eq!(ea.len(), 1);
        assert_eq!(ea[0].dir, CommDir::Send);
        assert_eq!(ea[0].bytes, 24);
        assert!(ea[0].label().contains("send xhat L2 -> 1"));
        let eb = b.into_events();
        assert_eq!(eb.len(), 1);
        assert_eq!(eb[0].dir, CommDir::Recv);
        assert!(eb[0].start >= 0.0 && eb[0].dur >= 0.0);
        assert!(eb[0].label().contains("recv xhat L2 <- 0"));
    }
}
