//! Request-coalescing HGEMV serving over a resident [`SocketSession`]:
//! many client threads submit independent products against one persistent
//! distributed session, and a dispatcher thread fuses whatever is queued
//! into one wide N×nv batched product (up to a configurable cap), keeps a
//! bounded number of products in flight through the session's pipelined
//! [`SocketSession::submit`]/[`SocketSession::wait`] path, and demuxes
//! the output columns back to the callers.
//!
//! This is the paper's `num_vectors` batching argument turned into a
//! serving policy: a single-vector HGEMV is bandwidth-bound, so fusing
//! concurrent requests converts GEMV-shaped work into GEMM-shaped work
//! at zero extra traversals, while the two-deep product pipeline keeps
//! the workers computing during the coordinator's gather of the previous
//! product. Demuxed results are **bitwise identical** to running each
//! request alone: the native GEMM microkernels accumulate every output
//! element in a fixed contraction order independent of the number of
//! columns, so column j of a fused product equals column j of any
//! narrower product containing it.
//!
//! Failure policy matches the pipe the dispatcher drives (the
//! [`ProductPipe`] trait): over a raw [`SocketSession`] a transport error
//! poisons the server — every in-flight and queued request gets the
//! error, later submissions fail fast, and the dispatcher exits (dropping
//! the session shuts the workers down). Over a
//! [`SessionSupervisor`](crate::dist::supervisor::SessionSupervisor)
//! ([`SessionServer::start_supervised`]) worker crashes are absorbed: the
//! supervisor rebuilds the crew and replays in-flight products
//! exactly-once, so requests only fail once the rebuild budget is
//! exhausted. [`ServerStats`] keeps the request ledger balanced either
//! way: `submitted == completed + failed` once the pipeline drains.

use std::collections::{BTreeMap, VecDeque};
use std::fmt::Write as _;
use std::io::ErrorKind;
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::Path;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use super::socket::{
    read_frame, write_frame, SocketOptions, SocketReport, SocketSession, MAX_WIRE_NV,
};
use super::{MatrixJob, Message, MsgKind, TransportError};
use crate::dist::supervisor::{SessionSupervisor, SupervisorOptions};
use crate::obs;
use crate::obs::names as obs_names;
use crate::obs::registry::latency_bounds;
use crate::obs::FixedHistogram;

/// Serving policy knobs.
#[derive(Clone, Debug)]
pub struct ServerOptions {
    /// Maximum width one fused product may reach (requests beyond it wait
    /// for the next batch). Clamped to [`MAX_WIRE_NV`].
    pub max_coalesce: usize,
    /// Maximum products in flight through the session pipeline. 2 means
    /// double-buffered: one product computing on the workers while the
    /// coordinator gathers the previous one. 1 degenerates to sequential
    /// dispatch (useful as a benchmark baseline).
    pub pipeline_depth: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions { max_coalesce: 16, pipeline_depth: 2 }
    }
}

/// What the dispatcher needs from its product backend: a pipelined
/// submit/wait pair plus the span flush. Implemented by the raw
/// [`SocketSession`] (fail-fast on poison) and by
/// [`SessionSupervisor`](crate::dist::supervisor::SessionSupervisor)
/// (crash recovery with exactly-once replay), so the same coalescing
/// dispatcher serves both fault models.
pub trait ProductPipe: Send + 'static {
    /// Matrix dimension N.
    fn n(&self) -> usize;
    /// Queue one N×nv pipelined product; returns its pid.
    fn submit(&mut self, x: &[f64], nv: usize) -> Result<u64, TransportError>;
    /// Collect product `pid` (submission order) into `y`.
    fn wait(&mut self, pid: u64, y: &mut [f64]) -> Result<SocketReport, TransportError>;
    /// Merge all processes' recorded spans into one Chrome-format trace.
    fn collect_spans(&mut self) -> Result<String, TransportError>;
}

impl ProductPipe for SocketSession {
    fn n(&self) -> usize {
        SocketSession::n(self)
    }

    fn submit(&mut self, x: &[f64], nv: usize) -> Result<u64, TransportError> {
        SocketSession::submit(self, x, nv)
    }

    fn wait(&mut self, pid: u64, y: &mut [f64]) -> Result<SocketReport, TransportError> {
        SocketSession::wait(self, pid, y)
    }

    fn collect_spans(&mut self) -> Result<String, TransportError> {
        SocketSession::collect_spans(self)
    }
}

/// Per-request serving outcome, returned alongside the demuxed columns.
#[derive(Clone, Debug)]
pub struct RequestStats {
    /// Session product id this request was fused into.
    pub pid: u64,
    /// Seconds the request waited in the server queue before dispatch.
    pub queue_wait_s: f64,
    /// Achieved width of the fused product (how many columns rode along).
    pub coalesced_nv: usize,
    /// The session's collection wall-clock for the fused product.
    pub measured_s: f64,
}

/// A served product: the request's own output columns plus its stats.
#[derive(Clone, Debug)]
pub struct Served {
    /// N × (request width), row-major — same layout the request used.
    pub y: Vec<f64>,
    pub stats: RequestStats,
}

/// Waitable handle of one submitted request.
pub struct ProductHandle {
    rx: Receiver<Result<Served, TransportError>>,
}

impl ProductHandle {
    /// Block until the request's product completes (or the server dies).
    pub fn wait(self) -> Result<Served, TransportError> {
        self.rx.recv().unwrap_or_else(|_| {
            Err(TransportError::Closed("server dispatcher exited".into()))
        })
    }
}

/// Aggregate serving counters (snapshot via [`SessionServer::stats`]).
#[derive(Clone, Debug)]
pub struct ServerStats {
    /// Fused products dispatched.
    pub products: u64,
    /// Requests served.
    pub requests: u64,
    /// Requests accepted into the queue (handles handed out). The ledger
    /// balances: once the pipeline drains,
    /// `submitted == completed + failed`.
    pub submitted: u64,
    /// Requests whose product was delivered to the caller.
    pub completed: u64,
    /// Requests failed with an error (poison, or a supervisor past its
    /// rebuild budget).
    pub failed: u64,
    /// Achieved-width histogram: fused nv → number of products.
    pub nv_histogram: BTreeMap<usize, u64>,
    /// Sum over requests of their queue wait (seconds).
    pub sum_queue_wait_s: f64,
    /// Sum over products of the session's collection wall-clock.
    pub sum_measured_s: f64,
    /// Per-request queue-wait distribution (seconds) — what the summary
    /// line's p50/p99 are estimated from, so serving regressions show up
    /// without re-deriving from raw [`RequestStats`].
    pub queue_wait: FixedHistogram,
}

impl Default for ServerStats {
    fn default() -> Self {
        ServerStats {
            products: 0,
            requests: 0,
            submitted: 0,
            completed: 0,
            failed: 0,
            nv_histogram: BTreeMap::new(),
            sum_queue_wait_s: 0.0,
            sum_measured_s: 0.0,
            queue_wait: FixedHistogram::latency(),
        }
    }
}

impl ServerStats {
    /// One-line human summary: request/product counts, fuse factor,
    /// queue-wait p50/p99 and the achieved-nv histogram.
    pub fn summary(&self) -> String {
        let fuse = if self.products == 0 {
            0.0
        } else {
            self.requests as f64 / self.products as f64
        };
        let mean_measured_ms = if self.products == 0 {
            0.0
        } else {
            1e3 * self.sum_measured_s / self.products as f64
        };
        let mut nv = String::new();
        for (w, c) in &self.nv_histogram {
            let _ = write!(nv, " {w}:{c}");
        }
        let mut line = format!(
            "served {} reqs in {} products | {:.2} reqs/product | queue wait p50 {:.3} ms \
             p99 {:.3} ms | mean measured {:.3} ms | nv{}",
            self.requests,
            self.products,
            fuse,
            1e3 * self.queue_wait.quantile(0.5),
            1e3 * self.queue_wait.quantile(0.99),
            mean_measured_ms,
            if nv.is_empty() { " -".to_string() } else { nv }
        );
        if self.failed > 0 {
            let _ = write!(
                line,
                " | FAILED {} of {} submitted",
                self.failed, self.submitted
            );
        }
        line
    }
}

struct PendingReq {
    x: Vec<f64>,
    nv: usize,
    enqueued: Instant,
    /// Enqueue stamp on the observability clock, for the `request queued`
    /// lifecycle span.
    enqueued_ns: u64,
    tx: Sender<Result<Served, TransportError>>,
}

struct ServerQueue {
    pending: VecDeque<PendingReq>,
    /// Pending span-flush requests ([`SessionServer::collect_spans`]):
    /// the dispatcher owns the session, so flushes are serviced by it at
    /// the next pipeline-empty point.
    flush_reqs: Vec<Sender<Result<String, TransportError>>>,
    shutdown: bool,
    poisoned: Option<TransportError>,
}

struct Shared {
    queue: Mutex<ServerQueue>,
    cv: Condvar,
    stats: Mutex<ServerStats>,
    n: usize,
    max_nv: usize,
}

/// One coalesced product in flight through the session pipeline.
struct Batch {
    pid: u64,
    nv: usize,
    reqs: Vec<PendingReq>,
    /// Column offset of each request inside the fused product.
    offsets: Vec<usize>,
    dispatched: Instant,
}

/// A throughput front end over one resident [`SocketSession`]. Client
/// threads call [`SessionServer::submit`] concurrently; a dispatcher
/// thread owns the session, coalesces queued requests into wide products
/// and pipelines them. Dropping the server drains nothing: it fails
/// queued requests with `Closed`, waits for in-flight products, then
/// shuts the session (and its workers) down.
pub struct SessionServer {
    shared: Arc<Shared>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl SessionServer {
    /// Spawn the session's worker ranks and the dispatcher thread
    /// (fail-fast: a worker crash poisons the server).
    pub fn start(
        job: &MatrixJob,
        p: usize,
        opts: SocketOptions,
        sopts: ServerOptions,
    ) -> Result<SessionServer, TransportError> {
        let max_nv = sopts.max_coalesce.clamp(1, MAX_WIRE_NV);
        // The session's default nv seeds the workers' plan caches; the
        // serving path dispatches variable widths, so seed with the cap
        // (the steady-state width under saturation).
        let session = SocketSession::start(job, p, max_nv, opts)?;
        SessionServer::start_with_pipe(session, max_nv, &sopts)
    }

    /// Like [`SessionServer::start`], but the dispatcher drives a
    /// [`SessionSupervisor`]: worker crashes are reaped, the crew is
    /// respawned from the job and in-flight fused products are replayed
    /// exactly-once — requests only observe an error after `max_rebuilds`
    /// rebuilds have been spent.
    pub fn start_supervised(
        job: &MatrixJob,
        p: usize,
        opts: SocketOptions,
        sopts: ServerOptions,
        sup: SupervisorOptions,
    ) -> Result<SessionServer, TransportError> {
        let max_nv = sopts.max_coalesce.clamp(1, MAX_WIRE_NV);
        let session = SessionSupervisor::start(job, p, max_nv, opts, sup)?;
        SessionServer::start_with_pipe(session, max_nv, &sopts)
    }

    fn start_with_pipe<S: ProductPipe>(
        session: S,
        max_nv: usize,
        sopts: &ServerOptions,
    ) -> Result<SessionServer, TransportError> {
        let depth = sopts.pipeline_depth.max(1);
        let shared = Arc::new(Shared {
            queue: Mutex::new(ServerQueue {
                pending: VecDeque::new(),
                flush_reqs: Vec::new(),
                shutdown: false,
                poisoned: None,
            }),
            cv: Condvar::new(),
            stats: Mutex::new(ServerStats::default()),
            n: session.n(),
            max_nv,
        });
        let shared2 = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("h2opus-dispatch".into())
            .spawn(move || dispatch_loop(session, shared2, depth))
            .map_err(|e| TransportError::Io(format!("spawning dispatcher: {e}")))?;
        Ok(SessionServer { shared, dispatcher: Some(dispatcher) })
    }

    /// Matrix dimension N.
    pub fn n(&self) -> usize {
        self.shared.n
    }

    /// The coalescing cap (widest fused product the server will build).
    pub fn max_coalesce(&self) -> usize {
        self.shared.max_nv
    }

    /// Queue one product request: `x` is N × w row-major for any width
    /// 1 ≤ w ≤ [`SessionServer::max_coalesce`] (its column count is
    /// inferred from the length). Returns immediately with a handle;
    /// the product runs fused with whatever else is queued.
    pub fn submit(&self, x: &[f64]) -> Result<ProductHandle, TransportError> {
        let n = self.shared.n;
        if x.is_empty() || x.len() % n != 0 {
            return Err(TransportError::Protocol(format!(
                "request must be N*w values (N = {n}, got {})",
                x.len()
            )));
        }
        let w = x.len() / n;
        if w > self.shared.max_nv {
            return Err(TransportError::Protocol(format!(
                "request width {w} exceeds the coalescing cap {}",
                self.shared.max_nv
            )));
        }
        let (tx, rx) = channel();
        {
            let mut q = self.shared.queue.lock().expect("server queue lock");
            if let Some(e) = &q.poisoned {
                return Err(e.clone());
            }
            if q.shutdown {
                return Err(TransportError::Closed("server is shutting down".into()));
            }
            q.pending.push_back(PendingReq {
                x: x.to_vec(),
                nv: w,
                enqueued: Instant::now(),
                enqueued_ns: obs::now_ns(),
                tx,
            });
        }
        self.shared.stats.lock().expect("server stats lock").submitted += 1;
        self.shared.cv.notify_one();
        Ok(ProductHandle { rx })
    }

    /// Snapshot of the aggregate serving counters.
    pub fn stats(&self) -> ServerStats {
        self.shared.stats.lock().expect("server stats lock").clone()
    }

    /// Flush recorded spans from every worker rank and the server process
    /// into one merged Chrome-format trace. The dispatcher owns the
    /// session, so the request is queued and serviced at its next
    /// pipeline-empty point (after in-flight products drain); blocks until
    /// the merged JSON is ready.
    pub fn collect_spans(&self) -> Result<String, TransportError> {
        let (tx, rx) = channel();
        {
            let mut q = self.shared.queue.lock().expect("server queue lock");
            if let Some(e) = &q.poisoned {
                return Err(e.clone());
            }
            if q.shutdown {
                return Err(TransportError::Closed("server is shutting down".into()));
            }
            q.flush_reqs.push(tx);
        }
        self.shared.cv.notify_one();
        rx.recv().unwrap_or_else(|_| {
            Err(TransportError::Closed("server dispatcher exited".into()))
        })
    }
}

impl Drop for SessionServer {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().expect("server queue lock");
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        if let Some(t) = self.dispatcher.take() {
            let _ = t.join();
        }
    }
}

/// Copy request columns into their slot of the fused row-major batch.
pub(crate) fn coalesce_columns(
    n: usize,
    nv: usize,
    x_req: &[f64],
    w: usize,
    off: usize,
    x_batch: &mut [f64],
) {
    for i in 0..n {
        x_batch[i * nv + off..i * nv + off + w].copy_from_slice(&x_req[i * w..(i + 1) * w]);
    }
}

/// Extract one request's columns back out of the fused product's output.
pub(crate) fn demux_columns(
    n: usize,
    nv: usize,
    y_batch: &[f64],
    w: usize,
    off: usize,
) -> Vec<f64> {
    let mut y = vec![0.0; n * w];
    for i in 0..n {
        y[i * w..(i + 1) * w].copy_from_slice(&y_batch[i * nv + off..i * nv + off + w]);
    }
    y
}

/// Fail every given request (and poison the queue) with `e`, keeping the
/// [`ServerStats`] ledger balanced.
fn fail_all(
    e: &TransportError,
    inflight: &mut VecDeque<Batch>,
    shared: &Shared,
) {
    let mut failed = 0u64;
    for b in inflight.drain(..) {
        for r in b.reqs {
            let _ = r.tx.send(Err(e.clone()));
            failed += 1;
        }
    }
    {
        let mut q = shared.queue.lock().expect("server queue lock");
        q.poisoned = Some(e.clone());
        for r in q.pending.drain(..) {
            let _ = r.tx.send(Err(e.clone()));
            failed += 1;
        }
    }
    shared.stats.lock().expect("server stats lock").failed += failed;
}

fn dispatch_loop<S: ProductPipe>(mut session: S, shared: Arc<Shared>, depth: usize) {
    let n = shared.n;
    let mut inflight: VecDeque<Batch> = VecDeque::new();
    loop {
        // Pull a dispatch plan under the lock; block only when idle.
        let mut to_dispatch: Vec<Vec<PendingReq>> = Vec::new();
        {
            let mut q = shared.queue.lock().expect("server queue lock");
            while q.pending.is_empty()
                && q.flush_reqs.is_empty()
                && !q.shutdown
                && inflight.is_empty()
            {
                q = shared.cv.wait(q).expect("server queue lock");
            }
            if q.shutdown && q.pending.is_empty() && inflight.is_empty() {
                // Dropping the pending flush senders fails their waiters
                // with Closed; dropping the session shuts the workers down.
                q.flush_reqs.clear();
                return;
            }
            let mut slots = depth.saturating_sub(inflight.len());
            // The fused width must stay expressible in the wire's 10-bit
            // nv field whatever the options said — the session layer only
            // validates per-submit widths, so the *combined* cap is
            // enforced here, at the fuse site.
            let cap = shared.max_nv.min(MAX_WIRE_NV);
            while slots > 0 && !q.pending.is_empty() {
                // FIFO coalesce: fuse queued requests until the cap.
                let mut reqs: Vec<PendingReq> = Vec::new();
                let mut nv = 0usize;
                while let Some(front) = q.pending.front() {
                    if !reqs.is_empty() && nv + front.nv > cap {
                        break;
                    }
                    let r = q.pending.pop_front().expect("front exists");
                    nv += r.nv;
                    reqs.push(r);
                    if nv >= cap {
                        break;
                    }
                }
                to_dispatch.push(reqs);
                slots -= 1;
            }
        }

        // Build and submit the fused products outside the lock, so
        // submitters and the marshaling never serialize on each other.
        for reqs in to_dispatch {
            let fused_ns = if obs::enabled() { obs::now_ns() } else { 0 };
            let nv: usize = reqs.iter().map(|r| r.nv).sum();
            let mut offsets = Vec::with_capacity(reqs.len());
            let mut x = vec![0.0; n * nv];
            let mut off = 0usize;
            for r in &reqs {
                offsets.push(off);
                coalesce_columns(n, nv, &r.x, r.nv, off, &mut x);
                off += r.nv;
            }
            let ship_ns = if obs::enabled() { obs::now_ns() } else { 0 };
            match session.submit(&x, nv) {
                Ok(pid) => {
                    // Request lifecycle, keyed by pid: each request's
                    // queue residency, then the fuse (marshal) and ship
                    // intervals the whole batch shared.
                    if obs::enabled() {
                        let done_ns = obs::now_ns();
                        for r in &reqs {
                            obs::record(
                                obs_names::REQ_QUEUED,
                                pid,
                                r.enqueued_ns,
                                fused_ns.saturating_sub(r.enqueued_ns),
                            );
                        }
                        obs::record(
                            obs_names::REQ_FUSED,
                            pid,
                            fused_ns,
                            ship_ns.saturating_sub(fused_ns),
                        );
                        obs::record(
                            obs_names::REQ_SHIPPED,
                            pid,
                            ship_ns,
                            done_ns.saturating_sub(ship_ns),
                        );
                    }
                    inflight.push_back(Batch {
                        pid,
                        nv,
                        reqs,
                        offsets,
                        dispatched: Instant::now(),
                    })
                }
                Err(e) => {
                    let mut failed = 0u64;
                    for r in reqs {
                        let _ = r.tx.send(Err(e.clone()));
                        failed += 1;
                    }
                    shared.stats.lock().expect("server stats lock").failed += failed;
                    fail_all(&e, &mut inflight, &shared);
                    return;
                }
            }
        }

        // Collect the oldest product; requests arriving meanwhile queue
        // up (and will coalesce) — that wait is the batching window.
        if let Some(batch) = inflight.pop_front() {
            let mut y = vec![0.0; n * batch.nv];
            let gather_ns = if obs::enabled() { obs::now_ns() } else { 0 };
            match session.wait(batch.pid, &mut y) {
                Ok(rep) => {
                    if obs::enabled() {
                        obs::record(
                            obs_names::REQ_GATHERED,
                            batch.pid,
                            gather_ns,
                            obs::now_ns().saturating_sub(gather_ns),
                        );
                    }
                    {
                        let mut st = shared.stats.lock().expect("server stats lock");
                        st.products += 1;
                        st.requests += batch.reqs.len() as u64;
                        st.completed += batch.reqs.len() as u64;
                        *st.nv_histogram.entry(batch.nv).or_insert(0) += 1;
                        st.sum_measured_s += rep.measured;
                        for r in &batch.reqs {
                            let w = (batch.dispatched - r.enqueued).as_secs_f64();
                            st.sum_queue_wait_s += w;
                            st.queue_wait.observe(w);
                        }
                    }
                    // Registry views of the same events, so a live `stats`
                    // request sees them without holding the stats lock.
                    let reg = obs::Registry::global();
                    reg.counter("h2opus_server_products_total").inc();
                    reg.counter("h2opus_server_requests_total").add(batch.reqs.len() as u64);
                    let qw = reg
                        .histogram("h2opus_request_queue_wait_seconds", &latency_bounds());
                    for r in &batch.reqs {
                        qw.observe((batch.dispatched - r.enqueued).as_secs_f64());
                    }
                    for (r, &off) in batch.reqs.iter().zip(&batch.offsets) {
                        let served = Served {
                            y: demux_columns(n, batch.nv, &y, r.nv, off),
                            stats: RequestStats {
                                pid: batch.pid,
                                queue_wait_s: (batch.dispatched - r.enqueued).as_secs_f64(),
                                coalesced_nv: batch.nv,
                                measured_s: rep.measured,
                            },
                        };
                        let _ = r.tx.send(Ok(served));
                    }
                }
                Err(e) => {
                    // The popped batch is no longer in `inflight`, so
                    // `fail_all` won't see it — count its requests here
                    // or the `submitted == completed + failed` invariant
                    // breaks on wait-path poisons.
                    shared.stats.lock().expect("server stats lock").failed +=
                        batch.reqs.len() as u64;
                    for r in batch.reqs {
                        let _ = r.tx.send(Err(e.clone()));
                    }
                    fail_all(&e, &mut inflight, &shared);
                    return;
                }
            }
        }

        // Service span flushes only at pipeline-empty points so the Flush
        // broadcast never interleaves with an in-flight product (the
        // session layer refuses otherwise).
        if inflight.is_empty() {
            let flushes: Vec<Sender<Result<String, TransportError>>> = {
                let mut q = shared.queue.lock().expect("server queue lock");
                std::mem::take(&mut q.flush_reqs)
            };
            for tx in flushes {
                match session.collect_spans() {
                    Ok(json) => {
                        let _ = tx.send(Ok(json));
                    }
                    Err(e) => {
                        let _ = tx.send(Err(e.clone()));
                        fail_all(&e, &mut inflight, &shared);
                        return;
                    }
                }
            }
        }
    }
}

/// Pack UTF-8 text into wire `f64` words: word 0 is the byte length, then
/// 4 bytes per word little-endian (each word holds a `u32` value, exactly
/// representable in an `f64` — no bit-pattern hazards on any float path).
pub(crate) fn pack_text(s: &str) -> Vec<f64> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(1 + bytes.len().div_ceil(4));
    out.push(bytes.len() as f64);
    for chunk in bytes.chunks(4) {
        let mut w = [0u8; 4];
        w[..chunk.len()].copy_from_slice(chunk);
        out.push(u32::from_le_bytes(w) as f64);
    }
    out
}

/// Inverse of [`pack_text`].
pub(crate) fn unpack_text(words: &[f64]) -> Result<String, TransportError> {
    if words.is_empty() {
        return Err(TransportError::Protocol("empty stats payload".into()));
    }
    let len = words[0] as usize;
    let body = &words[1..];
    if body.len() != len.div_ceil(4) {
        return Err(TransportError::Protocol(format!(
            "stats payload: {} bytes need {} words, got {}",
            len,
            len.div_ceil(4),
            body.len()
        )));
    }
    let mut bytes = Vec::with_capacity(len);
    for &w in body {
        bytes.extend_from_slice(&(w as u32).to_le_bytes());
    }
    bytes.truncate(len);
    String::from_utf8(bytes)
        .map_err(|e| TransportError::Protocol(format!("stats payload not UTF-8: {e}")))
}

/// The live stats payload: the server's one-line summary as a leading
/// comment plus the global registry's Prometheus-style exposition.
pub fn stats_text(server: &SessionServer) -> String {
    format!(
        "# h2opus {}\n{}",
        server.stats().summary(),
        obs::Registry::global().render_text()
    )
}

/// A control socket answering live [`MsgKind::Stats`] requests for a
/// running [`SessionServer`]: `h2opus stats --connect PATH` fetches one
/// snapshot per connection using the session wire framing.
pub struct StatsEndpoint {
    listener: UnixListener,
}

impl StatsEndpoint {
    /// Bind the control socket (replacing any stale file at `path`).
    pub fn bind(path: &Path) -> Result<StatsEndpoint, TransportError> {
        if path.exists() {
            let _ = std::fs::remove_file(path);
        }
        let listener = UnixListener::bind(path).map_err(|e| {
            TransportError::Io(format!("binding stats socket {}: {e}", path.display()))
        })?;
        listener
            .set_nonblocking(true)
            .map_err(|e| TransportError::Io(format!("stats socket nonblocking: {e}")))?;
        Ok(StatsEndpoint { listener })
    }

    /// Answer every queued connection without blocking; returns how many
    /// snapshots were served. Call from the serving loop between products.
    /// A misbehaving client only fails its own connection.
    pub fn poll(&self, server: &SessionServer) -> Result<usize, TransportError> {
        let mut served = 0;
        loop {
            match self.listener.accept() {
                Ok((mut stream, _)) => {
                    if answer_stats(&mut stream, server).is_ok() {
                        served += 1;
                    }
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => return Ok(served),
                Err(e) => return Err(TransportError::Io(format!("stats accept: {e}"))),
            }
        }
    }
}

fn answer_stats(
    stream: &mut UnixStream,
    server: &SessionServer,
) -> Result<(), TransportError> {
    stream
        .set_read_timeout(Some(Duration::from_secs(5)))
        .map_err(|e| TransportError::Io(format!("stats read timeout: {e}")))?;
    let (_dst, req) = read_frame(stream)?;
    if req.tag.kind != MsgKind::Stats {
        return Err(TransportError::Protocol(format!(
            "stats socket: unexpected {} frame",
            req.tag.kind.name()
        )));
    }
    let text = stats_text(server);
    write_frame(stream, 0, &Message::new(MsgKind::Stats, 0, 0, pack_text(&text)))
}

/// Connect to a [`StatsEndpoint`] and fetch one live snapshot, with a
/// 10 s deadline on the reply.
pub fn fetch_stats(path: &Path) -> Result<String, TransportError> {
    fetch_stats_within(path, Duration::from_secs(10))
}

/// [`fetch_stats`] with an explicit deadline covering both the write of
/// the request and the read of the reply: a server that accepted the
/// connection but never answers (hung dispatcher, killed rank) surfaces
/// as [`TransportError::Timeout`], never as a hang.
pub fn fetch_stats_within(path: &Path, timeout: Duration) -> Result<String, TransportError> {
    let mut stream = UnixStream::connect(path).map_err(|e| {
        TransportError::Io(format!("connecting stats socket {}: {e}", path.display()))
    })?;
    stream
        .set_read_timeout(Some(timeout))
        .map_err(|e| TransportError::Io(format!("stats read timeout: {e}")))?;
    stream
        .set_write_timeout(Some(timeout))
        .map_err(|e| TransportError::Io(format!("stats write timeout: {e}")))?;
    write_frame(&mut stream, 0, &Message::new(MsgKind::Stats, 0, 0, Vec::new()))?;
    // An expired read deadline surfaces from the frame reader as a typed
    // `Timeout`; annotate it with the socket and the budget.
    let (_dst, reply) = read_frame(&mut stream).map_err(|e| match e {
        TransportError::Timeout(m) => TransportError::Timeout(format!(
            "stats reply from {} not within {:.1} s ({m})",
            path.display(),
            timeout.as_secs_f64()
        )),
        other => other,
    })?;
    if reply.tag.kind != MsgKind::Stats {
        return Err(TransportError::Protocol(format!(
            "stats reply: unexpected {} frame",
            reply.tag.kind.name()
        )));
    }
    unpack_text(&reply.data)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coalesce_demux_roundtrip() {
        // Three requests of widths 1, 3, 2 fused into nv = 6: every
        // request's columns come back exactly where they went in.
        let n = 4;
        let widths = [1usize, 3, 2];
        let nv: usize = widths.iter().sum();
        let reqs: Vec<Vec<f64>> = widths
            .iter()
            .enumerate()
            .map(|(j, &w)| (0..n * w).map(|i| (j * 100 + i) as f64).collect())
            .collect();
        let mut x = vec![0.0; n * nv];
        let mut off = 0;
        let mut offsets = Vec::new();
        for (r, &w) in reqs.iter().zip(&widths) {
            offsets.push(off);
            coalesce_columns(n, nv, r, w, off, &mut x);
            off += w;
        }
        // Row i of the batch is the concatenation of every request's row i.
        for i in 0..n {
            let row: Vec<f64> = widths
                .iter()
                .zip(&reqs)
                .flat_map(|(&w, r)| r[i * w..(i + 1) * w].to_vec())
                .collect();
            assert_eq!(&x[i * nv..(i + 1) * nv], &row[..]);
        }
        for ((r, &w), &off) in reqs.iter().zip(&widths).zip(&offsets) {
            assert_eq!(&demux_columns(n, nv, &x, w, off), r, "width {w} at offset {off}");
        }
    }

    #[test]
    fn pack_unpack_text_roundtrip() {
        for s in ["", "x", "abcd", "abcde", "# TYPE a counter\na 1\nμs — exposition\n"] {
            assert_eq!(unpack_text(&pack_text(s)).unwrap(), s, "{s:?}");
        }
        assert!(unpack_text(&[]).is_err(), "empty payload");
        assert!(unpack_text(&[8.0, 0.0]).is_err(), "length/word-count mismatch");
    }

    #[test]
    fn stats_summary_line() {
        let mut st = ServerStats::default();
        assert!(st.summary().contains("served 0 reqs in 0 products"), "{}", st.summary());
        assert!(st.summary().contains("nv -"), "{}", st.summary());
        st.products = 2;
        st.requests = 5;
        st.nv_histogram.insert(1, 1);
        st.nv_histogram.insert(4, 1);
        st.sum_measured_s = 0.004;
        for w in [0.001, 0.002, 0.003, 0.004, 0.2] {
            st.sum_queue_wait_s += w;
            st.queue_wait.observe(w);
        }
        let s = st.summary();
        assert!(s.contains("served 5 reqs in 2 products"), "{s}");
        assert!(s.contains("2.50 reqs/product"), "{s}");
        assert!(s.contains("queue wait p50"), "{s}");
        assert!(s.contains("nv 1:1 4:1"), "{s}");
        let p50 = st.queue_wait.quantile(0.5);
        let p99 = st.queue_wait.quantile(0.99);
        assert!(p50 <= p99, "quantiles ordered: {p50} vs {p99}");
        assert!(p99 >= 0.2, "p99 sees the straggler: {p99}");
    }

    #[test]
    fn fifo_coalescing_respects_the_cap() {
        // Simulate the dispatcher's batching rule on widths only.
        let cap = 4usize;
        let queued = [1usize, 1, 3, 2, 4, 1];
        let mut pending: VecDeque<usize> = queued.into_iter().collect();
        let mut batches: Vec<Vec<usize>> = Vec::new();
        while !pending.is_empty() {
            let mut batch = Vec::new();
            let mut nv = 0;
            while let Some(&front) = pending.front() {
                if !batch.is_empty() && nv + front > cap {
                    break;
                }
                pending.pop_front();
                nv += front;
                batch.push(front);
                if nv >= cap {
                    break;
                }
            }
            batches.push(batch);
        }
        // 1+1 (3 would overflow), 3 (2 would overflow), 2 (4 would
        // overflow), 4 (hits the cap), 1.
        assert_eq!(batches, vec![vec![1, 1], vec![3], vec![2], vec![4], vec![1]]);
    }
}
