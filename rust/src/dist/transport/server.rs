//! Request-coalescing HGEMV serving over a resident [`SocketSession`]:
//! many client threads submit independent products against one persistent
//! distributed session, and a dispatcher thread fuses whatever is queued
//! into one wide N×nv batched product (up to a configurable cap), keeps a
//! bounded number of products in flight through the session's pipelined
//! [`SocketSession::submit`]/[`SocketSession::wait`] path, and demuxes
//! the output columns back to the callers.
//!
//! This is the paper's `num_vectors` batching argument turned into a
//! serving policy: a single-vector HGEMV is bandwidth-bound, so fusing
//! concurrent requests converts GEMV-shaped work into GEMM-shaped work
//! at zero extra traversals, while the two-deep product pipeline keeps
//! the workers computing during the coordinator's gather of the previous
//! product. Demuxed results are **bitwise identical** to running each
//! request alone: the native GEMM microkernels accumulate every output
//! element in a fixed contraction order independent of the number of
//! columns, so column j of a fused product equals column j of any
//! narrower product containing it.
//!
//! Failure policy matches the session's: a transport error poisons the
//! server — every in-flight and queued request gets the error, later
//! submissions fail fast, and the dispatcher exits (dropping the session
//! shuts the workers down).

use std::collections::{BTreeMap, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

use super::socket::{SocketOptions, SocketSession, MAX_WIRE_NV};
use super::{MatrixJob, TransportError};

/// Serving policy knobs.
#[derive(Clone, Debug)]
pub struct ServerOptions {
    /// Maximum width one fused product may reach (requests beyond it wait
    /// for the next batch). Clamped to [`MAX_WIRE_NV`].
    pub max_coalesce: usize,
    /// Maximum products in flight through the session pipeline. 2 means
    /// double-buffered: one product computing on the workers while the
    /// coordinator gathers the previous one. 1 degenerates to sequential
    /// dispatch (useful as a benchmark baseline).
    pub pipeline_depth: usize,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions { max_coalesce: 16, pipeline_depth: 2 }
    }
}

/// Per-request serving outcome, returned alongside the demuxed columns.
#[derive(Clone, Debug)]
pub struct RequestStats {
    /// Session product id this request was fused into.
    pub pid: u64,
    /// Seconds the request waited in the server queue before dispatch.
    pub queue_wait_s: f64,
    /// Achieved width of the fused product (how many columns rode along).
    pub coalesced_nv: usize,
    /// The session's collection wall-clock for the fused product.
    pub measured_s: f64,
}

/// A served product: the request's own output columns plus its stats.
#[derive(Clone, Debug)]
pub struct Served {
    /// N × (request width), row-major — same layout the request used.
    pub y: Vec<f64>,
    pub stats: RequestStats,
}

/// Waitable handle of one submitted request.
pub struct ProductHandle {
    rx: Receiver<Result<Served, TransportError>>,
}

impl ProductHandle {
    /// Block until the request's product completes (or the server dies).
    pub fn wait(self) -> Result<Served, TransportError> {
        self.rx.recv().unwrap_or_else(|_| {
            Err(TransportError::Closed("server dispatcher exited".into()))
        })
    }
}

/// Aggregate serving counters (snapshot via [`SessionServer::stats`]).
#[derive(Clone, Debug, Default)]
pub struct ServerStats {
    /// Fused products dispatched.
    pub products: u64,
    /// Requests served.
    pub requests: u64,
    /// Achieved-width histogram: fused nv → number of products.
    pub nv_histogram: BTreeMap<usize, u64>,
    /// Sum over requests of their queue wait (seconds).
    pub sum_queue_wait_s: f64,
    /// Sum over products of the session's collection wall-clock.
    pub sum_measured_s: f64,
}

struct PendingReq {
    x: Vec<f64>,
    nv: usize,
    enqueued: Instant,
    tx: Sender<Result<Served, TransportError>>,
}

struct ServerQueue {
    pending: VecDeque<PendingReq>,
    shutdown: bool,
    poisoned: Option<TransportError>,
}

struct Shared {
    queue: Mutex<ServerQueue>,
    cv: Condvar,
    stats: Mutex<ServerStats>,
    n: usize,
    max_nv: usize,
}

/// One coalesced product in flight through the session pipeline.
struct Batch {
    pid: u64,
    nv: usize,
    reqs: Vec<PendingReq>,
    /// Column offset of each request inside the fused product.
    offsets: Vec<usize>,
    dispatched: Instant,
}

/// A throughput front end over one resident [`SocketSession`]. Client
/// threads call [`SessionServer::submit`] concurrently; a dispatcher
/// thread owns the session, coalesces queued requests into wide products
/// and pipelines them. Dropping the server drains nothing: it fails
/// queued requests with `Closed`, waits for in-flight products, then
/// shuts the session (and its workers) down.
pub struct SessionServer {
    shared: Arc<Shared>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl SessionServer {
    /// Spawn the session's worker ranks and the dispatcher thread.
    pub fn start(
        job: &MatrixJob,
        p: usize,
        opts: SocketOptions,
        sopts: ServerOptions,
    ) -> Result<SessionServer, TransportError> {
        let max_nv = sopts.max_coalesce.clamp(1, MAX_WIRE_NV);
        let depth = sopts.pipeline_depth.max(1);
        // The session's default nv seeds the workers' plan caches; the
        // serving path dispatches variable widths, so seed with the cap
        // (the steady-state width under saturation).
        let session = SocketSession::start(job, p, max_nv, opts)?;
        let shared = Arc::new(Shared {
            queue: Mutex::new(ServerQueue {
                pending: VecDeque::new(),
                shutdown: false,
                poisoned: None,
            }),
            cv: Condvar::new(),
            stats: Mutex::new(ServerStats::default()),
            n: session.n(),
            max_nv,
        });
        let shared2 = Arc::clone(&shared);
        let dispatcher = std::thread::Builder::new()
            .name("h2opus-dispatch".into())
            .spawn(move || dispatch_loop(session, shared2, depth))
            .map_err(|e| TransportError::Io(format!("spawning dispatcher: {e}")))?;
        Ok(SessionServer { shared, dispatcher: Some(dispatcher) })
    }

    /// Matrix dimension N.
    pub fn n(&self) -> usize {
        self.shared.n
    }

    /// The coalescing cap (widest fused product the server will build).
    pub fn max_coalesce(&self) -> usize {
        self.shared.max_nv
    }

    /// Queue one product request: `x` is N × w row-major for any width
    /// 1 ≤ w ≤ [`SessionServer::max_coalesce`] (its column count is
    /// inferred from the length). Returns immediately with a handle;
    /// the product runs fused with whatever else is queued.
    pub fn submit(&self, x: &[f64]) -> Result<ProductHandle, TransportError> {
        let n = self.shared.n;
        if x.is_empty() || x.len() % n != 0 {
            return Err(TransportError::Protocol(format!(
                "request must be N*w values (N = {n}, got {})",
                x.len()
            )));
        }
        let w = x.len() / n;
        if w > self.shared.max_nv {
            return Err(TransportError::Protocol(format!(
                "request width {w} exceeds the coalescing cap {}",
                self.shared.max_nv
            )));
        }
        let (tx, rx) = channel();
        {
            let mut q = self.shared.queue.lock().expect("server queue lock");
            if let Some(e) = &q.poisoned {
                return Err(e.clone());
            }
            if q.shutdown {
                return Err(TransportError::Closed("server is shutting down".into()));
            }
            q.pending.push_back(PendingReq {
                x: x.to_vec(),
                nv: w,
                enqueued: Instant::now(),
                tx,
            });
        }
        self.shared.cv.notify_one();
        Ok(ProductHandle { rx })
    }

    /// Snapshot of the aggregate serving counters.
    pub fn stats(&self) -> ServerStats {
        self.shared.stats.lock().expect("server stats lock").clone()
    }
}

impl Drop for SessionServer {
    fn drop(&mut self) {
        {
            let mut q = self.shared.queue.lock().expect("server queue lock");
            q.shutdown = true;
        }
        self.shared.cv.notify_all();
        if let Some(t) = self.dispatcher.take() {
            let _ = t.join();
        }
    }
}

/// Copy request columns into their slot of the fused row-major batch.
pub(crate) fn coalesce_columns(
    n: usize,
    nv: usize,
    x_req: &[f64],
    w: usize,
    off: usize,
    x_batch: &mut [f64],
) {
    for i in 0..n {
        x_batch[i * nv + off..i * nv + off + w].copy_from_slice(&x_req[i * w..(i + 1) * w]);
    }
}

/// Extract one request's columns back out of the fused product's output.
pub(crate) fn demux_columns(
    n: usize,
    nv: usize,
    y_batch: &[f64],
    w: usize,
    off: usize,
) -> Vec<f64> {
    let mut y = vec![0.0; n * w];
    for i in 0..n {
        y[i * w..(i + 1) * w].copy_from_slice(&y_batch[i * nv + off..i * nv + off + w]);
    }
    y
}

/// Fail every given request (and poison the queue) with `e`.
fn fail_all(
    e: &TransportError,
    inflight: &mut VecDeque<Batch>,
    shared: &Shared,
) {
    for b in inflight.drain(..) {
        for r in b.reqs {
            let _ = r.tx.send(Err(e.clone()));
        }
    }
    let mut q = shared.queue.lock().expect("server queue lock");
    q.poisoned = Some(e.clone());
    for r in q.pending.drain(..) {
        let _ = r.tx.send(Err(e.clone()));
    }
}

fn dispatch_loop(mut session: SocketSession, shared: Arc<Shared>, depth: usize) {
    let n = shared.n;
    let mut inflight: VecDeque<Batch> = VecDeque::new();
    loop {
        // Pull a dispatch plan under the lock; block only when idle.
        let mut to_dispatch: Vec<Vec<PendingReq>> = Vec::new();
        {
            let mut q = shared.queue.lock().expect("server queue lock");
            while q.pending.is_empty() && !q.shutdown && inflight.is_empty() {
                q = shared.cv.wait(q).expect("server queue lock");
            }
            if q.shutdown && q.pending.is_empty() && inflight.is_empty() {
                return; // dropping the session shuts the workers down
            }
            let mut slots = depth.saturating_sub(inflight.len());
            // The fused width must stay expressible in the wire's 10-bit
            // nv field whatever the options said — the session layer only
            // validates per-submit widths, so the *combined* cap is
            // enforced here, at the fuse site.
            let cap = shared.max_nv.min(MAX_WIRE_NV);
            while slots > 0 && !q.pending.is_empty() {
                // FIFO coalesce: fuse queued requests until the cap.
                let mut reqs: Vec<PendingReq> = Vec::new();
                let mut nv = 0usize;
                while let Some(front) = q.pending.front() {
                    if !reqs.is_empty() && nv + front.nv > cap {
                        break;
                    }
                    let r = q.pending.pop_front().expect("front exists");
                    nv += r.nv;
                    reqs.push(r);
                    if nv >= cap {
                        break;
                    }
                }
                to_dispatch.push(reqs);
                slots -= 1;
            }
        }

        // Build and submit the fused products outside the lock, so
        // submitters and the marshaling never serialize on each other.
        for reqs in to_dispatch {
            let nv: usize = reqs.iter().map(|r| r.nv).sum();
            let mut offsets = Vec::with_capacity(reqs.len());
            let mut x = vec![0.0; n * nv];
            let mut off = 0usize;
            for r in &reqs {
                offsets.push(off);
                coalesce_columns(n, nv, &r.x, r.nv, off, &mut x);
                off += r.nv;
            }
            match session.submit(&x, nv) {
                Ok(pid) => inflight.push_back(Batch {
                    pid,
                    nv,
                    reqs,
                    offsets,
                    dispatched: Instant::now(),
                }),
                Err(e) => {
                    for r in reqs {
                        let _ = r.tx.send(Err(e.clone()));
                    }
                    fail_all(&e, &mut inflight, &shared);
                    return;
                }
            }
        }

        // Collect the oldest product; requests arriving meanwhile queue
        // up (and will coalesce) — that wait is the batching window.
        if let Some(batch) = inflight.pop_front() {
            let mut y = vec![0.0; n * batch.nv];
            match session.wait(batch.pid, &mut y) {
                Ok(rep) => {
                    {
                        let mut st = shared.stats.lock().expect("server stats lock");
                        st.products += 1;
                        st.requests += batch.reqs.len() as u64;
                        *st.nv_histogram.entry(batch.nv).or_insert(0) += 1;
                        st.sum_measured_s += rep.measured;
                        for r in &batch.reqs {
                            st.sum_queue_wait_s +=
                                (batch.dispatched - r.enqueued).as_secs_f64();
                        }
                    }
                    for (r, &off) in batch.reqs.iter().zip(&batch.offsets) {
                        let served = Served {
                            y: demux_columns(n, batch.nv, &y, r.nv, off),
                            stats: RequestStats {
                                pid: batch.pid,
                                queue_wait_s: (batch.dispatched - r.enqueued).as_secs_f64(),
                                coalesced_nv: batch.nv,
                                measured_s: rep.measured,
                            },
                        };
                        let _ = r.tx.send(Ok(served));
                    }
                }
                Err(e) => {
                    for r in batch.reqs {
                        let _ = r.tx.send(Err(e.clone()));
                    }
                    fail_all(&e, &mut inflight, &shared);
                    return;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coalesce_demux_roundtrip() {
        // Three requests of widths 1, 3, 2 fused into nv = 6: every
        // request's columns come back exactly where they went in.
        let n = 4;
        let widths = [1usize, 3, 2];
        let nv: usize = widths.iter().sum();
        let reqs: Vec<Vec<f64>> = widths
            .iter()
            .enumerate()
            .map(|(j, &w)| (0..n * w).map(|i| (j * 100 + i) as f64).collect())
            .collect();
        let mut x = vec![0.0; n * nv];
        let mut off = 0;
        let mut offsets = Vec::new();
        for (r, &w) in reqs.iter().zip(&widths) {
            offsets.push(off);
            coalesce_columns(n, nv, r, w, off, &mut x);
            off += w;
        }
        // Row i of the batch is the concatenation of every request's row i.
        for i in 0..n {
            let row: Vec<f64> = widths
                .iter()
                .zip(&reqs)
                .flat_map(|(&w, r)| r[i * w..(i + 1) * w].to_vec())
                .collect();
            assert_eq!(&x[i * nv..(i + 1) * nv], &row[..]);
        }
        for ((r, &w), &off) in reqs.iter().zip(&widths).zip(&offsets) {
            assert_eq!(&demux_columns(n, nv, &x, w, off), r, "width {w} at offset {off}");
        }
    }

    #[test]
    fn fifo_coalescing_respects_the_cap() {
        // Simulate the dispatcher's batching rule on widths only.
        let cap = 4usize;
        let queued = [1usize, 1, 3, 2, 4, 1];
        let mut pending: VecDeque<usize> = queued.into_iter().collect();
        let mut batches: Vec<Vec<usize>> = Vec::new();
        while !pending.is_empty() {
            let mut batch = Vec::new();
            let mut nv = 0;
            while let Some(&front) = pending.front() {
                if !batch.is_empty() && nv + front > cap {
                    break;
                }
                pending.pop_front();
                nv += front;
                batch.push(front);
                if nv >= cap {
                    break;
                }
            }
            batches.push(batch);
        }
        // 1+1 (3 would overflow), 3 (2 would overflow), 2 (4 would
        // overflow), 4 (hits the cap), 1.
        assert_eq!(batches, vec![vec![1, 1], vec![3], vec![2], vec![4], vec![1]]);
    }
}
