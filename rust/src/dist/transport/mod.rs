//! Pluggable rank-to-rank transports for the distributed executor.
//!
//! The branch/master runner of [`crate::dist::threaded`] is written against
//! one small surface — typed, tagged, point-to-point [`Endpoint::send`] /
//! [`Endpoint::recv`] plus a collective [`Endpoint::barrier`] — carrying
//! exactly the message sets of the [`crate::dist::ExchangePlan`]. Three
//! implementations plug in underneath:
//!
//! - [`inproc`] — one in-process endpoint per rank over `std::sync::mpsc`
//!   channels (the PR-2 executor's interconnect, refactored behind the
//!   trait). Ranks are OS threads of one address space.
//! - [`socket`] — *real* OS-process ranks: `h2opus worker` subprocesses
//!   exchanging length-prefixed binary frames over a Unix domain socket
//!   hub. Each rank holds only its O(N/P) branch workspace
//!   ([`crate::dist::branch`]), which is the paper's distributed-memory
//!   claim executed for real.
//! - [`recording`] — a wrapper endpoint stamping an `Instant` on every
//!   send/recv, so the measured Chrome trace shows actual message traffic
//!   next to the per-phase compute spans.
//! - [`chaos`] — a deterministic, seeded fault-injection layer: a
//!   [`chaos::FaultPlan`] drops, delays, duplicates, truncates or
//!   bit-flips the Nth frame on a (src, dst, kind) edge, or kills a rank
//!   after its Kth send — composable over inproc (message level) and the
//!   socket wire (byte level, below the frame CRC).
//!
//! Delivery is reliable and FIFO per (source, destination) pair, but
//! *unordered across sources* — the [`Mailbox`] gives the runner
//! tag-matched receives over that weaker guarantee (e.g. the master's ŷ
//! scatter may overtake a peer's x̂ block; the mailbox stashes whichever
//! arrives early).

pub mod chaos;
pub mod inproc;
pub mod recording;
#[cfg(unix)]
pub mod server;
#[cfg(unix)]
pub mod socket;

use std::collections::VecDeque;
use std::fmt;

use crate::admissibility::MatrixStructure;
use crate::config::H2Config;
use crate::construct::kernels::{paper_kappa, FractionalKernel};
use crate::construct::{build_branch, build_h2, build_top, ExponentialKernel, Kernel};
use crate::dist::shard::ShardedMatrix;
use crate::dist::DecompositionError;
use crate::geometry::{PointSet, MAX_DIM};
use crate::tree::H2Matrix;

/// Which kernel/point-set family a [`MatrixJob`] describes. Every variant
/// is fully determined by the job's scalar fields, so worker processes
/// reconstruct identical data from CLI flags.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum JobKind {
    /// §6.1 test sets: exp(−r/ℓ) over the unit-box grid (2D or 3D).
    Exponential,
    /// §6.4 fractional-diffusion kernel (Eq. 11) with the paper's bump
    /// diffusivity, over the cell-centered grid on Ω = [-1,1]² — what the
    /// persistent solver session ships to its workers.
    Fractional { beta: f64 },
}

/// A deterministic matrix specification that round-trips through worker
/// CLI flags, so every rank process of the socket transport rebuilds
/// identical data (construction involves no randomness). Lives here (not
/// in [`socket`]) so non-Unix builds and the CLI can share it.
#[derive(Clone, Debug, PartialEq)]
pub struct MatrixJob {
    pub dim: usize,
    pub n_side: usize,
    pub leaf_size: usize,
    pub eta: f64,
    pub cheb_grid: usize,
    pub corr_len: f64,
    pub kind: JobKind,
}

impl MatrixJob {
    /// The CLI defaults for `dim` (mirrors `h2opus matvec`'s).
    pub fn defaults(dim: usize, n_side: usize) -> Self {
        MatrixJob {
            dim,
            n_side,
            leaf_size: 32,
            eta: if dim == 2 { 0.9 } else { 0.95 },
            cheb_grid: if dim == 2 { 4 } else { 2 },
            corr_len: if dim == 2 { 0.1 } else { 0.2 },
            kind: JobKind::Exponential,
        }
    }

    /// Number of points (= matrix dimension N) without building anything.
    pub fn n_points(&self) -> usize {
        match self.kind {
            JobKind::Exponential => self.n_side.pow(self.dim as u32),
            // The fractional problem is 2-D regardless of `dim`.
            JobKind::Fractional { .. } => self.n_side * self.n_side,
        }
    }

    /// The job's point set.
    pub fn points(&self) -> PointSet {
        match self.kind {
            JobKind::Exponential => {
                if self.dim == 2 {
                    PointSet::grid_2d(self.n_side, 1.0)
                } else {
                    PointSet::grid_3d(self.n_side, 1.0)
                }
            }
            // The fractional problem is posed on the cell-centered grid
            // over Ω = [-1,1]² (apps::fractional uses the same one).
            JobKind::Fractional { .. } => {
                assert_eq!(
                    self.dim, 2,
                    "the fractional-diffusion kernel is 2-D (got --dim {})",
                    self.dim
                );
                PointSet::cell_grid_2d(self.n_side, -1.0, 1.0)
            }
        }
    }

    /// The job's kernel.
    pub fn kernel(&self) -> Box<dyn Kernel> {
        match self.kind {
            JobKind::Exponential => {
                Box::new(ExponentialKernel { dim: self.dim, corr_len: self.corr_len })
            }
            JobKind::Fractional { beta } => Box::new(FractionalKernel {
                dim: 2,
                beta,
                kappa: paper_kappa as fn(&[f64; MAX_DIM]) -> f64,
            }),
        }
    }

    /// The job's construction config.
    pub fn config(&self) -> H2Config {
        H2Config { leaf_size: self.leaf_size, eta: self.eta, cheb_grid: self.cheb_grid }
    }

    /// Build the *global* matrix (bit-identical across processes of one
    /// binary). Panics under the `H2OPUS_FORBID_FULL_MATRIX` guard —
    /// worker ranks must use [`MatrixJob::build_branch`] instead.
    pub fn build(&self) -> H2Matrix {
        build_h2(self.points(), self.kernel().as_ref(), &self.config())
    }

    /// Build only rank `rank`'s [`ShardedMatrix`] plus the index-only
    /// structure — the worker path: no global matrix is allocated.
    pub fn build_branch(
        &self,
        p: usize,
        rank: usize,
    ) -> Result<(ShardedMatrix, MatrixStructure), DecompositionError> {
        build_branch(self.points(), self.kernel().as_ref(), &self.config(), p, rank)
    }

    /// Build the coordinator's top-only shard plus the structure.
    pub fn build_top(
        &self,
        p: usize,
    ) -> Result<(ShardedMatrix, MatrixStructure), DecompositionError> {
        build_top(self.points(), self.kernel().as_ref(), &self.config(), p)
    }

    /// The worker CLI flags encoding this job (f64s print in Rust's
    /// shortest round-trip form, so parsing recovers the exact bits).
    pub fn to_args(&self) -> Vec<String> {
        let mut args = vec![
            "--dim".into(),
            self.dim.to_string(),
            "--n-side".into(),
            self.n_side.to_string(),
            "--leaf-size".into(),
            self.leaf_size.to_string(),
            "--eta".into(),
            self.eta.to_string(),
            "--g".into(),
            self.cheb_grid.to_string(),
            "--corr".into(),
            self.corr_len.to_string(),
        ];
        match self.kind {
            JobKind::Exponential => {
                args.push("--kernel".into());
                args.push("exp".into());
            }
            JobKind::Fractional { beta } => {
                args.push("--kernel".into());
                args.push("fractional".into());
                args.push("--beta".into());
                args.push(beta.to_string());
            }
        }
        args
    }
}

/// The message kinds of the distributed HGEMV protocol (plus the session
/// bookkeeping kinds the socket transport needs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MsgKind {
    /// Worker handshake: announces the sender's rank (socket only).
    Hello,
    /// The coordinator's branch-local padded input block (socket only).
    Input,
    /// Plan-driven x̂ exchange: the `level` node blocks of `src` that the
    /// receiver's coupling rows reference, in the plan's sorted node order.
    Xhat,
    /// A rank's level-C x̂ block, gathered to the master.
    Gather,
    /// The master's level-(C-1) ŷ block for the receiving rank's parent.
    Parent,
    /// A rank's disjoint slice of the output vector (socket only).
    Output,
    /// A rank's executed-work counters, f64-encoded (socket only).
    Metrics,
    /// A rank's phase/comm trace stamps, f64-encoded (socket only).
    Trace,
    /// Barrier token (collected and released by the master/hub).
    Barrier,
    /// Session end: the coordinator tells a worker to exit (socket only).
    Shutdown,
    /// Distributed compression, orthogonalization phase: the level-C
    /// R-factor gather to the coordinator, the re-orthogonalized top
    /// broadcast back, and the per-level R_v halo exchange between ranks
    /// (`dist::compress` encodes the sub-step in the tag's level word).
    Orthogonalize,
    /// Distributed compression, truncation phase: the session start frame,
    /// the σ_ref/k_new partial reductions and their broadcast decisions,
    /// the level-C projection-factor gather, the S-block and P_v
    /// exchanges, and the final stats ack.
    Truncate,
    /// Clock-alignment handshake (socket only): the coordinator pings
    /// each worker right after its `Hello` (level 0 carries `[seq]` out
    /// and `[seq, worker_now_ns]` back; level 1 ends the exchange), and
    /// the min-RTT sample estimates that worker's clock offset — what
    /// lets `obs` merge per-process span timelines onto one clock.
    ClockSync,
    /// Span-buffer flush (socket only): the coordinator requests each
    /// worker's recorded observability spans; the reply payload is the
    /// numeric span encoding of [`crate::obs::span::encode_spans`].
    Flush,
    /// Live metrics request/reply on the server's control socket: the
    /// reply payload is Prometheus-style exposition text packed into f64
    /// words (see [`crate::dist::transport::server`]).
    Stats,
}

impl MsgKind {
    pub fn to_u8(self) -> u8 {
        match self {
            MsgKind::Hello => 0,
            MsgKind::Input => 1,
            MsgKind::Xhat => 2,
            MsgKind::Gather => 3,
            MsgKind::Parent => 4,
            MsgKind::Output => 5,
            MsgKind::Metrics => 6,
            MsgKind::Trace => 7,
            MsgKind::Barrier => 8,
            MsgKind::Shutdown => 9,
            MsgKind::Orthogonalize => 10,
            MsgKind::Truncate => 11,
            MsgKind::ClockSync => 12,
            MsgKind::Flush => 13,
            MsgKind::Stats => 14,
        }
    }

    pub fn from_u8(v: u8) -> Option<MsgKind> {
        Some(match v {
            0 => MsgKind::Hello,
            1 => MsgKind::Input,
            2 => MsgKind::Xhat,
            3 => MsgKind::Gather,
            4 => MsgKind::Parent,
            5 => MsgKind::Output,
            6 => MsgKind::Metrics,
            7 => MsgKind::Trace,
            8 => MsgKind::Barrier,
            9 => MsgKind::Shutdown,
            10 => MsgKind::Orthogonalize,
            11 => MsgKind::Truncate,
            12 => MsgKind::ClockSync,
            13 => MsgKind::Flush,
            14 => MsgKind::Stats,
            _ => return None,
        })
    }

    /// Short name for traces and error messages.
    pub fn name(self) -> &'static str {
        match self {
            MsgKind::Hello => "hello",
            MsgKind::Input => "input",
            MsgKind::Xhat => "xhat",
            MsgKind::Gather => "gather",
            MsgKind::Parent => "parent",
            MsgKind::Output => "output",
            MsgKind::Metrics => "metrics",
            MsgKind::Trace => "trace",
            MsgKind::Barrier => "barrier",
            MsgKind::Shutdown => "shutdown",
            MsgKind::Orthogonalize => "orthogonalize",
            MsgKind::Truncate => "truncate",
            MsgKind::ClockSync => "clock-sync",
            MsgKind::Flush => "flush",
            MsgKind::Stats => "stats",
        }
    }
}

/// The (kind, level, source) tag every message carries; receives match on
/// it, so delivery order across sources is immaterial.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Tag {
    pub kind: MsgKind,
    /// Tree level for `Xhat`; 0 otherwise.
    pub level: u32,
    /// Sending endpoint id (rank, or P for the master/hub).
    pub src: u32,
}

impl Tag {
    pub fn new(kind: MsgKind, level: usize, src: usize) -> Self {
        Tag { kind, level: level as u32, src: src as u32 }
    }
}

/// One typed message: a tag plus an owned f64 payload.
#[derive(Clone, Debug)]
pub struct Message {
    pub tag: Tag,
    pub data: Vec<f64>,
}

impl Message {
    pub fn new(kind: MsgKind, level: usize, src: usize, data: Vec<f64>) -> Self {
        Message { tag: Tag::new(kind, level, src), data }
    }

    /// Wire payload size in bytes (what the metrics counters account).
    pub fn payload_bytes(&self) -> usize {
        self.data.len() * 8
    }
}

/// Why a transport operation failed. A worker crash surfaces as `Closed`
/// at every peer still expecting traffic from it — the executors propagate
/// it instead of hanging.
#[derive(Clone, Debug)]
pub enum TransportError {
    /// The peer (or the whole session) is gone: channel disconnected,
    /// socket EOF, worker process exited.
    Closed(String),
    /// An OS-level I/O failure on the socket transport.
    Io(String),
    /// A malformed or out-of-protocol frame.
    Protocol(String),
    /// A blocking receive exceeded the session deadline.
    Timeout(String),
}

impl fmt::Display for TransportError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TransportError::Closed(d) => write!(f, "transport closed: {d}"),
            TransportError::Io(d) => write!(f, "transport I/O error: {d}"),
            TransportError::Protocol(d) => write!(f, "transport protocol error: {d}"),
            TransportError::Timeout(d) => write!(f, "transport timeout: {d}"),
        }
    }
}

impl std::error::Error for TransportError {}

/// One rank's connection to the interconnect.
///
/// Endpoint ids are `0..P` for the branch ranks and `P` for the
/// master/hub. `barrier` is collective over every endpoint of the
/// transport and must only be called at quiescent points (no other
/// traffic in flight), which is how the executors use it.
pub trait Endpoint: Send {
    /// This endpoint's id (rank, or P for the master).
    fn id(&self) -> usize;

    /// Enqueue `msg` for endpoint `dst`. Does not block on the receiver.
    fn send(&mut self, dst: usize, msg: Message) -> Result<(), TransportError>;

    /// Blocking receive of the next message, in per-source FIFO order but
    /// arbitrary cross-source order — match on [`Message::tag`] (see
    /// [`Mailbox`]).
    fn recv(&mut self) -> Result<Message, TransportError>;

    /// Collective barrier over all endpoints of this transport.
    fn barrier(&mut self) -> Result<(), TransportError>;
}

/// A mutable reference is itself an endpoint — lets long-lived owners
/// (the persistent socket session) lend their endpoint to per-product
/// wrappers like [`recording::Recording`] without moving it.
impl<E: Endpoint + ?Sized> Endpoint for &mut E {
    fn id(&self) -> usize {
        (**self).id()
    }

    fn send(&mut self, dst: usize, msg: Message) -> Result<(), TransportError> {
        (**self).send(dst, msg)
    }

    fn recv(&mut self) -> Result<Message, TransportError> {
        (**self).recv()
    }

    fn barrier(&mut self) -> Result<(), TransportError> {
        (**self).barrier()
    }
}

/// Tag-matched receives over an [`Endpoint`]'s unordered delivery: stashes
/// messages that do not match the current predicate so they are delivered
/// to a later matching receive instead of being dropped. One mailbox per
/// endpoint, owned by the runner.
#[derive(Default)]
pub struct Mailbox {
    stash: VecDeque<Message>,
}

impl Mailbox {
    pub fn new() -> Self {
        Mailbox::default()
    }

    /// Receive the next message whose tag satisfies `pred`, buffering any
    /// other traffic that arrives first. A `Shutdown` message aborts the
    /// wait with [`TransportError::Closed`]: it is how a failing peer
    /// breaks the others out of their blocking receives (the executors
    /// broadcast it on error), so a rank failure surfaces as an error at
    /// every peer instead of a hang — on every transport.
    pub fn recv_where<E: Endpoint + ?Sized>(
        &mut self,
        ep: &mut E,
        pred: impl Fn(Tag) -> bool,
    ) -> Result<Message, TransportError> {
        if let Some(i) = self.stash.iter().position(|m| pred(m.tag)) {
            return Ok(self.stash.remove(i).expect("position is in range"));
        }
        loop {
            let msg = ep.recv()?;
            if pred(msg.tag) {
                return Ok(msg);
            }
            if msg.tag.kind == MsgKind::Shutdown {
                return Err(TransportError::Closed(format!(
                    "endpoint {} aborted the session",
                    msg.tag.src
                )));
            }
            self.stash.push_back(msg);
        }
    }

    /// Receive the next message of `kind`.
    pub fn recv_kind<E: Endpoint + ?Sized>(
        &mut self,
        ep: &mut E,
        kind: MsgKind,
    ) -> Result<Message, TransportError> {
        self.recv_where(ep, |t| t.kind == kind)
    }

    /// Number of stashed (received but not yet consumed) messages.
    pub fn stashed(&self) -> usize {
        self.stash.len()
    }

    /// Discard every stashed message whose tag satisfies `pred`; returns
    /// how many were dropped. Used by the socket session to clear stale
    /// duplicates of a completed product (a retransmitted `Output` that
    /// arrived after its product was fully collected would otherwise sit
    /// in the stash forever).
    pub fn purge(&mut self, pred: impl Fn(Tag) -> bool) -> usize {
        let before = self.stash.len();
        self.stash.retain(|m| !pred(m.tag));
        before - self.stash.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_u8_roundtrip() {
        for k in [
            MsgKind::Hello,
            MsgKind::Input,
            MsgKind::Xhat,
            MsgKind::Gather,
            MsgKind::Parent,
            MsgKind::Output,
            MsgKind::Metrics,
            MsgKind::Trace,
            MsgKind::Barrier,
            MsgKind::Shutdown,
            MsgKind::Orthogonalize,
            MsgKind::Truncate,
            MsgKind::ClockSync,
            MsgKind::Flush,
            MsgKind::Stats,
        ] {
            assert_eq!(MsgKind::from_u8(k.to_u8()), Some(k));
        }
        assert_eq!(MsgKind::from_u8(200), None);
    }

    #[test]
    fn error_messages_name_the_failure() {
        let e = TransportError::Closed("rank 2 exited".into());
        assert!(e.to_string().contains("rank 2 exited"));
        let e = TransportError::Timeout("no output within 30s".into());
        assert!(e.to_string().contains("timeout"));
    }
}
